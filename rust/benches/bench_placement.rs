//! Broker placement at scale — the §7.2 claim that a single-node broker
//! "can handle a market with thousands of participating VMs": cost
//! ranking + greedy assignment across 1k/5k/10k producers, and the full
//! request path including registry snapshotting.

use memtrade::broker::placement::{rank, ConsumerRequest, ProducerState};
use memtrade::broker::predictor::AvailabilityPredictor;
use memtrade::broker::pricing::{PricingEngine, PricingStrategy};
use memtrade::broker::Broker;
use memtrade::core::config::{BrokerConfig, PlacementWeights};
use memtrade::core::{ConsumerId, Money, ProducerId, SimTime};
use memtrade::util::bench::{bench, header};
use memtrade::util::rng::Rng;

fn states(n: usize, seed: u64) -> Vec<ProducerState> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| ProducerState {
            producer: ProducerId(i as u64 + 1),
            free_slabs: rng.below(512) as u32,
            predicted_safe_slabs: rng.below(512) as u32,
            cpu_headroom: rng.f64(),
            bandwidth_headroom: rng.f64(),
            latency_us: rng.below(3000),
            reputation: rng.f64(),
        })
        .collect()
}

fn request() -> ConsumerRequest {
    ConsumerRequest {
        consumer: ConsumerId(1),
        slabs: 64,
        min_slabs: 1,
        lease: SimTime::from_hours(1),
        max_price_per_slab_hour: None,
        latency_us_to: Default::default(),
        weights: None,
    }
}

fn main() {
    header("broker placement");

    for n in [1_000usize, 5_000, 10_000] {
        let s = states(n, n as u64);
        let req = request();
        let w = PlacementWeights::default();
        bench(&format!("rank/{n}-producers"), || {
            std::hint::black_box(rank(&s, &req, &w));
        });
    }

    // Full request path through a populated broker.
    for n in [1_000usize, 5_000] {
        let cfg = BrokerConfig::default();
        let predictor = AvailabilityPredictor::fallback(288, 12);
        let pricing = PricingEngine::new(
            PricingStrategy::FixedFraction,
            Money::from_dollars(0.00004),
            cfg.price_step_dollars,
        );
        let mut broker = Broker::new(cfg, predictor, pricing);
        let mut rng = Rng::new(3);
        for i in 0..n {
            let id = ProducerId(i as u64 + 1);
            broker.registry.register_producer(id, 64.0);
            for t in 0..48u64 {
                broker.registry.report_usage(
                    id,
                    SimTime::from_secs(t * 300),
                    rng.uniform(8.0, 32.0) as f32,
                );
            }
            broker
                .registry
                .update_producer_resources(id, rng.below(512) as u32, 0.8, 0.8);
        }
        broker.predictor.refresh(&mut broker.registry, SimTime::from_hours(4));
        let mut c = 0u64;
        bench(&format!("request_memory/{n}-producers/64-slabs"), || {
            c += 1;
            broker.registry.register_consumer(ConsumerId(c));
            std::hint::black_box(
                broker.request_memory(SimTime::from_hours(5), {
                    let mut r = request();
                    r.consumer = ConsumerId(c);
                    r
                }),
            );
        });
    }

    // Predictor refresh across the fleet (fallback backend; PJRT path is
    // measured in bench_forecast).
    let cfg = BrokerConfig::default();
    let mut broker = Broker::new(
        cfg,
        AvailabilityPredictor::fallback(288, 12),
        PricingEngine::new(PricingStrategy::FixedFraction, Money::ZERO, 0.00002),
    );
    let mut rng = Rng::new(5);
    for i in 0..1_000u64 {
        broker.registry.register_producer(ProducerId(i + 1), 64.0);
        for t in 0..288u64 {
            broker.registry.report_usage(
                ProducerId(i + 1),
                SimTime::from_secs(t * 300),
                rng.uniform(8.0, 32.0) as f32,
            );
        }
    }
    bench("predictor_refresh/1000-producers/rust-fallback", || {
        broker.predictor.refresh(&mut broker.registry, SimTime::from_hours(24));
    });
}

//! §7.3 crypto hot path: AES-128-CBC + SHA-256 seal/open at the value
//! sizes YCSB uses, plus the raw primitives. The paper reports integrity
//! hashing costing +24.3% median GET latency and encryption another
//! +19.8%; these benches give the absolute µs behind those ratios.

use memtrade::crypto::aes::Aes128;
use memtrade::crypto::secure::Envelope;
use memtrade::crypto::sha256::sha256;
use memtrade::util::bench::{bench, header};

fn main() {
    header("crypto (from-scratch AES-128-CBC + SHA-256)");

    for size in [64usize, 1024, 4096, 16384] {
        let data = vec![0xA5u8; size];
        bench(&format!("sha256/{size}B"), || {
            std::hint::black_box(sha256(&data));
        });
    }

    let aes = Aes128::new(&[7u8; 16]);
    for size in [64usize, 1024, 4096] {
        let data = vec![0xA5u8; size];
        let iv = [9u8; 16];
        bench(&format!("aes_cbc_encrypt/{size}B"), || {
            std::hint::black_box(aes.cbc_encrypt(&iv, &data));
        });
        let ct = aes.cbc_encrypt(&iv, &data);
        bench(&format!("aes_cbc_decrypt/{size}B"), || {
            std::hint::black_box(aes.cbc_decrypt(&iv, &ct).unwrap());
        });
    }

    // Full envelope (the per-op cost added to every remote KV op).
    for (mode, key, integrity) in [
        ("integrity_only", None, true),
        ("encrypt+integrity", Some([3u8; 16]), true),
    ] {
        let mut env = Envelope::with_iv_seed(key, integrity, 11);
        let value = vec![0xA5u8; 1024];
        bench(&format!("envelope_seal/1KB/{mode}"), || {
            std::hint::black_box(env.seal(&value, 0));
        });
        let mut env2 = Envelope::with_iv_seed(key, integrity, 11);
        let sealed = env2.seal(&value, 0);
        bench(&format!("envelope_open/1KB/{mode}"), || {
            std::hint::black_box(env2.open(&sealed.value_p, &sealed.meta).unwrap());
        });
    }
}

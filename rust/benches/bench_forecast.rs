//! The AOT compute path: PJRT execution of the forecast and demand
//! artifacts (the broker's per-market-epoch numeric work), compared with
//! the pure-Rust mirror — quantifying what the compiled XLA module buys
//! at fleet scale. Skips PJRT rows when artifacts are not built.

use memtrade::runtime::arima_fallback as fb;
use memtrade::runtime::engine::{Engine, DEMAND_SIZES, FORECAST_HORIZON, FORECAST_WINDOW};
use memtrade::util::bench::{bench, header};
use memtrade::util::rng::Rng;

fn series(n: usize, rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<f32>) {
    let s = (0..n)
        .map(|_| {
            let base = rng.uniform(4.0, 24.0);
            (0..FORECAST_WINDOW)
                .map(|t| {
                    (base
                        + 3.0 * (std::f64::consts::TAU * t as f64 / 288.0).sin()
                        + rng.normal(0.0, 0.4)) as f32
                })
                .collect()
        })
        .collect();
    let caps = (0..n).map(|_| rng.uniform(16.0, 64.0) as f32).collect();
    (s, caps)
}

fn gains(n: usize, rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<f32>) {
    let g = (0..n)
        .map(|_| {
            let rate = rng.uniform(10.0, 3000.0);
            let knee = rng.uniform(2.0, 48.0);
            (0..DEMAND_SIZES)
                .map(|s| (rate * (1.0 - (-(s as f64) / knee).exp())) as f32)
                .collect()
        })
        .collect();
    let v = (0..n).map(|_| rng.uniform(1e-6, 1e-3) as f32).collect();
    (g, v)
}

fn main() {
    header("forecast + demand (AOT/PJRT vs rust mirror)");
    let mut rng = Rng::new(17);

    for n in [256usize, 1024, 4096] {
        let (s, caps) = series(n, &mut rng);
        bench(&format!("rust_mirror_forecast/{n}-producers"), || {
            std::hint::black_box(fb::forecast_batch(
                &s,
                &caps,
                4,
                FORECAST_HORIZON,
                FORECAST_WINDOW,
            ));
        });
    }

    let dir = Engine::default_dir();
    if !Engine::artifacts_present(&dir) {
        println!("(artifacts not built — skipping PJRT rows; run `make artifacts`)");
        return;
    }
    let engine = Engine::load(&dir).expect("load artifacts");

    for n in [256usize, 1024, 4096] {
        let (s, caps) = series(n, &mut rng);
        bench(&format!("pjrt_forecast/{n}-producers"), || {
            std::hint::black_box(engine.forecast.predict(&s, &caps).unwrap());
        });
    }

    for n in [1024usize, 10_240] {
        let (g, v) = gains(n, &mut rng);
        let prices = [0.00004f32, 0.00005, 0.00006];
        bench(&format!("pjrt_demand/{n}-consumers/3-prices"), || {
            std::hint::black_box(engine.demand.evaluate(&g, &v, prices).unwrap());
        });
        bench(&format!("rust_mirror_demand/{n}-consumers/3-prices"), || {
            let mut acc = 0f64;
            for (gain, &val) in g.iter().zip(&v) {
                for p in prices {
                    acc += fb::demand_one(gain, val, p as f64) as f64;
                }
            }
            std::hint::black_box(acc);
        });
    }
}

//! Producer-store hot path: GET/PUT/DELETE on the Redis-like KV store,
//! including eviction pressure and harvester-initiated shrink (the data
//! path behind every consumer op in Table 2 / Fig 11).

use memtrade::kv::KvStore;
use memtrade::util::bench::{bench, header};
use memtrade::util::rng::Rng;

fn main() {
    header("kv (producer store)");

    // GET hit on a warm 64 MB store.
    let mut kv = KvStore::new(64 << 20, 1);
    let mut keys = Vec::new();
    for i in 0..10_000u32 {
        let k = format!("user{i}");
        kv.put(k.as_bytes(), &vec![0xAB; 1024]);
        keys.push(k.into_bytes());
    }
    let mut rng = Rng::new(7);
    bench("get_hit/1KB/10k-keys", || {
        let k = &keys[rng.below(keys.len() as u64) as usize];
        assert!(kv.get(k).is_some());
    });

    let mut rng2 = Rng::new(8);
    bench("get_miss", || {
        let k = format!("absent{}", rng2.below(1 << 20));
        assert!(kv.get(k.as_bytes()).is_none());
    });

    // PUT overwrite (steady state, no eviction).
    let mut rng3 = Rng::new(9);
    bench("put_overwrite/1KB", || {
        let k = &keys[rng3.below(keys.len() as u64) as usize];
        kv.put(k, &vec![0xCD; 1024]);
    });

    // PUT under eviction pressure (store full -> sampled-LRU eviction).
    let mut full = KvStore::new(8 << 20, 2);
    let mut i = 0u64;
    bench("put_with_eviction/1KB/full-store", || {
        let k = format!("grow{i}");
        i += 1;
        full.put(k.as_bytes(), &vec![0xEF; 1024]);
    });

    // Harvester reclaim: shrink by 1 MB then grow back.
    let mut shrink = KvStore::new(64 << 20, 3);
    for i in 0..40_000u32 {
        shrink.put(format!("s{i}").as_bytes(), &vec![1u8; 1024]);
    }
    bench("shrink_1MB_and_grow_back", || {
        let max = shrink.max_bytes();
        shrink.shrink_to(max - (1 << 20));
        shrink.grow_to(max);
    });

    // Defragmentation pass.
    let mut frag = KvStore::new(64 << 20, 4);
    for i in 0..20_000u32 {
        frag.put(format!("f{i}").as_bytes(), &vec![1u8; 150]);
    }
    bench("defragment/20k-entries", || {
        frag.defragment();
    });
}

//! Producer-store hot path: GET/PUT/DELETE on the Redis-like KV store,
//! including eviction pressure and harvester-initiated shrink (the data
//! path behind every consumer op in Table 2 / Fig 11), plus the
//! multi-threaded sharded-store hammer that quantifies the win from
//! hash-partitioning the store across independently locked shards.
//!
//! Emits `BENCH_kv.json` (in the crate root when run via `cargo bench`)
//! with aggregate ops/sec for the 1-shard (single global mutex) baseline
//! vs. the N-shard configuration, so the perf trajectory is tracked as a
//! number across PRs.

use memtrade::kv::{KvStore, ShardedKvStore};
use memtrade::metrics::Histogram;
use memtrade::trace::{self, Op, Role, SpanGuard};
use memtrade::util::bench::{bench, header, run_for as bench_run_for, smoke};
use memtrade::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Mixed 90% GET / 10% PUT hammer over a preloaded sharded store.
/// Returns aggregate ops/sec across `n_threads` worker threads. With
/// `traced`, every op runs under a root span — the tracing-overhead
/// gate measures this against `trace::set_enabled(false)`.
fn hammer_ops_per_sec(
    n_shards: usize,
    n_threads: usize,
    run_for: Duration,
    traced: bool,
) -> f64 {
    const KEYS: u64 = 20_000;
    let store = Arc::new(ShardedKvStore::new(256 << 20, n_shards, 1));
    let value = vec![0xAB_u8; 1024];
    for i in 0..KEYS {
        store.put(format!("user{i}").as_bytes(), &value);
    }
    let stop = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(Barrier::new(n_threads + 1));
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let store = store.clone();
            let stop = stop.clone();
            let barrier = barrier.clone();
            let value = value.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t as u64);
                let mut buf = Vec::with_capacity(2048);
                let mut ops = 0u64;
                barrier.wait();
                while !stop.load(Ordering::Relaxed) {
                    let key = format!("user{}", rng.below(KEYS));
                    let get = rng.below(10) < 9;
                    let _span = traced.then(|| {
                        SpanGuard::root(Role::Producer, if get { Op::Get } else { Op::Put })
                    });
                    if get {
                        std::hint::black_box(store.get_into(key.as_bytes(), &mut buf));
                    } else {
                        std::hint::black_box(store.put(key.as_bytes(), &value));
                    }
                    ops += 1;
                }
                ops
            })
        })
        .collect();
    barrier.wait();
    let t0 = Instant::now();
    std::thread::sleep(run_for);
    stop.store(true, Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    header("kv (producer store)");

    // GET hit on a warm 64 MB store: borrow-based, no value clone.
    let mut kv = KvStore::new(64 << 20, 1);
    let mut keys = Vec::new();
    for i in 0..10_000u32 {
        let k = format!("user{i}");
        kv.put(k.as_bytes(), &vec![0xAB; 1024]);
        keys.push(k.into_bytes());
    }
    let mut rng = Rng::new(7);
    let get_hit = bench("get_hit/1KB/10k-keys", || {
        let k = &keys[rng.below(keys.len() as u64) as usize];
        assert!(kv.get(k).is_some());
    });

    // The latency section of BENCH_kv.json comes from the production
    // instrument — the shared `metrics::Histogram` — not bench-local
    // math: per-op GET-hit latency recorded in nanoseconds.
    let get_hit_hist = Histogram::new();
    {
        let mut rng = Rng::new(19);
        let until = Instant::now() + bench_run_for(400);
        while Instant::now() < until {
            for _ in 0..256 {
                let k = &keys[rng.below(keys.len() as u64) as usize];
                let t0 = Instant::now();
                std::hint::black_box(kv.get(k));
                get_hit_hist.record(t0.elapsed().as_nanos() as u64);
            }
        }
    }
    let get_hit_snap = get_hit_hist.snapshot();
    println!("get_hit latency (metrics::Histogram, ns): {}", get_hit_snap.render());

    // GET into a reused caller buffer (the owned-copy path).
    let mut rng_into = Rng::new(12);
    let mut into_buf = Vec::with_capacity(2048);
    bench("get_into/1KB/reused-buffer", || {
        let k = &keys[rng_into.below(keys.len() as u64) as usize];
        assert!(kv.get_into(k, &mut into_buf));
    });

    let mut rng2 = Rng::new(8);
    bench("get_miss", || {
        let k = format!("absent{}", rng2.below(1 << 20));
        assert!(kv.get(k.as_bytes()).is_none());
    });

    // PUT overwrite (steady state, no eviction, value buffer reused).
    let mut rng3 = Rng::new(9);
    let overwrite_val = vec![0xCD; 1024];
    bench("put_overwrite/1KB", || {
        let k = &keys[rng3.below(keys.len() as u64) as usize];
        kv.put(k, &overwrite_val);
    });

    // PUT under eviction pressure (store full -> sampled-LRU eviction).
    let mut full = KvStore::new(8 << 20, 2);
    let mut i = 0u64;
    let evict_val = vec![0xEF; 1024];
    bench("put_with_eviction/1KB/full-store", || {
        let k = format!("grow{i}");
        i += 1;
        full.put(k.as_bytes(), &evict_val);
    });

    // Harvester reclaim: shrink by 1 MB then grow back.
    let mut shrink = KvStore::new(64 << 20, 3);
    for i in 0..40_000u32 {
        shrink.put(format!("s{i}").as_bytes(), &vec![1u8; 1024]);
    }
    bench("shrink_1MB_and_grow_back", || {
        let max = shrink.max_bytes();
        shrink.shrink_to(max - (1 << 20));
        shrink.grow_to(max);
    });

    // Defragmentation pass.
    let mut frag = KvStore::new(64 << 20, 4);
    for i in 0..20_000u32 {
        frag.put(format!("f{i}").as_bytes(), &vec![1u8; 150]);
    }
    bench("defragment/20k-entries", || {
        frag.defragment();
    });

    // --- Multi-threaded mixed GET/PUT: single global mutex (1 shard)
    // vs. the sharded store. The headline number of this subsystem.
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(4, 8);
    let shards = 16;
    let run_for = bench_run_for(1500);
    if smoke() {
        println!("\n(smoke mode: shortened measurement windows)");
    }
    println!("\n== bench: sharded hammer (90/10 GET/PUT, 1KB, {threads} threads) ==");
    let single = hammer_ops_per_sec(1, threads, run_for, false);
    println!("{:<48} {:>14.0} ops/s", "hammer/1-shard (global mutex baseline)", single);
    let multi = hammer_ops_per_sec(shards, threads, run_for, false);
    println!("{:<48} {:>14.0} ops/s", format!("hammer/{shards}-shards"), multi);
    println!("{:<48} {:>13.2}x", "speedup", multi / single);

    // --- Tracing overhead: the same sharded hammer with a root span
    // around every op, recording globally disabled vs enabled. CI gates
    // the delta at ≤ 3% — the cost of always-on tracing must stay in
    // the noise of the data path it observes.
    println!("\n== bench: tracing overhead (per-op root span, {shards} shards) ==");
    trace::set_enabled(false);
    let untraced = hammer_ops_per_sec(shards, threads, run_for, true);
    trace::set_enabled(true);
    let traced = hammer_ops_per_sec(shards, threads, run_for, true);
    let tracing_overhead_pct = ((untraced - traced) / untraced * 100.0).max(0.0);
    println!("{:<48} {:>14.0} ops/s", "hammer/tracing-disabled", untraced);
    println!("{:<48} {:>14.0} ops/s", "hammer/tracing-enabled", traced);
    println!("{:<48} {:>13.2}%", "tracing overhead", tracing_overhead_pct);

    let json = format!(
        "{{\n  \"bench\": \"kv_sharded_hammer\",\n  \"threads\": {threads},\n  \
         \"value_bytes\": 1024,\n  \"get_fraction\": 0.9,\n  \
         \"single_shard_ops_per_sec\": {single:.0},\n  \"shards\": {shards},\n  \
         \"sharded_ops_per_sec\": {multi:.0},\n  \"speedup\": {:.3},\n  \
         \"untraced_ops_per_sec\": {untraced:.0},\n  \
         \"traced_ops_per_sec\": {traced:.0},\n  \
         \"tracing_overhead_pct\": {tracing_overhead_pct:.2},\n  \
         \"get_hit_mean_ns\": {:.1},\n  \"latency\": {{\n    \
         \"source\": \"metrics-histogram\",\n    \"unit\": \"ns\",\n    \
         \"samples\": {},\n    \"get_hit_p50\": {:.1},\n    \
         \"get_hit_p99\": {:.1},\n    \"get_hit_p999\": {:.1}\n  }}\n}}\n",
        multi / single,
        get_hit.mean_ns,
        get_hit_snap.count(),
        get_hit_snap.p50(),
        get_hit_snap.p99(),
        get_hit_snap.p999(),
    );
    match std::fs::write("BENCH_kv.json", &json) {
        Ok(()) => println!("\nwrote BENCH_kv.json"),
        Err(e) => eprintln!("\ncould not write BENCH_kv.json: {e}"),
    }
}

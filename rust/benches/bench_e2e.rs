//! End-to-end request path: secure GET/PUT through the real wire codec
//! and a real TCP producer store on localhost (the Table 2 data path,
//! minus the simulated datacenter RTT), plus the in-process manager path
//! used by the cluster simulation.

use memtrade::consumer::client::SecureKv;
use memtrade::core::{ConsumerId, Lease, LeaseId, Money, ProducerId, SimTime, DEFAULT_SLAB_BYTES};
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{Request, Response};
use memtrade::producer::Manager;
use memtrade::util::bench::{bench, header};
use memtrade::util::rng::Rng;
use memtrade::workload::ycsb::YcsbWorkload;

fn main() {
    header("end-to-end secure KV");

    // --- In-process: consumer -> manager -> producer store.
    let mut manager = Manager::new(ProducerId(1), DEFAULT_SLAB_BYTES, 3);
    manager.set_harvestable(2 << 30, SimTime::ZERO);
    assert!(manager.grant_lease(
        Lease {
            id: LeaseId(1),
            consumer: ConsumerId(1),
            producer: ProducerId(1),
            slabs: 16,
            slab_bytes: DEFAULT_SLAB_BYTES,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(1),
            price_per_slab_hour: Money::from_dollars(0.00004),
        },
        1_250_000_000,
    ));
    let mut secure = SecureKv::new(Some([5u8; 16]), true, 1, 7);
    let mut now_us = 0u64;
    let value = vec![0xAB; 1024];
    // Preload.
    {
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(0))
        };
        for i in 0..5_000u32 {
            assert!(secure.put(&mut t, format!("user{i}").as_bytes(), &value));
        }
    }
    let mut rng = Rng::new(9);
    bench("inproc_secure_get/1KB (manager+rate-limit+crypto)", || {
        now_us += 50;
        let key = format!("user{}", rng.below(5_000));
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(now_us))
        };
        std::hint::black_box(secure.get(&mut t, key.as_bytes()));
    });
    bench("inproc_secure_put/1KB", || {
        now_us += 50;
        let key = format!("user{}", rng.below(5_000));
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(now_us))
        };
        std::hint::black_box(secure.put(&mut t, key.as_bytes(), &value));
    });

    // --- Real TCP on localhost.
    let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 30, None, 11).unwrap();
    let mut client = KvClient::connect(server.addr()).unwrap();
    let mut secure_tcp = SecureKv::new(Some([5u8; 16]), true, 1, 13);
    {
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        for i in 0..2_000u32 {
            assert!(secure_tcp.put(&mut t, format!("user{i}").as_bytes(), &value));
        }
    }
    let mut rng2 = Rng::new(10);
    bench("tcp_secure_get/1KB/localhost", || {
        let key = format!("user{}", rng2.below(2_000));
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        std::hint::black_box(secure_tcp.get(&mut t, key.as_bytes()));
    });
    bench("tcp_secure_put/1KB/localhost", || {
        let key = format!("user{}", rng2.below(2_000));
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        std::hint::black_box(secure_tcp.put(&mut t, key.as_bytes(), &value));
    });
    server.stop();

    // --- Wire codec alone.
    let req = Request::Put { key: b"user12345".to_vec(), value: vec![0xCD; 1024] };
    bench("wire_encode_decode/1KB-put", || {
        let enc = req.encode();
        std::hint::black_box(Request::decode(&enc).unwrap());
    });

    // --- Workload generator.
    let w = YcsbWorkload::paper_default(10_000_000, 1024);
    let mut rng3 = Rng::new(11);
    bench("ycsb_next_op/10M-keys-zipf0.7", || {
        std::hint::black_box(w.next_op(&mut rng3));
    });
}

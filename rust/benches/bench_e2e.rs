//! End-to-end request path: secure GET/PUT through the real wire codec
//! and a real TCP producer store on localhost (the Table 2 data path,
//! minus the simulated datacenter RTT), the in-process manager path used
//! by the cluster simulation, and the full marketplace control plane
//! (broker daemon + producer agents + lease-aware pool), including
//! recovery time after a producer kill. Emits `BENCH_e2e.json` so the
//! marketplace-path numbers accumulate across PRs.

use memtrade::consumer::client::SecureKv;
use memtrade::core::config::BrokerConfig;
use memtrade::core::{ConsumerId, Lease, LeaseId, Money, ProducerId, SimTime, DEFAULT_SLAB_BYTES};
use memtrade::market::chaos::{run_chaos, ChaosConfig, ChaosMix};
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig, RemotePool,
    RemotePoolConfig,
};
use memtrade::net::control::{client_handshake, DATA_MAGIC};
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::net::wire::{append_trace_ctx, read_frame_into, write_frame, Request, Response};
use memtrade::producer::Manager;
use memtrade::metrics::Histogram;
use memtrade::util::bench::{
    bench, ctx_switches, header, raise_nofile_limit, run_for as bench_run_for, smoke,
};
use memtrade::util::rng::Rng;
use memtrade::workload::ycsb::YcsbWorkload;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Boot broker + 2 agents + pool, run the marketplace path, measure
/// GET/PUT latency and post-kill recovery; returns the JSON fields.
fn marketplace_bench() -> String {
    const SLAB: u64 = 1 << 20;
    let broker_cfg = BrokerConfig {
        slab_bytes: SLAB,
        min_lease: SimTime::from_secs(30),
        ..Default::default()
    };
    let server_cfg = BrokerServerConfig {
        tick: Duration::from_millis(20),
        producer_timeout: Duration::from_millis(300),
        forecast_min_samples: usize::MAX,
        ..Default::default()
    };
    let broker = BrokerServer::start("127.0.0.1:0", broker_cfg, server_cfg).unwrap();
    let mk_agent = |id: u64| {
        ProducerAgent::start(ProducerAgentConfig {
            producer: id,
            brokers: vec![broker.addr().to_string()],
            data_addr: "127.0.0.1:0".to_string(),
            advertise: None,
            capacity_bytes: 64 * SLAB,
            harvest: false,
            heartbeat: Duration::from_millis(40),
            shards: 4,
            rate_bps: None,
            seed: id,
            ..Default::default()
        })
        .unwrap()
    };
    let mut agents = vec![mk_agent(1), mk_agent(2)];
    let mut pool = RemotePool::connect(RemotePoolConfig {
        consumer: 9,
        brokers: vec![broker.addr().to_string()],
        target_slabs: 96,
        min_slabs: 1,
        lease_ttl: Duration::from_secs(30),
        renew_margin: Duration::from_secs(10),
        maintain_every: Duration::from_millis(25),
        ..Default::default()
    })
    .unwrap();

    // Grant latency: from request to *mounted* capacity — grants held by
    // the pool AND producer stores grown to their lease targets (that
    // happens on the agents' next heartbeat ack; PUTs before it would be
    // rejected by the still-zero-budget stores).
    let t_grant = Instant::now();
    let mounted = |agents: &[ProducerAgent]| {
        agents.iter().all(|a| {
            let max = a.store().map(|s| s.max_bytes()).unwrap_or(0) as u64;
            max == a.target_bytes() && max > 0
        })
    };
    while pool.held_slabs() < 96 || pool.distinct_endpoints().len() < 2 || !mounted(&agents) {
        pool.maintain();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t_grant.elapsed() < Duration::from_secs(10), "grants never mounted");
    }
    let grant_ms = t_grant.elapsed().as_secs_f64() * 1e3;

    let mut secure = SecureKv::with_iv_seed(Some([5u8; 16]), true, 1, 7);
    let value = vec![0xAB_u8; 1024];
    const KEYS: u32 = 4_000;
    for i in 0..KEYS {
        assert!(secure.put(&mut pool, format!("user{i}").as_bytes(), &value));
    }

    // Steady-state marketplace GET/PUT (secure KV -> pool -> TCP
    // store). Latency goes through the production instrument — the
    // shared `metrics::Histogram`, recorded in ns — so the emitted
    // p50/p99 fields are the same math the live system reports.
    let mut rng = Rng::new(17);
    let get_hist = Histogram::new();
    let put_hist = Histogram::new();
    let run_for = bench_run_for(1200);
    let t0 = Instant::now();
    let mut ops = 0u64;
    while t0.elapsed() < run_for {
        let key = format!("user{}", rng.below(KEYS as u64));
        let t = Instant::now();
        if rng.below(10) < 9 {
            std::hint::black_box(secure.get(&mut pool, key.as_bytes()));
            get_hist.record(t.elapsed().as_nanos() as u64);
        } else {
            std::hint::black_box(secure.put(&mut pool, key.as_bytes(), &value));
            put_hist.record(t.elapsed().as_nanos() as u64);
        }
        ops += 1;
    }
    let ops_per_sec = ops as f64 / t0.elapsed().as_secs_f64();
    let (get_rec, put_rec) = (get_hist.snapshot(), put_hist.snapshot());
    println!(
        "{:<48} {:>14.0} ops/s",
        "marketplace_secure_90/10 (2 producers)", ops_per_sec
    );
    println!(
        "{:<48} p50 {:>7.1}µs p99 {:>7.1}µs",
        "  get latency",
        get_rec.p50() / 1e3,
        get_rec.p99() / 1e3
    );
    println!(
        "{:<48} p50 {:>7.1}µs p99 {:>7.1}µs",
        "  put latency",
        put_rec.p50() / 1e3,
        put_rec.p99() / 1e3
    );

    // Kill one producer: time until the pool is fully re-provisioned
    // from the survivor while traffic keeps flowing (misses, no errors).
    let survivor_capacity = 64; // slabs
    agents[0].kill();
    let t_kill = Instant::now();
    let mut recovered_ms = f64::NAN;
    while t_kill.elapsed() < Duration::from_secs(10) {
        let key = format!("user{}", rng.below(KEYS as u64));
        std::hint::black_box(secure.get(&mut pool, key.as_bytes()));
        // Distinct endpoints, not slot count: the survivor may back
        // several leases.
        if pool.distinct_endpoints().len() == 1 && pool.held_slabs() >= survivor_capacity {
            recovered_ms = t_kill.elapsed().as_secs_f64() * 1e3;
            break;
        }
    }
    println!(
        "{:<48} {:>12.1} ms",
        "recovery after producer kill (re-provisioned)", recovered_ms
    );
    assert_eq!(secure.stats.integrity_failures, 0);
    if recovered_ms.is_nan() {
        recovered_ms = -1.0; // keep the emitted JSON valid
    }

    let json = format!(
        "  \"marketplace\": {{\n    \"grant_to_mounted_ms\": {grant_ms:.1},\n    \
         \"ops_per_sec\": {ops_per_sec:.0},\n    \
         \"latency_source\": \"metrics-histogram\",\n    \
         \"latency_samples\": {},\n    \"get_p50_us\": {:.1},\n    \
         \"get_p99_us\": {:.1},\n    \"put_p50_us\": {:.1},\n    \"put_p99_us\": {:.1},\n    \
         \"recovery_after_kill_ms\": {recovered_ms:.1}\n  }}",
        get_rec.count() + put_rec.count(),
        get_rec.p50() / 1e3,
        get_rec.p99() / 1e3,
        put_rec.p50() / 1e3,
        put_rec.p99() / 1e3,
    );
    drop(pool);
    agents.remove(1).stop();
    broker.stop();
    json
}

/// The chaos plane under a standard fault mix: ops/sec degradation
/// versus a fault-free run of the same scenario shape, plus recovery
/// time back to target capacity after the faults disarm. Fixed seed so
/// the trajectory is comparable across PRs.
fn chaos_bench() -> String {
    let base = if smoke() {
        ChaosConfig {
            seed: 42,
            mix: ChaosMix::clean(),
            keys: 80,
            fault_ops: 200,
            ..Default::default()
        }
    } else {
        ChaosConfig { seed: 42, mix: ChaosMix::clean(), ..Default::default() }
    };
    let clean = run_chaos(&base);
    let faulty = run_chaos(&ChaosConfig { mix: ChaosMix::standard(), ..base });
    // Warm-standby failover under the same scenario shape: kill the
    // primary broker mid-run, measure how long until the marketplace is
    // back at target capacity on the promoted standby.
    let failover = run_chaos(&ChaosConfig { mix: ChaosMix::failover(), ..base });
    for o in [&clean, &faulty, &failover] {
        assert!(
            o.invariant_violations().is_empty(),
            "chaos invariants violated in bench: {}",
            o.report()
        );
    }
    assert_eq!(failover.broker_takeovers, Some(1), "bench failover never promoted the standby");
    let degradation_pct = if clean.ops_per_sec > 0.0 {
        100.0 * (1.0 - faulty.ops_per_sec / clean.ops_per_sec)
    } else {
        f64::NAN
    };
    println!("{:<48} {:>14.0} ops/s", "chaos/clean-baseline", clean.ops_per_sec);
    println!(
        "{:<48} {:>14.0} ops/s ({:.1}% degradation)",
        "chaos/standard-mix", faulty.ops_per_sec, degradation_pct
    );
    println!(
        "{:<48} {:>12.1} ms",
        "chaos recovery after faults disarm", faulty.recovery_ms
    );
    println!(
        "{:<48} {:>12.1} ms",
        "failover recovery after primary broker kill", failover.recovery_ms
    );
    format!(
        "  \"chaos\": {{\n    \"clean_ops_per_sec\": {:.0},\n    \
         \"faulty_ops_per_sec\": {:.0},\n    \"degradation_pct\": {:.1},\n    \
         \"recovery_ms\": {:.1},\n    \"failover_recovery_ms\": {:.1},\n    \
         \"integrity_caught\": {},\n    \"tampered_served\": {}\n  }}",
        clean.ops_per_sec,
        faulty.ops_per_sec,
        degradation_pct,
        faulty.recovery_ms,
        failover.recovery_ms,
        faulty.integrity_failures,
        faulty.tampered,
    )
}

/// The data-plane headline this PR's CI gates on: single-op GETs vs
/// batched multi-gets vs pipelined GETs against one TCP producer store
/// on localhost, same connection, same topology. Emits the `batch` JSON
/// section; CI fails if `batch_speedup` (multi-get, 32 ops/frame —
/// well past the gate's "window ≥ 8") drops below 1.5x single-op.
fn batch_bench() -> String {
    const KEYS: u64 = 8_192;
    const BATCH: usize = 32;
    const WINDOW: usize = 8;
    let server =
        ProducerStoreServer::start_sharded("127.0.0.1:0", 1 << 30, None, 31, 8).unwrap();
    let mut client = KvClient::connect(server.addr()).unwrap();
    let value = vec![0xAB_u8; 512];
    {
        // Preload through the batch path itself (also exercises it).
        let keys: Vec<Vec<u8>> = (0..KEYS).map(|i| format!("user{i}").into_bytes()).collect();
        for chunk in keys.chunks(256) {
            let pairs: Vec<(&[u8], &[u8])> =
                chunk.iter().map(|k| (k.as_slice(), value.as_slice())).collect();
            assert!(client.multi_put(&pairs).unwrap().iter().all(|&s| s));
        }
    }
    let run = bench_run_for(1000);

    // Single-op GETs: one round trip per key (the pre-batching path).
    let mut rng = Rng::new(71);
    let t0 = Instant::now();
    let mut single_ops = 0u64;
    while t0.elapsed() < run {
        let key = format!("user{}", rng.below(KEYS));
        assert!(client.get(key.as_bytes()).unwrap().is_some());
        single_ops += 1;
    }
    let single = single_ops as f64 / t0.elapsed().as_secs_f64();

    // Batched multi-gets: BATCH ops per frame, one round trip per frame.
    let t0 = Instant::now();
    let mut batch_ops = 0u64;
    while t0.elapsed() < run {
        let keys: Vec<Vec<u8>> =
            (0..BATCH).map(|_| format!("user{}", rng.below(KEYS)).into_bytes()).collect();
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        let got = client.multi_get(&key_refs).unwrap();
        assert!(got.iter().all(Option::is_some));
        batch_ops += BATCH as u64;
    }
    let batched = batch_ops as f64 / t0.elapsed().as_secs_f64();

    // Pipelined single-op GETs: WINDOW requests in flight.
    let t0 = Instant::now();
    let mut pipe_ops = 0u64;
    while t0.elapsed() < run {
        let reqs: Vec<Request> = (0..BATCH)
            .map(|_| Request::Get { key: format!("user{}", rng.below(KEYS)).into_bytes() })
            .collect();
        let resps = client.call_many(&reqs, WINDOW).unwrap();
        assert!(resps.iter().all(|r| matches!(r, Response::Value(_))));
        pipe_ops += BATCH as u64;
    }
    let pipelined = pipe_ops as f64 / t0.elapsed().as_secs_f64();
    server.stop();

    let batch_speedup = batched / single;
    let pipeline_speedup = pipelined / single;
    println!("{:<48} {:>14.0} ops/s", "batch/single-op GET (baseline)", single);
    println!(
        "{:<48} {:>14.0} ops/s ({:.2}x)",
        format!("batch/multi-get x{BATCH}"),
        batched,
        batch_speedup
    );
    println!(
        "{:<48} {:>14.0} ops/s ({:.2}x)",
        format!("batch/pipelined GET w={WINDOW}"),
        pipelined,
        pipeline_speedup
    );
    format!(
        "  \"batch\": {{\n    \"single_get_ops_per_sec\": {single:.0},\n    \
         \"multi_get_ops_per_sec\": {batched:.0},\n    \"batch_size\": {BATCH},\n    \
         \"pipelined_get_ops_per_sec\": {pipelined:.0},\n    \"window\": {WINDOW},\n    \
         \"batch_speedup\": {batch_speedup:.3},\n    \
         \"pipeline_speedup\": {pipeline_speedup:.3}\n  }}"
    )
}

/// Aggregate ops/sec for `clients` concurrent TCP connections doing a
/// 90/10 GET/PUT mix against a producer store with `n_shards` shards.
fn tcp_hammer_ops_per_sec(n_shards: usize, clients: usize, run_for: Duration) -> f64 {
    const KEYS: u64 = 10_000;
    let server =
        ProducerStoreServer::start_sharded("127.0.0.1:0", 1 << 30, None, 21, n_shards).unwrap();
    let addr = server.addr();
    let value = vec![0xAB_u8; 1024];
    {
        let mut c = KvClient::connect(addr).unwrap();
        for i in 0..KEYS {
            assert!(c.put(format!("user{i}").as_bytes(), &value).unwrap());
        }
    }
    let stop = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(Barrier::new(clients + 1));
    let handles: Vec<_> = (0..clients)
        .map(|t| {
            let stop = stop.clone();
            let barrier = barrier.clone();
            let value = value.clone();
            std::thread::spawn(move || {
                let mut c = KvClient::connect(addr).unwrap();
                let mut rng = Rng::new(300 + t as u64);
                let mut ops = 0u64;
                barrier.wait();
                while !stop.load(Ordering::Relaxed) {
                    let key = format!("user{}", rng.below(KEYS));
                    if rng.below(10) < 9 {
                        std::hint::black_box(c.get(key.as_bytes()).unwrap());
                    } else {
                        std::hint::black_box(c.put(key.as_bytes(), &value).unwrap());
                    }
                    ops += 1;
                }
                ops
            })
        })
        .collect();
    barrier.wait();
    let t0 = Instant::now();
    std::thread::sleep(run_for);
    stop.store(true, Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let elapsed = t0.elapsed().as_secs_f64();
    server.stop();
    total as f64 / elapsed
}

/// One lightweight sweep consumer: a handshaken raw socket with no
/// client-side buffering (10k `KvClient`s would pin ~640 MB in
/// `BufReader`/`BufWriter` capacity alone). The driver pipelines one
/// GET across its whole connection set per round, so aggregate
/// in-flight concurrency equals the connection count.
struct SweepConn {
    stream: TcpStream,
    /// Both hellos advertised tracing ⇒ request frames must carry the
    /// 16-byte trace-context suffix the server will strip.
    trace_wire: bool,
}

fn sweep_connect(addr: SocketAddr) -> SweepConn {
    // A 10k-dial SYN burst can momentarily overflow the loopback
    // accept backlog; retry briefly instead of failing the bench.
    let mut last = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true).unwrap();
                let hello =
                    client_handshake(&mut (&stream), &mut (&stream), DATA_MAGIC).unwrap();
                let trace_wire = hello.tracing && memtrade::trace::enabled();
                return SweepConn { stream, trace_wire };
            }
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    panic!("sweep connect to {addr} failed after retries: {last:?}");
}

/// Aggregate GET ops/sec for `count` concurrent pipelined consumer
/// connections against an already-preloaded store at `addr`.
fn sweep_ops_per_sec(addr: SocketAddr, count: usize, keys: u64, run: Duration) -> f64 {
    let drivers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 16)
        .min(count);
    let stop = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(Barrier::new(drivers + 1));
    let handles: Vec<_> = (0..drivers)
        .map(|d| {
            let stop = stop.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mine = count / drivers + usize::from(d < count % drivers);
                let conns: Vec<SweepConn> = (0..mine).map(|_| sweep_connect(addr)).collect();
                // One pre-encoded GET frame per connection, distinct
                // keys so shard traffic spreads like real consumers.
                let mut rng = Rng::new(900 + d as u64);
                let frames: Vec<Vec<u8>> = conns
                    .iter()
                    .map(|c| {
                        let key = format!("user{}", rng.below(keys)).into_bytes();
                        let mut f = Request::Get { key }.encode();
                        if c.trace_wire {
                            append_trace_ctx(&mut f, 0, 0);
                        }
                        f
                    })
                    .collect();
                // Verification round before the clock starts: every
                // connection must round-trip a decodable hit.
                let mut resp = Vec::new();
                for (c, f) in conns.iter().zip(&frames) {
                    write_frame(&mut &c.stream, f).unwrap();
                }
                for c in conns.iter() {
                    read_frame_into(&mut &c.stream, &mut resp).unwrap();
                    assert!(matches!(Response::decode(&resp), Ok(Response::Value(_))));
                }
                let mut ops = 0u64;
                barrier.wait();
                while !stop.load(Ordering::Relaxed) {
                    for (c, f) in conns.iter().zip(&frames) {
                        write_frame(&mut &c.stream, f).unwrap();
                    }
                    for c in conns.iter() {
                        read_frame_into(&mut &c.stream, &mut resp).unwrap();
                        ops += 1;
                    }
                }
                ops
            })
        })
        .collect();
    barrier.wait();
    let t0 = Instant::now();
    std::thread::sleep(run);
    stop.store(true, Ordering::Relaxed);
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    total as f64 / t0.elapsed().as_secs_f64()
}

/// The scaling headline for the epoll rewrite: one producer store
/// serving 100 → 10k concurrent consumer connections, with the
/// thread-per-connection baseline measured at 100 connections. CI's
/// bench-smoke gate asserts epoll at 1k connections is no slower than
/// the threaded server at 100 — the "one producer VM, thousands of
/// consumers" claim, checked on every PR. The p99 column is the
/// server's own `data.op_us` instrument (windowed delta over the run),
/// the same number producer heartbeats feed to broker placement.
fn conn_sweep_bench() -> String {
    const KEYS: u64 = 2_000;
    const SHARDS: usize = 8;
    let nofile = raise_nofile_limit();
    // Both ends of every connection live in this process (~2 fds per
    // simulated consumer); leave slack for stores and listeners.
    // `raise_nofile_limit` is best-effort: gate the sweep on the limit
    // actually achieved, and say loudly what got clamped — a capped
    // container must report a skip, not a misleading partial sweep.
    let max_conns = (nofile.saturating_sub(256) / 2) as usize;
    let full = [100usize, 1_000, 10_000];
    let short = [100usize, 1_000];
    let counts: &[usize] = if smoke() { &short } else { &full };
    if counts.iter().any(|&c| c > max_conns) {
        eprintln!(
            "conn_sweep: WARNING: nofile soft limit is {nofile} (raise failed or hard \
             limit is low); counts above ~{max_conns} connections will be SKIPPED, \
             not measured"
        );
    }
    let run = bench_run_for(1500);
    let value = vec![0xAB_u8; 512];
    let preload = |addr: SocketAddr| {
        let mut c = KvClient::connect(addr).unwrap();
        for i in 0..KEYS {
            assert!(c.put(format!("user{i}").as_bytes(), &value).unwrap());
        }
    };
    // One measured pass against a running server: windowed deltas of
    // the op histogram, the ops counter, the loop syscall estimate and
    // process-wide context switches (client driver included — both
    // ends live here, so the column is the whole loopback exchange).
    let measure = |server: &ProducerStoreServer, count: usize| {
        let hist0 = server.telemetry().histogram("op_us").snapshot();
        let ops0 = server.telemetry().counter("ops").get();
        let sys0 = server.loop_metrics().map(|m| m.syscalls.get());
        let cs0 = ctx_switches();
        let rate = sweep_ops_per_sec(server.addr(), count, KEYS, run);
        let cs1 = ctx_switches();
        let ops_done = server.telemetry().counter("ops").get().saturating_sub(ops0);
        let p99 = server.telemetry().histogram("op_us").snapshot().delta(&hist0).quantile(0.99);
        let per_op = |delta: u64| {
            if ops_done > 0 { delta as f64 / ops_done as f64 } else { 0.0 }
        };
        let sys_per_op = server
            .loop_metrics()
            .zip(sys0)
            .map(|(m, s0)| per_op(m.syscalls.get().saturating_sub(s0)));
        let cs_per_op = per_op(cs1.saturating_sub(cs0));
        (rate, p99, sys_per_op, cs_per_op)
    };
    let report = |label: &str, rate: f64, p99: f64, sys: Option<f64>, cs: f64| {
        let sys_col = sys.map_or("n/a".to_string(), |s| format!("{s:.2}"));
        println!(
            "{label:<40} {rate:>14.0} ops/s   p99 {p99:>7.1} µs   \
             {sys_col:>6} syscalls/op   {cs:>6.2} ctx/op"
        );
    };

    // Thread-per-connection baseline at 100 connections: same driver,
    // same store shape — the gate's denominator. No loop metrics here
    // (syscalls/op is owned-call-site counting, which the blocking
    // path does not instrument), so that column is null.
    let server =
        ProducerStoreServer::start_threaded_sharded("127.0.0.1:0", 1 << 30, None, 51, SHARDS)
            .unwrap();
    preload(server.addr());
    let (base_ops, base_p99, _, base_cs) = measure(&server, 100);
    server.stop();
    report("conn_sweep/threaded @100 (baseline)", base_ops, base_p99, None, base_cs);

    // Event-loop modes: level-triggered (one release of fallback, via
    // the same env toggle CI uses) vs the default edge-triggered +
    // writev path. Same seed, same store shape, same driver.
    let mut rows = Vec::new();
    for (mode, env_val) in [("level", Some("level")), ("et_writev", None)] {
        for &count in counts {
            if count > max_conns {
                eprintln!(
                    "conn_sweep/{mode} @{count}: SKIPPED (nofile limit {nofile} caps \
                     the sweep at ~{max_conns} connections)"
                );
                continue;
            }
            if let Some(v) = env_val {
                std::env::set_var("MEMTRADE_EVENT_MODE", v);
            }
            let server =
                ProducerStoreServer::start_sharded("127.0.0.1:0", 1 << 30, None, 52, SHARDS)
                    .unwrap();
            if env_val.is_some() {
                std::env::remove_var("MEMTRADE_EVENT_MODE");
            }
            preload(server.addr());
            let (ops, p99, sys_per_op, cs_per_op) = measure(&server, count);
            server.stop();
            report(
                &format!("conn_sweep/{mode} @{count}"),
                ops,
                p99,
                sys_per_op,
                cs_per_op,
            );
            let sys_json =
                sys_per_op.map_or("null".to_string(), |s| format!("{s:.3}"));
            rows.push(format!(
                "      {{\"mode\": \"{mode}\", \"connections\": {count}, \
                 \"ops_per_sec\": {ops:.0}, \"op_us_p99\": {p99:.1}, \
                 \"syscalls_per_op\": {sys_json}, \
                 \"ctx_switches_per_op\": {cs_per_op:.3}}}"
            ));
        }
    }
    format!(
        "  \"conn_sweep\": {{\n    \"baseline\": {{\"mode\": \"threaded\", \
         \"connections\": 100, \"ops_per_sec\": {base_ops:.0}, \
         \"op_us_p99\": {base_p99:.1}, \"syscalls_per_op\": null, \
         \"ctx_switches_per_op\": {base_cs:.3}}},\n    \"epoll\": [\n{}\n    ]\n  }}",
        rows.join(",\n")
    )
}

fn main() {
    header("end-to-end secure KV");

    // --- In-process: consumer -> manager -> producer store.
    let mut manager = Manager::new(ProducerId(1), DEFAULT_SLAB_BYTES, 3);
    manager.set_harvestable(2 << 30, SimTime::ZERO);
    assert!(manager.grant_lease(
        Lease {
            id: LeaseId(1),
            consumer: ConsumerId(1),
            producer: ProducerId(1),
            slabs: 16,
            slab_bytes: DEFAULT_SLAB_BYTES,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(1),
            price_per_slab_hour: Money::from_dollars(0.00004),
        },
        1_250_000_000,
    ));
    let mut secure = SecureKv::with_iv_seed(Some([5u8; 16]), true, 1, 7);
    let mut now_us = 0u64;
    let value = vec![0xAB; 1024];
    // Preload.
    {
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(0))
        };
        for i in 0..5_000u32 {
            assert!(secure.put(&mut t, format!("user{i}").as_bytes(), &value));
        }
    }
    let mut rng = Rng::new(9);
    bench("inproc_secure_get/1KB (manager+rate-limit+crypto)", || {
        now_us += 50;
        let key = format!("user{}", rng.below(5_000));
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(now_us))
        };
        std::hint::black_box(secure.get(&mut t, key.as_bytes()));
    });
    bench("inproc_secure_put/1KB", || {
        now_us += 50;
        let key = format!("user{}", rng.below(5_000));
        let mut t = |_p: u32, req: Request| -> Response {
            manager.handle(ConsumerId(1), &req, SimTime::from_micros(now_us))
        };
        std::hint::black_box(secure.put(&mut t, key.as_bytes(), &value));
    });

    // --- Real TCP on localhost.
    let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 30, None, 11).unwrap();
    let mut client = KvClient::connect(server.addr()).unwrap();
    let mut secure_tcp = SecureKv::with_iv_seed(Some([5u8; 16]), true, 1, 13);
    {
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        for i in 0..2_000u32 {
            assert!(secure_tcp.put(&mut t, format!("user{i}").as_bytes(), &value));
        }
    }
    let mut rng2 = Rng::new(10);
    bench("tcp_secure_get/1KB/localhost", || {
        let key = format!("user{}", rng2.below(2_000));
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        std::hint::black_box(secure_tcp.get(&mut t, key.as_bytes()));
    });
    bench("tcp_secure_put/1KB/localhost", || {
        let key = format!("user{}", rng2.below(2_000));
        let mut t = |_p: u32, req: Request| -> Response {
            client.call(&req).unwrap_or(Response::Error("io".into()))
        };
        std::hint::black_box(secure_tcp.put(&mut t, key.as_bytes(), &value));
    });
    server.stop();

    // --- Batched + pipelined data plane vs. single-op round trips
    // (the section CI's bench-smoke perf gate reads).
    println!("\n== bench: batched/pipelined data plane ==");
    let batch_json = batch_bench();

    // --- Multi-client TCP: single-mutex baseline vs. sharded server.
    let clients = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(4, 8);
    let run_for = bench_run_for(1200);
    println!("\n== bench: TCP hammer (90/10 GET/PUT, 1KB, {clients} clients) ==");
    let tcp_single = tcp_hammer_ops_per_sec(1, clients, run_for);
    println!("{:<48} {:>14.0} ops/s", "tcp_hammer/1-shard", tcp_single);
    let tcp_sharded = tcp_hammer_ops_per_sec(16, clients, run_for);
    println!("{:<48} {:>14.0} ops/s", "tcp_hammer/16-shards", tcp_sharded);
    println!("{:<48} {:>13.2}x", "speedup", tcp_sharded / tcp_single);

    // --- Wire codec alone.
    let req = Request::Put { key: b"user12345".to_vec(), value: vec![0xCD; 1024] };
    bench("wire_encode_decode/1KB-put", || {
        let enc = req.encode();
        std::hint::black_box(Request::decode(&enc).unwrap());
    });

    // --- Workload generator.
    let w = YcsbWorkload::paper_default(10_000_000, 1024);
    let mut rng3 = Rng::new(11);
    bench("ycsb_next_op/10M-keys-zipf0.7", || {
        std::hint::black_box(w.next_op(&mut rng3));
    });

    // --- Connection-count sweep: epoll server from 100 to 10k
    // concurrent consumers vs. the thread-per-connection baseline
    // (the section CI's conn-sweep perf gate reads).
    println!("\n== bench: connection sweep (pipelined GETs, epoll vs threaded) ==");
    let conn_sweep_json = conn_sweep_bench();

    // --- Full marketplace: broker daemon + 2 producer agents + pool,
    // grant -> put -> get -> kill -> recover.
    println!("\n== bench: marketplace control plane ==");
    let marketplace_json = marketplace_bench();

    // --- Chaos plane: ops/sec under the standard fault mix, and how
    // fast the marketplace reconverges once the faults stop.
    println!("\n== bench: chaos plane (standard fault mix, seed 42) ==");
    let chaos_json = chaos_bench();

    let json = format!(
        "{{\n{batch_json},\n{conn_sweep_json},\n{marketplace_json},\n{chaos_json}\n}}\n"
    );
    match std::fs::write("BENCH_e2e.json", &json) {
        Ok(()) => println!("\nwrote BENCH_e2e.json"),
        Err(e) => eprintln!("\ncould not write BENCH_e2e.json: {e}"),
    }
}

//! Harvester control loop (Algorithm 1): the per-epoch cost of the
//! baseline/recent p99 estimators (windowed AVL), the drop detector, and
//! a full producer tick including the guest-memory epoch — the overhead
//! the paper reports as "<1% CPU" on the producer.

use memtrade::core::config::HarvesterConfig;
use memtrade::core::{ProducerId, SimTime};
use memtrade::mem::{GuestMemory, SwapDevice};
use memtrade::producer::{Harvester, Producer};
use memtrade::util::avl::WindowedDist;
use memtrade::util::bench::{bench, header};
use memtrade::util::rng::Rng;
use memtrade::workload::apps::{AppKind, AppModel, AppRunner};

fn main() {
    header("harvester (Algorithm 1)");

    // Windowed-AVL sample insertion + p99 at realistic sizes (6h of 1s
    // samples = 21600 points).
    let mut dist = WindowedDist::new(SimTime::from_hours(6));
    let mut rng = Rng::new(5);
    let mut t = 0u64;
    for _ in 0..21_600 {
        t += 1;
        dist.insert(SimTime::from_secs(t), rng.normal(100.0, 10.0));
    }
    bench("windowed_dist_insert+expire/21600-live", || {
        t += 1;
        dist.insert(SimTime::from_secs(t), rng.normal(100.0, 10.0));
    });
    bench("windowed_dist_p99/21600-live", || {
        std::hint::black_box(dist.quantile(0.99));
    });

    // Harvester epoch step against a quiet guest.
    let cfg = HarvesterConfig::default();
    let mut h = Harvester::new(cfg.clone(), 8 << 30);
    let mut mem = GuestMemory::new(
        8 << 30,
        4 << 30,
        4 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        3,
    );
    let mut now = 0u64;
    bench("harvester_record+step_epoch", || {
        now += 5;
        h.record_sample(SimTime::from_secs(now), 100.0, 0);
        std::hint::black_box(h.step_epoch(SimTime::from_secs(now), &mut mem));
    });

    // Full producer tick (guest app epoch + harvester + manager refresh).
    let app = AppRunner::new(
        AppModel::preset(AppKind::Redis),
        4 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        9,
    );
    let mut producer = Producer::new(ProducerId(1), app, cfg, 64 << 20);
    let mut e = 0u64;
    bench("producer_tick/5s-epoch/2000-op-cap", || {
        e += 1;
        std::hint::black_box(producer.tick(
            SimTime::from_micros(e * 5_000_000),
            SimTime::from_secs(5),
        ));
    });

    // Guest page access paths.
    let mut guest = GuestMemory::new(
        8 << 30,
        4 << 30,
        4 << 20,
        SwapDevice::Ssd,
        Some(SimTime::from_mins(5)),
        5,
    );
    let pages = guest.app_pages() as u64;
    let mut rng2 = Rng::new(6);
    bench("guest_access_hit", || {
        let p = rng2.below(pages) as u32;
        std::hint::black_box(guest.access(p, SimTime::from_secs(1)));
    });
}

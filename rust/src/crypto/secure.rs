//! The paper's §6.1 envelope: value encryption + integrity hashing +
//! key substitution, exactly as specified.
//!
//! PUT: `V_P = IV || AES-CBC(K, IV, V_C)`, `H = trunc128(SHA-256(V_P))`,
//! substitute key `K_P` from a 64-bit counter; consumer stores
//! `M_C = (K_P, H, P_i)` locally. GET verifies `H` over the returned `V_P`
//! before decrypting. Integrity-only mode skips encryption/substitution
//! and keeps just the hash (16-byte metadata instead of 24).
//!
//! ## Threat model (IV unpredictability)
//!
//! The producer is *untrusted* (§6): it sees every `V_P` and may store,
//! replay, corrupt, or analyze them. CBC is only IND-CPA when IVs are
//! unpredictable to the adversary — with predictable IVs a producer
//! that can influence future plaintexts (e.g. a consumer caching
//! attacker-supplied values) can confirm guesses about earlier blocks.
//! The IV stream is therefore seeded from OS entropy by default
//! ([`Envelope::new`]); the xoshiro generator expanding that seed is
//! not itself cryptographic, which is an accepted trade-off of this
//! from-scratch reproduction (a production deployment would use the
//! platform CSPRNG per IV). [`Envelope::with_iv_seed`] keeps the fully
//! deterministic stream for tests, benchmarks, and the simulator,
//! where ciphertexts never cross a trust boundary. Integrity does not
//! depend on the IVs at all: `H` binds the exact `V_P` bytes, so a
//! Byzantine producer's corrupted, truncated, or replayed values are
//! rejected regardless (`tests/chaos.rs` drives that at 100% tamper
//! rates).

use crate::crypto::aes::Aes128;
use crate::crypto::sha256::sha256;
use crate::trace::{Op as TraceOp, Role, SpanGuard, Status};
use crate::util::rng::{os_seed, Rng};

/// Per-KV metadata kept locally by the consumer (paper: 24 bytes with
/// encryption, 16 bytes integrity-only; we also keep the producer index).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SealedValue {
    /// Substitute producer-visible key (64-bit counter).
    pub k_p: u64,
    /// Truncated 128-bit SHA-256 of the producer-visible value.
    pub hash: [u8; 16],
    /// Index into the consumer's producer table.
    pub producer_index: u32,
}

impl SealedValue {
    /// Metadata bytes as accounted by the paper (excluding the local map key).
    pub fn metadata_bytes(encrypting: bool) -> usize {
        if encrypting {
            24 // K_P (8) + H (16) — P_i lives in a small table
        } else {
            16 // integrity-only: H
        }
    }
}

/// Envelope sealing/opening values per the paper's construction.
pub struct Envelope {
    aes: Option<Aes128>,
    integrity: bool,
    counter: u64,
    iv_rng: Rng,
}

/// Result of sealing: producer-visible bytes + local metadata.
pub struct Sealed {
    pub value_p: Vec<u8>,
    pub meta: SealedValue,
}

#[derive(Debug, PartialEq, Eq)]
pub enum OpenError {
    /// Integrity hash mismatch — corrupted or tampered value discarded.
    BadHash,
    /// Ciphertext malformed (length / padding).
    BadCiphertext,
}

impl Envelope {
    /// `key = None` disables encryption (integrity-only mode when
    /// `integrity`, or fully transparent when neither). The CBC IV
    /// stream is seeded from OS entropy — IVs must be unpredictable to
    /// the untrusted producer (module doc); tests and simulations that
    /// need reproducibility use [`Self::with_iv_seed`].
    pub fn new(key: Option<[u8; 16]>, integrity: bool) -> Self {
        Self::with_iv_seed(key, integrity, os_seed())
    }

    /// [`Self::new`] with an explicit IV-stream seed. Deterministic —
    /// and therefore predictable: only for harnesses whose ciphertexts
    /// never reach an untrusted party.
    pub fn with_iv_seed(key: Option<[u8; 16]>, integrity: bool, seed: u64) -> Self {
        Envelope {
            aes: key.map(|k| Aes128::new(&k)),
            integrity,
            counter: 0,
            iv_rng: Rng::new(seed ^ 0x5ec0_de00_1eaf_fade),
        }
    }

    pub fn encrypting(&self) -> bool {
        self.aes.is_some()
    }

    fn fresh_iv(&mut self) -> [u8; 16] {
        let mut iv = [0u8; 16];
        iv[..8].copy_from_slice(&self.iv_rng.next_u64().to_le_bytes());
        iv[8..].copy_from_slice(&self.iv_rng.next_u64().to_le_bytes());
        iv
    }

    /// Seal a consumer value for storage at `producer_index`.
    pub fn seal(&mut self, value_c: &[u8], producer_index: u32) -> Sealed {
        // Child of the ambient trace (no-op outside one, so raw crypto
        // benchmarks never pay for recording).
        let mut span = SpanGuard::child(Role::Consumer, TraceOp::Seal);
        span.set_producer(producer_index as u64);
        let iv = self.fresh_iv();
        let value_p = match &self.aes {
            Some(aes) => {
                let ct = aes.cbc_encrypt(&iv, value_c);
                let mut out = Vec::with_capacity(16 + ct.len());
                out.extend_from_slice(&iv);
                out.extend_from_slice(&ct);
                out
            }
            None => value_c.to_vec(),
        };
        let hash = if self.integrity {
            let full = sha256(&value_p);
            let mut h = [0u8; 16];
            h.copy_from_slice(&full[..16]);
            h
        } else {
            [0u8; 16]
        };
        let k_p = self.counter;
        self.counter += 1;
        Sealed { value_p, meta: SealedValue { k_p, hash, producer_index } }
    }

    /// Verify + decrypt a producer-returned value against its metadata.
    pub fn open(&self, value_p: &[u8], meta: &SealedValue) -> Result<Vec<u8>, OpenError> {
        let mut span = SpanGuard::child(Role::Consumer, TraceOp::Verify);
        span.set_producer(meta.producer_index as u64);
        let out = self.open_inner(value_p, meta);
        if out.is_err() {
            span.set_status(Status::Error);
        }
        out
    }

    fn open_inner(&self, value_p: &[u8], meta: &SealedValue) -> Result<Vec<u8>, OpenError> {
        if self.integrity {
            let full = sha256(value_p);
            if full[..16] != meta.hash {
                return Err(OpenError::BadHash);
            }
        }
        match &self.aes {
            Some(aes) => {
                if value_p.len() < 16 {
                    return Err(OpenError::BadCiphertext);
                }
                let iv: [u8; 16] = value_p[..16].try_into().unwrap();
                aes.cbc_decrypt(&iv, &value_p[16..]).ok_or(OpenError::BadCiphertext)
            }
            None => Ok(value_p.to_vec()),
        }
    }

    /// Space overhead at the producer for a value of `len` bytes
    /// (IV + CBC padding when encrypting, zero otherwise).
    pub fn producer_overhead(&self, len: usize) -> usize {
        if self.aes.is_some() {
            16 + (16 - len % 16)
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seal_open_round_trip() {
        let mut env = Envelope::with_iv_seed(Some([5u8; 16]), true, 42);
        let sealed = env.seal(b"the consumer value", 3);
        assert_ne!(sealed.value_p, b"the consumer value".to_vec());
        assert_eq!(sealed.meta.producer_index, 3);
        let opened = env.open(&sealed.value_p, &sealed.meta).unwrap();
        assert_eq!(opened, b"the consumer value");
    }

    #[test]
    fn counter_keys_are_unique_and_sequential() {
        let mut env = Envelope::with_iv_seed(Some([5u8; 16]), true, 1);
        let a = env.seal(b"a", 0);
        let b = env.seal(b"b", 0);
        assert_eq!(a.meta.k_p, 0);
        assert_eq!(b.meta.k_p, 1);
    }

    #[test]
    fn detects_corruption() {
        let mut env = Envelope::with_iv_seed(Some([5u8; 16]), true, 7);
        let sealed = env.seal(b"value", 0);
        let mut corrupted = sealed.value_p.clone();
        corrupted[20] ^= 0x01;
        assert_eq!(env.open(&corrupted, &sealed.meta), Err(OpenError::BadHash));
    }

    #[test]
    fn integrity_only_mode() {
        let mut env = Envelope::with_iv_seed(None, true, 7);
        let sealed = env.seal(b"plain value", 0);
        assert_eq!(sealed.value_p, b"plain value".to_vec()); // no encryption
        assert!(env.open(&sealed.value_p, &sealed.meta).is_ok());
        let mut bad = sealed.value_p.clone();
        bad[0] ^= 1;
        assert_eq!(env.open(&bad, &sealed.meta), Err(OpenError::BadHash));
        assert_eq!(SealedValue::metadata_bytes(false), 16);
        assert_eq!(SealedValue::metadata_bytes(true), 24);
    }

    #[test]
    fn no_security_mode_passthrough() {
        let mut env = Envelope::with_iv_seed(None, false, 7);
        let sealed = env.seal(b"raw", 0);
        assert_eq!(sealed.value_p, b"raw");
        let mut tampered = sealed.value_p.clone();
        tampered[0] ^= 1;
        // Without integrity there is no detection — documented trade-off.
        assert!(env.open(&tampered, &sealed.meta).is_ok());
    }

    #[test]
    fn default_envelopes_draw_independent_iv_streams() {
        // Regression: IVs used to come from a fixed deterministic seed,
        // so every consumer process emitted the *same predictable* IV
        // sequence — exactly what CBC must not do in front of an
        // untrusted producer. Two entropy-seeded envelopes with the
        // same key must now produce different ciphertexts for the same
        // plaintext (2^-128 false-failure probability).
        let mut a = Envelope::new(Some([5u8; 16]), true);
        let mut b = Envelope::new(Some([5u8; 16]), true);
        assert_ne!(a.seal(b"same plaintext", 0).value_p, b.seal(b"same plaintext", 0).value_p);
        // The explicit-seed constructor stays bit-reproducible.
        let mut c = Envelope::with_iv_seed(Some([5u8; 16]), true, 9);
        let mut d = Envelope::with_iv_seed(Some([5u8; 16]), true, 9);
        assert_eq!(c.seal(b"same plaintext", 0).value_p, d.seal(b"same plaintext", 0).value_p);
    }

    #[test]
    fn fresh_ivs_randomize_ciphertext() {
        let mut env = Envelope::with_iv_seed(Some([9u8; 16]), true, 3);
        let a = env.seal(b"same", 0);
        let b = env.seal(b"same", 0);
        assert_ne!(a.value_p, b.value_p);
    }

    #[test]
    fn producer_overhead_accounting() {
        let env = Envelope::with_iv_seed(Some([9u8; 16]), true, 3);
        // 5-byte value: IV 16 + pad to 16 => 16 + 11 = 27 extra bytes.
        assert_eq!(env.producer_overhead(5), 16 + 11);
        let env2 = Envelope::with_iv_seed(None, true, 3);
        assert_eq!(env2.producer_overhead(5), 0);
    }
}

//! Cryptographic substrate for the consumer's confidentiality/integrity
//! layer (paper §6.1): AES-128 in CBC mode for value encryption and
//! SHA-256 (truncated to 128 bits) for integrity, both implemented from
//! scratch and verified against FIPS test vectors.
//!
//! The paper's construction, reproduced exactly by [`secure::Envelope`]:
//! a PUT encrypts `V_C` with the consumer secret key under a fresh random
//! IV, prepends the IV to form `V_P`, and stores `H = SHA-256(V_P)`
//! (truncated) locally; a GET verifies `H` before decrypting.

pub mod aes;
pub mod secure;
pub mod sha256;

pub use aes::Aes128;
pub use secure::{Envelope, SealedValue};
pub use sha256::{sha256, Sha256};

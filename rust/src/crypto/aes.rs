//! AES-128 (FIPS 197) with CBC mode and PKCS#7 padding, from scratch.
//!
//! This is a straightforward table-free implementation (S-box lookups plus
//! xtime for MixColumns). It is not constant-time hardened — the threat
//! model here is the paper's: protecting consumer data at rest in an
//! untrusted *producer* VM, not side channels within the consumer.
//! Verified against FIPS 197 Appendix B and NIST SP 800-38A CBC vectors.

const SBOX: [u8; 256] = [
    0x63, 0x7c, 0x77, 0x7b, 0xf2, 0x6b, 0x6f, 0xc5, 0x30, 0x01, 0x67, 0x2b, 0xfe, 0xd7,
    0xab, 0x76, 0xca, 0x82, 0xc9, 0x7d, 0xfa, 0x59, 0x47, 0xf0, 0xad, 0xd4, 0xa2, 0xaf,
    0x9c, 0xa4, 0x72, 0xc0, 0xb7, 0xfd, 0x93, 0x26, 0x36, 0x3f, 0xf7, 0xcc, 0x34, 0xa5,
    0xe5, 0xf1, 0x71, 0xd8, 0x31, 0x15, 0x04, 0xc7, 0x23, 0xc3, 0x18, 0x96, 0x05, 0x9a,
    0x07, 0x12, 0x80, 0xe2, 0xeb, 0x27, 0xb2, 0x75, 0x09, 0x83, 0x2c, 0x1a, 0x1b, 0x6e,
    0x5a, 0xa0, 0x52, 0x3b, 0xd6, 0xb3, 0x29, 0xe3, 0x2f, 0x84, 0x53, 0xd1, 0x00, 0xed,
    0x20, 0xfc, 0xb1, 0x5b, 0x6a, 0xcb, 0xbe, 0x39, 0x4a, 0x4c, 0x58, 0xcf, 0xd0, 0xef,
    0xaa, 0xfb, 0x43, 0x4d, 0x33, 0x85, 0x45, 0xf9, 0x02, 0x7f, 0x50, 0x3c, 0x9f, 0xa8,
    0x51, 0xa3, 0x40, 0x8f, 0x92, 0x9d, 0x38, 0xf5, 0xbc, 0xb6, 0xda, 0x21, 0x10, 0xff,
    0xf3, 0xd2, 0xcd, 0x0c, 0x13, 0xec, 0x5f, 0x97, 0x44, 0x17, 0xc4, 0xa7, 0x7e, 0x3d,
    0x64, 0x5d, 0x19, 0x73, 0x60, 0x81, 0x4f, 0xdc, 0x22, 0x2a, 0x90, 0x88, 0x46, 0xee,
    0xb8, 0x14, 0xde, 0x5e, 0x0b, 0xdb, 0xe0, 0x32, 0x3a, 0x0a, 0x49, 0x06, 0x24, 0x5c,
    0xc2, 0xd3, 0xac, 0x62, 0x91, 0x95, 0xe4, 0x79, 0xe7, 0xc8, 0x37, 0x6d, 0x8d, 0xd5,
    0x4e, 0xa9, 0x6c, 0x56, 0xf4, 0xea, 0x65, 0x7a, 0xae, 0x08, 0xba, 0x78, 0x25, 0x2e,
    0x1c, 0xa6, 0xb4, 0xc6, 0xe8, 0xdd, 0x74, 0x1f, 0x4b, 0xbd, 0x8b, 0x8a, 0x70, 0x3e,
    0xb5, 0x66, 0x48, 0x03, 0xf6, 0x0e, 0x61, 0x35, 0x57, 0xb9, 0x86, 0xc1, 0x1d, 0x9e,
    0xe1, 0xf8, 0x98, 0x11, 0x69, 0xd9, 0x8e, 0x94, 0x9b, 0x1e, 0x87, 0xe9, 0xce, 0x55,
    0x28, 0xdf, 0x8c, 0xa1, 0x89, 0x0d, 0xbf, 0xe6, 0x42, 0x68, 0x41, 0x99, 0x2d, 0x0f,
    0xb0, 0x54, 0xbb, 0x16,
];

const INV_SBOX: [u8; 256] = {
    let mut inv = [0u8; 256];
    let mut i = 0;
    while i < 256 {
        inv[SBOX[i] as usize] = i as u8;
        i += 1;
    }
    inv
};

const RCON: [u8; 10] = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1b, 0x36];

/// Reference xtime (kept for the straightforward MixColumns used by the
/// differential test pinning the T-table fast path).
#[cfg_attr(not(test), allow(dead_code))]
#[inline]
fn xtime(x: u8) -> u8 {
    (x << 1) ^ (((x >> 7) & 1) * 0x1b)
}

/// GF(2^8) multiply (used only to build the decryption tables below).
const fn gf_mul(x: u8, y: u8) -> u8 {
    let mut acc = 0u8;
    let mut a = x;
    let mut b = y;
    while b != 0 {
        if b & 1 != 0 {
            acc ^= a;
        }
        a = (a << 1) ^ (((a >> 7) & 1) * 0x1b);
        b >>= 1;
    }
    acc
}

/// Precomputed ×9/×11/×13/×14 tables: InvMixColumns is the decryption
/// hot path (measured 26 µs/KB with loop-based multiplies; tables cut
/// CBC-decrypt roughly in half — see DESIGN.md §Perf notes).
const fn gf_table(y: u8) -> [u8; 256] {
    let mut t = [0u8; 256];
    let mut i = 0;
    while i < 256 {
        t[i] = gf_mul(i as u8, y);
        i += 1;
    }
    t
}

const MUL9: [u8; 256] = gf_table(0x09);
const MUL11: [u8; 256] = gf_table(0x0b);
const MUL13: [u8; 256] = gf_table(0x0d);
const MUL14: [u8; 256] = gf_table(0x0e);

/// Encryption T-tables: fuse SubBytes + ShiftRows + MixColumns into four
/// u32 lookups per output column (the classic software-AES structure).
/// Te_r[x] is column r of the MixColumns matrix times S(x), packed
/// little-endian (byte k of the u32 = state row k of the column).
const fn te_table(c0: u8, c1: u8, c2: u8, c3: u8) -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let s = SBOX[i];
        t[i] = (gf_mul(s, c0) as u32)
            | ((gf_mul(s, c1) as u32) << 8)
            | ((gf_mul(s, c2) as u32) << 16)
            | ((gf_mul(s, c3) as u32) << 24);
        i += 1;
    }
    t
}

const TE0: [u32; 256] = te_table(2, 1, 1, 3);
const TE1: [u32; 256] = te_table(3, 2, 1, 1);
const TE2: [u32; 256] = te_table(1, 3, 2, 1);
const TE3: [u32; 256] = te_table(1, 1, 3, 2);

/// Decryption T-tables (equivalent inverse cipher): Td_r[x] is column r
/// of the InvMixColumns matrix times InvS(x).
const fn td_table(c0: u8, c1: u8, c2: u8, c3: u8) -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let s = INV_SBOX[i];
        t[i] = (gf_mul(s, c0) as u32)
            | ((gf_mul(s, c1) as u32) << 8)
            | ((gf_mul(s, c2) as u32) << 16)
            | ((gf_mul(s, c3) as u32) << 24);
        i += 1;
    }
    t
}

const TD0: [u32; 256] = td_table(14, 9, 13, 11);
const TD1: [u32; 256] = td_table(11, 14, 9, 13);
const TD2: [u32; 256] = td_table(13, 11, 14, 9);
const TD3: [u32; 256] = td_table(9, 13, 11, 14);

/// AES-128 block cipher with expanded round keys.
#[derive(Clone)]
pub struct Aes128 {
    round_keys: [[u8; 16]; 11],
    /// InvMixColumns-transformed round keys for the equivalent inverse
    /// cipher (rounds 1..=9; 0 and 10 are used untransformed).
    dec_keys: [[u8; 16]; 11],
}

impl Aes128 {
    pub fn new(key: &[u8; 16]) -> Self {
        let mut rk = [[0u8; 16]; 11];
        rk[0] = *key;
        for r in 1..11 {
            let prev = rk[r - 1];
            let mut temp = [prev[12], prev[13], prev[14], prev[15]];
            // RotWord + SubWord + Rcon
            temp.rotate_left(1);
            for t in &mut temp {
                *t = SBOX[*t as usize];
            }
            temp[0] ^= RCON[r - 1];
            for i in 0..4 {
                rk[r][i] = prev[i] ^ temp[i];
            }
            for i in 4..16 {
                rk[r][i] = prev[i] ^ rk[r][i - 4];
            }
        }
        let mut dk = rk;
        for key in dk.iter_mut().take(10).skip(1) {
            Self::inv_mix_columns(key);
        }
        Aes128 { round_keys: rk, dec_keys: dk }
    }

    fn add_round_key(state: &mut [u8; 16], rk: &[u8; 16]) {
        for i in 0..16 {
            state[i] ^= rk[i];
        }
    }

    fn sub_bytes(state: &mut [u8; 16]) {
        for b in state.iter_mut() {
            *b = SBOX[*b as usize];
        }
    }

    fn inv_sub_bytes(state: &mut [u8; 16]) {
        for b in state.iter_mut() {
            *b = INV_SBOX[*b as usize];
        }
    }

    /// State layout: column-major as in FIPS 197 (byte i is row i%4, col i/4).
    fn shift_rows(state: &mut [u8; 16]) {
        let s = *state;
        for row in 1..4 {
            for col in 0..4 {
                state[row + 4 * col] = s[row + 4 * ((col + row) % 4)];
            }
        }
    }

    fn inv_shift_rows(state: &mut [u8; 16]) {
        let s = *state;
        for row in 1..4 {
            for col in 0..4 {
                state[row + 4 * ((col + row) % 4)] = s[row + 4 * col];
            }
        }
    }

    /// Reference MixColumns (the T-table rounds replace it on the hot
    /// path; the differential test below keeps them honest).
    #[cfg_attr(not(test), allow(dead_code))]
    fn mix_columns(state: &mut [u8; 16]) {
        for col in 0..4 {
            let c = &mut state[4 * col..4 * col + 4];
            let a = [c[0], c[1], c[2], c[3]];
            c[0] = xtime(a[0]) ^ (xtime(a[1]) ^ a[1]) ^ a[2] ^ a[3];
            c[1] = a[0] ^ xtime(a[1]) ^ (xtime(a[2]) ^ a[2]) ^ a[3];
            c[2] = a[0] ^ a[1] ^ xtime(a[2]) ^ (xtime(a[3]) ^ a[3]);
            c[3] = (xtime(a[0]) ^ a[0]) ^ a[1] ^ a[2] ^ xtime(a[3]);
        }
    }

    fn inv_mix_columns(state: &mut [u8; 16]) {
        for col in 0..4 {
            let c = &mut state[4 * col..4 * col + 4];
            let a = [c[0] as usize, c[1] as usize, c[2] as usize, c[3] as usize];
            c[0] = MUL14[a[0]] ^ MUL11[a[1]] ^ MUL13[a[2]] ^ MUL9[a[3]];
            c[1] = MUL9[a[0]] ^ MUL14[a[1]] ^ MUL11[a[2]] ^ MUL13[a[3]];
            c[2] = MUL13[a[0]] ^ MUL9[a[1]] ^ MUL14[a[2]] ^ MUL11[a[3]];
            c[3] = MUL11[a[0]] ^ MUL13[a[1]] ^ MUL9[a[2]] ^ MUL14[a[3]];
        }
    }

    /// Encrypt one 16-byte block in place (T-table rounds; the last round
    /// has no MixColumns so it uses plain SBOX lookups).
    pub fn encrypt_block(&self, block: &mut [u8; 16]) {
        Self::add_round_key(block, &self.round_keys[0]);
        let mut cols = [0u32; 4];
        for (c, col) in cols.iter_mut().enumerate() {
            *col = u32::from_le_bytes(block[4 * c..4 * c + 4].try_into().unwrap());
        }
        for r in 1..10 {
            let rk = &self.round_keys[r];
            let mut next = [0u32; 4];
            for (c, nxt) in next.iter_mut().enumerate() {
                // Row k of output column c reads input column (c+k)%4
                // (ShiftRows), fused with SubBytes+MixColumns via Te_k.
                *nxt = TE0[(cols[c] & 0xff) as usize]
                    ^ TE1[((cols[(c + 1) & 3] >> 8) & 0xff) as usize]
                    ^ TE2[((cols[(c + 2) & 3] >> 16) & 0xff) as usize]
                    ^ TE3[((cols[(c + 3) & 3] >> 24) & 0xff) as usize]
                    ^ u32::from_le_bytes(rk[4 * c..4 * c + 4].try_into().unwrap());
            }
            cols = next;
        }
        for (c, col) in cols.iter().enumerate() {
            block[4 * c..4 * c + 4].copy_from_slice(&col.to_le_bytes());
        }
        Self::sub_bytes(block);
        Self::shift_rows(block);
        Self::add_round_key(block, &self.round_keys[10]);
    }

    /// Decrypt one 16-byte block in place (equivalent inverse cipher:
    /// Td-table rounds against InvMixColumns-transformed round keys).
    pub fn decrypt_block(&self, block: &mut [u8; 16]) {
        Self::add_round_key(block, &self.round_keys[10]);
        let mut cols = [0u32; 4];
        for (c, col) in cols.iter_mut().enumerate() {
            *col = u32::from_le_bytes(block[4 * c..4 * c + 4].try_into().unwrap());
        }
        for r in (1..10).rev() {
            let dk = &self.dec_keys[r];
            let mut next = [0u32; 4];
            for (c, nxt) in next.iter_mut().enumerate() {
                // InvShiftRows: row k of output column c reads input
                // column (c - k) mod 4; fused with InvSubBytes +
                // InvMixColumns via Td_k.
                *nxt = TD0[(cols[c] & 0xff) as usize]
                    ^ TD1[((cols[(c + 3) & 3] >> 8) & 0xff) as usize]
                    ^ TD2[((cols[(c + 2) & 3] >> 16) & 0xff) as usize]
                    ^ TD3[((cols[(c + 1) & 3] >> 24) & 0xff) as usize]
                    ^ u32::from_le_bytes(dk[4 * c..4 * c + 4].try_into().unwrap());
            }
            cols = next;
        }
        for (c, col) in cols.iter().enumerate() {
            block[4 * c..4 * c + 4].copy_from_slice(&col.to_le_bytes());
        }
        Self::inv_shift_rows(block);
        Self::inv_sub_bytes(block);
        Self::add_round_key(block, &self.round_keys[0]);
    }

    /// CBC-encrypt with PKCS#7 padding; returns ciphertext (len multiple of 16).
    pub fn cbc_encrypt(&self, iv: &[u8; 16], plaintext: &[u8]) -> Vec<u8> {
        let pad = 16 - (plaintext.len() % 16);
        let mut data = Vec::with_capacity(plaintext.len() + pad);
        data.extend_from_slice(plaintext);
        data.extend(std::iter::repeat(pad as u8).take(pad));

        let mut prev = *iv;
        for chunk in data.chunks_mut(16) {
            let block: &mut [u8; 16] = chunk.try_into().unwrap();
            for i in 0..16 {
                block[i] ^= prev[i];
            }
            self.encrypt_block(block);
            prev = *block;
        }
        data
    }

    /// CBC-decrypt and strip PKCS#7 padding. Returns None on malformed
    /// input (bad length or invalid padding).
    pub fn cbc_decrypt(&self, iv: &[u8; 16], ciphertext: &[u8]) -> Option<Vec<u8>> {
        if ciphertext.is_empty() || ciphertext.len() % 16 != 0 {
            return None;
        }
        let mut out = ciphertext.to_vec();
        let mut prev = *iv;
        for chunk in out.chunks_mut(16) {
            let cipher_block: [u8; 16] = (&*chunk).try_into().unwrap();
            let block: &mut [u8; 16] = chunk.try_into().unwrap();
            self.decrypt_block(block);
            for i in 0..16 {
                block[i] ^= prev[i];
            }
            prev = cipher_block;
        }
        let pad = *out.last().unwrap() as usize;
        if pad == 0 || pad > 16 || out.len() < pad {
            return None;
        }
        if !out[out.len() - pad..].iter().all(|&b| b == pad as u8) {
            return None;
        }
        out.truncate(out.len() - pad);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn from_hex(s: &str) -> Vec<u8> {
        (0..s.len())
            .step_by(2)
            .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
            .collect()
    }

    #[test]
    fn fips197_appendix_b() {
        let key: [u8; 16] = from_hex("2b7e151628aed2a6abf7158809cf4f3c").try_into().unwrap();
        let aes = Aes128::new(&key);
        let mut block: [u8; 16] =
            from_hex("3243f6a8885a308d313198a2e0370734").try_into().unwrap();
        aes.encrypt_block(&mut block);
        assert_eq!(block.to_vec(), from_hex("3925841d02dc09fbdc118597196a0b32"));
        aes.decrypt_block(&mut block);
        assert_eq!(block.to_vec(), from_hex("3243f6a8885a308d313198a2e0370734"));
    }

    #[test]
    fn fips197_appendix_c1() {
        let key: [u8; 16] = from_hex("000102030405060708090a0b0c0d0e0f").try_into().unwrap();
        let aes = Aes128::new(&key);
        let mut block: [u8; 16] =
            from_hex("00112233445566778899aabbccddeeff").try_into().unwrap();
        aes.encrypt_block(&mut block);
        assert_eq!(block.to_vec(), from_hex("69c4e0d86a7b0430d8cdb78070b4c55a"));
    }

    #[test]
    fn nist_sp800_38a_cbc() {
        // SP 800-38A F.2.1 CBC-AES128.Encrypt, first two blocks.
        let key: [u8; 16] = from_hex("2b7e151628aed2a6abf7158809cf4f3c").try_into().unwrap();
        let iv: [u8; 16] = from_hex("000102030405060708090a0b0c0d0e0f").try_into().unwrap();
        let pt = from_hex(
            "6bc1bee22e409f96e93d7e117393172aae2d8a571e03ac9c9eb76fac45af8e51",
        );
        let aes = Aes128::new(&key);
        let ct = aes.cbc_encrypt(&iv, &pt);
        // Our CBC adds PKCS#7; the first 32 bytes must match the NIST vector.
        assert_eq!(
            ct[..32].to_vec(),
            from_hex("7649abac8119b246cee98e9b12e9197d5086cb9b507219ee95db113a917678b2")
        );
        assert_eq!(aes.cbc_decrypt(&iv, &ct).unwrap(), pt);
    }

    #[test]
    fn cbc_round_trip_all_lengths() {
        let key = [7u8; 16];
        let iv = [9u8; 16];
        let aes = Aes128::new(&key);
        for len in 0..70 {
            let pt: Vec<u8> = (0..len as u8).collect();
            let ct = aes.cbc_encrypt(&iv, &pt);
            assert_eq!(ct.len() % 16, 0);
            assert!(ct.len() > pt.len()); // padding always added
            assert_eq!(aes.cbc_decrypt(&iv, &ct).unwrap(), pt, "len={len}");
        }
    }

    #[test]
    fn cbc_rejects_malformed() {
        let aes = Aes128::new(&[1u8; 16]);
        let iv = [0u8; 16];
        assert!(aes.cbc_decrypt(&iv, &[]).is_none());
        assert!(aes.cbc_decrypt(&iv, &[0u8; 15]).is_none());
        // Corrupt padding byte.
        let ct = aes.cbc_encrypt(&iv, b"hello");
        let mut bad = ct.clone();
        let n = bad.len();
        bad[n - 1] ^= 0xff;
        // Either padding check fails or decrypts to garbage != original;
        // with overwhelming probability the padding check fails.
        if let Some(pt) = aes.cbc_decrypt(&iv, &bad) {
            assert_ne!(pt, b"hello");
        }
    }

    /// Differential: the T-table fast path must equal the textbook
    /// round sequence on random blocks and keys.
    #[test]
    fn t_tables_match_reference_rounds() {
        let mut rng = crate::util::rng::Rng::new(55);
        for _ in 0..200 {
            let mut key = [0u8; 16];
            let mut block = [0u8; 16];
            for b in key.iter_mut().chain(block.iter_mut()) {
                *b = rng.next_u64() as u8;
            }
            let aes = Aes128::new(&key);
            // Reference encryption: straightforward round functions.
            let mut reference = block;
            Aes128::add_round_key(&mut reference, &aes.round_keys[0]);
            for r in 1..10 {
                Aes128::sub_bytes(&mut reference);
                Aes128::shift_rows(&mut reference);
                Aes128::mix_columns(&mut reference);
                Aes128::add_round_key(&mut reference, &aes.round_keys[r]);
            }
            Aes128::sub_bytes(&mut reference);
            Aes128::shift_rows(&mut reference);
            Aes128::add_round_key(&mut reference, &aes.round_keys[10]);

            let mut fast = block;
            aes.encrypt_block(&mut fast);
            assert_eq!(fast, reference);
            // And decryption inverts it.
            aes.decrypt_block(&mut fast);
            assert_eq!(fast, block);
        }
    }

    #[test]
    fn different_iv_different_ciphertext() {
        let aes = Aes128::new(&[3u8; 16]);
        let a = aes.cbc_encrypt(&[0u8; 16], b"same plaintext bytes");
        let b = aes.cbc_encrypt(&[1u8; 16], b"same plaintext bytes");
        assert_ne!(a, b);
    }
}

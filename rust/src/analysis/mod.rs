//! `memtrade lint`: a zero-dependency static analysis pass over this
//! repository's own sources, enforcing the invariants the rest of the
//! crate is built on (see DESIGN.md "Invariants & static analysis").
//!
//! The pass is a hand-rolled comment/string-stripping tokenizer
//! ([`tokens`]) plus a rule engine ([`rules`]) — no syn, no rustc
//! internals, because the crate is offline and dependency-free by
//! construction. Eight rules run over `src/**` (plus `tests/**` /
//! `benches/**` where noted):
//!
//! 1. **wire-tags** — every `TAG_*`/`METRIC_*`/`EVENT_*` constant in
//!    `net/wire.rs` + `net/control.rs` must be collision-free within
//!    its namespace *and* match the committed manifest
//!    (`src/analysis/wire_tags.txt`), so a protocol bump that reuses a
//!    tag value fails CI naming both frames.
//! 2. **decode-bounds** — decode paths may not grow a collection by a
//!    declared count before bounding it (`MAX_*` cap or remaining
//!    frame bytes).
//! 3. **clock** — `Instant::now`/`SystemTime::now` only in allowlisted
//!    files; lease/replication/codec code takes time as a value.
//! 4. **lock-order** — no second `lock_shard` while a `ShardGuard` is
//!    live, outside ascending-index acquisition loops.
//! 5. **no-alloc** — `// lint: no-alloc` marked hot paths may not
//!    allocate per call.
//! 6. **safety** — every `unsafe` needs an adjacent `// SAFETY:`.
//! 7. **protocol-doc** — `PROTOCOL.md` (the written wire spec at the
//!    repository root) must document every registry entry: the tag
//!    name must appear, on a line that also carries its wire value.
//!    The spec cannot drift from the protocol it describes.
//! 8. **syscall-site** — raw `extern "C"` syscall bindings only in
//!    `net/event_loop.rs`, `util/clock.rs`, `util/bench.rs` (escape
//!    hatch `// lint: allow-syscall`), so every syscall the data plane
//!    can make is declared in an auditable place and the loop's
//!    syscalls-per-op estimate counts all the calls there are.
//!
//! `tests/lint.rs` holds a passing and a failing fixture per rule plus
//! a self-check that the shipped tree is clean; the CI
//! `static-analysis` job gates on `memtrade lint`.

pub mod rules;
pub mod tokens;

use rules::WireTag;
use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

/// One finding, printed as `file:line: [rule] message`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// The result of linting a tree: findings plus how much was covered.
#[derive(Debug, Default)]
pub struct LintReport {
    pub diagnostics: Vec<Diagnostic>,
    pub files: usize,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Relative path of the committed wire-tag manifest under the crate
/// root.
pub const MANIFEST_PATH: &str = "src/analysis/wire_tags.txt";

/// File name of the written wire spec, kept at the repository root
/// (one level above the crate root `lint_tree` is pointed at).
pub const PROTOCOL_DOC: &str = "PROTOCOL.md";

// ------------------------------------------------------------ manifest

/// One `namespace name value` manifest line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub namespace: String,
    pub name: String,
    pub value: u64,
}

/// Parse the manifest text (`#` comments, blank lines allowed). A
/// malformed line becomes a diagnostic against the manifest itself.
pub fn parse_manifest(
    path: &str,
    text: &str,
    out: &mut Vec<Diagnostic>,
) -> Vec<ManifestEntry> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (ns, name, val) = (parts.next(), parts.next(), parts.next());
        let parsed = match (ns, name, val, parts.next()) {
            (Some(ns), Some(name), Some(val), None) => {
                tokens::parse_num(val).map(|value| ManifestEntry {
                    namespace: ns.to_string(),
                    name: name.to_string(),
                    value,
                })
            }
            _ => None,
        };
        match parsed {
            Some(e) => entries.push(e),
            None => out.push(Diagnostic {
                file: path.to_string(),
                line: idx as u32 + 1,
                rule: "wire-tags",
                msg: format!("malformed manifest line {raw:?} (want `namespace NAME value`)"),
            }),
        }
    }
    entries
}

/// Cross-file registry check: tags must be collision-free per namespace
/// and agree exactly with the manifest. `require_complete` is false for
/// single-file fixture runs (which cannot see the other protocol file,
/// so manifest entries may legitimately be missing from the extraction).
pub fn check_wire_registry(
    tags: &[WireTag],
    manifest: &[ManifestEntry],
    manifest_file: &str,
    require_complete: bool,
    out: &mut Vec<Diagnostic>,
) {
    // Collisions within a namespace, across both protocol files.
    for (i, a) in tags.iter().enumerate() {
        for b in &tags[i + 1..] {
            if a.namespace == b.namespace && a.value == b.value {
                out.push(Diagnostic {
                    file: b.file.clone(),
                    line: b.line,
                    rule: "wire-tags",
                    msg: format!(
                        "wire-tag collision in namespace `{}`: {} ({}:{}) and {} both \
                         use value {}",
                        a.namespace, a.name, a.file, a.line, b.name, a.value
                    ),
                });
            }
            if a.name == b.name {
                out.push(Diagnostic {
                    file: b.file.clone(),
                    line: b.line,
                    rule: "wire-tags",
                    msg: format!(
                        "duplicate wire-tag constant {} (also {}:{})",
                        b.name, a.file, a.line
                    ),
                });
            }
        }
    }
    // Source ↔ manifest agreement.
    for t in tags {
        match manifest.iter().find(|m| m.name == t.name && m.namespace == t.namespace) {
            None => out.push(Diagnostic {
                file: t.file.clone(),
                line: t.line,
                rule: "wire-tags",
                msg: format!(
                    "{} = {} is not in the committed registry — add `{} {} {}` to {}",
                    t.name, t.value, t.namespace, t.name, t.value, MANIFEST_PATH
                ),
            }),
            Some(m) if m.value != t.value => out.push(Diagnostic {
                file: t.file.clone(),
                line: t.line,
                rule: "wire-tags",
                msg: format!(
                    "{} = {} disagrees with the registry ({} = {}): tag values are wire \
                     ABI and may never be renumbered",
                    t.name, t.value, m.name, m.value
                ),
            }),
            _ => {}
        }
    }
    if require_complete {
        for m in manifest {
            if !tags.iter().any(|t| t.name == m.name && t.namespace == m.namespace) {
                out.push(Diagnostic {
                    file: manifest_file.to_string(),
                    line: 0,
                    rule: "wire-tags",
                    msg: format!(
                        "stale registry entry `{} {} {}`: constant no longer in the \
                         protocol sources",
                        m.namespace, m.name, m.value
                    ),
                });
            }
        }
    }
}

/// Rule 7 (protocol-doc): the written spec must document every registry
/// entry. For each manifest tag, the first spec line naming it must also
/// carry its decimal wire value — so renumbering a tag without fixing
/// the doc (or documenting a tag that was never registered the other
/// way around via the wire-tags rule) fails the lint. Pure over the doc
/// text so fixture tests can drive it directly.
pub fn check_protocol_doc(doc: &str, manifest: &[ManifestEntry], out: &mut Vec<Diagnostic>) {
    for m in manifest {
        let named = doc
            .lines()
            .enumerate()
            .find(|(_, line)| doc_words(line).any(|w| w == m.name));
        match named {
            None => out.push(Diagnostic {
                file: PROTOCOL_DOC.to_string(),
                line: 0,
                rule: "protocol-doc",
                msg: format!(
                    "spec never mentions `{}` (namespace `{}`, value {}) — PROTOCOL.md \
                     must enumerate every registered tag",
                    m.name, m.namespace, m.value
                ),
            }),
            Some((idx, line)) => {
                let value = m.value.to_string();
                if !doc_words(line).any(|w| w == value) {
                    out.push(Diagnostic {
                        file: PROTOCOL_DOC.to_string(),
                        line: idx as u32 + 1,
                        rule: "protocol-doc",
                        msg: format!(
                            "spec names `{}` without its wire value {} on that line — \
                             the doc and the registry must agree",
                            m.name, m.value
                        ),
                    });
                }
            }
        }
    }
}

/// Identifier-ish words of a spec line (`TAG_GET`, `64`, ...): split on
/// everything that is not `[A-Za-z0-9_]`, so `| TAG_GET | 1 |` yields
/// exact tokens and value `1` cannot false-match inside `11`.
fn doc_words(line: &str) -> impl Iterator<Item = &str> {
    line.split(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
        .filter(|w| !w.is_empty())
}

// ------------------------------------------------------------- driving

/// Lint one file's source text. `manifest` (if given, and if `path` is
/// a protocol file) enables the single-file wire-tag check — this is
/// the fixture-test entry point; whole-tree runs use [`lint_tree`].
pub fn lint_source(path: &str, src: &str, manifest: Option<&str>) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let lexed = tokens::lex(src);
    run_file_rules(path, &lexed, &mut out);
    if let Some(m) = manifest {
        if rules::is_protocol_file(path) {
            let tags = rules::extract_wire_tags(path, &lexed);
            let entries = parse_manifest("wire_tags.txt", m, &mut out);
            check_wire_registry(&tags, &entries, "wire_tags.txt", false, &mut out);
        }
    }
    sort(&mut out);
    out
}

fn run_file_rules(path: &str, lexed: &tokens::Lexed, out: &mut Vec<Diagnostic>) {
    let fns = rules::index_fns(lexed);
    rules::check_unsafe(path, lexed, out);
    rules::check_syscall_site(path, lexed, out);
    rules::check_no_alloc(path, lexed, &fns, out);
    rules::check_lock_order(path, lexed, &fns, out);
    if !rules::in_test_tree(path) {
        rules::check_clocks(path, lexed, out);
        rules::check_decode_bounds(path, lexed, &fns, out);
    }
}

/// Walk `root` (a crate root: the directory holding `src/`) and run
/// every rule, including the registry check against the committed
/// manifest. Paths in diagnostics are relative to `root`.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        collect_rs(&root.join(sub), &mut files)?;
    }
    files.sort();
    if files.is_empty() {
        return Err(io::Error::new(
            io::ErrorKind::NotFound,
            format!("no .rs files under {} — not a crate root?", root.display()),
        ));
    }

    let mut report = LintReport::default();
    let mut tags: Vec<WireTag> = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(f)?;
        let lexed = tokens::lex(&src);
        run_file_rules(&rel, &lexed, &mut report.diagnostics);
        if rules::is_protocol_file(&rel) {
            tags.extend(rules::extract_wire_tags(&rel, &lexed));
        }
        report.files += 1;
    }

    let manifest_path = root.join(MANIFEST_PATH);
    match std::fs::read_to_string(&manifest_path) {
        Ok(text) => {
            let entries = parse_manifest(MANIFEST_PATH, &text, &mut report.diagnostics);
            check_wire_registry(&tags, &entries, MANIFEST_PATH, true, &mut report.diagnostics);
            // The human-readable spec lives at the repository root, one
            // level above the crate root (fall back to the crate root
            // for relocated trees), and is held to the same registry.
            let doc_path = match root.parent() {
                Some(p) if p.join(PROTOCOL_DOC).exists() => p.join(PROTOCOL_DOC),
                _ => root.join(PROTOCOL_DOC),
            };
            match std::fs::read_to_string(&doc_path) {
                Ok(doc) => check_protocol_doc(&doc, &entries, &mut report.diagnostics),
                Err(_) => report.diagnostics.push(Diagnostic {
                    file: PROTOCOL_DOC.to_string(),
                    line: 0,
                    rule: "protocol-doc",
                    msg: "missing PROTOCOL.md — the written wire spec is part of the \
                          protocol ABI and must ship with the tree"
                        .to_string(),
                }),
            }
        }
        Err(_) => report.diagnostics.push(Diagnostic {
            file: MANIFEST_PATH.to_string(),
            line: 0,
            rule: "wire-tags",
            msg: "missing wire-tag registry (the committed manifest is part of the \
                  protocol ABI)"
                .to_string(),
        }),
    }

    sort(&mut report.diagnostics);
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return Ok(()); // missing subtree (e.g. no benches/) is fine
    };
    for e in entries {
        let e = e?;
        let p = e.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn sort(diags: &mut [Diagnostic]) {
    diags.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = "\
# comment
frame TAG_GET 1
frame TAG_PUT 2
";

    #[test]
    fn manifest_parses_and_flags_malformed_lines() {
        let mut out = Vec::new();
        let entries = parse_manifest("m", "frame TAG_X 4 # ok\nbogus\n", &mut out);
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].value, 4);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn reused_tag_value_names_both_frames() {
        let src = "pub const TAG_GET: u8 = 1;\npub const TAG_PUT: u8 = 1;";
        let diags = lint_source("src/net/wire.rs", src, Some(MANIFEST));
        let collision = diags
            .iter()
            .find(|d| d.msg.contains("collision"))
            .expect("collision reported");
        assert!(collision.msg.contains("TAG_GET") && collision.msg.contains("TAG_PUT"));
        // TAG_PUT = 1 also disagrees with the registry's TAG_PUT = 2.
        assert!(diags.iter().any(|d| d.msg.contains("never be renumbered")));
    }

    #[test]
    fn registered_tags_are_clean_and_new_tags_must_register() {
        let ok = "pub const TAG_GET: u8 = 1;\npub const TAG_PUT: u8 = 2;";
        assert!(lint_source("src/net/wire.rs", ok, Some(MANIFEST)).is_empty());
        let new = "pub const TAG_GET: u8 = 1;\npub const TAG_NEW: u8 = 9;";
        let diags = lint_source("src/net/wire.rs", new, Some(MANIFEST));
        assert_eq!(diags.len(), 1);
        assert!(diags[0].msg.contains("add `frame TAG_NEW 9`"), "{}", diags[0].msg);
    }

    #[test]
    fn protocol_doc_check_requires_name_and_value_together() {
        let entries =
            parse_manifest("m", "frame TAG_GET 1\nframe TAG_PUT 2\n", &mut Vec::new());
        let good = "| `TAG_GET` | 1 | read |\n| `TAG_PUT` | 2 | write |\n";
        let mut out = Vec::new();
        check_protocol_doc(good, &entries, &mut out);
        assert!(out.is_empty(), "{out:?}");

        // One tag never mentioned, one mentioned without its value —
        // and `11` in prose must not satisfy TAG_GET's value 1.
        let bad = "`TAG_GET` is documented in section 11, valuelessly.\n";
        out.clear();
        check_protocol_doc(bad, &entries, &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().any(|d| d.msg.contains("without its wire value 1")));
        assert!(out.iter().any(|d| d.msg.contains("never mentions `TAG_PUT`")));
        assert!(out.iter().all(|d| d.rule == "protocol-doc"));
    }

    #[test]
    fn diagnostics_format_as_file_line_rule() {
        let d = Diagnostic {
            file: "src/x.rs".into(),
            line: 7,
            rule: "clock",
            msg: "nope".into(),
        };
        assert_eq!(d.to_string(), "src/x.rs:7: [clock] nope");
    }
}

//! A comment/string-stripping Rust tokenizer — just enough lexer for
//! the invariant rules in [`crate::analysis::rules`].
//!
//! This is not a compiler front end: it produces a flat stream of
//! identifiers, numbers, and single-character punctuation with line
//! numbers, discarding the *content* of comments, string/char literals,
//! and raw strings so rule patterns can never match inside them. The
//! one thing it keeps from the discarded text is the set of structured
//! marker comments (`// SAFETY: ...`, `// lint: ...`) the rules key on.
//!
//! Handled literal forms: `// ...`, nested `/* ... */`, `"..."` with
//! escapes, `b"..."`, `r"..."` / `r#"..."#` (any hash depth, also
//! `br`-prefixed), `'c'` / `b'c'` char literals (escape-aware), and
//! lifetimes (`'a` is *not* a char literal). Numeric literals keep
//! their spelling (`0x50`, `1_000`) so the wire-tag rule can parse
//! values.

/// What a token is; rules mostly match on [`Tok::text`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unsafe`, `lock_shard`, ...).
    Ident,
    /// Numeric literal, spelling preserved (`64`, `0x50`, `1_000`).
    Num,
    /// One punctuation character (`::` arrives as two `:` tokens).
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A marker comment the rules care about, with the line it starts on.
/// `text` is the comment body after `//` (or inside `/* */`), trimmed.
#[derive(Clone, Debug)]
pub struct Marker {
    pub line: u32,
    pub text: String,
}

impl Marker {
    /// `// SAFETY: ...` (any leading `//!`/`///` doc sigils included).
    pub fn is_safety(&self) -> bool {
        self.text.starts_with("SAFETY:")
    }

    /// `// lint: <directive>` — returns the directive text.
    pub fn lint_directive(&self) -> Option<&str> {
        self.text.strip_prefix("lint:").map(str::trim)
    }
}

/// The output of [`lex`]: the token stream plus the marker comments.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub markers: Vec<Marker>,
}

fn keep_marker(markers: &mut Vec<Marker>, line: u32, body: &str) {
    // Doc-comment sigils (`/// SAFETY:` etc.) are stripped before the
    // prefix test so the marker syntax works in any comment flavor.
    let body = body.trim_start_matches(['/', '!']).trim();
    if body.starts_with("SAFETY:") || body.starts_with("lint:") {
        markers.push(Marker { line, text: body.to_string() });
    }
}

/// Lex `src` into tokens + markers. Never fails: unterminated literals
/// simply consume to end-of-file (the real compiler will reject such a
/// file anyway; the linter only needs to not panic on it).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut out = Lexed::default();

    // Count newlines inside the skipped range [from, to).
    fn lines_in(b: &[u8], from: usize, to: usize) -> u32 {
        b[from..to.min(b.len())].iter().filter(|&&c| c == b'\n').count() as u32
    }

    // Skip a quoted run starting at the opening quote; returns the index
    // just past the closing quote. Escape-aware.
    fn skip_quoted(b: &[u8], mut i: usize) -> usize {
        debug_assert_eq!(b[i], b'"');
        i += 1;
        while i < b.len() {
            match b[i] {
                b'\\' => i += 2,
                b'"' => return i + 1,
                _ => i += 1,
            }
        }
        i
    }

    // Raw string at `i` (pointing at `r`): `r"…"`, `r#"…"#`, any hash
    // depth. Returns Some(end) or None if this is not a raw string
    // (e.g. a raw identifier `r#match`).
    fn skip_raw_string(b: &[u8], i: usize) -> Option<usize> {
        let mut j = i + 1;
        let mut hashes = 0usize;
        while j < b.len() && b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j >= b.len() || b[j] != b'"' {
            return None;
        }
        j += 1;
        while j < b.len() {
            if b[j] == b'"' {
                let mut k = j + 1;
                let mut seen = 0usize;
                while seen < hashes && k < b.len() && b[k] == b'#' {
                    seen += 1;
                    k += 1;
                }
                if seen == hashes {
                    return Some(k);
                }
            }
            j += 1;
        }
        Some(j)
    }

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                keep_marker(&mut out.markers, line, src[start..j].trim());
                i = j;
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                // Nested block comments, as in real Rust.
                let start = i + 2;
                let mut depth = 1usize;
                let mut j = start;
                while j < b.len() && depth > 0 {
                    if j + 1 < b.len() && b[j] == b'/' && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if j + 1 < b.len() && b[j] == b'*' && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                let body_end = j.saturating_sub(2).max(start);
                keep_marker(&mut out.markers, line, src[start..body_end].trim());
                line += lines_in(b, i, j);
                i = j;
            }
            b'"' => {
                let j = skip_quoted(b, i);
                line += lines_in(b, i, j);
                i = j;
            }
            b'\'' => {
                // Char literal vs lifetime. `'\…'` and `'x'` are chars;
                // `'a` (no closing quote after one char) is a lifetime.
                if i + 1 < b.len() && b[i + 1] == b'\\' {
                    let mut j = i + 2;
                    while j < b.len() && b[j] != b'\'' {
                        j += if b[j] == b'\\' { 2 } else { 1 };
                    }
                    i = (j + 1).min(b.len());
                } else {
                    // One UTF-8 scalar after the quote.
                    let rest = &src[i + 1..];
                    let w = rest.chars().next().map_or(1, char::len_utf8);
                    if i + 1 + w < b.len() && b[i + 1 + w] == b'\'' {
                        i += w + 2; // char literal
                    } else {
                        i += 1; // lifetime: drop the quote, lex the ident
                    }
                }
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Num,
                    text: src[start..i].to_string(),
                    line,
                });
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                // Raw-string / byte-literal prefixes first: `r"`, `r#"`,
                // `b"`, `br"`, `b'`.
                if c == b'r' || c == b'b' {
                    let rpos = if c == b'b' && i + 1 < b.len() && b[i + 1] == b'r' {
                        Some(i + 1)
                    } else if c == b'r' {
                        Some(i)
                    } else {
                        None
                    };
                    if let Some(rp) = rpos {
                        if let Some(j) = skip_raw_string(b, rp) {
                            line += lines_in(b, i, j);
                            i = j;
                            continue;
                        }
                    }
                    if c == b'b' && i + 1 < b.len() && b[i + 1] == b'"' {
                        let j = skip_quoted(b, i + 1);
                        line += lines_in(b, i, j);
                        i = j;
                        continue;
                    }
                    if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
                        let mut j = i + 2;
                        while j < b.len() && b[j] != b'\'' {
                            j += if b[j] == b'\\' { 2 } else { 1 };
                        }
                        i = (j + 1).min(b.len());
                        continue;
                    }
                }
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: src[start..i].to_string(),
                    line,
                });
            }
            _ => {
                out.toks.push(Tok {
                    kind: TokKind::Punct,
                    text: (c as char).to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    out
}

/// Parse a numeric literal spelling (`64`, `0x50`, `1_000`) to u64.
pub fn parse_num(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        t.parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = r##"
            // Instant::now in a comment is invisible
            /* and /* nested */ too: lock_shard */
            let s = "Instant::now inside a string";
            let r = r#"raw "with quotes" and lock_shard"#;
            let by = b"bytes with unsafe";
            call();
        "##;
        let t = texts(src);
        assert!(!t.contains(&"Instant".to_string()), "{t:?}");
        assert!(!t.contains(&"lock_shard".to_string()), "{t:?}");
        assert!(!t.contains(&"unsafe".to_string()), "{t:?}");
        assert!(t.contains(&"call".to_string()));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        // 'a' is a char (stripped); 'a in a generic is a lifetime and
        // the following identifier must still be lexed.
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; g(x) }";
        let t = texts(src);
        assert!(t.contains(&"a".to_string()), "lifetime ident lost: {t:?}");
        assert!(!t.contains(&"x'".to_string()));
        assert!(t.contains(&"g".to_string()));
    }

    #[test]
    fn markers_are_collected_with_lines() {
        let src = "\n// SAFETY: delegation only\nunsafe { x() }\n// lint: no-alloc\nfn f() {}\n";
        let lexed = lex(src);
        assert_eq!(lexed.markers.len(), 2);
        assert!(lexed.markers[0].is_safety());
        assert_eq!(lexed.markers[0].line, 2);
        assert_eq!(lexed.markers[1].lint_directive(), Some("no-alloc"));
        assert_eq!(lexed.markers[1].line, 4);
    }

    #[test]
    fn numbers_keep_spelling_and_parse() {
        let lexed = lex("const TAG_X: u8 = 0x50; const Y: u64 = 1_000;");
        let nums: Vec<&str> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        // `u8`/`u64` lex as identifiers, not numbers.
        assert_eq!(nums, ["0x50", "1_000"]);
        assert_eq!(parse_num("0x50"), Some(80));
        assert_eq!(parse_num("1_000"), Some(1000));
    }

    #[test]
    fn line_numbers_survive_multiline_literals() {
        let src = "let a = \"one\ntwo\nthree\";\nmark();";
        let lexed = lex(src);
        let mark = lexed.toks.iter().find(|t| t.text == "mark").unwrap();
        assert_eq!(mark.line, 4);
    }
}

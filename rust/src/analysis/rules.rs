//! The seven invariant rules `memtrade lint` enforces, over the token
//! stream produced by [`crate::analysis::tokens`]. Each rule is a pure
//! function from one lexed file to diagnostics; the cross-file wire-tag
//! registry check lives in [`crate::analysis`] because it needs every
//! file's extraction plus the committed manifest.
//!
//! Rules are deliberately syntactic and conservative: they match the
//! idioms this codebase actually uses (see DESIGN.md "Invariants &
//! static analysis") and escape hatches are explicit marker comments,
//! never silent heuristics.

use super::tokens::{parse_num, Lexed, Tok, TokKind};
use super::Diagnostic;

/// Files allowed to read the monotonic wall clock (`Instant::now`).
/// Daemon loops, drivers, and instrumentation own real time; protocol
/// codecs, the lease state machine, replication events, and placement
/// logic must have time passed in (that is what makes them replayable
/// and simulator-drivable). Matched as a `/`-normalized path suffix.
pub const INSTANT_ALLOWLIST: &[&str] = &[
    "src/consumer/client.rs",
    "src/figures/consumer_eval.rs",
    "src/kv/sharded.rs",
    "src/main.rs",
    "src/market/broker_server.rs",
    "src/market/chaos.rs",
    "src/market/producer_agent.rs",
    "src/market/remote_pool.rs",
    "src/market/stats_server.rs",
    "src/net/tcp.rs",
    "src/trace/mod.rs",
    "src/util/bench.rs",
    "src/util/clock.rs",
];

/// Files allowed to read the calendar clock (`SystemTime::now`). Much
/// tighter than [`INSTANT_ALLOWLIST`]: calendar time only enters the
/// system through the `util::clock` shims (plus the RNG's seed
/// fallback), so everything downstream takes it as a value.
pub const SYSTEMTIME_ALLOWLIST: &[&str] = &["src/util/clock.rs", "src/util/rng.rs"];

/// Files allowed to declare raw `extern "C"` syscall bindings. Keeping
/// every syscall site in three audited files is what makes the
/// syscalls-per-op accounting honest: the loop counts the calls it
/// owns, and this rule is what guarantees it owns all of them.
pub const SYSCALL_ALLOWLIST: &[&str] = &[
    "src/net/event_loop.rs",
    "src/util/bench.rs",
    "src/util/clock.rs",
];

/// Identifier/macro calls banned inside `// lint: no-alloc` functions.
/// `extend_from_slice`/`push` into caller-owned buffers are allowed
/// (amortized, no fresh allocation per op); anything that creates a new
/// heap object per call is not.
const NO_ALLOC_BANNED_CALLS: &[&str] = &[
    "to_vec",
    "to_string",
    "to_owned",
    "with_capacity",
    "collect",
    "clone",
];

/// `Type::method` pairs banned inside `// lint: no-alloc` functions.
const NO_ALLOC_BANNED_PATHS: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("String", "new"),
    ("String", "from"),
    ("Box", "new"),
];

fn norm(path: &str) -> String {
    path.replace('\\', "/")
}

/// Is this file inside the test/bench tree (walked for `unsafe` and
/// marker rules, exempt from the clock rule)?
pub fn in_test_tree(path: &str) -> bool {
    let p = norm(path);
    p.contains("/tests/") || p.contains("/benches/") || p.starts_with("tests/")
        || p.starts_with("benches/")
}

fn allowlisted(path: &str, list: &[&str]) -> bool {
    let p = norm(path);
    list.iter().any(|s| p.ends_with(s))
}

fn is_seq(toks: &[Tok], at: usize, pat: &[&str]) -> bool {
    pat.iter()
        .enumerate()
        .all(|(k, want)| toks.get(at + k).is_some_and(|t| t.text == *want))
}

/// Is there a `lint: <directive>` marker on `line` or the line above?
fn marker_on(lexed: &Lexed, line: u32, directive: &str) -> bool {
    lexed.markers.iter().any(|m| {
        (m.line == line || m.line + 1 == line) && m.lint_directive() == Some(directive)
    })
}

// ------------------------------------------------------------ fn index

/// One `fn` item: its name, declaration line, body token range, and
/// whether a `// lint: no-alloc` marker is attached to it.
pub struct FnSpan {
    pub name: String,
    pub line: u32,
    /// Token indices of the body, `{` inclusive to `}` inclusive.
    pub body: std::ops::Range<usize>,
    pub no_alloc: bool,
}

/// Index every `fn` item (including nested ones) in the token stream.
pub fn index_fns(lexed: &Lexed) -> Vec<FnSpan> {
    let toks = &lexed.toks;
    let mut out = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        let is_fn = toks[i].kind == TokKind::Ident
            && toks[i].text == "fn"
            && toks.get(i + 1).is_some_and(|t| t.kind == TokKind::Ident);
        if !is_fn {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let line = toks[i].line;
        // The body is the first `{` at bracket depth 0 after the
        // signature; a `;` first means a bodyless trait method.
        let mut j = i + 2;
        let mut depth = 0i32;
        let mut open = None;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    open = Some(j);
                    break;
                }
                ";" if depth == 0 => break,
                _ => {}
            }
            j += 1;
        }
        if let Some(open) = open {
            let mut braces = 0i32;
            let mut k = open;
            while k < toks.len() {
                match toks[k].text.as_str() {
                    "{" => braces += 1,
                    "}" => {
                        braces -= 1;
                        if braces == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            out.push(FnSpan { name, line, body: open..(k + 1).min(toks.len()), no_alloc: false });
        }
        i += 2;
    }
    for m in &lexed.markers {
        if m.lint_directive() == Some("no-alloc") {
            // The marker binds to the nearest fn declared on or just
            // below it (doc comments and attributes may intervene).
            if let Some(f) = out
                .iter_mut()
                .filter(|f| f.line >= m.line && f.line <= m.line + 8)
                .min_by_key(|f| f.line)
            {
                f.no_alloc = true;
            }
        }
    }
    out
}

// -------------------------------------------------------- rule: clock

/// Rule 3: `Instant::now` / `SystemTime::now` outside the allowlists.
pub fn check_clocks(path: &str, lexed: &Lexed, out: &mut Vec<Diagnostic>) {
    if in_test_tree(path) {
        return;
    }
    let toks = &lexed.toks;
    for (clock, list) in [
        ("Instant", INSTANT_ALLOWLIST),
        ("SystemTime", SYSTEMTIME_ALLOWLIST),
    ] {
        if allowlisted(path, list) {
            continue;
        }
        for i in 0..toks.len() {
            if is_seq(toks, i, &[clock, ":", ":", "now"])
                && !marker_on(lexed, toks[i].line, "allow-clock")
            {
                out.push(Diagnostic {
                    file: path.to_string(),
                    line: toks[i].line,
                    rule: "clock",
                    msg: format!(
                        "{clock}::now outside the clock allowlist — lease/replication/codec \
                         code must take time as a value (use the util::clock shims from an \
                         allowlisted daemon, or `// lint: allow-clock` with a justification)"
                    ),
                });
            }
        }
    }
}

// ------------------------------------------------------- rule: safety

/// Rule 6: every `unsafe` token needs a `// SAFETY:` comment on the
/// same line or within the three lines above it.
pub fn check_unsafe(path: &str, lexed: &Lexed, out: &mut Vec<Diagnostic>) {
    for t in lexed.toks.iter().filter(|t| t.text == "unsafe") {
        let justified = lexed
            .markers
            .iter()
            .any(|m| m.is_safety() && m.line <= t.line && m.line + 3 >= t.line);
        if !justified {
            out.push(Diagnostic {
                file: path.to_string(),
                line: t.line,
                rule: "safety",
                msg: "`unsafe` without an adjacent `// SAFETY:` justification".to_string(),
            });
        }
    }
}

// ------------------------------------------------- rule: syscall-site

/// Rule 8: raw `extern` blocks (libc/syscall bindings) only in the
/// audited [`SYSCALL_ALLOWLIST`] files, escape hatch
/// `// lint: allow-syscall`. The lexer discards string literals, so
/// `extern "C" { ... }` arrives as a bare `extern` ident token — which
/// also catches `extern fn` types and `extern crate` (this crate is
/// zero-dependency; none of those belong outside the allowlist either).
pub fn check_syscall_site(path: &str, lexed: &Lexed, out: &mut Vec<Diagnostic>) {
    if allowlisted(path, SYSCALL_ALLOWLIST) {
        return;
    }
    for t in lexed.toks.iter().filter(|t| t.kind == TokKind::Ident && t.text == "extern") {
        if marker_on(lexed, t.line, "allow-syscall") {
            continue;
        }
        out.push(Diagnostic {
            file: path.to_string(),
            line: t.line,
            rule: "syscall-site",
            msg: "raw `extern` binding outside the syscall allowlist \
                  (net/event_loop.rs, util/clock.rs, util/bench.rs) — route the call \
                  through an audited site, or `// lint: allow-syscall` with a \
                  justification"
                .to_string(),
        });
    }
}

// ----------------------------------------------------- rule: no-alloc

/// Rule 5: `// lint: no-alloc` functions may not allocate per call.
pub fn check_no_alloc(path: &str, lexed: &Lexed, fns: &[FnSpan], out: &mut Vec<Diagnostic>) {
    let toks = &lexed.toks;
    for f in fns.iter().filter(|f| f.no_alloc) {
        for i in f.body.clone() {
            let t = &toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let next = toks.get(i + 1).map(|t| t.text.as_str());
            let hit = if (t.text == "format" || t.text == "vec") && next == Some("!") {
                Some(format!("{}!", t.text))
            } else if NO_ALLOC_BANNED_CALLS.contains(&t.text.as_str()) && next == Some("(") {
                Some(format!("{}()", t.text))
            } else if NO_ALLOC_BANNED_PATHS
                .iter()
                .any(|(ty, m)| *ty == t.text && is_seq(toks, i + 1, &[":", ":", m]))
            {
                Some(format!("{}::{}", t.text, toks[i + 3].text))
            } else {
                None
            };
            if let Some(what) = hit {
                out.push(Diagnostic {
                    file: path.to_string(),
                    line: t.line,
                    rule: "no-alloc",
                    msg: format!(
                        "{what} inside `// lint: no-alloc` fn `{}` — hot paths must reuse \
                         caller-owned buffers",
                        f.name
                    ),
                });
            }
        }
    }
}

// --------------------------------------------------- rule: lock-order

/// Rule 4: no second `lock_shard` while a `ShardGuard` may be live,
/// except ascending-index loops (`(0..n).map(|i| lock_shard(i))`,
/// `.enumerate().map(...)`) or an explicit
/// `// lint: ascending-shards` marker.
pub fn check_lock_order(path: &str, lexed: &Lexed, fns: &[FnSpan], out: &mut Vec<Diagnostic>) {
    let toks = &lexed.toks;
    for f in fns {
        let mut plain_sites: Vec<usize> = Vec::new();
        for i in f.body.clone() {
            let is_call = toks[i].text == "lock_shard"
                && toks.get(i + 1).is_some_and(|t| t.text == "(")
                && toks.get(i.wrapping_sub(1)).is_none_or(|t| t.text != "fn");
            if !is_call {
                continue;
            }
            let w0 = i.saturating_sub(40).max(f.body.start);
            let window = &toks[w0..i];
            let has_range = window.windows(2).any(|p| p[0].text == "." && p[1].text == ".");
            let has_map = window.iter().any(|t| t.text == "map");
            let has_enum = window.iter().any(|t| t.text == "enumerate");
            let ascending = has_enum || (has_map && has_range);
            if !ascending && !marker_on(lexed, toks[i].line, "ascending-shards") {
                plain_sites.push(i);
            }
        }
        for &i in plain_sites.iter().skip(1) {
            out.push(Diagnostic {
                file: path.to_string(),
                line: toks[i].line,
                rule: "lock-order",
                msg: format!(
                    "second lock_shard in fn `{}` while an earlier ShardGuard may be live — \
                     acquire all shards in one ascending-index pass (or mark the site \
                     `// lint: ascending-shards` if the order is provably ascending)",
                    f.name
                ),
            });
        }
    }
}

// ------------------------------------------------ rule: decode-bounds

/// Rule 2: in decode paths (`fn *decode*` / `fn take_*`), a collection
/// may only grow by a count that was bounded first — against a `MAX_*`
/// style constant or the remaining buffer length.
pub fn check_decode_bounds(path: &str, lexed: &Lexed, fns: &[FnSpan], out: &mut Vec<Diagnostic>) {
    let toks = &lexed.toks;
    for f in fns {
        if !(f.name.contains("decode") || f.name.starts_with("take_")) {
            continue;
        }
        for i in f.body.clone() {
            let grower = (toks[i].text == "with_capacity" || toks[i].text == "reserve")
                && toks.get(i + 1).is_some_and(|t| t.text == "(");
            if !grower {
                continue;
            }
            // Collect the argument tokens up to the matching `)`.
            let mut depth = 0i32;
            let mut args: Vec<&Tok> = Vec::new();
            let mut j = i + 1;
            while j < toks.len() {
                match toks[j].text.as_str() {
                    "(" => depth += 1,
                    ")" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                if depth >= 1 && !(depth == 1 && toks[j].text == "(") {
                    args.push(&toks[j]);
                }
                j += 1;
            }
            // Capacities derived from an existing collection's length
            // are already memory-bounded; uppercase idents are named
            // constants; pure literals are fine.
            let count_var = args.iter().find(|t| {
                t.kind == TokKind::Ident
                    && t.text.chars().next().is_some_and(|c| c.is_lowercase())
            });
            let Some(var) = count_var else { continue };
            if args.iter().any(|t| t.text == "len") {
                continue;
            }
            if !bounded_before(toks, f.body.start, i, &var.text) {
                out.push(Diagnostic {
                    file: path.to_string(),
                    line: toks[i].line,
                    rule: "decode-bounds",
                    msg: format!(
                        "decode path `{}` grows a collection by unchecked count `{}` — \
                         compare it against remaining frame bytes or a MAX_* cap first",
                        f.name, var.text
                    ),
                });
            }
        }
    }
}

/// Was `var` compared (`>`/`<`) against a `MAX_*`-style constant or a
/// `len` expression anywhere in the body before token `at`?
fn bounded_before(toks: &[Tok], body_start: usize, at: usize, var: &str) -> bool {
    for k in body_start..at {
        if toks[k].text != var {
            continue;
        }
        let near = &toks[k.saturating_sub(1)..(k + 4).min(toks.len())];
        let compared = near.iter().any(|t| t.text == ">" || t.text == "<");
        if !compared {
            continue;
        }
        let scope = &toks[k.saturating_sub(4)..(k + 18).min(toks.len())];
        let against_bound = scope.iter().any(|t| {
            t.text == "len"
                || (t.kind == TokKind::Ident
                    && t.text.len() >= 3
                    && t.text.chars().all(|c| c.is_uppercase() || c == '_' || c.is_numeric()))
        });
        if against_bound {
            return true;
        }
    }
    false
}

// -------------------------------------------------- wire-tag extraction

/// A `const TAG_*/METRIC_*/EVENT_*: u8 = N;` found in a protocol file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireTag {
    /// Registry namespace: `frame` (TAG_*, global across both planes),
    /// `metric`, or `event` (sub-namespaces inside STATS / replication
    /// payloads).
    pub namespace: &'static str,
    pub name: String,
    pub value: u64,
    pub file: String,
    pub line: u32,
}

/// Is this file part of the wire protocol (tag extraction applies)?
pub fn is_protocol_file(path: &str) -> bool {
    let p = norm(path);
    p.ends_with("src/net/wire.rs") || p.ends_with("src/net/control.rs")
}

fn tag_namespace(name: &str) -> Option<&'static str> {
    if name.starts_with("TAG_") {
        Some("frame")
    } else if name.starts_with("METRIC_") {
        Some("metric")
    } else if name.starts_with("EVENT_") {
        Some("event")
    } else {
        None
    }
}

/// Extract every wire-tag constant from a protocol file.
pub fn extract_wire_tags(path: &str, lexed: &Lexed) -> Vec<WireTag> {
    let toks = &lexed.toks;
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if toks[i].text != "const" {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        let Some(ns) = tag_namespace(&name_tok.text) else { continue };
        if !is_seq(toks, i + 2, &[":", "u8", "="]) {
            continue;
        }
        let Some(val_tok) = toks.get(i + 5) else { continue };
        let Some(value) = parse_num(&val_tok.text) else { continue };
        out.push(WireTag {
            namespace: ns,
            name: name_tok.text.clone(),
            value,
            file: path.to_string(),
            line: name_tok.line,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::tokens::lex;

    #[test]
    fn fn_index_finds_bodies_and_markers() {
        let src = "\
// lint: no-alloc
fn hot(x: u64) -> u64 { x + 1 }
fn cold() { let v = Vec::new(); drop(v); }
";
        let lexed = lex(src);
        let fns = index_fns(&lexed);
        assert_eq!(fns.len(), 2);
        assert!(fns[0].no_alloc && fns[0].name == "hot");
        assert!(!fns[1].no_alloc && fns[1].name == "cold");
    }

    #[test]
    fn wire_tags_extracted_with_values() {
        let src = "pub const TAG_GET: u8 = 1;\nconst METRIC_GAUGE: u8 = 0x02;\nconst OTHER: u8 = 9;\nconst TAG_NOT_U8: u16 = 3;";
        let tags = extract_wire_tags("src/net/wire.rs", &lex(src));
        assert_eq!(tags.len(), 2);
        assert_eq!(tags[0].name, "TAG_GET");
        assert_eq!(tags[0].value, 1);
        assert_eq!(tags[1].namespace, "metric");
        assert_eq!(tags[1].value, 2);
    }

    #[test]
    fn ascending_lock_patterns_pass_and_plain_pairs_fail() {
        let ok = "\
fn all(&self) { let g: Vec<_> = (0..self.n).map(|i| self.lock_shard(i)).collect(); drop(g); }
fn one(&self, k: &[u8]) { let g = self.lock_shard(self.index(k)); drop(g); }
";
        let lexed = lex(ok);
        let fns = index_fns(&lexed);
        let mut out = Vec::new();
        check_lock_order("src/kv/x.rs", &lexed, &fns, &mut out);
        assert!(out.is_empty(), "{out:?}");

        let bad = "fn two(&self) { let a = self.lock_shard(3); let b = self.lock_shard(1); drop((a, b)); }";
        let lexed = lex(bad);
        let fns = index_fns(&lexed);
        let mut out = Vec::new();
        check_lock_order("src/kv/x.rs", &lexed, &fns, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "lock-order");
    }

    #[test]
    fn decode_bounds_requires_a_check() {
        let bad = "fn decode_list(buf: &[u8]) { let n = read_u32(buf) as usize; let mut v = Vec::with_capacity(n); v.push(0); }";
        let lexed = lex(bad);
        let fns = index_fns(&lexed);
        let mut out = Vec::new();
        check_decode_bounds("src/net/wire.rs", &lexed, &fns, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");

        let ok = "fn decode_list(buf: &[u8]) { let n = read_u32(buf) as usize; if n > MAX_OPS || n > buf.len() { return; } let mut v = Vec::with_capacity(n); v.push(0); }";
        let lexed = lex(ok);
        let fns = index_fns(&lexed);
        let mut out = Vec::new();
        check_decode_bounds("src/net/wire.rs", &lexed, &fns, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn clock_rule_honors_allowlist_and_marker() {
        let src = "fn f() { let t = Instant::now(); drop(t); }";
        let mut out = Vec::new();
        check_clocks("src/market/lease.rs", &lex(src), &mut out);
        assert_eq!(out.len(), 1);
        out.clear();
        check_clocks("src/net/tcp.rs", &lex(src), &mut out);
        assert!(out.is_empty());
        let marked = "fn f() { // lint: allow-clock — explained\n let t = Instant::now(); drop(t); }";
        out.clear();
        check_clocks("src/market/lease.rs", &lex(marked), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn no_alloc_rule_flags_fresh_allocations_only_in_marked_fns() {
        let src = "\
// lint: no-alloc
fn hot(out: &mut Vec<u8>) { out.extend_from_slice(b\"x\"); let s = value.to_vec(); drop(s); }
fn cold() { let s = value.to_vec(); drop(s); }
";
        let lexed = lex(src);
        let fns = index_fns(&lexed);
        let mut out = Vec::new();
        check_no_alloc("src/metrics/hist.rs", &lexed, &fns, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].msg.contains("to_vec"));
    }

    #[test]
    fn syscall_sites_confined_to_allowlist() {
        let src = "fn f() { extern \"C\" { fn getpid() -> i32; } }";
        let mut out = Vec::new();
        check_syscall_site("src/market/lease.rs", &lex(src), &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, "syscall-site");
        out.clear();
        check_syscall_site("src/net/event_loop.rs", &lex(src), &mut out);
        check_syscall_site("src/util/bench.rs", &lex(src), &mut out);
        check_syscall_site("src/util/clock.rs", &lex(src), &mut out);
        assert!(out.is_empty(), "{out:?}");
        let marked =
            "// lint: allow-syscall — justified\nextern \"C\" { fn getpid() -> i32; }";
        out.clear();
        check_syscall_site("src/figures/x.rs", &lex(marked), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn unsafe_requires_adjacent_safety_comment() {
        let bad = "fn f() { unsafe { core(); } }";
        let mut out = Vec::new();
        check_unsafe("src/x.rs", &lex(bad), &mut out);
        assert_eq!(out.len(), 1);
        let ok = "fn f() {\n    // SAFETY: core() has no preconditions here.\n    unsafe { core(); }\n}";
        out.clear();
        check_unsafe("src/x.rs", &lex(ok), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}

//! Market simulation (paper §7.4, Fig 12 & Fig 13): N consumers whose
//! demand comes from MemCachier-style MRCs, a remote-memory supply series
//! (from the cluster-trace generator's idle memory), an exogenous spot
//! price series, and the broker's pricing engine under each strategy.

use crate::broker::pricing::{DemandInputs, PricingEngine, PricingStrategy};
use crate::broker::registry::Registry;
use crate::core::{Money, DEFAULT_SLAB_BYTES, GIB};
use crate::runtime::arima_fallback::demand_one;
use crate::util::rng::Rng;
use crate::workload::memcachier::{Mrc, MrcLibrary};
use crate::workload::spot::SpotPriceSeries;

/// One simulated consumer: an app with an MRC, a local cache sized for
/// 80% of optimal hit ratio (§7.4), and a per-hit value.
pub struct MarketConsumer {
    pub mrc: Mrc,
    pub local_bytes: u64,
    pub hit_value: f32,
    /// Gain curve above local size, one entry per slab (cached).
    gain: Vec<f32>,
}

/// Configuration for a market simulation.
pub struct MarketSimConfig {
    pub n_consumers: usize,
    pub strategy: PricingStrategy,
    pub seed: u64,
    /// Max slabs any consumer may lease per step.
    pub max_slabs: usize,
    /// Probability a leased slab is revoked early (demand discount).
    pub eviction_probability: f64,
}

impl Default for MarketSimConfig {
    fn default() -> Self {
        MarketSimConfig {
            n_consumers: 10_000,
            strategy: PricingStrategy::MaxRevenue,
            seed: 42,
            max_slabs: 64,
            eviction_probability: 0.0,
        }
    }
}

/// Per-step market outcome (one row of Fig 13).
#[derive(Clone, Debug, Default)]
pub struct MarketStep {
    pub price_per_slab_hour: f64,
    pub spot_per_slab_hour: f64,
    pub demanded_slabs: f64,
    pub supplied_slabs: f64,
    pub traded_slabs: f64,
    pub revenue: f64,
    pub utilization: f64,
    /// Mean relative hit-ratio improvement across participating consumers.
    pub rel_hit_improvement: f64,
    /// Mean consumer cost saving vs leasing spot memory for the same GB.
    pub cost_saving_vs_spot: f64,
}

/// The market simulator.
pub struct MarketSim {
    pub cfg: MarketSimConfig,
    pub consumers: Vec<MarketConsumer>,
    pub pricing: PricingEngine,
    registry: Registry,
}

impl MarketSim {
    pub fn new(cfg: MarketSimConfig, library: &MrcLibrary, initial_price: Money) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let consumers = (0..cfg.n_consumers)
            .map(|_| {
                let mrc = library.sample(&mut rng).clone();
                // Local memory serves >= 80% of the optimal hit ratio (§7.4).
                let local_bytes = mrc.size_for_relative_hit_ratio(0.8);
                // Hit value: dollars per (hit/sec·hour); spread over apps.
                let hit_value = rng.uniform(2e-7, 6e-6) as f32;
                let gain = mrc.gain_curve(local_bytes, DEFAULT_SLAB_BYTES, cfg.max_slabs + 1);
                MarketConsumer { mrc, local_bytes, hit_value, gain }
            })
            .collect();
        let pricing = PricingEngine::new(cfg.strategy, initial_price, 0.00002);
        MarketSim { cfg, consumers, pricing, registry: Registry::default() }
    }

    /// Demand inputs for the pricing engine's local search (the gain
    /// curves have fixed length DEMAND_SIZES=64+1 here; trim to 64).
    fn demand_inputs(&self) -> DemandInputs {
        let mut d = DemandInputs::default();
        for c in &self.consumers {
            let mut g = c.gain.clone();
            g.truncate(crate::runtime::engine::DEMAND_SIZES);
            // Discount by eviction probability (§7.4 realistic scenario).
            if self.cfg.eviction_probability > 0.0 {
                let f = (1.0 - self.cfg.eviction_probability) as f32;
                for v in &mut g {
                    *v *= f;
                }
            }
            d.push(g, c.hit_value);
        }
        d
    }

    /// Run one market step: adjust the price, clear demand against
    /// `supply_gb`, report the paper's Fig 13 metrics.
    pub fn step(&mut self, supply_gb: f64, spot: &SpotPriceSeries, t: usize) -> MarketStep {
        let spot_gb = spot.per_gb_hour(t);
        let slab_frac = DEFAULT_SLAB_BYTES as f64 / GIB as f64;
        let spot_slab = spot_gb.scale(slab_frac);

        self.pricing.set_demand_inputs(self.demand_inputs());
        self.pricing.adjust(&self.registry, spot_gb, DEFAULT_SLAB_BYTES);
        let price = self.pricing.current_price();

        let supply_slabs = supply_gb / slab_frac;
        let evict_f = 1.0 - self.cfg.eviction_probability;

        let mut demanded = 0f64;
        let mut hit_impr = 0f64;
        let mut hit_n = 0usize;
        let mut saving = 0f64;
        let mut saving_n = 0usize;
        let mut per_consumer: Vec<u32> = Vec::with_capacity(self.consumers.len());
        for c in &self.consumers {
            let gain: Vec<f32> =
                c.gain.iter().map(|&g| g * evict_f as f32).collect();
            let slabs = demand_one(&gain, c.hit_value, price.as_dollars());
            per_consumer.push(slabs);
            demanded += slabs as f64;
        }

        // Supply clearing: scale allocations down proportionally if the
        // market is short (the broker's partial-allocation rule).
        let fill = if demanded > supply_slabs && demanded > 0.0 {
            supply_slabs / demanded
        } else {
            1.0
        };

        let mut traded = 0f64;
        for (c, &slabs) in self.consumers.iter().zip(&per_consumer) {
            let granted = (slabs as f64 * fill).floor();
            traded += granted;
            if granted > 0.0 {
                let bytes = granted as u64 * DEFAULT_SLAB_BYTES;
                let h_before = c.mrc.hit_ratio_at(c.local_bytes);
                let h_after = c.mrc.hit_ratio_at(c.local_bytes + bytes);
                if h_before > 0.0 {
                    hit_impr += (h_after - h_before) / h_before;
                    hit_n += 1;
                }
                // Cost vs leasing the same GB at spot price.
                let ours = price.as_dollars() * granted;
                let spot_cost = spot_slab.as_dollars() * granted;
                if spot_cost > 0.0 {
                    saving += 1.0 - ours / spot_cost;
                    saving_n += 1;
                }
            }
        }

        MarketStep {
            price_per_slab_hour: price.as_dollars(),
            spot_per_slab_hour: spot_slab.as_dollars(),
            demanded_slabs: demanded,
            supplied_slabs: supply_slabs,
            traded_slabs: traded,
            revenue: price.as_dollars() * traded,
            utilization: if supply_slabs > 0.0 { traded / supply_slabs } else { 0.0 },
            rel_hit_improvement: if hit_n > 0 { hit_impr / hit_n as f64 } else { 0.0 },
            cost_saving_vs_spot: if saving_n > 0 { saving / saving_n as f64 } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(strategy: PricingStrategy, n: usize) -> MarketSim {
        let lib = MrcLibrary::paper_population(7);
        let cfg = MarketSimConfig { n_consumers: n, strategy, seed: 11, ..Default::default() };
        MarketSim::new(cfg, &lib, Money::from_dollars(0.00001))
    }

    #[test]
    fn market_clears_within_supply() {
        let mut m = sim(PricingStrategy::MaxRevenue, 500);
        let spot = SpotPriceSeries::r3_large(100, 3);
        for t in 0..20 {
            let step = m.step(100.0, &spot, t);
            assert!(step.traded_slabs <= step.supplied_slabs + 1e-9);
            assert!(step.price_per_slab_hour <= step.spot_per_slab_hour + 1e-12);
            assert!(step.utilization <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn scarce_supply_highly_utilized_and_priced_up() {
        // Revenue-optimal pricing may undersell scarce supply slightly
        // (unclamped-demand optimum), but utilization should stay high and
        // the price should settle above the abundant-supply price.
        let spot = SpotPriceSeries::r3_large(100, 3);
        let mut scarce = sim(PricingStrategy::MaxRevenue, 500);
        let mut abundant = sim(PricingStrategy::MaxRevenue, 500);
        let mut s_last = MarketStep::default();
        let mut a_last = MarketStep::default();
        for t in 0..30 {
            s_last = scarce.step(20.0, &spot, t);
            a_last = abundant.step(50_000.0, &spot, t);
        }
        assert!(s_last.utilization > 0.5, "utilization {}", s_last.utilization);
        assert!(s_last.utilization > a_last.utilization);
    }

    #[test]
    fn consumers_save_versus_spot() {
        let mut m = sim(PricingStrategy::FixedFraction, 300);
        let spot = SpotPriceSeries::r3_large(100, 5);
        let step = m.step(5000.0, &spot, 50);
        // Fixed quarter-of-spot pricing => 75% saving by construction.
        assert!((step.cost_saving_vs_spot - 0.75).abs() < 0.01);
        assert!(step.rel_hit_improvement > 0.0);
    }

    #[test]
    fn revenue_strategy_beats_fixed_on_revenue() {
        let spot = SpotPriceSeries::r3_large(300, 9);
        let mut fixed = sim(PricingStrategy::FixedFraction, 800);
        let mut maxrev = sim(PricingStrategy::MaxRevenue, 800);
        let mut rev_fixed = 0.0;
        let mut rev_max = 0.0;
        for t in 0..200 {
            rev_fixed += fixed.step(3000.0, &spot, t).revenue;
            rev_max += maxrev.step(3000.0, &spot, t).revenue;
        }
        assert!(
            rev_max >= rev_fixed * 0.95,
            "max-revenue {rev_max} much worse than fixed {rev_fixed}"
        );
    }
}

//! Simulation harnesses tying the whole system together:
//!
//! * [`market`] — the pure market simulation (pricing strategies, supply
//!   from cluster traces, MRC-driven consumers) behind Fig 12/13 and the
//!   pricing sections of §7.4.
//! * [`cluster`] — the full-stack cluster simulation (producers with
//!   harvesters + guest memory, consumers with local cache + secure
//!   remote KV + SSD miss path, the broker in the middle) behind
//!   Table 2, Fig 11 and the end-to-end example.
//! * [`replay`] — Google-trace-style replay of broker placement at scale
//!   (Fig 10, §7.2 predictor accuracy).

pub mod cluster;
pub mod market;
pub mod replay;

pub use cluster::{ClusterSim, ClusterSimConfig, ConsumerMode};
pub use market::{MarketSim, MarketSimConfig, MarketStep};
pub use replay::{ReplayConfig, ReplayResult};

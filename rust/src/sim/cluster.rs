//! Full-stack cluster simulation (paper §7.3, Table 2 & Fig 11): the
//! end-to-end composition of every layer.
//!
//! * Producers: a guest app ([`AppRunner`]) under the harvester +
//!   manager, periodically reporting usage to the broker.
//! * Consumers: YCSB over a two-tier cache — a local in-memory tier
//!   (their rightsized VM memory) plus, with Memtrade, leased remote
//!   producer stores accessed through the secure KV client with real
//!   AES/SHA sealing. Misses fall through to an SSD-resident store.
//! * Broker: availability prediction (AOT artifact or fallback),
//!   placement, pricing, lease lifecycle.
//!
//! Latency model per GET (µs): local hit = base op cost; remote hit =
//! base + VPC RTT + producer store service + crypto; miss = base + SSD
//! read (the paper's "remote requests served from SSD" baseline).

use crate::broker::placement::ConsumerRequest;
use crate::broker::predictor::AvailabilityPredictor;
use crate::broker::pricing::{PricingEngine, PricingStrategy};
use crate::broker::Broker;
use crate::core::config::MemtradeConfig;
use crate::core::{ConsumerId, Lease, Money, ProducerId, SimTime, GIB};
use crate::market::lease::{LeaseState, LeaseTable};
use crate::mem::SwapDevice;
use crate::net::model::{Locality, NetworkModel};
use crate::net::wire::{Request, Response};
use crate::producer::Producer;
use crate::util::rng::Rng;
use crate::util::stats::LatencyRecorder;
use crate::consumer::client::SecureKv;
use crate::kv::KvStore;
use crate::workload::apps::{AppKind, AppModel, AppRunner};
use crate::workload::spot::SpotPriceSeries;
use crate::workload::ycsb::{Op, YcsbWorkload};

/// Whether consumers use Memtrade, and in which security mode (Fig 11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsumerMode {
    /// No remote memory: misses go to SSD.
    NoMemtrade,
    /// Remote KV with encryption + integrity (fully secure).
    Secure,
    /// Remote KV with integrity only.
    IntegrityOnly,
    /// Remote KV with no crypto at all (upper bound).
    Plain,
}

impl ConsumerMode {
    pub fn uses_remote(self) -> bool {
        !matches!(self, ConsumerMode::NoMemtrade)
    }
    fn envelope_key(self) -> Option<[u8; 16]> {
        matches!(self, ConsumerMode::Secure).then_some([7u8; 16])
    }
    fn integrity(self) -> bool {
        matches!(self, ConsumerMode::Secure | ConsumerMode::IntegrityOnly)
    }
    /// Crypto CPU cost per operation on a value of `len` bytes (µs),
    /// calibrated to the paper's §7.3 overheads.
    fn crypto_us(self, len: usize) -> f64 {
        match self {
            ConsumerMode::NoMemtrade | ConsumerMode::Plain => 0.0,
            ConsumerMode::IntegrityOnly => 5.0 + 0.012 * len as f64,
            ConsumerMode::Secure => 10.0 + 0.035 * len as f64,
        }
    }
}

/// One simulated consumer VM.
pub struct SimConsumer {
    pub id: ConsumerId,
    workload: YcsbWorkload,
    /// Local tier: holds the hot (1-x) share of the working set.
    local: KvStore,
    /// Keys with hash below this threshold live locally (the x% split).
    remote_fraction: f64,
    secure: SecureKv,
    /// producer_index (SecureKv routing) -> (producer id, lease).
    pub leases: Vec<Lease>,
    pub lat: LatencyRecorder,
    /// Cumulative spend on (re)leases.
    pub spend: Money,
    rng: Rng,
    value_size: usize,
    /// Base op service cost, µs (local Redis work).
    base_us: f64,
    pub mode: ConsumerMode,
}

impl SimConsumer {
    fn is_local_key(&self, key: u64) -> bool {
        // Deterministic split: hot (low-rank-hashed) keys stay local.
        let mut h = key.wrapping_mul(0x9E3779B97F4A7C15);
        h ^= h >> 33;
        (h as f64 / u64::MAX as f64) >= self.remote_fraction
    }
}

/// Cluster simulation configuration.
pub struct ClusterSimConfig {
    pub n_producers: usize,
    pub n_consumers: usize,
    /// Fraction of each consumer's working set that must be remote
    /// (the paper's x ∈ {10%, 30%, 50%}).
    pub remote_fraction: f64,
    pub mode: ConsumerMode,
    /// Consumer working set keys and value size.
    pub n_keys: u64,
    pub value_size: usize,
    /// Enable the harvester on producers (off = static producers).
    pub harvest: bool,
    /// Ops simulated per consumer per epoch.
    pub ops_per_epoch: u32,
    /// Guest page size for producer memory models.
    pub page_bytes: u64,
    pub seed: u64,
    /// Use the PJRT artifacts if present.
    pub use_pjrt: bool,
}

impl Default for ClusterSimConfig {
    fn default() -> Self {
        ClusterSimConfig {
            n_producers: 8,
            n_consumers: 6,
            remote_fraction: 0.3,
            mode: ConsumerMode::Secure,
            n_keys: 40_000,
            value_size: 1024,
            harvest: true,
            ops_per_epoch: 300,
            page_bytes: 4 << 20,
            seed: 42,
            use_pjrt: false,
        }
    }
}

/// SSD miss penalty (µs): a miss reads from the consumer's SSD-resident
/// dataset (paper: "If remote memory is not available, the I/O operation
/// is performed using SSD"). Includes queueing/filesystem overheads.
const SSD_MISS_US: f64 = 4_500.0;
/// Producer-store service time (µs) per request.
const STORE_SERVICE_US: f64 = 30.0;
/// Local-tier base op cost (µs) — the paper's 0% row is ~0.62 ms average
/// under load; single-op service time is lower.
const LOCAL_BASE_US: f64 = 550.0;

/// The full cluster simulation.
pub struct ClusterSim {
    pub cfg: ClusterSimConfig,
    pub mt: MemtradeConfig,
    pub broker: Broker,
    pub producers: Vec<Producer>,
    pub consumers: Vec<SimConsumer>,
    pub net: NetworkModel,
    pub now: SimTime,
    /// Lease lifecycle book — the same state machine the networked
    /// broker daemon runs, driven here on simulated time.
    pub leases: LeaseTable,
    spot: SpotPriceSeries,
    epoch_count: u64,
}

impl ClusterSim {
    pub fn new(cfg: ClusterSimConfig) -> Self {
        let mt = MemtradeConfig::default();
        let mut rng = Rng::new(cfg.seed);

        // Producers: cycle through the six paper app kinds.
        let mut producers = Vec::with_capacity(cfg.n_producers);
        for i in 0..cfg.n_producers {
            let kind = AppKind::ALL[i % AppKind::ALL.len()];
            let model = AppModel::preset(kind);
            let app = AppRunner::new(
                model,
                cfg.page_bytes,
                SwapDevice::Ssd,
                cfg.harvest.then(|| mt.harvester.cooling_period),
                cfg.seed ^ (i as u64 + 1),
            );
            let mut p = Producer::new(
                ProducerId(i as u64 + 1),
                app,
                mt.harvester.clone(),
                mt.broker.slab_bytes,
            );
            p.app.ops_cap_per_epoch = 400;
            producers.push(p);
        }

        // Broker.
        let predictor = if cfg.use_pjrt {
            AvailabilityPredictor::auto()
        } else {
            AvailabilityPredictor::fallback(288, 12)
        };
        let pricing = PricingEngine::new(
            PricingStrategy::FixedFraction,
            Money::from_dollars(0.00001),
            mt.broker.price_step_dollars,
        );
        let mut broker = Broker::new(mt.broker.clone(), predictor, pricing);
        for p in &producers {
            broker
                .registry
                .register_producer(p.id, p.app.model.vm_bytes as f32 / GIB as f32);
        }

        // Consumers.
        let consumers = (0..cfg.n_consumers)
            .map(|i| {
                let id = ConsumerId(1000 + i as u64);
                broker.registry.register_consumer(id);
                // Local tier sized for the non-remote share of the set.
                let set_bytes =
                    cfg.n_keys as usize * (cfg.value_size + 16 + 64);
                let local_bytes =
                    ((set_bytes as f64) * (1.0 - cfg.remote_fraction) * 1.15) as usize;
                SimConsumer {
                    id,
                    workload: YcsbWorkload::paper_default(cfg.n_keys, cfg.value_size),
                    local: KvStore::new(local_bytes.max(1 << 20), cfg.seed ^ (0xC0 + i as u64)),
                    remote_fraction: cfg.remote_fraction,
                    secure: SecureKv::with_iv_seed(
                        cfg.mode.envelope_key(),
                        cfg.mode.integrity(),
                        1,
                        cfg.seed ^ (0xD0 + i as u64),
                    ),
                    leases: Vec::new(),
                    lat: LatencyRecorder::new(),
                    spend: Money::ZERO,
                    rng: rng.fork(i as u64),
                    value_size: cfg.value_size,
                    base_us: LOCAL_BASE_US,
                    mode: cfg.mode,
                }
            })
            .collect();

        ClusterSim {
            cfg,
            mt,
            broker,
            producers,
            consumers,
            net: NetworkModel::default(),
            now: SimTime::ZERO,
            leases: LeaseTable::default(),
            spot: SpotPriceSeries::r3_large(4096, 17),
            epoch_count: 0,
        }
    }

    /// Track a consumer-held lease in the lifecycle book.
    fn track_lease(leases: &mut LeaseTable, lease: &Lease) {
        let _ = leases.insert(
            lease.id.0,
            lease.consumer.0,
            lease.producer.0,
            lease.slabs,
            lease.slab_bytes,
            lease.price_per_slab_hour.0,
            lease.start.as_micros(),
            lease.duration.as_micros(),
        );
    }

    /// Warm the market: producers report history so the predictor has
    /// data, then consumers lease their remote share.
    pub fn bootstrap(&mut self) {
        // Seed 24h of usage history per producer (steady at current RSS).
        for p in &self.producers {
            let used_gb = p.app.model.footprint_bytes as f32 / GIB as f32;
            for t in 0..288u64 {
                self.broker
                    .registry
                    .report_usage(p.id, SimTime::from_secs(t * 300), used_gb);
            }
        }
        // Managers learn their pools (everything currently harvestable).
        for p in &mut self.producers {
            let shape = p.app.memory.shape();
            p.manager.set_harvestable(shape.harvestable, SimTime::ZERO);
            self.broker.registry.update_producer_resources(
                p.id,
                p.manager.free_slabs(),
                0.9,
                0.9,
            );
        }
        self.broker.predictor.refresh(&mut self.broker.registry, SimTime::ZERO);

        if !self.cfg.mode.uses_remote() {
            return;
        }
        // Each consumer leases slabs for its remote share.
        let slab = self.mt.broker.slab_bytes;
        for ci in 0..self.consumers.len() {
            let c = &self.consumers[ci];
            let set_bytes = self.cfg.n_keys as usize * (self.cfg.value_size + 80);
            let need_bytes = (set_bytes as f64 * self.cfg.remote_fraction * 1.6) as u64;
            let slabs = (need_bytes / slab).max(1) as u32;
            let req = ConsumerRequest {
                consumer: c.id,
                slabs,
                min_slabs: 1,
                lease: SimTime::from_hours(4),
                max_price_per_slab_hour: None,
                latency_us_to: Default::default(),
                weights: None,
            };
            let leases = self.broker.request_memory(self.now, req);
            for lease in leases {
                let pid = lease.producer;
                let p = self
                    .producers
                    .iter_mut()
                    .find(|p| p.id == pid)
                    .expect("lease to unknown producer");
                assert!(p.manager.grant_lease(lease.clone(), 1_250_000_000 / 8));
                Self::track_lease(&mut self.leases, &lease);
                self.consumers[ci].leases.push(lease);
            }
            let n = self.consumers[ci].leases.len() as u32;
            self.consumers[ci].secure.set_n_producers(n.max(1));
        }

        // Warm the remote tier (the paper populates YCSB stores before
        // measuring): pre-PUT every remote key. The clock advances during
        // the load so the rate limiter behaves as in a real bulk load.
        for ci in 0..self.consumers.len() {
            if self.consumers[ci].leases.is_empty() {
                continue;
            }
            let n_keys = self.cfg.n_keys;
            let value_size = self.cfg.value_size;
            let mut loaded = 0u64;
            for key in 0..n_keys {
                if self.consumers[ci].is_local_key(key) {
                    continue;
                }
                let kb = YcsbWorkload::key_bytes(key);
                let val = vec![0xAB; value_size];
                let _ = self.secure_put(ci, &kb, &val);
                loaded += 1;
                if loaded % 64 == 0 {
                    self.now += SimTime::from_millis(1);
                }
            }
        }
    }

    /// Route one secure-KV request to the producer backing lease
    /// `producer_index` of consumer `ci`. Returns (response, network µs).
    fn route(
        producers: &mut [Producer],
        consumers: &mut [SimConsumer],
        ci: usize,
        producer_index: u32,
        req: Request,
        now: SimTime,
        net: &NetworkModel,
    ) -> (Response, f64) {
        let lease = match consumers[ci].leases.get(producer_index as usize) {
            Some(l) => l.clone(),
            None => return (Response::Error("no lease".into()), 0.0),
        };
        let req_bytes = req.wire_bytes() as u64;
        let p = producers
            .iter_mut()
            .find(|p| p.id == lease.producer)
            .expect("producer exists");
        let resp = p.manager.handle(lease.consumer, &req, now);
        let resp_bytes = resp.wire_bytes() as u64;
        let net_us = net
            .round_trip(Locality::SameDatacenter, req_bytes, resp_bytes)
            .as_micros() as f64;
        (resp, net_us + STORE_SERVICE_US)
    }

    /// Run one consumer operation, returning its latency in µs.
    fn consumer_op(&mut self, ci: usize) -> f64 {
        let op = {
            let c = &mut self.consumers[ci];
            c.workload.next_op(&mut c.rng)
        };
        let key = op.key();
        let key_bytes = YcsbWorkload::key_bytes(key);
        let is_local = self.consumers[ci].is_local_key(key);
        let mode = self.consumers[ci].mode;
        let value_size = self.consumers[ci].value_size;
        let mut latency = self.consumers[ci].base_us;

        match op {
            Op::Read { .. } => {
                if is_local {
                    // Local tier: populate lazily, always resident. The
                    // presence probe uses `touch` so no value bytes are
                    // read or copied on this hot path.
                    let c = &mut self.consumers[ci];
                    if !c.local.touch(&key_bytes) {
                        let val = vec![0xAB; value_size];
                        c.local.put(&key_bytes, &val);
                    }
                } else if mode.uses_remote() && !self.consumers[ci].leases.is_empty() {
                    latency += mode.crypto_us(value_size);
                    let (hit, net_us) = self.secure_get(ci, &key_bytes);
                    latency += net_us;
                    if !hit {
                        // Fault from SSD and refill the remote tier.
                        latency += SSD_MISS_US;
                        let val = vec![0xCD; value_size];
                        let (_ok, put_net) = self.secure_put(ci, &key_bytes, &val);
                        // Refill happens asynchronously; don't charge the op.
                        let _ = put_net;
                    }
                } else {
                    latency += SSD_MISS_US;
                }
            }
            Op::Update { .. } => {
                if is_local {
                    let c = &mut self.consumers[ci];
                    let val = vec![0xEF; value_size];
                    c.local.put(&key_bytes, &val);
                } else if mode.uses_remote() && !self.consumers[ci].leases.is_empty() {
                    latency += mode.crypto_us(value_size);
                    let val = vec![0xEF; value_size];
                    let (_ok, net_us) = self.secure_put(ci, &key_bytes, &val);
                    latency += net_us;
                } else {
                    latency += SSD_MISS_US * 0.4; // write-back to SSD
                }
            }
        }
        latency
    }

    fn secure_get(&mut self, ci: usize, key: &[u8]) -> (bool, f64) {
        let mut net_us = 0.0;
        let now = self.now;
        let net = self.net.clone();
        let producers = &mut self.producers;
        let consumers = &mut self.consumers;
        // SAFETY dance: split borrows via raw pointer is avoided by
        // temporarily taking the SecureKv out of the consumer.
        let mut secure = std::mem::replace(
            &mut consumers[ci].secure,
            SecureKv::with_iv_seed(None, false, 1, 0),
        );
        let result = {
            let mut transport = |producer_index: u32, req: Request| {
                let (resp, us) =
                    Self::route(producers, consumers, ci, producer_index, req, now, &net);
                net_us += us;
                resp
            };
            secure.get(&mut transport, key)
        };
        self.consumers[ci].secure = secure;
        (result.is_some(), net_us)
    }

    fn secure_put(&mut self, ci: usize, key: &[u8], value: &[u8]) -> (bool, f64) {
        let mut net_us = 0.0;
        let now = self.now;
        let net = self.net.clone();
        let producers = &mut self.producers;
        let consumers = &mut self.consumers;
        let mut secure = std::mem::replace(
            &mut consumers[ci].secure,
            SecureKv::with_iv_seed(None, false, 1, 0),
        );
        let ok = {
            let mut transport = |producer_index: u32, req: Request| {
                let (resp, us) =
                    Self::route(producers, consumers, ci, producer_index, req, now, &net);
                net_us += us;
                resp
            };
            secure.put(&mut transport, key, value)
        };
        self.consumers[ci].secure = secure;
        (ok, net_us)
    }

    /// Advance one monitoring epoch (producers harvest, consumers serve).
    pub fn step_epoch(&mut self) {
        let epoch = self.mt.harvester.epoch;
        self.now += epoch;
        self.epoch_count += 1;

        // Producers: run guest workloads + harvester control loops.
        for pi in 0..self.producers.len() {
            let p = &mut self.producers[pi];
            p.tick(self.now, epoch);
        }

        // Consumers: serve ops.
        for ci in 0..self.consumers.len() {
            for _ in 0..self.cfg.ops_per_epoch {
                let lat = self.consumer_op(ci);
                self.consumers[ci].lat.record(lat);
            }
        }

        // Lease expiry + renewal (paper §4.2: at expiry the manager asks
        // the broker whether the consumer extends at the current market
        // price; our consumers renew while they still hold remote keys).
        // Expiry runs through the shared lease state machine; a renewal
        // is a fresh grant at the current price, as in the daemon.
        let price = self.broker.current_price();
        self.leases.sweep_expired(self.now.as_micros());
        for end in self.leases.take_ended() {
            if end.cause != LeaseState::Expired {
                continue;
            }
            let Some(ci) = self
                .consumers
                .iter()
                .position(|c| c.id.0 == end.record.consumer)
            else {
                continue;
            };
            let Some(li) = self.consumers[ci]
                .leases
                .iter()
                .position(|l| l.id.0 == end.record.id)
            else {
                continue;
            };
            let lease = self.consumers[ci].leases[li].clone();
            let renewed = Lease {
                start: self.now,
                price_per_slab_hour: price,
                ..lease.clone()
            };
            self.consumers[ci].spend += renewed.total_cost();
            Self::track_lease(&mut self.leases, &renewed);
            self.consumers[ci].leases[li] = renewed;
            self.broker.lease_ended(&lease, false);
        }

        // Market epoch every 5 minutes of sim time.
        let market_every =
            (self.mt.broker.market_epoch.as_micros() / epoch.as_micros()).max(1);
        if self.epoch_count % market_every == 0 {
            for p in &self.producers {
                let used_gb = (p.app.memory.rss_pages() as u64 * p.app.memory.page_bytes())
                    as f32
                    / GIB as f32;
                self.broker.registry.report_usage(p.id, self.now, used_gb);
                self.broker.registry.update_producer_resources(
                    p.id,
                    p.manager.free_slabs(),
                    0.9,
                    0.9,
                );
            }
            let t = (self.now.as_secs_f64() / 300.0) as usize;
            let spot = self.spot.per_gb_hour(t);
            let granted = self.broker.market_epoch(self.now, spot);
            for lease in granted {
                let pid = lease.producer;
                if let Some(p) = self.producers.iter_mut().find(|p| p.id == pid) {
                    if p.manager.grant_lease(lease.clone(), 1_250_000_000 / 8) {
                        if let Some(c) =
                            self.consumers.iter_mut().find(|c| c.id == lease.consumer)
                        {
                            Self::track_lease(&mut self.leases, &lease);
                            c.leases.push(lease);
                            let n = c.leases.len() as u32;
                            c.secure.set_n_producers(n);
                        }
                    }
                }
            }
        }
    }

    /// Run for `sim_duration`, reporting (consumer latencies, producer
    /// mean latencies).
    pub fn run(&mut self, sim_duration: SimTime) {
        let epochs = sim_duration.as_micros() / self.mt.harvester.epoch.as_micros();
        for _ in 0..epochs {
            self.step_epoch();
        }
    }

    /// Mean consumer latency (µs) across all consumers.
    pub fn consumer_mean_latency(&self) -> f64 {
        let mut rec = LatencyRecorder::new();
        for c in &self.consumers {
            rec.merge(&c.lat);
        }
        rec.mean()
    }

    pub fn consumer_p99_latency(&self) -> f64 {
        let mut rec = LatencyRecorder::new();
        for c in &self.consumers {
            rec.merge(&c.lat);
        }
        rec.p99()
    }

    /// Total bytes currently leased to consumers.
    pub fn leased_bytes(&self) -> u64 {
        self.producers.iter().map(|p| p.manager.leased_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(mode: ConsumerMode, remote: f64) -> ClusterSim {
        let cfg = ClusterSimConfig {
            n_producers: 4,
            n_consumers: 3,
            remote_fraction: remote,
            mode,
            n_keys: 5_000,
            value_size: 512,
            ops_per_epoch: 100,
            page_bytes: 16 << 20,
            seed: 7,
            ..Default::default()
        };
        let mut sim = ClusterSim::new(cfg);
        sim.bootstrap();
        sim
    }

    #[test]
    fn bootstrap_grants_leases() {
        let sim = small(ConsumerMode::Secure, 0.3);
        for c in &sim.consumers {
            assert!(!c.leases.is_empty(), "consumer {:?} got no leases", c.id);
        }
        assert!(sim.leased_bytes() > 0);
    }

    #[test]
    fn memtrade_beats_ssd_baseline() {
        let mut with = small(ConsumerMode::Secure, 0.5);
        with.run(SimTime::from_mins(5));
        let mut without = small(ConsumerMode::NoMemtrade, 0.5);
        without.run(SimTime::from_mins(5));
        let w = with.consumer_mean_latency();
        let wo = without.consumer_mean_latency();
        assert!(
            w < wo * 0.75,
            "memtrade {w:.0}µs not clearly better than ssd {wo:.0}µs"
        );
    }

    #[test]
    fn security_modes_ordered() {
        let mut secure = small(ConsumerMode::Secure, 0.5);
        secure.run(SimTime::from_mins(3));
        let mut int_only = small(ConsumerMode::IntegrityOnly, 0.5);
        int_only.run(SimTime::from_mins(3));
        let mut plain = small(ConsumerMode::Plain, 0.5);
        plain.run(SimTime::from_mins(3));
        let s = secure.consumer_mean_latency();
        let i = int_only.consumer_mean_latency();
        let p = plain.consumer_mean_latency();
        assert!(p <= i + 50.0, "plain {p} vs integrity {i}");
        assert!(i <= s + 50.0, "integrity {i} vs secure {s}");
    }

    #[test]
    fn zero_remote_fraction_stays_local() {
        let mut sim = small(ConsumerMode::Secure, 0.0);
        sim.run(SimTime::from_mins(2));
        let lat = sim.consumer_mean_latency();
        assert!(
            (lat - LOCAL_BASE_US).abs() < 100.0,
            "0% remote should be ~base: {lat}"
        );
    }
}

//! Google-trace replay of broker placement at scale (paper §7.2, Fig 10):
//! machines with high memory demand become consumers, machines with
//! medium pressure become producers; when a consumer's demand exceeds its
//! capacity it requests remote memory from the broker.

use crate::broker::placement::ConsumerRequest;
use crate::broker::predictor::AvailabilityPredictor;
use crate::broker::pricing::{PricingEngine, PricingStrategy};
use crate::broker::Broker;
use crate::core::config::BrokerConfig;
use crate::core::{ConsumerId, Money, ProducerId, SimTime, GIB};
use crate::workload::cluster_trace::{ClusterTrace, MachineClass};

/// Replay configuration (defaults = paper §7.2 setup, scaled).
pub struct ReplayConfig {
    pub n_producers: usize,
    pub n_consumers: usize,
    /// Producer machine DRAM (the paper sweeps 64-512 GB).
    pub producer_gb: f64,
    /// Consumer machine DRAM (512 GB in the paper).
    pub consumer_gb: f64,
    /// Steps to replay (5-minute steps).
    pub steps: usize,
    pub seed: u64,
    /// Use PJRT artifacts when available.
    pub use_pjrt: bool,
    /// Ablation: ignore the availability forecast during placement
    /// (grantable slabs capped only by advertised free slabs).
    pub ignore_availability_prediction: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            n_producers: 100,
            n_consumers: 200,
            producer_gb: 256.0,
            consumer_gb: 512.0,
            steps: 576, // 48 hours
            seed: 21,
            use_pjrt: false,
            ignore_availability_prediction: false,
        }
    }
}

/// Replay outcome (Fig 10 + §7.2 accuracy numbers).
#[derive(Clone, Debug, Default)]
pub struct ReplayResult {
    pub slabs_requested: u64,
    pub slabs_granted: u64,
    pub requests: u64,
    pub requests_satisfied_eventually: u64,
    /// Cluster-wide memory utilization without / with Memtrade.
    pub base_utilization: f64,
    pub memtrade_utilization: f64,
    /// §7.2: fraction of predictions over-predicting usage by >4%.
    pub overprediction_fraction: f64,
    /// Fraction of leased slabs revoked before expiry.
    pub revoked_fraction: f64,
}

/// Run the replay.
pub fn run(cfg: ReplayConfig) -> ReplayResult {
    // Producer usage = medium-pressure machines (scaled Google trace);
    // consumer demand = high-demand machines that sometimes overflow.
    let producer_trace = ClusterTrace::generate(
        MachineClass::Alibaba, // medium pressure (>=40% use)
        cfg.n_producers,
        cfg.steps,
        288,
        cfg.seed,
    );
    let consumer_trace = ClusterTrace::generate(
        MachineClass::Alibaba,
        cfg.n_consumers,
        cfg.steps,
        288,
        cfg.seed ^ 0xBEEF,
    );

    let broker_cfg = BrokerConfig::default();
    let slab_gb = broker_cfg.slab_bytes as f64 / GIB as f64;
    let predictor = if cfg.use_pjrt {
        AvailabilityPredictor::auto()
    } else {
        AvailabilityPredictor::fallback(288, 12)
    };
    let pricing = PricingEngine::new(
        PricingStrategy::FixedFraction,
        Money::from_dollars(0.00001),
        broker_cfg.price_step_dollars,
    );
    let mut broker = Broker::new(broker_cfg, predictor, pricing);

    for i in 0..cfg.n_producers {
        broker
            .registry
            .register_producer(ProducerId(i as u64 + 1), cfg.producer_gb as f32);
    }
    for i in 0..cfg.n_consumers {
        broker.registry.register_consumer(ConsumerId(10_000 + i as u64));
    }

    let mut result = ReplayResult::default();
    let mut base_used_sum = 0f64;
    let mut mem_used_sum = 0f64;
    let mut cap_sum = 0f64;
    // Active leases: (producer, consumer_idx, slabs, end_step).
    let mut leases: Vec<(ProducerId, usize, u32, usize)> = Vec::new();
    let mut revoked = 0u64;
    let mut granted_total = 0u64;

    for step in 0..cfg.steps {
        let now = SimTime::from_secs(step as u64 * 300);

        // Producers report usage; free slab pool derives from idle memory
        // with a safety reserve.
        for (i, m) in producer_trace.machines.iter().enumerate() {
            let id = ProducerId(i as u64 + 1);
            let used_gb = (m.mem[step] * cfg.producer_gb) as f32;
            broker.registry.report_usage(id, now, used_gb);
            let leased: u32 = leases
                .iter()
                .filter(|(p, _, _, end)| *p == id && *end > step)
                .map(|(_, _, s, _)| *s)
                .sum();
            let idle_gb = (cfg.producer_gb - used_gb as f64).max(0.0);
            let free = ((idle_gb * 0.9) / slab_gb) as u32;
            broker.registry.update_producer_resources(
                id,
                free.saturating_sub(leased),
                1.0 - m.cpu[step],
                1.0 - m.net[step],
            );
        }
        if step % 12 == 0 || step < 2 {
            broker.predictor.refresh(&mut broker.registry, now);
        }
        if cfg.ignore_availability_prediction {
            // Ablation: trust advertised free slabs blindly.
            for p in broker.registry.producers_mut() {
                p.predicted_safe_slabs = u32::MAX / 2;
            }
        }

        // Expire leases; check for early revocation (producer usage burst
        // ate into leased memory).
        leases.retain_mut(|(pid, _ci, slabs, end)| {
            if *end <= step {
                return false;
            }
            let i = (pid.0 - 1) as usize;
            let used = producer_trace.machines[i].mem[step] * cfg.producer_gb;
            let leased_gb = *slabs as f64 * slab_gb;
            if used + leased_gb > cfg.producer_gb {
                // Revoke enough slabs to fit.
                let over = ((used + leased_gb - cfg.producer_gb) / slab_gb).ceil() as u32;
                let cut = over.min(*slabs);
                *slabs -= cut;
                revoked += cut as u64;
                *slabs > 0
            } else {
                true
            }
        });

        // Consumers whose demand exceeds capacity request the overflow.
        for (i, m) in consumer_trace.machines.iter().enumerate() {
            // Consumers are "machines with high memory demand - often
            // exceeding the machine's capacity" (§7.2): scale up so the
            // typical consumer overflows.
            let demand_gb = m.mem[step] * cfg.consumer_gb * 2.0;
            let overflow_gb = demand_gb - cfg.consumer_gb;
            // Request only the *shortfall*: overflow not already covered
            // by active leases (consumers renew, they don't re-request).
            let held: u32 = leases
                .iter()
                .filter(|(_, ci, _, end)| *ci == i && *end > step)
                .map(|(_, _, s, _)| *s)
                .sum();
            let shortfall_gb = overflow_gb - held as f64 * slab_gb;
            if shortfall_gb >= 1.0 {
                let slabs = (shortfall_gb / slab_gb) as u32;
                let req = ConsumerRequest {
                    consumer: ConsumerId(10_000 + i as u64),
                    slabs,
                    min_slabs: (1.0 / slab_gb) as u32, // 1 GB minimum
                    lease: SimTime::from_mins(10),
                    max_price_per_slab_hour: None,
                    latency_us_to: Default::default(),
                    weights: None,
                };
                let granted = broker.request_memory(now, req);
                for lease in granted {
                    granted_total += lease.slabs as u64;
                    leases.push((lease.producer, i, lease.slabs, step + 2));
                }
            }
        }

        // Cluster-wide utilization is measured over the *producer* pool
        // (the memory Memtrade puts to work): base = producers' own
        // usage; with Memtrade, leased slabs count as used too.
        for (i, m) in producer_trace.machines.iter().enumerate() {
            let id = ProducerId(i as u64 + 1);
            let used = m.mem[step] * cfg.producer_gb;
            let leased_gb: f64 = leases
                .iter()
                .filter(|(p, _, _, end)| *p == id && *end > step)
                .map(|(_, _, s, _)| *s as f64 * slab_gb)
                .sum();
            base_used_sum += used;
            mem_used_sum += (used + leased_gb).min(cfg.producer_gb);
            cap_sum += cfg.producer_gb;
        }

        let _ = broker.market_epoch(now, Money::from_dollars(0.003));
    }

    let (checks, over) = broker.registry.prediction_accuracy();
    result.slabs_requested = broker.stats.slabs_requested;
    result.slabs_granted = broker.stats.slabs_granted;
    result.requests = broker.stats.requests;
    result.requests_satisfied_eventually =
        broker.stats.requests_fully_satisfied + broker.stats.requests_partially_satisfied;
    result.base_utilization = base_used_sum / cap_sum;
    result.memtrade_utilization = mem_used_sum / cap_sum;
    result.overprediction_fraction = if checks > 0 { over as f64 / checks as f64 } else { 0.0 };
    result.revoked_fraction = if granted_total > 0 {
        revoked as f64 / granted_total as f64
    } else {
        0.0
    };
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_produces_sensible_market() {
        let cfg = ReplayConfig {
            n_producers: 20,
            n_consumers: 40,
            steps: 60,
            ..Default::default()
        };
        let r = run(cfg);
        assert!(r.requests > 0, "no requests generated");
        assert!(r.slabs_granted > 0, "nothing granted");
        assert!(r.slabs_granted <= r.slabs_requested);
        // Memtrade must raise utilization.
        assert!(
            r.memtrade_utilization > r.base_utilization,
            "no utilization gain: {} vs {}",
            r.memtrade_utilization,
            r.base_utilization
        );
        assert!(r.revoked_fraction < 0.5);
    }

    #[test]
    fn bigger_producers_satisfy_more() {
        let small = run(ReplayConfig {
            n_producers: 10,
            n_consumers: 40,
            producer_gb: 64.0,
            steps: 40,
            ..Default::default()
        });
        let big = run(ReplayConfig {
            n_producers: 10,
            n_consumers: 40,
            producer_gb: 512.0,
            steps: 40,
            ..Default::default()
        });
        let frac = |r: &ReplayResult| r.slabs_granted as f64 / r.slabs_requested.max(1) as f64;
        assert!(
            frac(&big) >= frac(&small),
            "big {} < small {}",
            frac(&big),
            frac(&small)
        );
    }
}

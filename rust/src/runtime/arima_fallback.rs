//! Pure-Rust mirror of the L2/L1 forecast math (autocovariance →
//! Levinson-Durbin AR(p) → iterated forecast → (d,p) selection → safety
//! margin). Used (a) when artifacts are not built, (b) as the
//! differential-testing oracle for the PJRT path (runtime_artifacts
//! integration test), and (c) by pure-sim experiments that don't want a
//! PJRT dependency.

use crate::runtime::engine::ForecastResult;

pub const RIDGE: f64 = 1e-6;
pub const KAPPA_CLAMP: f64 = 0.999;
pub const SAFETY_Z: f64 = 1.64;

/// Autocovariances r_0..r_order of a centered series (biased, /n).
pub fn autocov(xc: &[f64], order: usize) -> Vec<f64> {
    let n = xc.len();
    (0..=order)
        .map(|lag| {
            let mut s = 0.0;
            for t in lag..n {
                s += xc[t] * xc[t - lag];
            }
            s / n as f64
        })
        .collect()
}

/// Levinson-Durbin; returns (phi[0..order], prediction error variance).
pub fn levinson_durbin(rs: &[f64]) -> (Vec<f64>, f64) {
    let order = rs.len() - 1;
    let r0 = rs[0] + RIDGE;
    let mut phi = vec![0.0; order];
    let mut err = r0;
    for k in 1..=order {
        let mut acc = rs[k];
        for j in 1..k {
            acc -= phi[j - 1] * rs[k - j];
        }
        let kappa = (acc / err).clamp(-KAPPA_CLAMP, KAPPA_CLAMP);
        let mut new_phi = phi.clone();
        new_phi[k - 1] = kappa;
        for j in 1..k {
            new_phi[j - 1] = phi[j - 1] - kappa * phi[k - 1 - j];
        }
        phi = new_phi;
        err *= 1.0 - kappa * kappa;
    }
    (phi, err)
}

/// AR(p) fit + H-step forecast of one series; mirrors kernels/forecast.py.
pub fn ar_forecast(x: &[f32], order: usize, horizon: usize) -> (Vec<f64>, f64) {
    let n = x.len();
    let mu = x.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
    let xc: Vec<f64> = x.iter().map(|&v| v as f64 - mu).collect();
    let rs = autocov(&xc, order);
    let (phi, err) = levinson_durbin(&rs);
    let mut window: Vec<f64> = (0..order).map(|j| xc[n - 1 - j]).collect();
    let mut out = Vec::with_capacity(horizon);
    for _ in 0..horizon {
        let f: f64 = phi.iter().zip(&window).map(|(p, w)| p * w).sum();
        out.push(f + mu);
        window.rotate_right(1);
        window[0] = f;
    }
    (out, err.max(0.0).sqrt())
}

/// Full forecast-model mirror: (d,p) selection + clipping + safety margin.
/// Matches python/compile/model.py::forecast_model for one series.
pub fn forecast_one(series: &[f32], capacity: f32, order: usize, horizon: usize) -> ForecastResult {
    // d=0 candidate.
    let (f0, s0) = ar_forecast(series, order, horizon);
    // d=1 candidate: AR on diffs, re-integrated from the last level.
    let diff: Vec<f32> = series.windows(2).map(|w| w[1] - w[0]).collect();
    let (fd, s1) = if diff.len() > order {
        ar_forecast(&diff, order, horizon)
    } else {
        (vec![0.0; horizon], f64::INFINITY)
    };
    let last = *series.last().unwrap_or(&0.0) as f64;
    let mut acc = last;
    let f1: Vec<f64> = fd
        .iter()
        .map(|&d| {
            acc += d;
            acc
        })
        .collect();

    let used_diff = s1 < s0;
    let (raw, sigma) = if used_diff { (f1, s1) } else { (f0, s0) };
    let cap = capacity as f64;
    let pred: Vec<f32> = raw.iter().map(|&p| p.clamp(0.0, cap) as f32).collect();
    let safe: Vec<f32> = raw
        .iter()
        .enumerate()
        .map(|(h, &p)| {
            let margin = SAFETY_Z * sigma * ((h + 1) as f64).sqrt();
            (cap - (p.clamp(0.0, cap) + margin)).clamp(0.0, cap) as f32
        })
        .collect();
    ForecastResult { pred, safe, sigma: sigma as f32, used_diff }
}

/// Batch helper mirroring `ForecastEngine::predict`.
pub fn forecast_batch(
    series: &[Vec<f32>],
    capacities: &[f32],
    order: usize,
    horizon: usize,
    window: usize,
) -> Vec<ForecastResult> {
    series
        .iter()
        .zip(capacities)
        .map(|(s, &cap)| {
            let mut row = vec![0f32; window];
            crate::runtime::engine::fill_window(&mut row, s);
            forecast_one(&row, cap, order, horizon)
        })
        .collect()
}

/// Demand-model mirror (per consumer): surplus-maximizing slab count.
pub fn demand_one(gain: &[f32], hit_value: f32, price: f64) -> u32 {
    let mut best_s = 0usize;
    let mut best_v = f64::MIN;
    for (s, &g) in gain.iter().enumerate() {
        let surplus = hit_value as f64 * g as f64 - price * s as f64;
        if surplus > best_v {
            best_v = surplus;
            best_s = s;
        }
    }
    if best_v > 0.0 {
        best_s as u32
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_series_flat_forecast() {
        let x = vec![5.0f32; 100];
        let (f, sigma) = ar_forecast(&x, 4, 8);
        for v in &f {
            assert!((v - 5.0).abs() < 1e-6, "forecast {v}");
        }
        assert!(sigma < 1e-2);
    }

    #[test]
    fn strong_ar1_tracked() {
        // x_t = 0.9 x_{t-1} + eps
        let mut rng = crate::util::rng::Rng::new(3);
        let mut x = vec![0f32; 400];
        for t in 1..400 {
            x[t] = 0.9 * x[t - 1] + rng.normal(0.0, 0.1) as f32;
        }
        let (f, _) = ar_forecast(&x, 4, 1);
        let mu = x.iter().map(|&v| v as f64).sum::<f64>() / 400.0;
        let expected = mu + 0.9 * (x[399] as f64 - mu);
        assert!((f[0] - expected).abs() < 0.15, "got {} want {}", f[0], expected);
    }

    #[test]
    fn linear_ramp_prefers_diff_and_extrapolates() {
        let x: Vec<f32> = (0..200).map(|t| 0.5 * t as f32).collect();
        let r = forecast_one(&x, 1e9, 4, 6);
        assert!(r.used_diff, "ramp should select d=1");
        for (h, &p) in r.pred.iter().enumerate() {
            let want = 0.5 * (199.0 + (h + 1) as f32);
            assert!((p - want).abs() < 1.0, "h={h} p={p} want={want}");
        }
    }

    #[test]
    fn safe_leaves_margin_and_respects_capacity() {
        let x = vec![10.0f32; 300];
        let r = forecast_one(&x, 16.0, 4, 12);
        for (h, (&p, &s)) in r.pred.iter().zip(&r.safe).enumerate() {
            assert!(s >= 0.0 && s <= 16.0);
            assert!(s <= 16.0 - p + 1e-3, "h={h}");
        }
    }

    #[test]
    fn demand_rule() {
        // gain: 0, 10, 18, 24, 28 ... concave; value $0.001/hit.
        let gain = vec![0.0, 10.0, 18.0, 24.0, 28.0];
        // price 0.005: marginal gain*value per slab = .01,.008,.006,.004 —
        // worth buying 3 slabs (4th marginal 0.004 < 0.005).
        assert_eq!(demand_one(&gain, 0.001, 0.005), 3);
        assert_eq!(demand_one(&gain, 0.001, 100.0), 0);
        assert_eq!(demand_one(&gain, 0.001, 0.0), 4);
    }
}

//! The PJRT engine: compile-once, execute-many wrappers around the two
//! HLO artifacts.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Compiled-in shapes; must match python/compile/model.py (the manifest
/// is checked at load time).
pub const FORECAST_BATCH: usize = 256;
pub const FORECAST_WINDOW: usize = 288;
pub const FORECAST_HORIZON: usize = 12;
pub const DEMAND_BATCH: usize = 1024;
pub const DEMAND_SIZES: usize = 64;
pub const DEMAND_PRICES: usize = 3;

/// One producer's forecast output.
#[derive(Clone, Debug)]
pub struct ForecastResult {
    /// Predicted usage (GB) over the horizon.
    pub pred: Vec<f32>,
    /// Safe leaseable memory (GB) over the horizon.
    pub safe: Vec<f32>,
    /// One-step prediction-error std (GB).
    pub sigma: f32,
    /// Whether the differenced (d=1) model was selected.
    pub used_diff: bool,
}

/// Shared PJRT client + both executables.
pub struct Engine {
    pub forecast: ForecastEngine,
    pub demand: DemandEngine,
}

impl Engine {
    /// Load both artifacts from `dir` (e.g. `artifacts/`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let client = std::rc::Rc::new(
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?,
        );
        let forecast = ForecastEngine::load(client.clone(), &dir.join("forecast.hlo.txt"))?;
        let demand = DemandEngine::load(client, &dir.join("demand.hlo.txt"))?;
        Ok(Engine { forecast, demand })
    }

    /// Default artifact location (repo-root/artifacts), overridable via
    /// MEMTRADE_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("MEMTRADE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// True when both artifacts exist on disk.
    pub fn artifacts_present(dir: &Path) -> bool {
        dir.join("forecast.hlo.txt").exists() && dir.join("demand.hlo.txt").exists()
    }
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

fn literal_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(values)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

/// Availability forecaster (paper §5.1), compiled once.
pub struct ForecastEngine {
    client: std::rc::Rc<xla::PjRtClient>,
    exe: xla::PjRtLoadedExecutable,
}

impl ForecastEngine {
    fn load(client: std::rc::Rc<xla::PjRtClient>, path: &Path) -> Result<Self> {
        let exe = compile(&client, path)?;
        Ok(ForecastEngine { client, exe })
    }

    /// Forecast for `series.len()` producers; each series is padded/
    /// truncated to the compiled window, the batch is chunked to the
    /// compiled batch size.
    pub fn predict(&self, series: &[Vec<f32>], capacities: &[f32]) -> Result<Vec<ForecastResult>> {
        anyhow::ensure!(series.len() == capacities.len(), "series/capacity length mismatch");
        let n = series.len();
        let mut out = Vec::with_capacity(n);
        let mut start = 0usize;
        while start < n {
            let end = (start + FORECAST_BATCH).min(n);
            out.extend(self.predict_chunk(&series[start..end], &capacities[start..end])?);
            start = end;
        }
        Ok(out)
    }

    fn predict_chunk(&self, series: &[Vec<f32>], caps: &[f32]) -> Result<Vec<ForecastResult>> {
        let real = series.len();
        let mut usage = vec![0f32; FORECAST_BATCH * FORECAST_WINDOW];
        for (i, s) in series.iter().enumerate() {
            let row = &mut usage[i * FORECAST_WINDOW..(i + 1) * FORECAST_WINDOW];
            fill_window(row, s);
        }
        let mut capacity = vec![0f32; FORECAST_BATCH];
        capacity[..real].copy_from_slice(caps);

        let usage_lit =
            literal_f32(&usage, &[FORECAST_BATCH as i64, FORECAST_WINDOW as i64])?;
        let cap_lit = literal_f32(&capacity, &[FORECAST_BATCH as i64])?;

        let result = self
            .exe
            .execute::<xla::Literal>(&[usage_lit, cap_lit])
            .map_err(|e| anyhow!("execute forecast: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch forecast result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let pred: Vec<f32> = parts[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let safe: Vec<f32> = parts[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let sigma: Vec<f32> = parts[2].to_vec().map_err(|e| anyhow!("{e:?}"))?;
        let used_d: Vec<f32> = parts[3].to_vec().map_err(|e| anyhow!("{e:?}"))?;

        Ok((0..real)
            .map(|i| ForecastResult {
                pred: pred[i * FORECAST_HORIZON..(i + 1) * FORECAST_HORIZON].to_vec(),
                safe: safe[i * FORECAST_HORIZON..(i + 1) * FORECAST_HORIZON].to_vec(),
                sigma: sigma[i],
                used_diff: used_d[i] > 0.5,
            })
            .collect())
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

/// Left-pad (with the oldest value) or truncate to the compiled window.
pub fn fill_window(row: &mut [f32], s: &[f32]) {
    let w = row.len();
    if s.is_empty() {
        row.fill(0.0);
        return;
    }
    if s.len() >= w {
        row.copy_from_slice(&s[s.len() - w..]);
    } else {
        let pad = w - s.len();
        row[..pad].fill(s[0]);
        row[pad..].copy_from_slice(s);
    }
}

/// Market demand evaluator (paper §5.3), compiled once.
pub struct DemandEngine {
    exe: xla::PjRtLoadedExecutable,
}

/// Demand evaluation output for one price candidate set.
#[derive(Clone, Debug, Default)]
pub struct DemandResult {
    /// Per-consumer demanded slabs, per price candidate: `[n][k]`.
    pub demand: Vec<Vec<f32>>,
    /// Total volume per candidate.
    pub volume: [f64; DEMAND_PRICES],
    /// Producer revenue per candidate.
    pub revenue: [f64; DEMAND_PRICES],
}

impl DemandEngine {
    fn load(client: std::rc::Rc<xla::PjRtClient>, path: &Path) -> Result<Self> {
        let exe = compile(&client, path)?;
        Ok(DemandEngine { exe })
    }

    /// Evaluate demand for all consumers at 3 candidate prices.
    /// `gains[i]` must have exactly `DEMAND_SIZES` entries.
    pub fn evaluate(
        &self,
        gains: &[Vec<f32>],
        hit_values: &[f32],
        prices: [f32; DEMAND_PRICES],
    ) -> Result<DemandResult> {
        anyhow::ensure!(gains.len() == hit_values.len());
        let n = gains.len();
        let mut result = DemandResult { demand: Vec::with_capacity(n), ..Default::default() };
        let mut start = 0usize;
        while start < n {
            let end = (start + DEMAND_BATCH).min(n);
            self.evaluate_chunk(&gains[start..end], &hit_values[start..end], prices, &mut result)?;
            start = end;
        }
        for k in 0..DEMAND_PRICES {
            result.revenue[k] = result.volume[k] * prices[k] as f64;
        }
        Ok(result)
    }

    fn evaluate_chunk(
        &self,
        gains: &[Vec<f32>],
        hit_values: &[f32],
        prices: [f32; DEMAND_PRICES],
        out: &mut DemandResult,
    ) -> Result<()> {
        let real = gains.len();
        let mut gain_flat = vec![0f32; DEMAND_BATCH * DEMAND_SIZES];
        for (i, g) in gains.iter().enumerate() {
            anyhow::ensure!(g.len() == DEMAND_SIZES, "gain curve must have {DEMAND_SIZES} points");
            gain_flat[i * DEMAND_SIZES..(i + 1) * DEMAND_SIZES].copy_from_slice(g);
        }
        let mut values = vec![0f32; DEMAND_BATCH];
        values[..real].copy_from_slice(hit_values);

        let gain_lit = literal_f32(&gain_flat, &[DEMAND_BATCH as i64, DEMAND_SIZES as i64])?;
        let val_lit = literal_f32(&values, &[DEMAND_BATCH as i64])?;
        let price_lit = literal_f32(&prices, &[DEMAND_PRICES as i64])?;

        let result = self
            .exe
            .execute::<xla::Literal>(&[gain_lit, val_lit, price_lit])
            .map_err(|e| anyhow!("execute demand: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch demand result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs");
        let demand: Vec<f32> = parts[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;

        // Padded rows have zero gain => zero demand; volume still summed
        // from real rows only for exactness.
        for i in 0..real {
            let row = demand[i * DEMAND_PRICES..(i + 1) * DEMAND_PRICES].to_vec();
            for k in 0..DEMAND_PRICES {
                out.volume[k] += row[k] as f64;
            }
            out.demand.push(row);
        }
        Ok(())
    }
}

/// Verify the manifest written by aot.py matches the compiled-in shapes.
pub fn check_manifest(dir: &Path) -> Result<()> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
    for (key, want) in [
        ("\"batch\": 256", true),
        ("\"window\": 288", true),
        ("\"horizon\": 12", true),
        ("\"batch\": 1024", true),
        ("\"sizes\": 64", true),
        ("\"n_prices\": 3", true),
    ] {
        anyhow::ensure!(text.contains(key) == want, "manifest mismatch on {key}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_window_pads_and_truncates() {
        let mut row = [0f32; 5];
        fill_window(&mut row, &[1.0, 2.0]);
        assert_eq!(row, [1.0, 1.0, 1.0, 1.0, 2.0]);
        fill_window(&mut row, &[1., 2., 3., 4., 5., 6., 7.]);
        assert_eq!(row, [3., 4., 5., 6., 7.]);
        fill_window(&mut row, &[]);
        assert_eq!(row, [0.0; 5]);
    }

    // Artifact-dependent tests live in rust/tests/runtime_artifacts.rs and
    // skip gracefully when `make artifacts` hasn't run.
}

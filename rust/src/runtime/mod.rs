//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the broker's epoch path.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. See `/opt/xla-example/README.md`.
//!
//! The compiled modules have fixed batch shapes (see `artifacts/
//! manifest.json`); [`ForecastEngine`]/[`DemandEngine`] pad and chunk
//! arbitrary-sized requests to the compiled batch. When artifacts are not
//! built, [`arima_fallback`] (also used for differential testing) provides
//! a pure-Rust implementation of exactly the same math.

pub mod arima_fallback;
pub mod engine;

pub use engine::{DemandEngine, Engine, ForecastEngine, ForecastResult};

//! Networking substrate: the producer-store wire protocol (from-scratch
//! binary codec, spec in `PROTOCOL.md` at the repo root), the
//! marketplace *control-plane* protocol with its magic-bytes/version
//! handshake, a network *model* for the discrete-event simulator
//! (VPC-peering latency + NIC bandwidth, paper §3/§7), a real TCP
//! transport over actual sockets, and the chaos plane ([`faults`]):
//! deterministic seeded fault injection threaded under both planes,
//! plus the Byzantine producer mode the §6.1 envelope is tested
//! against.
//!
//! Both servers — the producer store ([`tcp::ProducerStoreServer`])
//! and the broker's control port — serve on the hand-rolled epoll
//! readiness loop in [`event_loop`], so one daemon holds thousands of
//! connections on a few threads. The legacy thread-per-connection
//! path survives as [`tcp::ProducerStoreServer::start_threaded`], the
//! baseline the `bench_e2e` connection sweep compares against.

pub mod control;
pub mod event_loop;
pub mod faults;
pub mod model;
pub mod tcp;
pub mod wire;

pub use control::{CtrlClient, CtrlRequest, CtrlResponse, GrantInfo, RefuseCode};
pub use faults::{ByzantineSpec, FaultPlan, FaultSpec, FaultyStream};
pub use model::NetworkModel;
pub use tcp::{KvClient, ProducerStoreServer};
pub use wire::{Request, Response};

//! Networking substrate: the producer-store wire protocol (from-scratch
//! binary codec), a network *model* for the discrete-event simulator
//! (VPC-peering latency + NIC bandwidth, paper §3/§7), and a real TCP
//! transport (std::net, threaded) used by the runnable examples so the
//! request path is exercised over actual sockets.

pub mod model;
pub mod tcp;
pub mod wire;

pub use model::NetworkModel;
pub use tcp::{KvClient, ProducerStoreServer};
pub use wire::{Request, Response};

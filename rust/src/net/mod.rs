//! Networking substrate: the producer-store wire protocol (from-scratch
//! binary codec), the marketplace *control-plane* protocol with its
//! magic-bytes/version handshake, a network *model* for the
//! discrete-event simulator (VPC-peering latency + NIC bandwidth, paper
//! §3/§7), a real TCP transport (std::net, threaded) used by the
//! runnable examples so the request path is exercised over actual
//! sockets, and the chaos plane ([`faults`]): deterministic seeded
//! fault injection threaded under both planes, plus the Byzantine
//! producer mode the §6.1 envelope is tested against.

pub mod control;
pub mod faults;
pub mod model;
pub mod tcp;
pub mod wire;

pub use control::{CtrlClient, CtrlRequest, CtrlResponse, GrantInfo, RefuseCode};
pub use faults::{ByzantineSpec, FaultPlan, FaultSpec, FaultyStream};
pub use model::NetworkModel;
pub use tcp::{KvClient, ProducerStoreServer};
pub use wire::{Request, Response};

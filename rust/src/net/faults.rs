//! The chaos plane: deterministic, seeded fault injection threaded
//! under both wire protocols.
//!
//! A [`FaultPlan`] describes a schedule of faults — drop, delay,
//! disconnect, truncate, duplicate, bit-flip — with independent rates
//! per direction (read vs. write). Every endpoint that owns a TCP
//! stream ([`crate::net::tcp::KvClient`], [`crate::net::control::
//! CtrlClient`], [`crate::market::BrokerServer`], [`crate::net::tcp::
//! ProducerStoreServer`]) is constructed over a [`FaultyStream`], a
//! `Read + Write` wrapper around the raw `TcpStream`. With no plan
//! installed the wrapper is a single branch around the raw socket call
//! — no allocation, no copy, no extra syscall — so production paths are
//! unchanged; with a plan, every I/O call consults a seeded RNG.
//!
//! ## Determinism contract
//!
//! The fault schedule observed by one connection is a pure function of
//! `(plan.seed, connection index, I/O call sequence on that
//! connection)`: each accepted/dialed connection derives an independent
//! RNG stream via SplitMix64 over its index, and fault decisions are
//! drawn in a fixed order per call. Concurrency can reorder *which*
//! connection gets which index when peers race to dial, but a failing
//! schedule replayed with the same seed exercises the same per-
//! connection fault sequences — which is what makes a red chaos run
//! reproducible from its printed seed (see `memtrade chaos --seed`).
//!
//! Plans are *armed* by default and can be [`FaultPlan::disarm`]ed at
//! runtime: the switch is shared by every stream built from (a clone
//! of) the plan, so a chaos scenario can stop injecting faults on live
//! connections and then assert that the system reconverges.
//!
//! ## Byzantine producers
//!
//! [`ByzantineSpec`] is the data plane's application-level attacker: a
//! producer store that serves *syntactically valid* but wrong GET
//! responses — a corrupted value, a stale (replayed) value, or a
//! truncated value — for a seeded fraction of hits. The paper's §6.1
//! envelope must catch 100% of these as `BadHash`/`BadCiphertext`
//! misses; `tests/chaos.rs` asserts exactly that.

use crate::metrics::{scoped, Counter, MetricSet, Observe};
use crate::util::rng::{splitmix64_once, Rng};
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-direction fault rates. All probabilities are per I/O call (not
/// per byte); `Default` is all-zero (no faults).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Silently discard the payload (write side only): the caller sees
    /// success, the peer sees nothing — a lost frame.
    pub drop_p: f64,
    /// Sleep up to `delay_max_ms` before the call proceeds.
    pub delay_p: f64,
    pub delay_max_ms: u64,
    /// Shut the socket down; every later call on either half errors.
    pub disconnect_p: f64,
    /// Lose the tail of the payload: a partial write the caller thinks
    /// completed, or a read whose trailing bytes are discarded.
    pub truncate_p: f64,
    /// Write the payload twice (write side only).
    pub duplicate_p: f64,
    /// Flip one random bit of the payload.
    pub bitflip_p: f64,
}

/// Injected-fault counters, shared by every stream built from one plan
/// (and its clones): the chaos plane's own telemetry, so scenarios and
/// `memtrade top` can report *how much* chaos actually landed instead
/// of inferring it from rates.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub drops: Counter,
    pub delays: Counter,
    pub disconnects: Counter,
    pub truncates: Counter,
    pub duplicates: Counter,
    pub bitflips: Counter,
}

impl FaultCounters {
    /// Total faults injected across all kinds.
    pub fn total(&self) -> u64 {
        self.drops.get()
            + self.delays.get()
            + self.disconnects.get()
            + self.truncates.get()
            + self.duplicates.get()
            + self.bitflips.get()
    }
}

impl Observe for FaultCounters {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        out.set_counter(scoped(prefix, "drops"), self.drops.get());
        out.set_counter(scoped(prefix, "delays"), self.delays.get());
        out.set_counter(scoped(prefix, "disconnects"), self.disconnects.get());
        out.set_counter(scoped(prefix, "truncates"), self.truncates.get());
        out.set_counter(scoped(prefix, "duplicates"), self.duplicates.get());
        out.set_counter(scoped(prefix, "bitflips"), self.bitflips.get());
    }
}

/// A seeded, per-direction fault schedule for one plane's connections.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Faults on the inbound direction (reads from the peer).
    pub read: FaultSpec,
    /// Faults on the outbound direction (writes to the peer).
    pub write: FaultSpec,
    /// Live kill switch, shared by every stream built from this plan
    /// (clones share it too).
    armed: Arc<AtomicBool>,
    /// Injected-fault counts (shared with clones, like `armed`).
    counters: Arc<FaultCounters>,
    /// One banner per *plan*: a multi-role chaos run clones one plan
    /// into several servers/clients, each of which announces itself at
    /// startup — this latch (shared with clones, like `armed`) lets
    /// only the first announcement through.
    banner_logged: Arc<AtomicBool>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            read: FaultSpec::default(),
            write: FaultSpec::default(),
            armed: Arc::new(AtomicBool::new(true)),
            counters: Arc::new(FaultCounters::default()),
            banner_logged: Arc::new(AtomicBool::new(false)),
        }
    }
}

impl FaultPlan {
    /// A plan with independent per-direction rates.
    pub fn new(seed: u64, read: FaultSpec, write: FaultSpec) -> Self {
        FaultPlan { seed, read, write, ..Default::default() }
    }

    /// Same fault rates in both directions.
    pub fn symmetric(seed: u64, spec: FaultSpec) -> Self {
        Self::new(seed, spec, spec)
    }

    /// Stop injecting faults on every stream built from this plan (or a
    /// clone of it), including connections already established.
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    pub fn arm(&self) {
        self.armed.store(true, Ordering::Relaxed);
    }

    pub fn is_armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    /// Counts of faults actually injected on streams built from this
    /// plan (or a clone of it).
    pub fn counters(&self) -> &FaultCounters {
        &self.counters
    }

    /// Print the one-line chaos banner a fault-carrying role logs at
    /// startup: which role is under chaos, the plan seed, and the exact
    /// command that replays this schedule (the determinism contract
    /// above is what makes the repro command meaningful).
    ///
    /// Prints at most once per plan — clones share the latch, so a
    /// multi-role scenario that hands one plan to a broker, two
    /// agents, and a pool emits one banner (from whichever role starts
    /// first), not one per constructed role or connection. Returns
    /// whether this call was the one that printed.
    pub fn log_banner(&self, role: &str) -> bool {
        if self.banner_logged.swap(true, Ordering::Relaxed) {
            return false;
        }
        eprintln!(
            "[chaos] {role}: fault plan armed, seed={} \
             (reproduce: memtrade chaos --seed {})",
            self.seed, self.seed
        );
        true
    }

    /// Derive the deterministic per-connection fault state for the
    /// `conn`-th connection under this plan.
    fn state_for(&self, conn: u64) -> Arc<Mutex<FaultState>> {
        Arc::new(Mutex::new(FaultState {
            rng: Rng::new(self.seed ^ splitmix64_once(conn)),
            read: self.read,
            write: self.write,
            armed: self.armed.clone(),
            counters: self.counters.clone(),
            dead: false,
        }))
    }
}

/// Shared mutable fault state of one connection (reader and writer
/// halves of the same connection share it, so the combined fault
/// sequence is deterministic for single-threaded request/response use).
struct FaultState {
    rng: Rng,
    read: FaultSpec,
    write: FaultSpec,
    armed: Arc<AtomicBool>,
    counters: Arc<FaultCounters>,
    /// A disconnect fault fired: every later call errors.
    dead: bool,
}

fn injected_disconnect() -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionReset, "injected disconnect (chaos plane)")
}

/// A `TcpStream` with an optional installed fault schedule. Without one
/// (`state == None`) every call is a direct delegation to the socket.
pub struct FaultyStream {
    inner: TcpStream,
    state: Option<Arc<Mutex<FaultState>>>,
}

impl FaultyStream {
    /// A pass-through stream: byte-identical to the raw socket.
    pub fn clean(inner: TcpStream) -> Self {
        FaultyStream { inner, state: None }
    }

    /// Wrap `inner` under `plan` as that plan's `conn`-th connection
    /// (`plan = None` is [`Self::clean`]).
    pub fn new(inner: TcpStream, plan: Option<&FaultPlan>, conn: u64) -> Self {
        FaultyStream { inner, state: plan.map(|p| p.state_for(conn)) }
    }

    /// Clone the underlying socket; both halves share one fault state,
    /// so reads and writes draw from a single deterministic sequence.
    pub fn try_clone(&self) -> io::Result<FaultyStream> {
        Ok(FaultyStream { inner: self.inner.try_clone()?, state: self.state.clone() })
    }

    pub fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        self.inner.set_read_timeout(t)
    }

    pub fn set_nodelay(&self, on: bool) -> io::Result<()> {
        self.inner.set_nodelay(on)
    }

    /// Switch the underlying socket to nonblocking mode (the epoll
    /// event loop drives accepted sockets this way).
    pub fn set_nonblocking(&self, on: bool) -> io::Result<()> {
        self.inner.set_nonblocking(on)
    }

    /// The raw fd of the underlying socket, for epoll registration.
    /// The stream keeps ownership; the fd is valid until `self` drops.
    pub fn as_raw_fd(&self) -> std::os::fd::RawFd {
        use std::os::fd::AsRawFd;
        self.inner.as_raw_fd()
    }
}

fn flip_random_bit(buf: &mut [u8], rng: &mut Rng) {
    if buf.is_empty() {
        return;
    }
    let byte = rng.below(buf.len() as u64) as usize;
    let bit = rng.below(8) as u32;
    buf[byte] ^= 1u8 << bit;
}

impl Read for FaultyStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let Some(state) = &self.state else {
            return self.inner.read(buf);
        };
        let mut s = state.lock().unwrap();
        if s.dead {
            return Err(injected_disconnect());
        }
        if !s.armed.load(Ordering::Relaxed) {
            return self.inner.read(buf);
        }
        // Decisions drawn in a fixed order per call (see module doc).
        // Edge-triggered parity: a call whose inner read would block
        // transfers no bytes, so it must consume no fault draws —
        // otherwise every spurious wakeup under `EPOLLET` would drift
        // the schedule away from the threaded path's. Snapshot the RNG
        // and restore it on `WouldBlock`.
        let drawn = s.rng.clone();
        if s.rng.chance(s.read.disconnect_p) {
            s.dead = true;
            s.counters.disconnects.inc();
            self.inner.shutdown(Shutdown::Both).ok();
            return Err(injected_disconnect());
        }
        if s.rng.chance(s.read.delay_p) {
            let ms = s.rng.below(s.read.delay_max_ms.max(1) + 1);
            s.counters.delays.inc();
            std::thread::sleep(Duration::from_millis(ms));
        }
        let n = match self.inner.read(buf) {
            Ok(n) => n,
            Err(e) => {
                if e.kind() == io::ErrorKind::WouldBlock {
                    s.rng = drawn;
                }
                return Err(e);
            }
        };
        if n > 0 && s.rng.chance(s.read.bitflip_p) {
            s.counters.bitflips.inc();
            flip_random_bit(&mut buf[..n], &mut s.rng);
        }
        if n > 1 && s.rng.chance(s.read.truncate_p) {
            // Discard the tail: those bytes were consumed from the
            // socket and are gone — the peer and we now disagree about
            // the stream position.
            s.counters.truncates.inc();
            let keep = 1 + s.rng.below(n as u64 - 1) as usize;
            return Ok(keep);
        }
        Ok(n)
    }
}

impl Write for FaultyStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let Some(state) = &self.state else {
            return self.inner.write(buf);
        };
        let mut s = state.lock().unwrap();
        if s.dead {
            return Err(injected_disconnect());
        }
        if !s.armed.load(Ordering::Relaxed) {
            return self.inner.write(buf);
        }
        // Same would-block rule as the read side: a call that
        // transfers no bytes consumes no draws. Every fault path
        // below issues exactly one bounded write (partial-accept
        // semantics, like the clean path), so a full send buffer
        // surfaces as an ordinary `WouldBlock` with the RNG restored
        // — never as a mid-fault `write_all` error that would close
        // the connection and desync the seeded schedule on a
        // nonblocking socket. Counters bump only once bytes actually
        // moved, so a blocked-then-retried fault is counted once.
        let drawn = s.rng.clone();
        if s.rng.chance(s.write.disconnect_p) {
            s.dead = true;
            s.counters.disconnects.inc();
            self.inner.shutdown(Shutdown::Both).ok();
            return Err(injected_disconnect());
        }
        if s.rng.chance(s.write.delay_p) {
            let ms = s.rng.below(s.write.delay_max_ms.max(1) + 1);
            s.counters.delays.inc();
            std::thread::sleep(Duration::from_millis(ms));
        }
        if s.rng.chance(s.write.drop_p) {
            // Vanished in flight; the caller believes it was sent.
            s.counters.drops.inc();
            return Ok(buf.len());
        }
        if !buf.is_empty() && s.rng.chance(s.write.bitflip_p) {
            let mut copy = buf.to_vec();
            flip_random_bit(&mut copy, &mut s.rng);
            // Report the true count: the caller resumes from byte `n`
            // of its own clean buffer, so a short write stays in sync
            // — the flip lands only if the flipped byte was among the
            // `n` accepted (at worst the fault fails to stick).
            return match self.inner.write(&copy) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    s.rng = drawn;
                    Err(e)
                }
                Ok(n) => {
                    s.counters.bitflips.inc();
                    Ok(n)
                }
                other => other,
            };
        }
        if buf.len() > 1 && s.rng.chance(s.write.truncate_p) {
            let keep = 1 + s.rng.below(buf.len() as u64 - 1) as usize;
            // Report full success: the dropped tail — plus whatever
            // part of the kept prefix the socket declined — is
            // silently lost, which is exactly what this fault means.
            return match self.inner.write(&buf[..keep]) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    s.rng = drawn;
                    Err(e)
                }
                Ok(_) => {
                    s.counters.truncates.inc();
                    Ok(buf.len())
                }
                other => other,
            };
        }
        if !buf.is_empty() && s.rng.chance(s.write.duplicate_p) {
            // Both copies in one bounded vectored write. Reporting
            // `min(n, len)` keeps the caller's cursor honest: at most
            // the whole payload is acknowledged, and any accepted
            // bytes beyond it are the injected duplicate (possibly a
            // partial one — a smaller fault, not a desync).
            let iov = [io::IoSlice::new(buf), io::IoSlice::new(buf)];
            return match self.inner.write_vectored(&iov) {
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    s.rng = drawn;
                    Err(e)
                }
                Ok(n) => {
                    s.counters.duplicates.inc();
                    Ok(n.min(buf.len()))
                }
                other => other,
            };
        }
        match self.inner.write(buf) {
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                s.rng = drawn;
                Err(e)
            }
            other => other,
        }
    }

    /// Vectored writes power the event loop's coalesced `writev`
    /// flush. A clean stream forwards straight to the socket (one real
    /// `writev` syscall for many frames); a faulty stream routes the
    /// first non-empty slice through [`FaultyStream::write`] so every
    /// fault decision still happens per call, in the same draw order
    /// the threaded path sees.
    fn write_vectored(&mut self, bufs: &[io::IoSlice<'_>]) -> io::Result<usize> {
        if self.state.is_none() {
            return self.inner.write_vectored(bufs);
        }
        match bufs.iter().find(|b| !b.is_empty()) {
            Some(first) => self.write(first),
            None => Ok(0),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// A Byzantine producer store: tampers with a seeded fraction of GET
/// hit responses (application-level, under any transport faults).
#[derive(Clone, Debug)]
pub struct ByzantineSpec {
    pub seed: u64,
    /// Fraction of GET hits answered with a tampered value.
    pub tamper_p: f64,
    armed: Arc<AtomicBool>,
}

impl Default for ByzantineSpec {
    fn default() -> Self {
        ByzantineSpec { seed: 0, tamper_p: 0.0, armed: Arc::new(AtomicBool::new(true)) }
    }
}

impl ByzantineSpec {
    pub fn new(seed: u64, tamper_p: f64) -> Self {
        ByzantineSpec { seed, tamper_p, ..Default::default() }
    }

    /// Stop tampering on every connection built from this spec (or a
    /// clone of it).
    pub fn disarm(&self) {
        self.armed.store(false, Ordering::Relaxed);
    }

    pub fn arm(&self) {
        self.armed.store(true, Ordering::Relaxed);
    }

    /// Deterministic per-connection tamper state (same index contract
    /// as [`FaultPlan`]).
    pub fn state_for(&self, conn: u64) -> ByzantineState {
        ByzantineState {
            rng: Rng::new(self.seed ^ splitmix64_once(conn) ^ 0xB12A_2717),
            tamper_p: self.tamper_p,
            armed: self.armed.clone(),
            last_clean: Vec::new(),
        }
    }
}

/// Encoded `Response::Value` layout this module rewrites: 1 tag byte +
/// `u32 LE` value length + value bytes (see `crate::net::wire`). The
/// round-trip test below pins the assumption.
const VALUE_HDR: usize = 5;

/// Per-connection Byzantine state: a seeded RNG plus the last clean
/// value response served (the replay source).
pub struct ByzantineState {
    rng: Rng,
    tamper_p: f64,
    armed: Arc<AtomicBool>,
    last_clean: Vec<u8>,
}

impl ByzantineState {
    /// Maybe tamper with an encoded GET-hit (`Value`) response in
    /// place; returns true if the response was tampered. Tampered
    /// responses stay syntactically valid frames — they must survive
    /// decoding and die at the consumer's integrity check, not at the
    /// codec.
    pub fn process_value_response(&mut self, out: &mut Vec<u8>) -> bool {
        self.process_value_response_at(out, 0)
    }

    /// [`Self::process_value_response`] for a `Value` sub-response that
    /// starts at byte `start` of `out` — the batch path encodes several
    /// per-op responses into one shared output buffer, and each GET hit
    /// must be independently tamperable so the envelope is exercised
    /// *per op* inside a batch, not just per frame.
    pub fn process_value_response_at(&mut self, out: &mut Vec<u8>, start: usize) -> bool {
        let clean = out[start..].to_vec();
        let mut tampered = false;
        // Empty values have no bytes to corrupt detectably; skip them
        // (sealed values are never empty: IV + padding ≥ 32 bytes).
        if self.armed.load(Ordering::Relaxed)
            && out.len() - start > VALUE_HDR
            && self.rng.chance(self.tamper_p)
        {
            match self.rng.below(3) {
                0 => self.corrupt(out, start),
                1 => self.truncate(out, start),
                _ => {
                    // Replay the previous clean value — if there is one
                    // and it actually differs (tampering must always be
                    // detectable, never a silent no-op).
                    if !self.last_clean.is_empty() && self.last_clean != clean {
                        out.truncate(start);
                        let replay = std::mem::take(&mut self.last_clean);
                        out.extend_from_slice(&replay);
                        self.last_clean = replay;
                    } else {
                        self.corrupt(out, start);
                    }
                }
            }
            tampered = true;
        }
        self.last_clean = clean;
        tampered
    }

    fn corrupt(&mut self, out: &mut Vec<u8>, start: usize) {
        let hdr = start + VALUE_HDR;
        let idx = hdr + self.rng.below((out.len() - hdr) as u64) as usize;
        let bit = self.rng.below(8) as u32;
        out[idx] ^= 1u8 << bit;
    }

    fn truncate(&mut self, out: &mut Vec<u8>, start: usize) {
        let hdr = start + VALUE_HDR;
        let value_len = out.len() - hdr;
        let cut = 1 + self.rng.below(value_len as u64) as usize;
        out.truncate(out.len() - cut);
        let new_len = (out.len() - hdr) as u32;
        out[start + 1..hdr].copy_from_slice(&new_len.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::wire::{encode_value_response, Response};

    #[test]
    fn clean_stream_is_pure_delegation() {
        // A clean FaultyStream has no fault state at all — the no-plan
        // path cannot consult an RNG or allocate.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 5];
            s.read_exact(&mut buf).unwrap();
            s.write_all(&buf).unwrap();
        });
        let mut fs = FaultyStream::clean(TcpStream::connect(addr).unwrap());
        assert!(fs.state.is_none());
        fs.write_all(b"hello").unwrap();
        let mut back = [0u8; 5];
        fs.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello");
        t.join().unwrap();
    }

    #[test]
    fn fault_schedule_is_deterministic_per_connection() {
        let plan = FaultPlan::symmetric(
            7,
            FaultSpec { drop_p: 0.3, bitflip_p: 0.3, ..Default::default() },
        );
        // Same plan, same connection index → identical decision streams.
        let a = plan.state_for(3);
        let b = plan.state_for(3);
        let mut a = a.lock().unwrap();
        let mut b = b.lock().unwrap();
        for _ in 0..64 {
            assert_eq!(a.rng.next_u64(), b.rng.next_u64());
        }
        // Different connection index → an independent stream.
        let c = plan.state_for(4);
        let mut c = c.lock().unwrap();
        let mut same = 0;
        let mut a2 = plan.state_for(3);
        let a2 = Arc::get_mut(&mut a2).unwrap().get_mut().unwrap();
        for _ in 0..64 {
            if a2.rng.next_u64() == c.rng.next_u64() {
                same += 1;
            }
        }
        assert!(same < 4, "streams not independent: {same}/64 collisions");
    }

    #[test]
    fn disarm_stops_faults_on_live_connections() {
        let spec = FaultSpec { drop_p: 1.0, ..Default::default() };
        let plan = FaultPlan::symmetric(1, spec);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = [0u8; 3];
            // Only the post-disarm write ever arrives.
            s.read_exact(&mut buf).unwrap();
            buf
        });
        let mut fs = FaultyStream::new(TcpStream::connect(addr).unwrap(), Some(&plan), 0);
        fs.write_all(b"xxx").unwrap(); // dropped (drop_p = 1)
        assert_eq!(plan.counters().drops.get(), 1, "injected drop not counted");
        plan.disarm();
        fs.write_all(b"yyy").unwrap(); // delivered
        assert_eq!(&t.join().unwrap(), b"yyy");
        // Disarmed injections are not injections: the count is frozen.
        assert_eq!(plan.counters().drops.get(), 1);
        assert_eq!(plan.counters().total(), 1);
        let mut m = MetricSet::new();
        plan.counters().observe("faults", &mut m);
        assert_eq!(m.counter("faults.drops"), Some(1));
    }

    #[test]
    fn banner_prints_once_per_plan_across_roles_and_clones() {
        // A multi-role chaos run clones one plan into the broker, the
        // agents, and the consumer pool; each role calls log_banner at
        // startup. Only the first call across all clones may print.
        let plan = FaultPlan::symmetric(5, FaultSpec { drop_p: 0.1, ..Default::default() });
        let broker = plan.clone();
        let agent = plan.clone();
        let pool = plan.clone();
        assert!(broker.log_banner("broker"), "first role must print");
        assert!(!agent.log_banner("producer-agent"), "second role reprinted the banner");
        assert!(!pool.log_banner("consumer-pool ctrl"));
        assert!(!plan.log_banner("consumer-pool data"));
        // An independent plan (its own seed/latch) still announces.
        let other = FaultPlan::symmetric(6, FaultSpec::default());
        assert!(other.log_banner("producer-store"));
    }

    /// A chaos write fault hitting a full send buffer on a
    /// nonblocking socket must surface `WouldBlock` with the RNG
    /// restored and the fault uncounted — not a mid-fault `write_all`
    /// error that closes the connection and desyncs the seeded
    /// schedule (the event loop retries blocked writes; it cannot
    /// retry a dead connection).
    #[test]
    fn write_faults_surface_would_block_on_full_send_buffer() {
        let plan =
            FaultPlan::symmetric(11, FaultSpec { bitflip_p: 1.0, ..Default::default() });
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let sock = TcpStream::connect(addr).unwrap();
        let (mut peer, _) = listener.accept().unwrap();
        sock.set_nonblocking(true).unwrap();
        peer.set_nonblocking(true).unwrap();
        let mut fs = FaultyStream::new(sock, Some(&plan), 0);
        // With bitflip_p = 1 every write is a fault-path write; the
        // peer is not reading, so the send buffer must fill.
        let chunk = [0x77u8; 64 << 10];
        let mut oks = 0u64;
        loop {
            match fs.write(&chunk) {
                Ok(n) => {
                    assert!(n <= chunk.len());
                    oks += 1;
                    assert!(oks < 100_000, "send buffer never filled");
                }
                Err(e) => {
                    assert_eq!(
                        e.kind(),
                        io::ErrorKind::WouldBlock,
                        "fault path turned a full buffer into: {e}"
                    );
                    break;
                }
            }
        }
        // The blocked attempt counted no fault...
        assert_eq!(plan.counters().bitflips.get(), oks);
        // ...and did not kill the connection: once the peer drains,
        // the same stream writes again and the schedule continues.
        let mut sink = vec![0u8; 256 << 10];
        let mut recovered = false;
        for _ in 0..1_000 {
            while matches!(peer.read(&mut sink), Ok(n) if n > 0) {}
            match fs.write(&chunk) {
                Ok(_) => {
                    recovered = true;
                    break;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => panic!("connection died after a blocked fault: {e}"),
            }
        }
        assert!(recovered, "writer never recovered after the peer drained");
        assert_eq!(plan.counters().bitflips.get(), oks + 1);
    }

    #[test]
    fn injected_disconnect_kills_both_halves() {
        let spec = FaultSpec { disconnect_p: 1.0, ..Default::default() };
        let plan = FaultPlan::symmetric(2, spec);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _keep = listener; // hold the listener so connect succeeds
        let mut fs = FaultyStream::new(TcpStream::connect(addr).unwrap(), Some(&plan), 0);
        let mut half = fs.try_clone().unwrap();
        assert!(fs.write_all(b"x").is_err());
        // The shared state is dead: the cloned half errors too.
        let mut buf = [0u8; 1];
        assert!(half.read(&mut buf).is_err());
    }

    fn value_response(v: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        encode_value_response(&mut out, v);
        out
    }

    #[test]
    fn byzantine_tampering_stays_decodable_and_always_differs() {
        let spec = ByzantineSpec::new(9, 1.0);
        let mut st = spec.state_for(0);
        for i in 0..200u32 {
            let clean = value_response(&[i as u8; 48]);
            let mut out = clean.clone();
            assert!(st.process_value_response(&mut out), "tamper_p=1 must fire");
            assert_ne!(out, clean, "tampering was a silent no-op at i={i}");
            // Still a valid wire frame — it must reach the envelope.
            match Response::decode(&out) {
                Ok(Response::Value(_)) => {}
                other => panic!("tampered frame undecodable: {other:?}"),
            }
        }
    }

    #[test]
    fn byzantine_tampering_at_offset_leaves_batch_prefix_intact() {
        // The batch path appends sub-responses into one shared buffer;
        // tampering op k must keep ops 0..k byte-identical and leave
        // the whole buffer a valid concatenation of Value responses.
        let spec = ByzantineSpec::new(4, 1.0);
        let mut st = spec.state_for(0);
        for i in 0..100u32 {
            let mut out = Vec::new();
            encode_value_response(&mut out, &[0x5A; 24]); // op 0: clean
            let prefix = out.clone();
            let start = out.len();
            encode_value_response(&mut out, &[i as u8; 48]); // op 1
            let clean_tail = out[start..].to_vec();
            assert!(st.process_value_response_at(&mut out, start));
            assert_eq!(&out[..start], &prefix[..], "prefix disturbed at i={i}");
            assert_ne!(&out[start..], &clean_tail[..], "no-op tamper at i={i}");
            // The tampered tail still decodes as a Value sub-response.
            match Response::decode(&out[start..]) {
                Ok(Response::Value(_)) => {}
                other => panic!("tampered sub-response undecodable: {other:?}"),
            }
        }
    }

    #[test]
    fn byzantine_disarm_and_empty_value_are_clean() {
        let spec = ByzantineSpec::new(9, 1.0);
        let mut st = spec.state_for(1);
        // Empty value: nothing to corrupt detectably — passed through.
        let clean = value_response(b"");
        let mut out = clean.clone();
        assert!(!st.process_value_response(&mut out));
        assert_eq!(out, clean);
        // Disarmed: passed through.
        spec.disarm();
        let clean = value_response(&[1, 2, 3, 4]);
        let mut out = clean.clone();
        assert!(!st.process_value_response(&mut out));
        assert_eq!(out, clean);
    }
}

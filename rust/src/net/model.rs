//! Network latency/bandwidth model for the simulator.
//!
//! The paper's testbed: 10 Gb NICs, consumers and producers in the same
//! datacenter connected via VPC peering. We model a request's network
//! time as propagation RTT + serialization at the bottleneck NIC, with
//! distinct RTTs for same-rack / same-DC / cross-DC placements.

use crate::core::SimTime;

/// Relative placement of two VMs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Locality {
    SameRack,
    SameDatacenter,
    CrossDatacenter,
}

/// Simple but faithful latency/bandwidth model.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// One-way propagation per locality, µs.
    pub rtt_same_rack_us: u64,
    pub rtt_same_dc_us: u64,
    pub rtt_cross_dc_us: u64,
    /// NIC line rate, bytes/sec (10 Gb/s default).
    pub nic_bps: u64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel {
            rtt_same_rack_us: 50,
            rtt_same_dc_us: 200,
            rtt_cross_dc_us: 2_000,
            nic_bps: 1_250_000_000, // 10 Gb/s
        }
    }
}

impl NetworkModel {
    pub fn rtt(&self, locality: Locality) -> SimTime {
        let us = match locality {
            Locality::SameRack => self.rtt_same_rack_us,
            Locality::SameDatacenter => self.rtt_same_dc_us,
            Locality::CrossDatacenter => self.rtt_cross_dc_us,
        };
        SimTime::from_micros(us)
    }

    /// Serialization time for `bytes` at the NIC.
    pub fn transfer(&self, bytes: u64) -> SimTime {
        SimTime::from_micros(bytes * 1_000_000 / self.nic_bps)
    }

    /// Full request-response network time: RTT + both directions'
    /// serialization at the bottleneck NIC.
    pub fn round_trip(&self, locality: Locality, req_bytes: u64, resp_bytes: u64) -> SimTime {
        self.rtt(locality) + self.transfer(req_bytes + resp_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtt_ordering() {
        let m = NetworkModel::default();
        assert!(m.rtt(Locality::SameRack) < m.rtt(Locality::SameDatacenter));
        assert!(m.rtt(Locality::SameDatacenter) < m.rtt(Locality::CrossDatacenter));
    }

    #[test]
    fn transfer_scales_with_bytes() {
        let m = NetworkModel::default();
        // 1.25 GB/s -> 1 MB takes 800 µs.
        assert_eq!(m.transfer(1 << 20).as_micros(), 838);
        assert_eq!(m.transfer(0), SimTime::ZERO);
    }

    #[test]
    fn round_trip_composition() {
        let m = NetworkModel::default();
        let rt = m.round_trip(Locality::SameDatacenter, 100, 4096);
        assert_eq!(rt, m.rtt(Locality::SameDatacenter) + m.transfer(4196));
    }
}

//! Control-plane wire protocol between market participants and the
//! broker daemon, plus the magic-bytes/version handshake both planes
//! (control and data) perform before exchanging frames.
//!
//! The control protocol reuses the data plane's length-prefixed frame
//! codec ([`crate::net::wire`]) and scratch-buffer discipline: one tag
//! byte, then tag-specific fields, byte strings as `u32 LE` length +
//! bytes. Frames: `Register`, `Heartbeat` (harvester-reported available
//! slabs), `RequestSlabs`, grants, `Renew`, `Revoke`, `Release`,
//! `Deregister`, and their acks. Lease lifetimes travel as *remaining*
//! TTLs (`ttl_us`), never absolute deadlines, so participants need no
//! clock agreement.
//!
//! ## Handshake
//!
//! Every memtrade TCP connection opens with one hello frame each way:
//! 4 magic bytes naming the plane (`MTCP` control / `MTDP` data), a
//! `u16 LE` protocol version, and — since v3 — a `u32 LE` advertising
//! the most ops the sender accepts in one batch frame. The accepting
//! side answers with its own hello even when the peer's is wrong, so a
//! data-plane [`crate::net::tcp::KvClient`] dialing a broker port (or
//! vice versa, or a stale peer from before the handshake existed) fails
//! with a clear "wrong plane / wrong version" error instead of
//! desyncing on garbage frames. Batch capability rides the same check:
//! a pre-batching (v≤2) peer is refused at the handshake with the
//! version named, never sent a batch frame it would die decoding
//! mid-stream, and both sides cap outgoing batches at the pairwise
//! minimum of the advertised limits.
//!
//! Trace capability (v6) negotiates the same way: the hello carries a
//! flags byte whose bit 0 advertises tracing, data frames append a
//! 16-byte trace context only when *both* hellos advertised it, and a
//! pre-tracing peer is refused at the version check with its version
//! named — exactly the batch-cap discipline. Market verbs
//! (`RequestSlabs`/`Renew`/`Revoke`) carry a trace id inline (0 =
//! untraced), and `TraceQuery` fetches an endpoint's live span rings.

use crate::market::lease::LeaseEvent;
use crate::metrics::{HistogramSnapshot, Metric, MetricSet, HIST_BUCKETS};
use crate::net::faults::{FaultPlan, FaultyStream};
use crate::trace::{Span, SPAN_WORDS};
use crate::net::wire::{
    put_bytes, read_frame_into, read_frame_into_patient, take_bytes, take_u32, take_u64,
    write_frame, CodecError,
};
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Dialing side must hear a hello within this long — a silent or
/// non-memtrade peer yields a timeout error, not an indefinite hang.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Control calls are tiny; a response this late means the broker is
/// gone. Callers treat the timeout as connection loss and reconnect.
pub const CONTROL_CALL_TIMEOUT: Duration = Duration::from_secs(10);

/// `TcpStream::connect` with a bounded SYN wait, trying each resolved
/// address: a black-holed peer costs `timeout`, not the OS's ~2-minute
/// SYN retry schedule. Essential on paths that retry inline (the
/// consumer pool's maintenance runs on its data path).
pub fn connect_with_timeout(addr: &str, timeout: Duration) -> io::Result<TcpStream> {
    let mut last = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing")
    }))
}

/// Version of both wire protocols; bumped by the handshake-introducing
/// revision (v1 was the pre-handshake data plane, v2 the pre-batching
/// handshake), by the batch frames + negotiated batch cap (v3), by the
/// telemetry spine (v4: heartbeats carry observed data-plane
/// p99/ops-per-sec, and `StatsQuery`/`Stats` expose live metrics), and
/// by broker failover (v5: `ReplicaPoll`/`ReplicaEvents` replication
/// frames and the `NotPrimary` refusal a standby answers market verbs
/// with), and by end-to-end tracing (v6: hellos carry a tracing flags
/// byte, negotiated data frames append a trace context, market verbs
/// carry a trace id, histograms travel with exemplar trace ids, and
/// `TraceQuery`/`Traces` fetch live span rings).
pub const PROTOCOL_VERSION: u16 = 6;
/// Hello magic of the broker control plane.
pub const CONTROL_MAGIC: [u8; 4] = *b"MTCP";
/// Hello magic of the producer-store data plane.
pub const DATA_MAGIC: [u8; 4] = *b"MTDP";

/// Human name of the plane a hello magic identifies.
pub fn plane_name(magic: [u8; 4]) -> &'static str {
    match magic {
        CONTROL_MAGIC => "control",
        DATA_MAGIC => "data",
        _ => "unknown",
    }
}

/// What a valid peer hello negotiated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HelloInfo {
    /// Most ops the peer accepts in one batch frame. Senders cap their
    /// batches at `min(this, own MAX_BATCH_OPS)`, so a frame the peer
    /// cannot decode is never on the wire.
    pub max_batch_ops: u32,
    /// Peer advertised tracing (v6 flags bit 0). Data frames carry the
    /// trace-context suffix only when *both* sides advertised it, so a
    /// run with tracing disabled puts zero extra bytes on the wire.
    pub tracing: bool,
}

/// Hello flags (v6): bit 0 = this endpoint records + propagates traces.
const HELLO_FLAG_TRACING: u8 = 1;

/// v6 hello: magic (4) + version (2) + max batch ops (4) + flags (1).
const HELLO_LEN: usize = 11;

pub(crate) fn hello_payload(magic: [u8; 4]) -> [u8; HELLO_LEN] {
    let v = PROTOCOL_VERSION.to_le_bytes();
    let b = (crate::net::wire::MAX_BATCH_OPS as u32).to_le_bytes();
    let flags = if crate::trace::enabled() { HELLO_FLAG_TRACING } else { 0 };
    [
        magic[0], magic[1], magic[2], magic[3], v[0], v[1], b[0], b[1], b[2], b[3], flags,
    ]
}

pub(crate) fn check_hello(payload: &[u8], expected: [u8; 4]) -> Result<HelloInfo, String> {
    // Plane and version are judged from the v1-compatible prefix, so an
    // old (shorter-hello) peer gets told its *version* is wrong rather
    // than a generic length complaint.
    if payload.len() < 6 {
        return Err(format!(
            "peer did not answer the memtrade handshake ({}-byte frame)",
            payload.len()
        ));
    }
    let magic: [u8; 4] = payload[..4].try_into().unwrap();
    let version = u16::from_le_bytes(payload[4..6].try_into().unwrap());
    if magic != expected {
        return Err(format!(
            "peer speaks the memtrade {} plane v{version}, this endpoint speaks the {} \
             plane v{PROTOCOL_VERSION}",
            plane_name(magic),
            plane_name(expected)
        ));
    }
    if version != PROTOCOL_VERSION {
        return Err(format!(
            "peer speaks {} plane v{version}, this endpoint requires v{PROTOCOL_VERSION}",
            plane_name(magic)
        ));
    }
    if payload.len() != HELLO_LEN {
        return Err(format!(
            "malformed {} plane v{PROTOCOL_VERSION} hello ({}-byte frame, expected \
             {HELLO_LEN})",
            plane_name(magic),
            payload.len()
        ));
    }
    Ok(HelloInfo {
        max_batch_ops: u32::from_le_bytes(payload[6..10].try_into().unwrap()),
        tracing: payload[10] & HELLO_FLAG_TRACING != 0,
    })
}

fn handshake_err(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("handshake failed: {msg}"))
}

/// Dialing side of the handshake: send our hello, require a matching
/// one back. Errors name the plane/version mismatch explicitly; success
/// returns what the peer negotiated (its batch cap).
pub fn client_handshake<R: Read, W: Write>(
    r: &mut R,
    w: &mut W,
    magic: [u8; 4],
) -> io::Result<HelloInfo> {
    write_frame(w, &hello_payload(magic))?;
    let mut buf = Vec::with_capacity(HELLO_LEN + 2);
    read_frame_into(r, &mut buf)?;
    check_hello(&buf, magic).map_err(handshake_err)
}

/// Accepting side: read the peer's hello (timeout-tolerant, polling
/// `keep_going` like the serving loops do), then answer with ours — even
/// on mismatch, so the peer can print a clear error before we refuse.
/// Returns Ok(None) when told to stop before a hello arrived, and the
/// peer's negotiated [`HelloInfo`] on success.
pub fn server_handshake_patient<R: Read, W: Write>(
    r: &mut R,
    w: &mut W,
    magic: [u8; 4],
    keep_going: impl Fn() -> bool,
) -> io::Result<Option<HelloInfo>> {
    let mut buf = Vec::with_capacity(HELLO_LEN + 2);
    if !read_frame_into_patient(r, &mut buf, keep_going)? {
        return Ok(None);
    }
    match check_hello(&buf, magic) {
        Ok(info) => {
            write_frame(w, &hello_payload(magic))?;
            Ok(Some(info))
        }
        Err(msg) => {
            let _ = write_frame(w, &hello_payload(magic));
            Err(handshake_err(msg))
        }
    }
}

/// Why the broker refused a control request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefuseCode {
    UnknownLease,
    LeaseExpired,
    LeaseRevoked,
    LeaseReleased,
    UnknownProducer,
    NoCapacity,
    Malformed,
    /// This endpoint is a warm standby (v5): it replicates the primary's
    /// lease log but grants nothing until takeover. Clients advance to
    /// the next endpoint in their broker list instead of retrying here.
    NotPrimary,
}

impl RefuseCode {
    fn to_byte(self) -> u8 {
        match self {
            RefuseCode::UnknownLease => 1,
            RefuseCode::LeaseExpired => 2,
            RefuseCode::LeaseRevoked => 3,
            RefuseCode::LeaseReleased => 4,
            RefuseCode::UnknownProducer => 5,
            RefuseCode::NoCapacity => 6,
            RefuseCode::Malformed => 7,
            RefuseCode::NotPrimary => 8,
        }
    }

    fn from_byte(b: u8) -> Result<Self, CodecError> {
        Ok(match b {
            1 => RefuseCode::UnknownLease,
            2 => RefuseCode::LeaseExpired,
            3 => RefuseCode::LeaseRevoked,
            4 => RefuseCode::LeaseReleased,
            5 => RefuseCode::UnknownProducer,
            6 => RefuseCode::NoCapacity,
            7 => RefuseCode::Malformed,
            8 => RefuseCode::NotPrimary,
            t => return Err(CodecError::UnknownTag(t)),
        })
    }
}

/// One granted lease as told to the *consumer* (who must dial the
/// producer's data plane itself — the broker only brokers, §3).
#[derive(Clone, Debug, PartialEq)]
pub struct GrantInfo {
    pub lease: u64,
    pub producer: u64,
    /// Producer data-plane endpoint, `host:port`.
    pub endpoint: String,
    pub slabs: u32,
    pub slab_bytes: u64,
    /// Remaining lifetime at send time.
    pub ttl_us: u64,
    /// Agreed price, nano-dollars per slab-hour.
    pub price_nd_per_slab_hour: i64,
}

/// One granted lease as told to the *producer* in a heartbeat ack.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProducerGrant {
    pub lease: u64,
    pub consumer: u64,
    pub slabs: u32,
    pub slab_bytes: u64,
    pub ttl_us: u64,
}

/// Participant -> broker control requests.
#[derive(Clone, Debug, PartialEq)]
pub enum CtrlRequest {
    /// Producer agent announces itself and its data-plane endpoint.
    /// Availability is in *bytes* here — the agent only learns the
    /// market's slab granularity from the `Registered` answer.
    Register { producer: u64, capacity_gb: f32, endpoint: String, free_bytes: u64 },
    /// Periodic producer report: harvester-decided availability, plus
    /// the producer's *observed* data-plane telemetry over the last
    /// heartbeat window (v4) — the feedback loop that lets placement
    /// rank producers by measured tail latency instead of self-reports.
    Heartbeat {
        producer: u64,
        free_slabs: u32,
        used_gb: f32,
        cpu_headroom: f32,
        bandwidth_headroom: f32,
        /// p99 of the store's per-op service latency in the last window
        /// (µs; 0 = no traffic observed).
        observed_p99_us: u32,
        /// Data-plane ops/sec served in the last window.
        observed_ops_per_sec: u32,
    },
    /// Consumer asks for capacity; the broker answers with grants.
    /// `trace` (v6) is the caller's trace id — 0 when untraced — so the
    /// broker's grant handling records into the same causal chain.
    RequestSlabs { consumer: u64, slabs: u32, min_slabs: u32, ttl_us: u64, trace: u64 },
    /// Consumer extends a lease before it expires. The broker verifies
    /// `consumer` against the lease record — lease ids are guessable.
    /// `trace` (v6): caller's trace id, 0 when untraced.
    Renew { consumer: u64, lease: u64, trace: u64 },
    /// Consumer returns a lease early (graceful; identity verified).
    Release { consumer: u64, lease: u64 },
    /// Producer takes leased memory back early (harvester reclaim;
    /// identity verified). `trace` (v6): caller's trace id, 0 when
    /// untraced.
    Revoke { producer: u64, lease: u64, trace: u64 },
    /// Producer leaves the market; its leases are revoked.
    Deregister { producer: u64 },
    /// Ask this endpoint for its live metrics (v4). Served by the
    /// broker (market + per-producer observed telemetry) and by each
    /// producer agent's stats endpoint; `memtrade top` polls it.
    StatsQuery,
    /// Standby -> primary (v5): pull lease-log events from `from_seq`
    /// onward, at most `max` per answer. Pull keeps the primary's serve
    /// loop request/response like every other verb — no push channel,
    /// no replication-specific connection state.
    ReplicaPoll { from_seq: u64, max: u32 },
    /// Ask this endpoint for its newest recorded spans (v6), at most
    /// `max`. Served by the broker (primary *and* standby — a trace
    /// fetch must work exactly when the market is mid-anomaly) and by
    /// each producer agent's stats endpoint; `memtrade trace` calls it.
    TraceQuery { max: u32 },
}

/// Broker -> participant control responses.
#[derive(Clone, Debug, PartialEq)]
pub enum CtrlResponse {
    Registered {
        producer: u64,
        /// The broker's slab granularity, authoritative for this market.
        slab_bytes: u64,
    },
    HeartbeatAck {
        /// Authoritative store size: total bytes of this producer's
        /// active leases. The agent sizes its store to exactly this.
        target_bytes: u64,
        /// Leases granted since the last ack.
        granted: Vec<ProducerGrant>,
        /// Lease ids ended (expired/revoked/released) since the last ack.
        ended: Vec<u64>,
    },
    Grants { leases: Vec<GrantInfo> },
    Renewed { lease: u64, ttl_us: u64 },
    Released { lease: u64 },
    Revoked { lease: u64 },
    Deregistered { producer: u64 },
    /// Live metrics snapshot answering a [`CtrlRequest::StatsQuery`].
    Stats { uptime_us: u64, metrics: MetricSet },
    /// Lease-log slice answering a [`CtrlRequest::ReplicaPoll`] (v5).
    /// `first_seq` is the sequence of `events[0]` — or, with no events,
    /// the next sequence the log will assign. A `first_seq` above the
    /// polled `from_seq` means the primary compacted that span away; the
    /// standby tolerates the gap (re-registration at takeover repairs
    /// whatever it missed) and resumes from `first_seq`.
    ReplicaEvents { first_seq: u64, events: Vec<LeaseEvent> },
    /// Newest recorded spans answering a [`CtrlRequest::TraceQuery`]
    /// (v6), oldest first.
    Traces { spans: Vec<Span> },
    Refused { code: RefuseCode, detail: String },
}

const TAG_REGISTER: u8 = 64;
const TAG_HEARTBEAT: u8 = 65;
const TAG_REQUEST_SLABS: u8 = 66;
const TAG_RENEW: u8 = 67;
const TAG_RELEASE: u8 = 68;
const TAG_REVOKE: u8 = 69;
const TAG_DEREGISTER: u8 = 70;
const TAG_STATS_QUERY: u8 = 71;
const TAG_REPLICA_POLL: u8 = 72;
const TAG_TRACE_QUERY: u8 = 73;

const TAG_REGISTERED: u8 = 80;
const TAG_HEARTBEAT_ACK: u8 = 81;
const TAG_GRANTS: u8 = 82;
const TAG_RENEWED: u8 = 83;
const TAG_RELEASED: u8 = 84;
const TAG_REVOKED: u8 = 85;
const TAG_DEREGISTERED: u8 = 86;
const TAG_REFUSED: u8 = 87;
const TAG_STATS: u8 = 88;
const TAG_REPLICA_EVENTS: u8 = 89;
const TAG_TRACES: u8 = 90;

/// Wire kind bytes of one [`Metric`] inside a metric set.
const METRIC_COUNTER: u8 = 1;
const METRIC_GAUGE: u8 = 2;
const METRIC_HISTOGRAM: u8 = 3;

/// Append a [`MetricSet`]: `u32` entry count, then per entry the name
/// (length-prefixed bytes), a kind byte, and the kind's payload.
/// Histograms travel as their nonzero `(bucket, count)` pairs — at most
/// [`HIST_BUCKETS`], usually a handful — followed (v6) by their nonzero
/// `(bucket, exemplar trace id)` pairs, so `memtrade top` can name a
/// trace behind a remote endpoint's tail bucket.
fn put_metric_set(out: &mut Vec<u8>, m: &MetricSet) {
    out.extend_from_slice(&(m.len() as u32).to_le_bytes());
    for (name, metric) in m.iter() {
        put_bytes(out, name.as_bytes());
        match metric {
            Metric::Counter(v) => {
                out.push(METRIC_COUNTER);
                out.extend_from_slice(&v.to_le_bytes());
            }
            Metric::Gauge(v) => {
                out.push(METRIC_GAUGE);
                out.extend_from_slice(&(*v as u64).to_le_bytes());
            }
            Metric::Histogram(s) => {
                out.push(METRIC_HISTOGRAM);
                let nz = s.nonzero_buckets();
                out.push(nz.len() as u8);
                for (i, c) in nz {
                    out.push(i);
                    out.extend_from_slice(&c.to_le_bytes());
                }
                let ex = s.nonzero_exemplars();
                out.push(ex.len() as u8);
                for (i, t) in ex {
                    out.push(i);
                    out.extend_from_slice(&t.to_le_bytes());
                }
            }
        }
    }
}

/// Decode a [`MetricSet`] with allocation bounded by the frame itself:
/// a hostile entry count cannot reserve more than the frame could hold,
/// and histogram bucket/exemplar lists are each bounded by both
/// [`HIST_BUCKETS`] and the remaining bytes. The per-entry floor stays
/// 6 wire bytes (an empty histogram entry is 7 since the v6 exemplar
/// count byte, but a *lower* floor only loosens the bound) — NOT the 13
/// bytes of a counter entry; a tighter bound would refuse legitimately
/// encoded frames.
fn take_metric_set(buf: &[u8], off: &mut usize) -> Result<MetricSet, CodecError> {
    let n = take_u32(buf, off)? as usize;
    if n > buf.len() / 6 {
        return Err(CodecError::Truncated);
    }
    let mut m = MetricSet::new();
    for _ in 0..n {
        let name = take_string(buf, off)?;
        match take_u8(buf, off)? {
            METRIC_COUNTER => m.set_counter(name, take_u64(buf, off)?),
            METRIC_GAUGE => m.set_gauge(name, take_u64(buf, off)? as i64),
            METRIC_HISTOGRAM => {
                let k = take_u8(buf, off)? as usize;
                if k > HIST_BUCKETS || k * 9 > buf.len() - *off {
                    return Err(CodecError::Truncated);
                }
                let mut buckets = Vec::with_capacity(k);
                for _ in 0..k {
                    let idx = take_u8(buf, off)?;
                    if idx as usize >= HIST_BUCKETS {
                        return Err(CodecError::Truncated);
                    }
                    buckets.push((idx, take_u64(buf, off)?));
                }
                let e = take_u8(buf, off)? as usize;
                if e > HIST_BUCKETS || e * 9 > buf.len() - *off {
                    return Err(CodecError::Truncated);
                }
                let mut exemplars = Vec::with_capacity(e);
                for _ in 0..e {
                    let idx = take_u8(buf, off)?;
                    if idx as usize >= HIST_BUCKETS {
                        return Err(CodecError::Truncated);
                    }
                    exemplars.push((idx, take_u64(buf, off)?));
                }
                m.set_histogram(name, HistogramSnapshot::from_parts(&buckets, &exemplars));
            }
            t => return Err(CodecError::UnknownTag(t)),
        }
    }
    Ok(m)
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn take_f32(buf: &[u8], off: &mut usize) -> Result<f32, CodecError> {
    take_u32(buf, off).map(f32::from_bits)
}

fn take_i64(buf: &[u8], off: &mut usize) -> Result<i64, CodecError> {
    take_u64(buf, off).map(|v| v as i64)
}

fn take_u8(buf: &[u8], off: &mut usize) -> Result<u8, CodecError> {
    if buf.len() <= *off {
        return Err(CodecError::Truncated);
    }
    let v = buf[*off];
    *off += 1;
    Ok(v)
}

fn take_string(buf: &[u8], off: &mut usize) -> Result<String, CodecError> {
    String::from_utf8(take_bytes(buf, off)?).map_err(|_| CodecError::BadUtf8)
}

fn finish<T>(value: T, buf: &[u8], off: usize) -> Result<T, CodecError> {
    if off == buf.len() {
        Ok(value)
    } else {
        Err(CodecError::TrailingBytes)
    }
}

impl GrantInfo {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.lease.to_le_bytes());
        out.extend_from_slice(&self.producer.to_le_bytes());
        put_bytes(out, self.endpoint.as_bytes());
        out.extend_from_slice(&self.slabs.to_le_bytes());
        out.extend_from_slice(&self.slab_bytes.to_le_bytes());
        out.extend_from_slice(&self.ttl_us.to_le_bytes());
        out.extend_from_slice(&self.price_nd_per_slab_hour.to_le_bytes());
    }

    fn decode(buf: &[u8], off: &mut usize) -> Result<Self, CodecError> {
        Ok(GrantInfo {
            lease: take_u64(buf, off)?,
            producer: take_u64(buf, off)?,
            endpoint: take_string(buf, off)?,
            slabs: take_u32(buf, off)?,
            slab_bytes: take_u64(buf, off)?,
            ttl_us: take_u64(buf, off)?,
            price_nd_per_slab_hour: take_i64(buf, off)?,
        })
    }
}

impl ProducerGrant {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.lease.to_le_bytes());
        out.extend_from_slice(&self.consumer.to_le_bytes());
        out.extend_from_slice(&self.slabs.to_le_bytes());
        out.extend_from_slice(&self.slab_bytes.to_le_bytes());
        out.extend_from_slice(&self.ttl_us.to_le_bytes());
    }

    fn decode(buf: &[u8], off: &mut usize) -> Result<Self, CodecError> {
        Ok(ProducerGrant {
            lease: take_u64(buf, off)?,
            consumer: take_u64(buf, off)?,
            slabs: take_u32(buf, off)?,
            slab_bytes: take_u64(buf, off)?,
            ttl_us: take_u64(buf, off)?,
        })
    }
}

/// Wire kind bytes of one [`LeaseEvent`] inside a replica answer.
const EVENT_GRANTED: u8 = 1;
const EVENT_RENEWED: u8 = 2;
const EVENT_RELEASED: u8 = 3;
const EVENT_REVOKED: u8 = 4;
const EVENT_EXPIRED: u8 = 5;
const EVENT_PRODUCER_UP: u8 = 6;
const EVENT_PRODUCER_DOWN: u8 = 7;

/// Append one [`LeaseEvent`]: a kind byte, then kind-specific fields.
/// Lifetimes travel as remaining TTLs like every other control frame,
/// so the standby needs no clock agreement with the primary.
fn put_lease_event(out: &mut Vec<u8>, ev: &LeaseEvent) {
    match ev {
        LeaseEvent::Granted {
            lease,
            consumer,
            producer,
            slabs,
            slab_bytes,
            price_nd_per_slab_hour,
            ttl_us,
        } => {
            out.push(EVENT_GRANTED);
            out.extend_from_slice(&lease.to_le_bytes());
            out.extend_from_slice(&consumer.to_le_bytes());
            out.extend_from_slice(&producer.to_le_bytes());
            out.extend_from_slice(&slabs.to_le_bytes());
            out.extend_from_slice(&slab_bytes.to_le_bytes());
            out.extend_from_slice(&price_nd_per_slab_hour.to_le_bytes());
            out.extend_from_slice(&ttl_us.to_le_bytes());
        }
        LeaseEvent::Renewed { lease, ttl_us } => {
            out.push(EVENT_RENEWED);
            out.extend_from_slice(&lease.to_le_bytes());
            out.extend_from_slice(&ttl_us.to_le_bytes());
        }
        LeaseEvent::Released { lease } => {
            out.push(EVENT_RELEASED);
            out.extend_from_slice(&lease.to_le_bytes());
        }
        LeaseEvent::Revoked { lease } => {
            out.push(EVENT_REVOKED);
            out.extend_from_slice(&lease.to_le_bytes());
        }
        LeaseEvent::Expired { lease } => {
            out.push(EVENT_EXPIRED);
            out.extend_from_slice(&lease.to_le_bytes());
        }
        LeaseEvent::ProducerUp { producer, endpoint, capacity_gb } => {
            out.push(EVENT_PRODUCER_UP);
            out.extend_from_slice(&producer.to_le_bytes());
            put_bytes(out, endpoint.as_bytes());
            put_f32(out, *capacity_gb);
        }
        LeaseEvent::ProducerDown { producer } => {
            out.push(EVENT_PRODUCER_DOWN);
            out.extend_from_slice(&producer.to_le_bytes());
        }
    }
}

fn take_lease_event(buf: &[u8], off: &mut usize) -> Result<LeaseEvent, CodecError> {
    Ok(match take_u8(buf, off)? {
        EVENT_GRANTED => LeaseEvent::Granted {
            lease: take_u64(buf, off)?,
            consumer: take_u64(buf, off)?,
            producer: take_u64(buf, off)?,
            slabs: take_u32(buf, off)?,
            slab_bytes: take_u64(buf, off)?,
            price_nd_per_slab_hour: take_i64(buf, off)?,
            ttl_us: take_u64(buf, off)?,
        },
        EVENT_RENEWED => LeaseEvent::Renewed {
            lease: take_u64(buf, off)?,
            ttl_us: take_u64(buf, off)?,
        },
        EVENT_RELEASED => LeaseEvent::Released { lease: take_u64(buf, off)? },
        EVENT_REVOKED => LeaseEvent::Revoked { lease: take_u64(buf, off)? },
        EVENT_EXPIRED => LeaseEvent::Expired { lease: take_u64(buf, off)? },
        EVENT_PRODUCER_UP => LeaseEvent::ProducerUp {
            producer: take_u64(buf, off)?,
            endpoint: take_string(buf, off)?,
            capacity_gb: take_f32(buf, off)?,
        },
        EVENT_PRODUCER_DOWN => LeaseEvent::ProducerDown { producer: take_u64(buf, off)? },
        t => return Err(CodecError::UnknownTag(t)),
    })
}

/// Bytes of one [`Span`] on the wire: its [`SPAN_WORDS`] `u64 LE` words.
const SPAN_WIRE_BYTES: usize = SPAN_WORDS * 8;

fn put_span(out: &mut Vec<u8>, s: &Span) {
    for w in s.to_words() {
        out.extend_from_slice(&w.to_le_bytes());
    }
}

fn take_span(buf: &[u8], off: &mut usize) -> Result<Span, CodecError> {
    let mut w = [0u64; SPAN_WORDS];
    for word in w.iter_mut() {
        *word = take_u64(buf, off)?;
    }
    // An invalid role/op/status is a hostile or corrupt frame; the tag
    // word's low (role) byte names the offender.
    Span::from_words(&w).ok_or(CodecError::UnknownTag(w[3] as u8))
}

impl CtrlRequest {
    /// Append the encoded payload to `out` (does not clear it).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            CtrlRequest::Register { producer, capacity_gb, endpoint, free_bytes } => {
                out.push(TAG_REGISTER);
                out.extend_from_slice(&producer.to_le_bytes());
                put_f32(out, *capacity_gb);
                put_bytes(out, endpoint.as_bytes());
                out.extend_from_slice(&free_bytes.to_le_bytes());
            }
            CtrlRequest::Heartbeat {
                producer,
                free_slabs,
                used_gb,
                cpu_headroom,
                bandwidth_headroom,
                observed_p99_us,
                observed_ops_per_sec,
            } => {
                out.push(TAG_HEARTBEAT);
                out.extend_from_slice(&producer.to_le_bytes());
                out.extend_from_slice(&free_slabs.to_le_bytes());
                put_f32(out, *used_gb);
                put_f32(out, *cpu_headroom);
                put_f32(out, *bandwidth_headroom);
                out.extend_from_slice(&observed_p99_us.to_le_bytes());
                out.extend_from_slice(&observed_ops_per_sec.to_le_bytes());
            }
            CtrlRequest::RequestSlabs { consumer, slabs, min_slabs, ttl_us, trace } => {
                out.push(TAG_REQUEST_SLABS);
                out.extend_from_slice(&consumer.to_le_bytes());
                out.extend_from_slice(&slabs.to_le_bytes());
                out.extend_from_slice(&min_slabs.to_le_bytes());
                out.extend_from_slice(&ttl_us.to_le_bytes());
                out.extend_from_slice(&trace.to_le_bytes());
            }
            CtrlRequest::Renew { consumer, lease, trace } => {
                out.push(TAG_RENEW);
                out.extend_from_slice(&consumer.to_le_bytes());
                out.extend_from_slice(&lease.to_le_bytes());
                out.extend_from_slice(&trace.to_le_bytes());
            }
            CtrlRequest::Release { consumer, lease } => {
                out.push(TAG_RELEASE);
                out.extend_from_slice(&consumer.to_le_bytes());
                out.extend_from_slice(&lease.to_le_bytes());
            }
            CtrlRequest::Revoke { producer, lease, trace } => {
                out.push(TAG_REVOKE);
                out.extend_from_slice(&producer.to_le_bytes());
                out.extend_from_slice(&lease.to_le_bytes());
                out.extend_from_slice(&trace.to_le_bytes());
            }
            CtrlRequest::Deregister { producer } => {
                out.push(TAG_DEREGISTER);
                out.extend_from_slice(&producer.to_le_bytes());
            }
            CtrlRequest::StatsQuery => out.push(TAG_STATS_QUERY),
            CtrlRequest::ReplicaPoll { from_seq, max } => {
                out.push(TAG_REPLICA_POLL);
                out.extend_from_slice(&from_seq.to_le_bytes());
                out.extend_from_slice(&max.to_le_bytes());
            }
            CtrlRequest::TraceQuery { max } => {
                out.push(TAG_TRACE_QUERY);
                out.extend_from_slice(&max.to_le_bytes());
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<CtrlRequest, CodecError> {
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let mut off = 1usize;
        let o = &mut off;
        let req = match buf[0] {
            TAG_REGISTER => CtrlRequest::Register {
                producer: take_u64(buf, o)?,
                capacity_gb: take_f32(buf, o)?,
                endpoint: take_string(buf, o)?,
                free_bytes: take_u64(buf, o)?,
            },
            TAG_HEARTBEAT => CtrlRequest::Heartbeat {
                producer: take_u64(buf, o)?,
                free_slabs: take_u32(buf, o)?,
                used_gb: take_f32(buf, o)?,
                cpu_headroom: take_f32(buf, o)?,
                bandwidth_headroom: take_f32(buf, o)?,
                observed_p99_us: take_u32(buf, o)?,
                observed_ops_per_sec: take_u32(buf, o)?,
            },
            TAG_REQUEST_SLABS => CtrlRequest::RequestSlabs {
                consumer: take_u64(buf, o)?,
                slabs: take_u32(buf, o)?,
                min_slabs: take_u32(buf, o)?,
                ttl_us: take_u64(buf, o)?,
                trace: take_u64(buf, o)?,
            },
            TAG_RENEW => CtrlRequest::Renew {
                consumer: take_u64(buf, o)?,
                lease: take_u64(buf, o)?,
                trace: take_u64(buf, o)?,
            },
            TAG_RELEASE => CtrlRequest::Release {
                consumer: take_u64(buf, o)?,
                lease: take_u64(buf, o)?,
            },
            TAG_REVOKE => CtrlRequest::Revoke {
                producer: take_u64(buf, o)?,
                lease: take_u64(buf, o)?,
                trace: take_u64(buf, o)?,
            },
            TAG_DEREGISTER => CtrlRequest::Deregister { producer: take_u64(buf, o)? },
            TAG_STATS_QUERY => CtrlRequest::StatsQuery,
            TAG_REPLICA_POLL => CtrlRequest::ReplicaPoll {
                from_seq: take_u64(buf, o)?,
                max: take_u32(buf, o)?,
            },
            TAG_TRACE_QUERY => CtrlRequest::TraceQuery { max: take_u32(buf, o)? },
            t => return Err(CodecError::UnknownTag(t)),
        };
        finish(req, buf, off)
    }
}

impl CtrlResponse {
    /// Append the encoded payload to `out` (does not clear it).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            CtrlResponse::Registered { producer, slab_bytes } => {
                out.push(TAG_REGISTERED);
                out.extend_from_slice(&producer.to_le_bytes());
                out.extend_from_slice(&slab_bytes.to_le_bytes());
            }
            CtrlResponse::HeartbeatAck { target_bytes, granted, ended } => {
                out.push(TAG_HEARTBEAT_ACK);
                out.extend_from_slice(&target_bytes.to_le_bytes());
                out.extend_from_slice(&(granted.len() as u32).to_le_bytes());
                for g in granted {
                    g.encode_into(out);
                }
                out.extend_from_slice(&(ended.len() as u32).to_le_bytes());
                for id in ended {
                    out.extend_from_slice(&id.to_le_bytes());
                }
            }
            CtrlResponse::Grants { leases } => {
                out.push(TAG_GRANTS);
                out.extend_from_slice(&(leases.len() as u32).to_le_bytes());
                for g in leases {
                    g.encode_into(out);
                }
            }
            CtrlResponse::Renewed { lease, ttl_us } => {
                out.push(TAG_RENEWED);
                out.extend_from_slice(&lease.to_le_bytes());
                out.extend_from_slice(&ttl_us.to_le_bytes());
            }
            CtrlResponse::Released { lease } => {
                out.push(TAG_RELEASED);
                out.extend_from_slice(&lease.to_le_bytes());
            }
            CtrlResponse::Revoked { lease } => {
                out.push(TAG_REVOKED);
                out.extend_from_slice(&lease.to_le_bytes());
            }
            CtrlResponse::Deregistered { producer } => {
                out.push(TAG_DEREGISTERED);
                out.extend_from_slice(&producer.to_le_bytes());
            }
            CtrlResponse::Stats { uptime_us, metrics } => {
                out.push(TAG_STATS);
                out.extend_from_slice(&uptime_us.to_le_bytes());
                put_metric_set(out, metrics);
            }
            CtrlResponse::ReplicaEvents { first_seq, events } => {
                out.push(TAG_REPLICA_EVENTS);
                out.extend_from_slice(&first_seq.to_le_bytes());
                out.extend_from_slice(&(events.len() as u32).to_le_bytes());
                for ev in events {
                    put_lease_event(out, ev);
                }
            }
            CtrlResponse::Traces { spans } => {
                out.push(TAG_TRACES);
                out.extend_from_slice(&(spans.len() as u32).to_le_bytes());
                for s in spans {
                    put_span(out, s);
                }
            }
            CtrlResponse::Refused { code, detail } => {
                out.push(TAG_REFUSED);
                out.push(code.to_byte());
                put_bytes(out, detail.as_bytes());
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<CtrlResponse, CodecError> {
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let mut off = 1usize;
        let o = &mut off;
        let resp = match buf[0] {
            TAG_REGISTERED => CtrlResponse::Registered {
                producer: take_u64(buf, o)?,
                slab_bytes: take_u64(buf, o)?,
            },
            TAG_HEARTBEAT_ACK => {
                let target_bytes = take_u64(buf, o)?;
                // Pre-allocation bound: each element needs at least its
                // fixed wire size, so a hostile count can't force a
                // huge allocation out of a small frame.
                let n = take_u32(buf, o)? as usize;
                if n > buf.len() / 32 {
                    return Err(CodecError::Truncated);
                }
                let mut granted = Vec::with_capacity(n);
                for _ in 0..n {
                    granted.push(ProducerGrant::decode(buf, o)?);
                }
                let m = take_u32(buf, o)? as usize;
                if m > buf.len() / 8 {
                    return Err(CodecError::Truncated);
                }
                let mut ended = Vec::with_capacity(m);
                for _ in 0..m {
                    ended.push(take_u64(buf, o)?);
                }
                CtrlResponse::HeartbeatAck { target_bytes, granted, ended }
            }
            TAG_GRANTS => {
                let n = take_u32(buf, o)? as usize;
                if n > buf.len() / 44 {
                    return Err(CodecError::Truncated);
                }
                let mut leases = Vec::with_capacity(n);
                for _ in 0..n {
                    leases.push(GrantInfo::decode(buf, o)?);
                }
                CtrlResponse::Grants { leases }
            }
            TAG_RENEWED => CtrlResponse::Renewed {
                lease: take_u64(buf, o)?,
                ttl_us: take_u64(buf, o)?,
            },
            TAG_RELEASED => CtrlResponse::Released { lease: take_u64(buf, o)? },
            TAG_REVOKED => CtrlResponse::Revoked { lease: take_u64(buf, o)? },
            TAG_DEREGISTERED => CtrlResponse::Deregistered { producer: take_u64(buf, o)? },
            TAG_STATS => CtrlResponse::Stats {
                uptime_us: take_u64(buf, o)?,
                metrics: take_metric_set(buf, o)?,
            },
            TAG_REPLICA_EVENTS => {
                let first_seq = take_u64(buf, o)?;
                // Per-event wire floor is 9 bytes (kind + one u64 id),
                // so a hostile count can't reserve more than the frame
                // could hold.
                let n = take_u32(buf, o)? as usize;
                if n > buf.len() / 9 {
                    return Err(CodecError::Truncated);
                }
                let mut events = Vec::with_capacity(n);
                for _ in 0..n {
                    events.push(take_lease_event(buf, o)?);
                }
                CtrlResponse::ReplicaEvents { first_seq, events }
            }
            TAG_TRACES => {
                // Spans are fixed-size, so the count bound is exact.
                let n = take_u32(buf, o)? as usize;
                if n > buf.len() / SPAN_WIRE_BYTES {
                    return Err(CodecError::Truncated);
                }
                let mut spans = Vec::with_capacity(n);
                for _ in 0..n {
                    spans.push(take_span(buf, o)?);
                }
                CtrlResponse::Traces { spans }
            }
            TAG_REFUSED => CtrlResponse::Refused {
                code: RefuseCode::from_byte(take_u8(buf, o)?)?,
                detail: take_string(buf, o)?,
            },
            t => return Err(CodecError::UnknownTag(t)),
        };
        finish(resp, buf, off)
    }
}

/// Blocking control-plane client: one handshaked TCP connection to the
/// broker, with reusable frame buffers like [`crate::net::tcp::KvClient`].
pub struct CtrlClient {
    reader: BufReader<FaultyStream>,
    writer: BufWriter<FaultyStream>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl CtrlClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        Self::from_stream(FaultyStream::clean(TcpStream::connect(addr)?), HANDSHAKE_TIMEOUT)
    }

    /// [`Self::connect`] with the whole attempt bounded — dial *and*
    /// handshake — for reconnect paths that must not stall their caller.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> io::Result<Self> {
        let stream = connect_with_timeout(addr, timeout)?;
        Self::from_stream(FaultyStream::clean(stream), timeout.min(HANDSHAKE_TIMEOUT))
    }

    /// [`Self::connect_timeout`] with a fault schedule installed: the
    /// connection becomes `plan`'s `conn`-th deterministic stream.
    pub fn connect_faulty(
        addr: &str,
        timeout: Duration,
        plan: &FaultPlan,
        conn: u64,
    ) -> io::Result<Self> {
        let stream = connect_with_timeout(addr, timeout)?;
        Self::from_stream(
            FaultyStream::new(stream, Some(plan), conn),
            timeout.min(HANDSHAKE_TIMEOUT),
        )
    }

    fn from_stream(stream: FaultyStream, handshake_timeout: Duration) -> io::Result<Self> {
        // Control RPCs are small request/response frames: without
        // nodelay, Nagle holds the request tail for the delayed ACK.
        stream.set_nodelay(true)?;
        // Bounded reads for the connection's whole life: a hello (or any
        // control response) that never arrives is an error, not a hang —
        // a blocked call here would wedge agent/pool maintenance loops.
        stream.set_read_timeout(Some(handshake_timeout))?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        client_handshake(&mut reader, &mut writer, CONTROL_MAGIC)?;
        reader.get_ref().set_read_timeout(Some(CONTROL_CALL_TIMEOUT))?;
        Ok(CtrlClient { reader, writer, send_buf: Vec::new(), recv_buf: Vec::new() })
    }

    /// Override the per-call response deadline (default
    /// [`CONTROL_CALL_TIMEOUT`]). Chaos scenarios tighten this so a
    /// dropped control frame costs milliseconds, not ten seconds of a
    /// wedged maintenance loop.
    pub fn set_call_timeout(&mut self, timeout: Duration) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(Some(timeout))
    }

    /// One control request/response exchange. A read timeout surfaces as
    /// an error; the connection is then desynced and must be dropped
    /// (every in-tree caller reconnects on `Err`).
    pub fn call(&mut self, req: &CtrlRequest) -> io::Result<CtrlResponse> {
        self.send_buf.clear();
        req.encode_into(&mut self.send_buf);
        write_frame(&mut self.writer, &self.send_buf)?;
        read_frame_into(&mut self.reader, &mut self.recv_buf)?;
        CtrlResponse::decode(&self.recv_buf)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn grant(i: u64) -> GrantInfo {
        GrantInfo {
            lease: i,
            producer: 10 + i,
            endpoint: format!("127.0.0.1:{}", 7000 + i),
            slabs: 4,
            slab_bytes: 64 << 20,
            ttl_us: 5_000_000,
            price_nd_per_slab_hour: 42_000,
        }
    }

    #[test]
    fn request_round_trip() {
        let cases = vec![
            CtrlRequest::Register {
                producer: 7,
                capacity_gb: 31.5,
                endpoint: "10.0.0.2:7077".into(),
                free_bytes: 4 << 30,
            },
            CtrlRequest::Heartbeat {
                producer: 7,
                free_slabs: 48,
                used_gb: 3.25,
                cpu_headroom: 0.9,
                bandwidth_headroom: 0.5,
                observed_p99_us: 740,
                observed_ops_per_sec: 12_500,
            },
            CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 16,
                min_slabs: 1,
                ttl_us: 1,
                trace: 0xDEAD_BEEF,
            },
            CtrlRequest::Renew { consumer: 9, lease: 3, trace: 0 },
            CtrlRequest::Release { consumer: 9, lease: 4 },
            CtrlRequest::Revoke { producer: 7, lease: 5, trace: 11 },
            CtrlRequest::Deregister { producer: 7 },
            CtrlRequest::StatsQuery,
            CtrlRequest::ReplicaPoll { from_seq: 42, max: 256 },
            CtrlRequest::TraceQuery { max: 512 },
        ];
        for req in cases {
            let enc = req.encode();
            assert_eq!(CtrlRequest::decode(&enc).unwrap(), req, "{req:?}");
        }
    }

    #[test]
    fn response_round_trip() {
        let cases = vec![
            CtrlResponse::Registered { producer: 7, slab_bytes: 64 << 20 },
            CtrlResponse::HeartbeatAck {
                target_bytes: 1 << 30,
                granted: vec![
                    ProducerGrant {
                        lease: 1,
                        consumer: 9,
                        slabs: 4,
                        slab_bytes: 64 << 20,
                        ttl_us: 1_000_000,
                    },
                ],
                ended: vec![2, 3],
            },
            CtrlResponse::HeartbeatAck { target_bytes: 0, granted: vec![], ended: vec![] },
            CtrlResponse::Grants { leases: vec![grant(1), grant(2)] },
            CtrlResponse::Grants { leases: vec![] },
            CtrlResponse::Renewed { lease: 3, ttl_us: 9 },
            CtrlResponse::Released { lease: 4 },
            CtrlResponse::Revoked { lease: 5 },
            CtrlResponse::Deregistered { producer: 7 },
            CtrlResponse::Stats { uptime_us: 123_456, metrics: MetricSet::new() },
            CtrlResponse::Stats {
                uptime_us: 1,
                metrics: {
                    let mut m = MetricSet::new();
                    m.set_counter("ctrl.heartbeats", 42);
                    m.set_gauge("market.producers", -1);
                    let h = crate::metrics::Histogram::new();
                    for v in [0u64, 3, 90, 90, 5_000, 1 << 40] {
                        h.record(v);
                    }
                    m.set_histogram("data.op_us", h.snapshot());
                    // Exemplar-pinned samples must survive the wire (v6).
                    let ht = crate::metrics::Histogram::new();
                    ht.record_traced(4_096, 0xFACE);
                    ht.record_traced(12, 0xBEEF);
                    m.set_histogram("data.call_us", ht.snapshot());
                    m
                },
            },
            CtrlResponse::ReplicaEvents {
                first_seq: 17,
                events: vec![
                    LeaseEvent::Granted {
                        lease: 3,
                        consumer: 9,
                        producer: 7,
                        slabs: 4,
                        slab_bytes: 64 << 20,
                        price_nd_per_slab_hour: 42_000,
                        ttl_us: 5_000_000,
                    },
                    LeaseEvent::Renewed { lease: 3, ttl_us: 5_000_000 },
                    LeaseEvent::Released { lease: 3 },
                    LeaseEvent::Revoked { lease: 4 },
                    LeaseEvent::Expired { lease: 5 },
                    LeaseEvent::ProducerUp {
                        producer: 7,
                        endpoint: "10.0.0.2:7077".into(),
                        capacity_gb: 31.5,
                    },
                    LeaseEvent::ProducerDown { producer: 7 },
                ],
            },
            CtrlResponse::ReplicaEvents { first_seq: 0, events: vec![] },
            CtrlResponse::Traces {
                spans: vec![
                    Span {
                        trace_id: 0xABCD,
                        span_id: 1,
                        parent: 0,
                        role: crate::trace::Role::Consumer,
                        op: crate::trace::Op::MultiGet,
                        status: crate::trace::Status::Ok,
                        t_start_us: 10,
                        dur_us: 900,
                        lease_id: 0,
                        producer_id: 0,
                    },
                    Span {
                        trace_id: 0xABCD,
                        span_id: 2,
                        parent: 1,
                        role: crate::trace::Role::Producer,
                        op: crate::trace::Op::Shard,
                        status: crate::trace::Status::Miss,
                        t_start_us: 12,
                        dur_us: 340,
                        lease_id: 5,
                        producer_id: 7,
                    },
                ],
            },
            CtrlResponse::Traces { spans: vec![] },
            CtrlResponse::Refused { code: RefuseCode::LeaseExpired, detail: "late".into() },
            CtrlResponse::Refused { code: RefuseCode::NotPrimary, detail: "standby".into() },
        ];
        for resp in cases {
            let enc = resp.encode();
            assert_eq!(CtrlResponse::decode(&enc).unwrap(), resp, "{resp:?}");
        }
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(CtrlRequest::decode(&[]), Err(CodecError::Truncated));
        assert_eq!(CtrlRequest::decode(&[1]), Err(CodecError::UnknownTag(1)));
        let mut ok = CtrlRequest::Renew { consumer: 9, lease: 1, trace: 0 }.encode();
        ok.push(0);
        assert_eq!(CtrlRequest::decode(&ok), Err(CodecError::TrailingBytes));
        assert_eq!(CtrlResponse::decode(&[TAG_REFUSED, 99]), Err(CodecError::UnknownTag(99)));
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = Rng::new(77);
        for _ in 0..20_000 {
            let len = rng.below(96) as usize;
            let mut buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = CtrlRequest::decode(&buf);
            let _ = CtrlResponse::decode(&buf);
            // Bias toward valid tags so field decoding is fuzzed too.
            if !buf.is_empty() {
                buf[0] = 64 + (rng.below(28) as u8);
                let _ = CtrlRequest::decode(&buf);
                let _ = CtrlResponse::decode(&buf);
            }
        }
    }

    #[test]
    fn stats_decode_bounds_hostile_counts() {
        // A tiny frame declaring 2^32-1 metric entries must be refused
        // before any table is reserved.
        let mut buf = vec![TAG_STATS];
        buf.extend_from_slice(&7u64.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(CtrlResponse::decode(&buf), Err(CodecError::Truncated));
        // Same for a histogram whose bucket index is out of range.
        let mut m = MetricSet::new();
        m.set_counter("x", 1);
        let mut ok = CtrlResponse::Stats { uptime_us: 1, metrics: m }.encode();
        // name "x" is 4(len)+1 bytes at offset 13; kind at 18; value 19..27.
        ok[18] = METRIC_HISTOGRAM;
        ok[19] = 1; // one bucket pair
        ok[20] = 64; // bucket index out of range
        assert!(CtrlResponse::decode(&ok).is_err());
    }

    #[test]
    fn traces_decode_bounds_hostile_counts() {
        // A tiny frame declaring 2^32-1 spans must be refused before
        // any span list is reserved.
        let mut buf = vec![TAG_TRACES];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(CtrlResponse::decode(&buf), Err(CodecError::Truncated));
        // A span whose packed role/op/status word is invalid is an
        // error, not a silently mangled span.
        let mut buf = vec![TAG_TRACES];
        buf.extend_from_slice(&1u32.to_le_bytes());
        for w in [1u64, 2, 0, 0xFF, 5, 6, 7, 8] {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        assert_eq!(CtrlResponse::decode(&buf), Err(CodecError::UnknownTag(0xFF)));
    }

    #[test]
    fn replica_events_decode_bounds_hostile_counts() {
        // A tiny frame declaring 2^32-1 events must be refused before
        // any event list is reserved.
        let mut buf = vec![TAG_REPLICA_EVENTS];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert_eq!(CtrlResponse::decode(&buf), Err(CodecError::Truncated));
        // An unknown event kind is an error, not a skip.
        let mut buf = vec![TAG_REPLICA_EVENTS];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(99);
        buf.extend_from_slice(&7u64.to_le_bytes());
        assert_eq!(CtrlResponse::decode(&buf), Err(CodecError::UnknownTag(99)));
    }

    #[test]
    fn hello_mismatch_names_planes() {
        let err = check_hello(&hello_payload(DATA_MAGIC), CONTROL_MAGIC).unwrap_err();
        assert!(err.contains("data plane"), "{err}");
        assert!(err.contains("control plane"), "{err}");
        let err = check_hello(b"junk!", CONTROL_MAGIC).unwrap_err();
        assert!(err.contains("handshake"), "{err}");
        let info = check_hello(&hello_payload(CONTROL_MAGIC), CONTROL_MAGIC).unwrap();
        assert_eq!(info.max_batch_ops, crate::net::wire::MAX_BATCH_OPS as u32);
    }

    #[test]
    fn pre_batching_peer_is_refused_with_its_version_named() {
        // A v2 peer sent a 6-byte hello (magic + version, no batch cap).
        // It must be refused with the version mismatch spelled out — the
        // clear "wrong version" error — instead of ever being sent a
        // batch frame it would die decoding mid-stream.
        let mut old = Vec::new();
        old.extend_from_slice(&DATA_MAGIC);
        old.extend_from_slice(&2u16.to_le_bytes());
        let err = check_hello(&old, DATA_MAGIC).unwrap_err();
        assert!(err.contains("v2"), "{err}");
        assert!(err.contains("requires v6"), "{err}");
        // A pre-tracing v5 peer (10-byte hello, no flags byte) is
        // refused the same way: version named, never sent a trace-
        // suffixed frame it would reject as trailing bytes.
        let mut v5 = Vec::new();
        v5.extend_from_slice(&DATA_MAGIC);
        v5.extend_from_slice(&5u16.to_le_bytes());
        v5.extend_from_slice(&1024u32.to_le_bytes());
        let err = check_hello(&v5, DATA_MAGIC).unwrap_err();
        assert!(err.contains("v5"), "{err}");
        assert!(err.contains("requires v6"), "{err}");
        // A current-versioned hello of the wrong shape is named malformed.
        let mut bad = hello_payload(DATA_MAGIC).to_vec();
        bad.push(0);
        let err = check_hello(&bad, DATA_MAGIC).unwrap_err();
        assert!(err.contains("malformed"), "{err}");
    }

    #[test]
    fn handshake_over_pipes() {
        // Client and server halves over in-memory buffers.
        let mut c2s = Vec::new();
        write_frame(&mut c2s, &hello_payload(DATA_MAGIC)).unwrap();
        let mut s_out = Vec::new();
        let info = server_handshake_patient(
            &mut std::io::Cursor::new(c2s),
            &mut s_out,
            DATA_MAGIC,
            || true,
        )
        .unwrap()
        .expect("handshake must complete");
        assert_eq!(info.max_batch_ops, crate::net::wire::MAX_BATCH_OPS as u32);
        assert!(info.tracing, "default-enabled tracing must be advertised");
        // The server's answer satisfies the client side and carries the
        // same negotiated batch cap.
        let mut c_out = Vec::new();
        let info =
            client_handshake(&mut std::io::Cursor::new(s_out), &mut c_out, DATA_MAGIC).unwrap();
        assert_eq!(info.max_batch_ops, crate::net::wire::MAX_BATCH_OPS as u32);
        assert!(info.tracing);
    }

    #[test]
    fn server_refuses_wrong_plane_but_still_answers() {
        let mut c2s = Vec::new();
        write_frame(&mut c2s, &hello_payload(CONTROL_MAGIC)).unwrap();
        let mut s_out = Vec::new();
        let err = server_handshake_patient(
            &mut std::io::Cursor::new(c2s),
            &mut s_out,
            DATA_MAGIC,
            || true,
        )
        .unwrap_err();
        assert!(err.to_string().contains("control plane"), "{err}");
        // The refusing server still sent its own hello for diagnosis.
        assert!(!s_out.is_empty());
    }
}

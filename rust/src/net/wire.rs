//! Binary wire protocol between consumers and producer stores.
//!
//! Frame layout: `u32 LE` payload length, then payload. Payload: one tag
//! byte, then tag-specific fields; byte strings are `u32 LE` length +
//! bytes. No external serialization deps — the codec is exhaustively
//! round-trip and fuzz tested below.
//!
//! Two decoding layers: [`RequestRef`] borrows key/value slices straight
//! out of the frame buffer (the server's zero-allocation path), while
//! [`Request`]/[`Response`] are the owned forms used by clients, the
//! in-process manager, and the simulator. Encoders append into
//! caller-owned buffers (`encode_into`) so steady-state connections
//! reuse one scratch buffer per direction.

use std::io::{self, Read, Write};

/// Consumer -> producer-store requests (paper §4.2: GET / PUT / DELETE).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    Get { key: Vec<u8> },
    Put { key: Vec<u8>, value: Vec<u8> },
    Delete { key: Vec<u8> },
    Ping,
}

/// Borrowed view of a [`Request`], decoded without copying key or value
/// bytes out of the frame buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestRef<'a> {
    Get { key: &'a [u8] },
    Put { key: &'a [u8], value: &'a [u8] },
    Delete { key: &'a [u8] },
    Ping,
}

/// Producer-store -> consumer responses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// GET hit.
    Value(Vec<u8>),
    /// GET miss (evicted or never stored).
    NotFound,
    /// PUT accepted.
    Stored,
    /// PUT rejected (store full of larger-than-capacity object).
    Rejected,
    /// DELETE outcome.
    Deleted(bool),
    /// Rate limiter refused the I/O (paper §4.2); retry after the hint.
    Throttled { retry_after_us: u64 },
    Pong,
    Error(String),
}

const TAG_GET: u8 = 1;
const TAG_PUT: u8 = 2;
const TAG_DELETE: u8 = 3;
const TAG_PING: u8 = 4;

const TAG_VALUE: u8 = 10;
const TAG_NOT_FOUND: u8 = 11;
const TAG_STORED: u8 = 12;
const TAG_REJECTED: u8 = 13;
const TAG_DELETED: u8 = 14;
const TAG_THROTTLED: u8 = 15;
const TAG_PONG: u8 = 16;
const TAG_ERROR: u8 = 17;

/// Hard cap on frame size (16 MB) — malformed/hostile lengths are
/// rejected rather than allocated.
pub const MAX_FRAME: usize = 16 << 20;

pub(crate) fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn take_bytes_ref<'a>(buf: &'a [u8], off: &mut usize) -> Result<&'a [u8], CodecError> {
    let len = take_u32(buf, off)? as usize;
    if buf.len() - *off < len {
        return Err(CodecError::Truncated);
    }
    let out = &buf[*off..*off + len];
    *off += len;
    Ok(out)
}

pub(crate) fn take_bytes(buf: &[u8], off: &mut usize) -> Result<Vec<u8>, CodecError> {
    take_bytes_ref(buf, off).map(|b| b.to_vec())
}

pub(crate) fn take_u32(buf: &[u8], off: &mut usize) -> Result<u32, CodecError> {
    if buf.len() - *off < 4 {
        return Err(CodecError::Truncated);
    }
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

pub(crate) fn take_u64(buf: &[u8], off: &mut usize) -> Result<u64, CodecError> {
    if buf.len() - *off < 8 {
        return Err(CodecError::Truncated);
    }
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    Truncated,
    UnknownTag(u8),
    TrailingBytes,
    FrameTooLarge(usize),
    BadUtf8,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for CodecError {}

impl<'a> RequestRef<'a> {
    /// Decode a request, borrowing key/value bytes from `buf`.
    pub fn decode(buf: &'a [u8]) -> Result<RequestRef<'a>, CodecError> {
        let mut off = 0usize;
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let tag = buf[0];
        off += 1;
        let req = match tag {
            TAG_GET => RequestRef::Get { key: take_bytes_ref(buf, &mut off)? },
            TAG_PUT => RequestRef::Put {
                key: take_bytes_ref(buf, &mut off)?,
                value: take_bytes_ref(buf, &mut off)?,
            },
            TAG_DELETE => RequestRef::Delete { key: take_bytes_ref(buf, &mut off)? },
            TAG_PING => RequestRef::Ping,
            t => return Err(CodecError::UnknownTag(t)),
        };
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(req)
    }

    /// Copy into the owned form.
    pub fn to_owned(self) -> Request {
        match self {
            RequestRef::Get { key } => Request::Get { key: key.to_vec() },
            RequestRef::Put { key, value } => {
                Request::Put { key: key.to_vec(), value: value.to_vec() }
            }
            RequestRef::Delete { key } => Request::Delete { key: key.to_vec() },
            RequestRef::Ping => Request::Ping,
        }
    }

    /// Append the encoded payload to `out` (does not clear it). This is
    /// the single encoder: the owned [`Request`] delegates here.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            RequestRef::Get { key } => {
                out.push(TAG_GET);
                put_bytes(out, key);
            }
            RequestRef::Put { key, value } => {
                out.push(TAG_PUT);
                put_bytes(out, key);
                put_bytes(out, value);
            }
            RequestRef::Delete { key } => {
                out.push(TAG_DELETE);
                put_bytes(out, key);
            }
            RequestRef::Ping => out.push(TAG_PING),
        }
    }

    /// Exact bytes on the wire (frame header + payload), without
    /// encoding.
    pub fn wire_bytes(&self) -> usize {
        4 + 1
            + match self {
                RequestRef::Get { key } | RequestRef::Delete { key } => 4 + key.len(),
                RequestRef::Put { key, value } => 8 + key.len() + value.len(),
                RequestRef::Ping => 0,
            }
    }
}

impl Request {
    /// Borrowed view (for allocation-free encoding of owned requests).
    pub fn to_ref(&self) -> RequestRef<'_> {
        match self {
            Request::Get { key } => RequestRef::Get { key },
            Request::Put { key, value } => RequestRef::Put { key, value },
            Request::Delete { key } => RequestRef::Delete { key },
            Request::Ping => RequestRef::Ping,
        }
    }

    /// Append the encoded payload to `out` (does not clear it).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.to_ref().encode_into(out)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Request, CodecError> {
        RequestRef::decode(buf).map(RequestRef::to_owned)
    }

    /// Exact bytes on the wire (frame header + payload), computed without
    /// encoding (used for bandwidth accounting on the simulator hot path).
    pub fn wire_bytes(&self) -> usize {
        self.to_ref().wire_bytes()
    }
}

/// Append a `Response::Value` payload built from a borrowed value slice:
/// the server's zero-copy GET path encodes straight from the store's
/// entry into the connection's reusable output buffer.
pub fn encode_value_response(out: &mut Vec<u8>, value: &[u8]) {
    out.push(TAG_VALUE);
    put_bytes(out, value);
}

impl Response {
    /// Append the encoded payload to `out` (does not clear it).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Response::Value(v) => encode_value_response(out, v),
            Response::NotFound => out.push(TAG_NOT_FOUND),
            Response::Stored => out.push(TAG_STORED),
            Response::Rejected => out.push(TAG_REJECTED),
            Response::Deleted(ok) => {
                out.push(TAG_DELETED);
                out.push(*ok as u8);
            }
            Response::Throttled { retry_after_us } => {
                out.push(TAG_THROTTLED);
                out.extend_from_slice(&retry_after_us.to_le_bytes());
            }
            Response::Pong => out.push(TAG_PONG),
            Response::Error(msg) => {
                out.push(TAG_ERROR);
                put_bytes(out, msg.as_bytes());
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Response, CodecError> {
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let mut off = 1usize;
        let resp = match buf[0] {
            TAG_VALUE => Response::Value(take_bytes(buf, &mut off)?),
            TAG_NOT_FOUND => Response::NotFound,
            TAG_STORED => Response::Stored,
            TAG_REJECTED => Response::Rejected,
            TAG_DELETED => {
                if buf.len() < 2 {
                    return Err(CodecError::Truncated);
                }
                off += 1;
                Response::Deleted(buf[1] != 0)
            }
            TAG_THROTTLED => Response::Throttled { retry_after_us: take_u64(buf, &mut off)? },
            TAG_PONG => Response::Pong,
            TAG_ERROR => {
                let bytes = take_bytes(buf, &mut off)?;
                Response::Error(String::from_utf8(bytes).map_err(|_| CodecError::BadUtf8)?)
            }
            t => return Err(CodecError::UnknownTag(t)),
        };
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(resp)
    }

    /// Exact bytes on the wire (frame header + payload), without encoding.
    pub fn wire_bytes(&self) -> usize {
        4 + 1
            + match self {
                Response::Value(v) => 4 + v.len(),
                Response::NotFound
                | Response::Stored
                | Response::Rejected
                | Response::Pong => 0,
                Response::Deleted(_) => 1,
                Response::Throttled { .. } => 8,
                Response::Error(msg) => 4 + msg.len(),
            }
    }
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame into a reusable buffer (resized in
/// place and fully overwritten; steady state performs no allocation, and
/// no redundant zero-fill of bytes `read_exact` is about to overwrite).
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            CodecError::FrameTooLarge(len),
        ));
    }
    buf.resize(len, 0);
    r.read_exact(buf)?;
    Ok(())
}

/// Read one length-prefixed frame into a fresh buffer.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    read_frame_into(r, &mut buf)?;
    Ok(buf)
}

/// `read_exact` that survives read timeouts without losing data: plain
/// `read_exact` discards whatever it consumed before a `WouldBlock`/
/// `TimedOut`, desynchronizing the frame stream if the peer stalls
/// mid-frame. This loop keeps partial progress and polls `keep_going`
/// at every timeout tick; returns Ok(false) when told to stop.
fn read_exact_interruptible<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    keep_going: &impl Fn() -> bool,
) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if !keep_going() {
            return Ok(false);
        }
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// [`read_frame_into`] for sockets with a read timeout: tolerates
/// mid-frame timeouts without desync, polling `keep_going` while
/// waiting. Returns Ok(true) with a complete frame in `buf`, Ok(false)
/// if `keep_going` said to stop, or the I/O / frame-size error.
pub fn read_frame_into_patient<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    keep_going: impl Fn() -> bool,
) -> io::Result<bool> {
    let mut len_buf = [0u8; 4];
    if !read_exact_interruptible(r, &mut len_buf, &keep_going)? {
        return Ok(false);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            CodecError::FrameTooLarge(len),
        ));
    }
    buf.resize(len, 0);
    if !read_exact_interruptible(r, buf, &keep_going)? {
        return Ok(false);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn request_round_trip() {
        let cases = vec![
            Request::Get { key: b"k".to_vec() },
            Request::Put { key: b"key".to_vec(), value: vec![0u8; 1000] },
            Request::Delete { key: vec![] },
            Request::Ping,
        ];
        for req in cases {
            let enc = req.encode();
            assert_eq!(Request::decode(&enc).unwrap(), req);
            // The borrowed decoder sees the same structure.
            assert_eq!(RequestRef::decode(&enc).unwrap().to_owned(), req);
        }
    }

    #[test]
    fn request_ref_borrows_from_frame() {
        let req = Request::Put { key: b"key".to_vec(), value: vec![9u8; 64] };
        let enc = req.encode();
        match RequestRef::decode(&enc).unwrap() {
            RequestRef::Put { key, value } => {
                assert_eq!(key, b"key");
                assert_eq!(value, &[9u8; 64][..]);
                // Borrowed straight out of the encoded frame.
                let base = enc.as_ptr() as usize;
                let kp = key.as_ptr() as usize;
                assert!(kp >= base && kp < base + enc.len());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn response_round_trip() {
        let cases = vec![
            Response::Value(vec![1, 2, 3]),
            Response::Value(vec![]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Deleted(false),
            Response::Throttled { retry_after_us: 12345 },
            Response::Pong,
            Response::Error("boom".into()),
        ];
        for resp in cases {
            let enc = resp.encode();
            assert_eq!(Response::decode(&enc).unwrap(), resp);
        }
    }

    #[test]
    fn wire_bytes_matches_encoding_exactly() {
        let reqs = [
            Request::Get { key: b"abc".to_vec() },
            Request::Put { key: b"k".to_vec(), value: vec![0u8; 777] },
            Request::Delete { key: vec![] },
            Request::Ping,
        ];
        for r in &reqs {
            assert_eq!(r.wire_bytes(), 4 + r.encode().len(), "{r:?}");
        }
        let resps = [
            Response::Value(vec![0u8; 321]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Throttled { retry_after_us: 9 },
            Response::Pong,
            Response::Error("e".into()),
        ];
        for r in &resps {
            assert_eq!(r.wire_bytes(), 4 + r.encode().len(), "{r:?}");
        }
    }

    #[test]
    fn encode_into_appends_without_clearing() {
        let mut out = vec![0xFF];
        Response::Pong.encode_into(&mut out);
        assert_eq!(out, vec![0xFF, TAG_PONG]);
        let mut out2 = Vec::new();
        encode_value_response(&mut out2, &[1, 2]);
        assert_eq!(Response::decode(&out2).unwrap(), Response::Value(vec![1, 2]));
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(Request::decode(&[]), Err(CodecError::Truncated));
        assert_eq!(Request::decode(&[99]), Err(CodecError::UnknownTag(99)));
        assert_eq!(Request::decode(&[TAG_GET, 5, 0, 0, 0, 1]), Err(CodecError::Truncated));
        let mut ok = Request::Ping.encode();
        ok.push(0);
        assert_eq!(Request::decode(&ok), Err(CodecError::TrailingBytes));
        assert_eq!(Response::decode(&[TAG_DELETED]), Err(CodecError::Truncated));
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = Rng::new(31);
        for _ in 0..20_000 {
            let len = rng.below(64) as usize;
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = Request::decode(&buf);
            let _ = RequestRef::decode(&buf);
            let _ = Response::decode(&buf);
        }
    }

    #[test]
    fn frame_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello frame").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello frame");
    }

    #[test]
    fn frame_into_reuses_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[7u8; 100]).unwrap();
        write_frame(&mut wire, &[8u8; 50]).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let mut buf = Vec::with_capacity(128);
        let cap = buf.capacity();
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![7u8; 100]);
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![8u8; 50]);
        assert_eq!(buf.capacity(), cap, "reused read buffer reallocated");
    }

    #[test]
    fn frame_rejects_giant_length() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}

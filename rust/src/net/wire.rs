//! Binary wire protocol between consumers and producer stores.
//!
//! Frame layout: `u32 LE` payload length, then payload. Payload: one tag
//! byte, then tag-specific fields; byte strings are `u32 LE` length +
//! bytes. No external serialization deps — the codec is exhaustively
//! round-trip and fuzz tested below.

use std::io::{self, Read, Write};

/// Consumer -> producer-store requests (paper §4.2: GET / PUT / DELETE).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    Get { key: Vec<u8> },
    Put { key: Vec<u8>, value: Vec<u8> },
    Delete { key: Vec<u8> },
    Ping,
}

/// Producer-store -> consumer responses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// GET hit.
    Value(Vec<u8>),
    /// GET miss (evicted or never stored).
    NotFound,
    /// PUT accepted.
    Stored,
    /// PUT rejected (store full of larger-than-capacity object).
    Rejected,
    /// DELETE outcome.
    Deleted(bool),
    /// Rate limiter refused the I/O (paper §4.2); retry after the hint.
    Throttled { retry_after_us: u64 },
    Pong,
    Error(String),
}

const TAG_GET: u8 = 1;
const TAG_PUT: u8 = 2;
const TAG_DELETE: u8 = 3;
const TAG_PING: u8 = 4;

const TAG_VALUE: u8 = 10;
const TAG_NOT_FOUND: u8 = 11;
const TAG_STORED: u8 = 12;
const TAG_REJECTED: u8 = 13;
const TAG_DELETED: u8 = 14;
const TAG_THROTTLED: u8 = 15;
const TAG_PONG: u8 = 16;
const TAG_ERROR: u8 = 17;

/// Hard cap on frame size (16 MB) — malformed/hostile lengths are
/// rejected rather than allocated.
pub const MAX_FRAME: usize = 16 << 20;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn take_bytes(buf: &[u8], off: &mut usize) -> Result<Vec<u8>, CodecError> {
    let len = take_u32(buf, off)? as usize;
    if buf.len() - *off < len {
        return Err(CodecError::Truncated);
    }
    let out = buf[*off..*off + len].to_vec();
    *off += len;
    Ok(out)
}

fn take_u32(buf: &[u8], off: &mut usize) -> Result<u32, CodecError> {
    if buf.len() - *off < 4 {
        return Err(CodecError::Truncated);
    }
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

fn take_u64(buf: &[u8], off: &mut usize) -> Result<u64, CodecError> {
    if buf.len() - *off < 8 {
        return Err(CodecError::Truncated);
    }
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    Truncated,
    UnknownTag(u8),
    TrailingBytes,
    FrameTooLarge(usize),
    BadUtf8,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for CodecError {}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Get { key } => {
                out.push(TAG_GET);
                put_bytes(&mut out, key);
            }
            Request::Put { key, value } => {
                out.push(TAG_PUT);
                put_bytes(&mut out, key);
                put_bytes(&mut out, value);
            }
            Request::Delete { key } => {
                out.push(TAG_DELETE);
                put_bytes(&mut out, key);
            }
            Request::Ping => out.push(TAG_PING),
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Request, CodecError> {
        let mut off = 0usize;
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let tag = buf[0];
        off += 1;
        let req = match tag {
            TAG_GET => Request::Get { key: take_bytes(buf, &mut off)? },
            TAG_PUT => Request::Put {
                key: take_bytes(buf, &mut off)?,
                value: take_bytes(buf, &mut off)?,
            },
            TAG_DELETE => Request::Delete { key: take_bytes(buf, &mut off)? },
            TAG_PING => Request::Ping,
            t => return Err(CodecError::UnknownTag(t)),
        };
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(req)
    }

    /// Approximate bytes on the wire (for bandwidth accounting).
    pub fn wire_bytes(&self) -> usize {
        4 + self.encode().len()
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::Value(v) => {
                out.push(TAG_VALUE);
                put_bytes(&mut out, v);
            }
            Response::NotFound => out.push(TAG_NOT_FOUND),
            Response::Stored => out.push(TAG_STORED),
            Response::Rejected => out.push(TAG_REJECTED),
            Response::Deleted(ok) => {
                out.push(TAG_DELETED);
                out.push(*ok as u8);
            }
            Response::Throttled { retry_after_us } => {
                out.push(TAG_THROTTLED);
                out.extend_from_slice(&retry_after_us.to_le_bytes());
            }
            Response::Pong => out.push(TAG_PONG),
            Response::Error(msg) => {
                out.push(TAG_ERROR);
                put_bytes(&mut out, msg.as_bytes());
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Response, CodecError> {
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let mut off = 1usize;
        let resp = match buf[0] {
            TAG_VALUE => Response::Value(take_bytes(buf, &mut off)?),
            TAG_NOT_FOUND => Response::NotFound,
            TAG_STORED => Response::Stored,
            TAG_REJECTED => Response::Rejected,
            TAG_DELETED => {
                if buf.len() < 2 {
                    return Err(CodecError::Truncated);
                }
                off += 1;
                Response::Deleted(buf[1] != 0)
            }
            TAG_THROTTLED => Response::Throttled { retry_after_us: take_u64(buf, &mut off)? },
            TAG_PONG => Response::Pong,
            TAG_ERROR => {
                let bytes = take_bytes(buf, &mut off)?;
                Response::Error(String::from_utf8(bytes).map_err(|_| CodecError::BadUtf8)?)
            }
            t => return Err(CodecError::UnknownTag(t)),
        };
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(resp)
    }

    pub fn wire_bytes(&self) -> usize {
        4 + self.encode().len()
    }
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one length-prefixed frame.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Vec<u8>> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            CodecError::FrameTooLarge(len),
        ));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn request_round_trip() {
        let cases = vec![
            Request::Get { key: b"k".to_vec() },
            Request::Put { key: b"key".to_vec(), value: vec![0u8; 1000] },
            Request::Delete { key: vec![] },
            Request::Ping,
        ];
        for req in cases {
            let enc = req.encode();
            assert_eq!(Request::decode(&enc).unwrap(), req);
        }
    }

    #[test]
    fn response_round_trip() {
        let cases = vec![
            Response::Value(vec![1, 2, 3]),
            Response::Value(vec![]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Deleted(false),
            Response::Throttled { retry_after_us: 12345 },
            Response::Pong,
            Response::Error("boom".into()),
        ];
        for resp in cases {
            let enc = resp.encode();
            assert_eq!(Response::decode(&enc).unwrap(), resp);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(Request::decode(&[]), Err(CodecError::Truncated));
        assert_eq!(Request::decode(&[99]), Err(CodecError::UnknownTag(99)));
        assert_eq!(Request::decode(&[TAG_GET, 5, 0, 0, 0, 1]), Err(CodecError::Truncated));
        let mut ok = Request::Ping.encode();
        ok.push(0);
        assert_eq!(Request::decode(&ok), Err(CodecError::TrailingBytes));
        assert_eq!(Response::decode(&[TAG_DELETED]), Err(CodecError::Truncated));
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = Rng::new(31);
        for _ in 0..20_000 {
            let len = rng.below(64) as usize;
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = Request::decode(&buf);
            let _ = Response::decode(&buf);
        }
    }

    #[test]
    fn frame_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello frame").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello frame");
    }

    #[test]
    fn frame_rejects_giant_length() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}

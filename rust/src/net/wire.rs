//! Binary wire protocol between consumers and producer stores.
//!
//! Frame layout: `u32 LE` payload length, then payload. Payload: one tag
//! byte, then tag-specific fields; byte strings are `u32 LE` length +
//! bytes. No external serialization deps — the codec is exhaustively
//! round-trip and fuzz tested below.
//!
//! Two decoding layers: [`RequestRef`] borrows key/value slices straight
//! out of the frame buffer (the server's zero-allocation path), while
//! [`Request`]/[`Response`] are the owned forms used by clients, the
//! in-process manager, and the simulator. Encoders append into
//! caller-owned buffers (`encode_into`) so steady-state connections
//! reuse one scratch buffer per direction.
//!
//! ## Batch frames (protocol v3)
//!
//! `MultiGet` / `MultiPut` / `MultiDelete` frames carry up to
//! [`MAX_BATCH_OPS`] homogeneous ops in one frame, amortizing the
//! per-request round trip that dominates remote-memory latency. A batch
//! request is answered by exactly one batch response carrying one
//! status per op, *in request order* — a miss, rejection, or throttle
//! on one op never fails its siblings (the partial-failure contract the
//! consumer layers rely on). Batches are not transactional: ops execute
//! independently, interleaved with other connections' traffic. The per-
//! frame op cap is advertised in the handshake hello and the effective
//! limit is the pairwise minimum, so a frame a peer cannot decode is
//! never sent (see [`crate::net::control`]). Batch ops stay *outside*
//! the [`Request`]/[`RequestRef`]/[`Response`] enums: the single-op
//! types keep their exhaustive matches everywhere (manager, simulator,
//! transports), and batch framing lives in the dedicated
//! `encode_multi_*` / [`decode_batch_request`] /
//! [`decode_batch_response`] entry points below.

use std::io::{self, Read, Write};

/// Consumer -> producer-store requests (paper §4.2: GET / PUT / DELETE).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    Get { key: Vec<u8> },
    Put { key: Vec<u8>, value: Vec<u8> },
    Delete { key: Vec<u8> },
    Ping,
}

/// Borrowed view of a [`Request`], decoded without copying key or value
/// bytes out of the frame buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestRef<'a> {
    Get { key: &'a [u8] },
    Put { key: &'a [u8], value: &'a [u8] },
    Delete { key: &'a [u8] },
    Ping,
}

/// Producer-store -> consumer responses.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// GET hit.
    Value(Vec<u8>),
    /// GET miss (evicted or never stored).
    NotFound,
    /// PUT accepted.
    Stored,
    /// PUT rejected (store full of larger-than-capacity object).
    Rejected,
    /// DELETE outcome.
    Deleted(bool),
    /// Rate limiter refused the I/O (paper §4.2); retry after the hint.
    Throttled { retry_after_us: u64 },
    Pong,
    Error(String),
}

const TAG_GET: u8 = 1;
const TAG_PUT: u8 = 2;
const TAG_DELETE: u8 = 3;
const TAG_PING: u8 = 4;
const TAG_MULTI_GET: u8 = 5;
const TAG_MULTI_PUT: u8 = 6;
const TAG_MULTI_DELETE: u8 = 7;

const TAG_VALUE: u8 = 10;
const TAG_NOT_FOUND: u8 = 11;
const TAG_STORED: u8 = 12;
const TAG_REJECTED: u8 = 13;
const TAG_DELETED: u8 = 14;
const TAG_THROTTLED: u8 = 15;
const TAG_PONG: u8 = 16;
const TAG_ERROR: u8 = 17;
const TAG_BATCH: u8 = 18;

/// Hard cap on frame size (16 MB) — malformed/hostile lengths are
/// rejected rather than allocated.
pub const MAX_FRAME: usize = 16 << 20;

/// Most ops one batch frame may carry. Advertised in the handshake
/// hello; the effective per-connection limit is the pairwise minimum,
/// so clients chunk larger batches before encoding. Decoders enforce it
/// so a hostile count cannot force a huge table allocation.
pub const MAX_BATCH_OPS: usize = 1024;

/// Bytes of the trace context (v6) a data-plane *request* frame carries
/// as a fixed suffix when — and only when — both hellos advertised
/// tracing: `trace_id u64 LE` + `parent_span_id u64 LE`, zeros when the
/// caller is untraced. The suffix rides *outside* the request payload:
/// [`RequestRef::decode`] and [`decode_batch_request`] keep their
/// strict trailing-bytes discipline, and the server splits the context
/// off with [`split_trace_ctx`] before decoding. Responses never carry
/// it — the requester already knows its own trace.
pub const TRACE_CTX_BYTES: usize = 16;

/// Append the (v6) trace-context suffix to an encoded request frame.
#[inline]
pub fn append_trace_ctx(out: &mut Vec<u8>, trace_id: u64, parent_span: u64) {
    out.extend_from_slice(&trace_id.to_le_bytes());
    out.extend_from_slice(&parent_span.to_le_bytes());
}

/// Split the (v6) trace-context suffix off a request frame, returning
/// `(request payload, trace_id, parent_span_id)`. Only called on
/// connections whose handshake negotiated tracing — there the suffix is
/// unconditional, so a frame too short to carry it is truncated, not
/// ambiguous.
#[inline]
pub fn split_trace_ctx(frame: &[u8]) -> Result<(&[u8], u64, u64), CodecError> {
    if frame.len() < TRACE_CTX_BYTES {
        return Err(CodecError::Truncated);
    }
    let at = frame.len() - TRACE_CTX_BYTES;
    let trace_id = u64::from_le_bytes(frame[at..at + 8].try_into().unwrap());
    let parent = u64::from_le_bytes(frame[at + 8..].try_into().unwrap());
    Ok((&frame[..at], trace_id, parent))
}

// lint: no-alloc
pub(crate) fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn take_bytes_ref<'a>(buf: &'a [u8], off: &mut usize) -> Result<&'a [u8], CodecError> {
    let len = take_u32(buf, off)? as usize;
    if buf.len() - *off < len {
        return Err(CodecError::Truncated);
    }
    let out = &buf[*off..*off + len];
    *off += len;
    Ok(out)
}

pub(crate) fn take_bytes(buf: &[u8], off: &mut usize) -> Result<Vec<u8>, CodecError> {
    take_bytes_ref(buf, off).map(|b| b.to_vec())
}

pub(crate) fn take_u32(buf: &[u8], off: &mut usize) -> Result<u32, CodecError> {
    if buf.len() - *off < 4 {
        return Err(CodecError::Truncated);
    }
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

pub(crate) fn take_u64(buf: &[u8], off: &mut usize) -> Result<u64, CodecError> {
    if buf.len() - *off < 8 {
        return Err(CodecError::Truncated);
    }
    let v = u64::from_le_bytes(buf[*off..*off + 8].try_into().unwrap());
    *off += 8;
    Ok(v)
}

#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    Truncated,
    UnknownTag(u8),
    TrailingBytes,
    FrameTooLarge(usize),
    /// Batch frame declares more ops than [`MAX_BATCH_OPS`].
    BatchTooLarge(usize),
    BadUtf8,
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for CodecError {}

impl<'a> RequestRef<'a> {
    /// Decode a request, borrowing key/value bytes from `buf`.
    pub fn decode(buf: &'a [u8]) -> Result<RequestRef<'a>, CodecError> {
        let mut off = 0usize;
        if buf.is_empty() {
            return Err(CodecError::Truncated);
        }
        let tag = buf[0];
        off += 1;
        let req = match tag {
            TAG_GET => RequestRef::Get { key: take_bytes_ref(buf, &mut off)? },
            TAG_PUT => RequestRef::Put {
                key: take_bytes_ref(buf, &mut off)?,
                value: take_bytes_ref(buf, &mut off)?,
            },
            TAG_DELETE => RequestRef::Delete { key: take_bytes_ref(buf, &mut off)? },
            TAG_PING => RequestRef::Ping,
            t => return Err(CodecError::UnknownTag(t)),
        };
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(req)
    }

    /// Copy into the owned form.
    pub fn to_owned(self) -> Request {
        match self {
            RequestRef::Get { key } => Request::Get { key: key.to_vec() },
            RequestRef::Put { key, value } => {
                Request::Put { key: key.to_vec(), value: value.to_vec() }
            }
            RequestRef::Delete { key } => Request::Delete { key: key.to_vec() },
            RequestRef::Ping => Request::Ping,
        }
    }

    /// Append the encoded payload to `out` (does not clear it). This is
    /// the single encoder: the owned [`Request`] delegates here.
    // lint: no-alloc
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            RequestRef::Get { key } => {
                out.push(TAG_GET);
                put_bytes(out, key);
            }
            RequestRef::Put { key, value } => {
                out.push(TAG_PUT);
                put_bytes(out, key);
                put_bytes(out, value);
            }
            RequestRef::Delete { key } => {
                out.push(TAG_DELETE);
                put_bytes(out, key);
            }
            RequestRef::Ping => out.push(TAG_PING),
        }
    }

    /// Exact bytes on the wire (frame header + payload), without
    /// encoding.
    pub fn wire_bytes(&self) -> usize {
        4 + 1
            + match self {
                RequestRef::Get { key } | RequestRef::Delete { key } => 4 + key.len(),
                RequestRef::Put { key, value } => 8 + key.len() + value.len(),
                RequestRef::Ping => 0,
            }
    }
}

impl Request {
    /// Borrowed view (for allocation-free encoding of owned requests).
    pub fn to_ref(&self) -> RequestRef<'_> {
        match self {
            Request::Get { key } => RequestRef::Get { key },
            Request::Put { key, value } => RequestRef::Put { key, value },
            Request::Delete { key } => RequestRef::Delete { key },
            Request::Ping => RequestRef::Ping,
        }
    }

    /// Append the encoded payload to `out` (does not clear it).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.to_ref().encode_into(out)
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Request, CodecError> {
        RequestRef::decode(buf).map(RequestRef::to_owned)
    }

    /// Exact bytes on the wire (frame header + payload), computed without
    /// encoding (used for bandwidth accounting on the simulator hot path).
    pub fn wire_bytes(&self) -> usize {
        self.to_ref().wire_bytes()
    }

    /// Which batch frame this single-op request belongs in (`None` for
    /// `Ping`, which has no batched form).
    pub fn batch_kind(&self) -> Option<BatchKind> {
        match self {
            Request::Get { .. } => Some(BatchKind::Get),
            Request::Put { .. } => Some(BatchKind::Put),
            Request::Delete { .. } => Some(BatchKind::Delete),
            Request::Ping => None,
        }
    }
}

/// The three homogeneous batch frame kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchKind {
    Get,
    Put,
    Delete,
}

/// Borrowed view of one op inside a decoded batch request frame
/// (key/value slices point into the frame buffer, like [`RequestRef`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchOpRef<'a> {
    Get { key: &'a [u8] },
    Put { key: &'a [u8], value: &'a [u8] },
    Delete { key: &'a [u8] },
}

impl<'a> BatchOpRef<'a> {
    /// The op's key (every batch op has exactly one).
    pub fn key(&self) -> &'a [u8] {
        match self {
            BatchOpRef::Get { key }
            | BatchOpRef::Put { key, .. }
            | BatchOpRef::Delete { key } => key,
        }
    }
}

/// True when `frame` opens with a batch request tag — the server's
/// dispatch test between the single-op and batch paths.
pub fn is_batch_request(frame: &[u8]) -> bool {
    matches!(frame.first(), Some(&TAG_MULTI_GET | &TAG_MULTI_PUT | &TAG_MULTI_DELETE))
}

fn batch_header(out: &mut Vec<u8>, tag: u8, count: usize) {
    out.push(tag);
    out.extend_from_slice(&(count as u32).to_le_bytes());
}

/// Append a `MultiGet` request payload: `count` keys, answered per-op.
pub fn encode_multi_get_into(out: &mut Vec<u8>, keys: &[&[u8]]) {
    batch_header(out, TAG_MULTI_GET, keys.len());
    for k in keys {
        put_bytes(out, k);
    }
}

/// Append a `MultiPut` request payload: `count` key/value pairs.
pub fn encode_multi_put_into(out: &mut Vec<u8>, pairs: &[(&[u8], &[u8])]) {
    batch_header(out, TAG_MULTI_PUT, pairs.len());
    for (k, v) in pairs {
        put_bytes(out, k);
        put_bytes(out, v);
    }
}

/// Append a `MultiDelete` request payload: `count` keys.
pub fn encode_multi_delete_into(out: &mut Vec<u8>, keys: &[&[u8]]) {
    batch_header(out, TAG_MULTI_DELETE, keys.len());
    for k in keys {
        put_bytes(out, k);
    }
}

/// Decode a batch request frame into `ops` (cleared first), borrowing
/// key/value bytes from `buf`. Allocation is bounded before any table
/// growth: the declared count must fit [`MAX_BATCH_OPS`] *and* the
/// remaining frame bytes (every op costs ≥ 4 bytes on the wire), so a
/// hostile count out of a small frame is rejected, not allocated.
pub fn decode_batch_request<'a>(
    buf: &'a [u8],
    ops: &mut Vec<BatchOpRef<'a>>,
) -> Result<(), CodecError> {
    ops.clear();
    if buf.is_empty() {
        return Err(CodecError::Truncated);
    }
    let tag = buf[0];
    if !matches!(tag, TAG_MULTI_GET | TAG_MULTI_PUT | TAG_MULTI_DELETE) {
        return Err(CodecError::UnknownTag(tag));
    }
    let mut off = 1usize;
    let n = take_u32(buf, &mut off)? as usize;
    if n > MAX_BATCH_OPS {
        return Err(CodecError::BatchTooLarge(n));
    }
    if n > (buf.len() - off) / 4 {
        return Err(CodecError::Truncated);
    }
    ops.reserve(n);
    for _ in 0..n {
        let op = match tag {
            TAG_MULTI_GET => BatchOpRef::Get { key: take_bytes_ref(buf, &mut off)? },
            TAG_MULTI_PUT => BatchOpRef::Put {
                key: take_bytes_ref(buf, &mut off)?,
                value: take_bytes_ref(buf, &mut off)?,
            },
            _ => BatchOpRef::Delete { key: take_bytes_ref(buf, &mut off)? },
        };
        ops.push(op);
    }
    if off != buf.len() {
        return Err(CodecError::TrailingBytes);
    }
    Ok(())
}

/// Open a batch response payload in `out`: tag + op count. The caller
/// then appends one encoded single-op [`Response`] per op, in request
/// order (GET hits may use [`encode_value_response`] for the zero-copy
/// path).
pub fn encode_batch_response_header(out: &mut Vec<u8>, count: u32) {
    out.push(TAG_BATCH);
    out.extend_from_slice(&count.to_le_bytes());
}

/// Decode a batch response frame into per-op responses, in request
/// order. Count is allocation-bounded like the request decoder (every
/// sub-response costs ≥ 1 byte).
pub fn decode_batch_response(buf: &[u8]) -> Result<Vec<Response>, CodecError> {
    if buf.is_empty() {
        return Err(CodecError::Truncated);
    }
    if buf[0] != TAG_BATCH {
        return Err(CodecError::UnknownTag(buf[0]));
    }
    let mut off = 1usize;
    let n = take_u32(buf, &mut off)? as usize;
    if n > MAX_BATCH_OPS {
        return Err(CodecError::BatchTooLarge(n));
    }
    if n > buf.len() - off {
        return Err(CodecError::Truncated);
    }
    let mut resps = Vec::with_capacity(n);
    for _ in 0..n {
        resps.push(Response::decode_at(buf, &mut off)?);
    }
    if off != buf.len() {
        return Err(CodecError::TrailingBytes);
    }
    Ok(resps)
}

/// Append a `Response::Value` payload built from a borrowed value slice:
/// the server's zero-copy GET path encodes straight from the store's
/// entry into the connection's reusable output buffer.
// lint: no-alloc
pub fn encode_value_response(out: &mut Vec<u8>, value: &[u8]) {
    out.push(TAG_VALUE);
    put_bytes(out, value);
}

impl Response {
    /// Append the encoded payload to `out` (does not clear it).
    // lint: no-alloc
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Response::Value(v) => encode_value_response(out, v),
            Response::NotFound => out.push(TAG_NOT_FOUND),
            Response::Stored => out.push(TAG_STORED),
            Response::Rejected => out.push(TAG_REJECTED),
            Response::Deleted(ok) => {
                out.push(TAG_DELETED);
                out.push(*ok as u8);
            }
            Response::Throttled { retry_after_us } => {
                out.push(TAG_THROTTLED);
                out.extend_from_slice(&retry_after_us.to_le_bytes());
            }
            Response::Pong => out.push(TAG_PONG),
            Response::Error(msg) => {
                out.push(TAG_ERROR);
                put_bytes(out, msg.as_bytes());
            }
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Response, CodecError> {
        let mut off = 0usize;
        let resp = Self::decode_at(buf, &mut off)?;
        if off != buf.len() {
            return Err(CodecError::TrailingBytes);
        }
        Ok(resp)
    }

    /// Decode one response starting at `*off` (responses are self-
    /// delimiting, so batch frames concatenate them back to back).
    fn decode_at(buf: &[u8], off: &mut usize) -> Result<Response, CodecError> {
        if *off >= buf.len() {
            return Err(CodecError::Truncated);
        }
        let tag = buf[*off];
        *off += 1;
        Ok(match tag {
            TAG_VALUE => Response::Value(take_bytes(buf, off)?),
            TAG_NOT_FOUND => Response::NotFound,
            TAG_STORED => Response::Stored,
            TAG_REJECTED => Response::Rejected,
            TAG_DELETED => {
                if *off >= buf.len() {
                    return Err(CodecError::Truncated);
                }
                let b = buf[*off];
                *off += 1;
                Response::Deleted(b != 0)
            }
            TAG_THROTTLED => Response::Throttled { retry_after_us: take_u64(buf, off)? },
            TAG_PONG => Response::Pong,
            TAG_ERROR => {
                let bytes = take_bytes(buf, off)?;
                Response::Error(String::from_utf8(bytes).map_err(|_| CodecError::BadUtf8)?)
            }
            t => return Err(CodecError::UnknownTag(t)),
        })
    }

    /// Exact bytes on the wire (frame header + payload), without encoding.
    pub fn wire_bytes(&self) -> usize {
        4 + 1
            + match self {
                Response::Value(v) => 4 + v.len(),
                Response::NotFound
                | Response::Stored
                | Response::Rejected
                | Response::Pong => 0,
                Response::Deleted(_) => 1,
                Response::Throttled { .. } => 8,
                Response::Error(msg) => 4 + msg.len(),
            }
    }
}

/// Write one length-prefixed frame and flush it to the wire.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    write_frame_noflush(w, payload)?;
    w.flush()
}

/// [`write_frame`] without the trailing flush: pipelined senders queue
/// several frames into one buffered write and flush once per window,
/// collapsing per-request syscalls.
pub fn write_frame_noflush<W: Write>(w: &mut W, payload: &[u8]) -> io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)
}

/// Read one length-prefixed frame into a reusable buffer (resized in
/// place and fully overwritten; steady state performs no allocation, and
/// no redundant zero-fill of bytes `read_exact` is about to overwrite).
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> io::Result<()> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            CodecError::FrameTooLarge(len),
        ));
    }
    buf.resize(len, 0);
    r.read_exact(buf)?;
    Ok(())
}

/// Read one length-prefixed frame into a fresh buffer.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Vec<u8>> {
    let mut buf = Vec::new();
    read_frame_into(r, &mut buf)?;
    Ok(buf)
}

/// `read_exact` that survives read timeouts without losing data: plain
/// `read_exact` discards whatever it consumed before a `WouldBlock`/
/// `TimedOut`, desynchronizing the frame stream if the peer stalls
/// mid-frame. This loop keeps partial progress and polls `keep_going`
/// at every timeout tick; returns Ok(false) when told to stop.
fn read_exact_interruptible<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    keep_going: &impl Fn() -> bool,
) -> io::Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        if !keep_going() {
            return Ok(false);
        }
        match r.read(&mut buf[filled..]) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// [`read_frame_into`] for sockets with a read timeout: tolerates
/// mid-frame timeouts without desync, polling `keep_going` while
/// waiting. Returns Ok(true) with a complete frame in `buf`, Ok(false)
/// if `keep_going` said to stop, or the I/O / frame-size error.
pub fn read_frame_into_patient<R: Read>(
    r: &mut R,
    buf: &mut Vec<u8>,
    keep_going: impl Fn() -> bool,
) -> io::Result<bool> {
    let mut len_buf = [0u8; 4];
    if !read_exact_interruptible(r, &mut len_buf, &keep_going)? {
        return Ok(false);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            CodecError::FrameTooLarge(len),
        ));
    }
    buf.resize(len, 0);
    if !read_exact_interruptible(r, buf, &keep_going)? {
        return Ok(false);
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn request_round_trip() {
        let cases = vec![
            Request::Get { key: b"k".to_vec() },
            Request::Put { key: b"key".to_vec(), value: vec![0u8; 1000] },
            Request::Delete { key: vec![] },
            Request::Ping,
        ];
        for req in cases {
            let enc = req.encode();
            assert_eq!(Request::decode(&enc).unwrap(), req);
            // The borrowed decoder sees the same structure.
            assert_eq!(RequestRef::decode(&enc).unwrap().to_owned(), req);
        }
    }

    #[test]
    fn request_ref_borrows_from_frame() {
        let req = Request::Put { key: b"key".to_vec(), value: vec![9u8; 64] };
        let enc = req.encode();
        match RequestRef::decode(&enc).unwrap() {
            RequestRef::Put { key, value } => {
                assert_eq!(key, b"key");
                assert_eq!(value, &[9u8; 64][..]);
                // Borrowed straight out of the encoded frame.
                let base = enc.as_ptr() as usize;
                let kp = key.as_ptr() as usize;
                assert!(kp >= base && kp < base + enc.len());
            }
            other => panic!("wrong variant {other:?}"),
        }
    }

    #[test]
    fn response_round_trip() {
        let cases = vec![
            Response::Value(vec![1, 2, 3]),
            Response::Value(vec![]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Deleted(false),
            Response::Throttled { retry_after_us: 12345 },
            Response::Pong,
            Response::Error("boom".into()),
        ];
        for resp in cases {
            let enc = resp.encode();
            assert_eq!(Response::decode(&enc).unwrap(), resp);
        }
    }

    #[test]
    fn wire_bytes_matches_encoding_exactly() {
        let reqs = [
            Request::Get { key: b"abc".to_vec() },
            Request::Put { key: b"k".to_vec(), value: vec![0u8; 777] },
            Request::Delete { key: vec![] },
            Request::Ping,
        ];
        for r in &reqs {
            assert_eq!(r.wire_bytes(), 4 + r.encode().len(), "{r:?}");
        }
        let resps = [
            Response::Value(vec![0u8; 321]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Throttled { retry_after_us: 9 },
            Response::Pong,
            Response::Error("e".into()),
        ];
        for r in &resps {
            assert_eq!(r.wire_bytes(), 4 + r.encode().len(), "{r:?}");
        }
    }

    #[test]
    fn encode_into_appends_without_clearing() {
        let mut out = vec![0xFF];
        Response::Pong.encode_into(&mut out);
        assert_eq!(out, vec![0xFF, TAG_PONG]);
        let mut out2 = Vec::new();
        encode_value_response(&mut out2, &[1, 2]);
        assert_eq!(Response::decode(&out2).unwrap(), Response::Value(vec![1, 2]));
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(Request::decode(&[]), Err(CodecError::Truncated));
        assert_eq!(Request::decode(&[99]), Err(CodecError::UnknownTag(99)));
        assert_eq!(Request::decode(&[TAG_GET, 5, 0, 0, 0, 1]), Err(CodecError::Truncated));
        let mut ok = Request::Ping.encode();
        ok.push(0);
        assert_eq!(Request::decode(&ok), Err(CodecError::TrailingBytes));
        assert_eq!(Response::decode(&[TAG_DELETED]), Err(CodecError::Truncated));
    }

    #[test]
    fn trace_ctx_suffix_splits_cleanly() {
        // The v6 suffix rides outside the payload: append it, split it,
        // and the remaining body still satisfies the strict
        // trailing-bytes decode.
        let mut frame = Request::Get { key: b"k1".to_vec() }.encode();
        append_trace_ctx(&mut frame, 0xABCD_EF01, 0x42);
        let (body, trace, parent) = split_trace_ctx(&frame).unwrap();
        assert_eq!((trace, parent), (0xABCD_EF01, 0x42));
        assert_eq!(
            RequestRef::decode(body).unwrap(),
            RequestRef::Get { key: b"k1" }
        );
        // An untraced caller sends zeros — same framing, no ambiguity.
        let mut frame = Request::Ping.encode();
        append_trace_ctx(&mut frame, 0, 0);
        let (body, trace, parent) = split_trace_ctx(&frame).unwrap();
        assert_eq!((trace, parent), (0, 0));
        assert_eq!(RequestRef::decode(body).unwrap(), RequestRef::Ping);
        // On a tracing-negotiated connection a too-short frame is
        // truncated, never silently treated as suffix-less.
        assert_eq!(split_trace_ctx(&[0u8; 15]), Err(CodecError::Truncated));
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut rng = Rng::new(31);
        for _ in 0..20_000 {
            let len = rng.below(64) as usize;
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = Request::decode(&buf);
            let _ = RequestRef::decode(&buf);
            let _ = Response::decode(&buf);
        }
    }

    fn batch_get_frame(keys: &[&[u8]]) -> Vec<u8> {
        let mut out = Vec::new();
        encode_multi_get_into(&mut out, keys);
        out
    }

    #[test]
    fn batch_request_round_trips() {
        let keys: Vec<Vec<u8>> = (0..5).map(|i| format!("key{i}").into_bytes()).collect();
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        let mut ops = Vec::new();

        decode_batch_request(&batch_get_frame(&key_refs), &mut ops).unwrap();
        assert_eq!(ops.len(), 5);
        for (op, k) in ops.iter().zip(&keys) {
            assert_eq!(*op, BatchOpRef::Get { key: k.as_slice() });
            assert_eq!(op.key(), k.as_slice());
        }

        let vals: Vec<Vec<u8>> = (0..5).map(|i| vec![i as u8; 32]).collect();
        let pairs: Vec<(&[u8], &[u8])> = key_refs
            .iter()
            .zip(&vals)
            .map(|(k, v)| (*k, v.as_slice()))
            .collect();
        let mut enc = Vec::new();
        encode_multi_put_into(&mut enc, &pairs);
        assert!(is_batch_request(&enc));
        decode_batch_request(&enc, &mut ops).unwrap();
        assert_eq!(ops.len(), 5);
        for (op, (k, v)) in ops.iter().zip(&pairs) {
            assert_eq!(*op, BatchOpRef::Put { key: k, value: v });
        }

        let mut enc = Vec::new();
        encode_multi_delete_into(&mut enc, &key_refs);
        decode_batch_request(&enc, &mut ops).unwrap();
        assert_eq!(ops[0], BatchOpRef::Delete { key: b"key0" });
    }

    #[test]
    fn empty_batch_is_legal() {
        let enc = batch_get_frame(&[]);
        let mut ops = vec![BatchOpRef::Get { key: b"stale" }];
        decode_batch_request(&enc, &mut ops).unwrap();
        assert!(ops.is_empty(), "decode must clear the reused table");

        let mut resp = Vec::new();
        encode_batch_response_header(&mut resp, 0);
        assert_eq!(decode_batch_response(&resp).unwrap(), vec![]);
    }

    #[test]
    fn max_size_batch_round_trips_and_one_more_is_rejected() {
        let key = b"k".as_slice();
        let keys: Vec<&[u8]> = vec![key; MAX_BATCH_OPS];
        let enc = batch_get_frame(&keys);
        let mut ops = Vec::new();
        decode_batch_request(&enc, &mut ops).unwrap();
        assert_eq!(ops.len(), MAX_BATCH_OPS);

        // Same frame, count inflated past the cap: refused before any
        // table allocation, with the count named.
        let mut oversized = enc.clone();
        oversized[1..5].copy_from_slice(&((MAX_BATCH_OPS + 1) as u32).to_le_bytes());
        assert_eq!(
            decode_batch_request(&oversized, &mut ops),
            Err(CodecError::BatchTooLarge(MAX_BATCH_OPS + 1))
        );
        // A huge count out of a tiny frame is Truncated, not allocated.
        let mut tiny = batch_get_frame(&[b"k".as_slice()]);
        tiny[1..5].copy_from_slice(&1000u32.to_le_bytes());
        assert_eq!(decode_batch_request(&tiny, &mut ops), Err(CodecError::Truncated));
    }

    #[test]
    fn batch_request_truncated_at_every_boundary_errors_cleanly() {
        let keys: Vec<Vec<u8>> = (0..4).map(|i| format!("some-key-{i}").into_bytes()).collect();
        let vals: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 17]).collect();
        let pairs: Vec<(&[u8], &[u8])> =
            keys.iter().zip(&vals).map(|(k, v)| (k.as_slice(), v.as_slice())).collect();
        let mut enc = Vec::new();
        encode_multi_put_into(&mut enc, &pairs);
        let mut ops = Vec::new();
        for cut in 0..enc.len() {
            let r = decode_batch_request(&enc[..cut], &mut ops);
            assert!(r.is_err(), "prefix of {cut}/{} bytes decoded", enc.len());
        }
        decode_batch_request(&enc, &mut ops).unwrap();
    }

    #[test]
    fn batch_response_round_trips_with_per_op_status() {
        let resps = vec![
            Response::Value(vec![1, 2, 3]),
            Response::NotFound,
            Response::Stored,
            Response::Rejected,
            Response::Deleted(true),
            Response::Deleted(false),
            Response::Throttled { retry_after_us: 77 },
            Response::Error("one bad op".into()),
            Response::Value(vec![]),
        ];
        let mut enc = Vec::new();
        encode_batch_response_header(&mut enc, resps.len() as u32);
        for r in &resps {
            r.encode_into(&mut enc);
        }
        assert_eq!(decode_batch_response(&enc).unwrap(), resps);
        // Truncated at every boundary: clean error, never a panic and
        // never a short silently-accepted batch.
        for cut in 0..enc.len() {
            assert!(decode_batch_response(&enc[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn batch_fuzz_decode_never_panics() {
        let mut rng = Rng::new(93);
        let mut ops = Vec::new();
        for _ in 0..20_000 {
            let len = rng.below(96) as usize;
            let mut buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = decode_batch_request(&buf, &mut ops);
            let _ = decode_batch_response(&buf);
            // Bias toward valid tags so field decoding is fuzzed too.
            if !buf.is_empty() {
                buf[0] = 5 + (rng.below(3) as u8);
                let _ = decode_batch_request(&buf, &mut ops);
                buf[0] = TAG_BATCH;
                let _ = decode_batch_response(&buf);
            }
        }
    }

    #[test]
    fn frame_round_trip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello frame").unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cursor).unwrap(), b"hello frame");
    }

    #[test]
    fn frame_into_reuses_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[7u8; 100]).unwrap();
        write_frame(&mut wire, &[8u8; 50]).unwrap();
        let mut cursor = std::io::Cursor::new(wire);
        let mut buf = Vec::with_capacity(128);
        let cap = buf.capacity();
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![7u8; 100]);
        read_frame_into(&mut cursor, &mut buf).unwrap();
        assert_eq!(buf, vec![8u8; 50]);
        assert_eq!(buf.capacity(), cap, "reused read buffer reallocated");
    }

    #[test]
    fn frame_rejects_giant_length() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}

//! Readiness-driven (epoll) server substrate shared by both planes.
//!
//! One producer VM must hold thousands of consumer connections (the
//! paper's whole economic argument: spot-block pricing only beats
//! dedicated instances when a harvested VM is shared wide), and the
//! broker must hold heartbeats from every producer agent in the
//! cluster. Thread-per-connection tops out far earlier, so both
//! servers run on this hand-rolled epoll loop instead: a few loop
//! threads, each owning an epoll instance, multiplex nonblocking
//! sockets through per-connection state machines.
//!
//! The loop is deliberately small and zero-dependency — raw
//! `epoll_create1`/`epoll_ctl`/`epoll_wait` through `extern "C"`
//! glibc bindings, no reactor framework. Pieces:
//!
//! - [`Poller`]: thin RAII wrapper over one epoll file descriptor.
//! - [`FrameAssembler`]: incremental reassembly of the u32-LE
//!   length-prefixed frames described in PROTOCOL.md. It buffers only
//!   bytes actually received — a peer declaring a 16 MiB frame and
//!   then stalling (slow loris) pins a 4-byte header, not 16 MiB —
//!   and rejects hostile lengths (`> MAX_FRAME`) as soon as the
//!   prefix arrives, before any body byte is stored.
//! - [`Conn`]: per-connection state machine. A connection is born in
//!   the *hello* state (first frame must be the 11-byte handshake,
//!   answered in kind even on plane/version mismatch so the peer can
//!   print a useful error), then moves to *serving*, where every
//!   complete frame is handed to the [`Service`] and the response is
//!   queued on the connection's write queue. Partial writes park in
//!   the queue; `EPOLLOUT` interest is registered only while bytes
//!   are pending. When the queue passes [`HIGH_WATER`] the loop stops
//!   reading (and decoding) for that connection until the peer drains
//!   it — backpressure, not buffering.
//! - [`Service`]: what a plane plugs in — its hello magic, its
//!   per-connection state, and a frame handler. The data plane's
//!   handler is the same shard-grouped batch executor the threaded
//!   path uses; the control plane's is the broker verb dispatch.
//!
//! Chaos parity: accepted sockets are wrapped in
//! [`FaultyStream`](crate::net::faults::FaultyStream) exactly like
//! the threaded path, keyed by the same global connection index, so a
//! fault schedule is still a pure function of `(seed, conn)`. One
//! caveat is documented rather than hidden: the chaos write paths
//! (duplicate/truncate) issue short internal writes; under a
//! nonblocking socket a full send buffer mid-fault could desync the
//! stream. That can corrupt or drop *unacked* bytes — which the
//! envelope already allows — but can never fabricate an ack, so the
//! chaos invariants (100% envelope catch, no lost acked writes) are
//! unaffected.
//!
//! This file stays off the `Instant::now` allowlist on purpose: the
//! loop itself never reads a clock. Time-dependent behavior (token
//! buckets, lease expiry) takes time as a value inside the service,
//! which keeps the loop replayable and the clock lint meaningful.

use std::io::{self, Read, Write};
use std::net::TcpListener;
use std::os::fd::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::control::{check_hello, hello_payload, HelloInfo};
use super::faults::{FaultPlan, FaultyStream};
use super::wire::{CodecError, MAX_FRAME};

/// epoll wait granularity: how often an idle loop rechecks `stop`.
const WAIT_MS: i32 = 50;
/// Readiness events drained per `epoll_wait` call.
const EVENT_BATCH: usize = 256;
/// Read chunk size; also the slack a connection may hold beyond one
/// partial frame (complete frames are consumed after every chunk).
const READ_CHUNK: usize = 64 << 10;
/// Write-queue backpressure threshold: past this many pending bytes
/// the loop stops reading/decoding for the connection until the peer
/// drains its responses.
const HIGH_WATER: usize = 1 << 20;
/// Idle buffers are shrunk back to at most this capacity (mirrors
/// `CONN_BUF_BYTES` on the threaded path) so one large frame does not
/// pin megabytes for a connection's lifetime.
const IDLE_BUF_BYTES: usize = 32 << 10;
/// epoll token reserved for the shared listener.
const LISTENER_TOKEN: u64 = u64::MAX;

// ------------------------------------------------------------- syscalls

/// Raw epoll bindings. `std::net` exposes no readiness API, and the
/// crate takes no dependencies, so these three syscalls (plus `close`)
/// come straight from glibc.
mod sys {
    use std::os::raw::c_int;

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    /// Kernel ≥ 4.5: wake one loop per listener readiness instead of
    /// the whole herd. Valid only at ADD time, which is the only way
    /// this module registers the listener.
    pub const EPOLLEXCLUSIVE: u32 = 1 << 28;

    /// Matches the kernel's `struct epoll_event`, which is packed on
    /// x86-64 (and only there) for historical 32/64-bit compat.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn close(fd: c_int) -> c_int;
    }
}

/// RAII handle over one epoll instance.
struct Poller {
    epfd: RawFd,
}

impl Poller {
    fn new() -> io::Result<Poller> {
        // SAFETY: plain syscall with no pointer arguments; the result
        // is checked before use.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events: interest, data: token };
        // SAFETY: `epfd` and `fd` are open descriptors owned by this
        // loop, and `ev` is a valid epoll_event for the kernel to read
        // (DEL ignores it but pre-2.6.9 kernels want it non-null).
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, interest)
    }

    // lint: no-alloc
    fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn remove(&self, fd: RawFd) {
        let _ = self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// Wait for readiness into the caller-owned `events` buffer.
    // lint: no-alloc
    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        // SAFETY: `events` is a live, writable buffer of `len`
        // epoll_event structs and the kernel fills at most that many.
        let n = unsafe {
            sys::epoll_wait(self.epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
        };
        if n < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(n as usize)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: closing the epoll descriptor this struct exclusively
        // owns; no other handle refers to it.
        let _ = unsafe { sys::close(self.epfd) };
    }
}

// ------------------------------------------------------ frame assembly

/// Incremental reassembly of u32-LE length-prefixed frames from a
/// nonblocking byte stream.
///
/// Allocation is bounded by bytes *received*, never by lengths
/// *declared*: the buffer grows only via `push` of real socket bytes,
/// and a declared length over [`MAX_FRAME`] is rejected as soon as the
/// 4-byte prefix arrives — the body is never buffered. This is the
/// event-loop twin of the `read_frame_into` bound on the blocking
/// path, and it is what makes a slow-loris peer cost a few bytes
/// instead of 16 MiB (see `tests/chaos.rs::half_open_connections_*`).
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before `head` belong to frames already
    /// yielded and are reclaimed by [`FrameAssembler::compact`].
    head: usize,
}

impl Default for FrameAssembler {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler { buf: Vec::new(), head: 0 }
    }

    /// Buffer freshly received bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (received but not yet yielded).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.head
    }

    /// Bytes of heap the assembler is pinning right now.
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Pop the next complete frame, if one has fully arrived.
    ///
    /// `Ok(None)` means "need more bytes". A declared length over
    /// [`MAX_FRAME`] errors immediately — before the body exists.
    // lint: no-alloc
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, CodecError> {
        let avail = &self.buf[self.head..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len > MAX_FRAME {
            return Err(CodecError::FrameTooLarge(len));
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let start = self.head + 4;
        self.head = start + len;
        Ok(Some(&self.buf[start..start + len]))
    }

    /// Reclaim the consumed prefix and release slack capacity, keeping
    /// any partial frame in place. Called once per readiness pass, not
    /// per frame, so steady-state serving does no copying.
    pub fn compact(&mut self) {
        if self.head > 0 {
            self.buf.drain(..self.head);
            self.head = 0;
        }
        if self.buf.capacity() > IDLE_BUF_BYTES && self.buf.capacity() / 2 > self.buf.len() {
            self.buf.shrink_to(IDLE_BUF_BYTES.max(self.buf.len()));
        }
    }
}

// -------------------------------------------------------- service trait

/// What a plane plugs into the loop: its handshake magic, its
/// per-connection state, and a handler turning one request frame into
/// one response payload.
///
/// One clone of the service lives on each loop thread; shared state
/// goes behind `Arc`s inside the implementor. Handlers run inline on
/// the loop thread, so they must not block on the network (blocking on
/// a shard mutex is fine — that is the same contention the threaded
/// path has).
pub trait Service: Clone + Send + 'static {
    /// Per-connection handler state, created once the hello completes.
    type Conn: Send;

    /// The 4-byte plane magic this service answers with and requires.
    fn magic(&self) -> [u8; 4];

    /// Build per-connection state for a handshaken peer. `conn` is the
    /// process-wide connection index — the same index that keys the
    /// connection's fault/tamper schedule, so byzantine state derived
    /// from it matches the threaded path exactly.
    fn open_conn(&self, conn: u64, hello: HelloInfo) -> Self::Conn;

    /// Handle one complete request frame, appending exactly one
    /// response payload to `out` (the loop adds the length prefix).
    fn on_frame(&self, conn: &mut Self::Conn, frame: &[u8], out: &mut Vec<u8>);
}

// --------------------------------------------------- connection machine

/// Per-connection state: socket, reassembly buffer, write queue, and
/// the hello→serving handshake state.
struct Conn<C> {
    stream: FaultyStream,
    fd: RawFd,
    token: u64,
    conn_id: u64,
    asm: FrameAssembler,
    /// Encoded-but-unsent response bytes (length prefixes included).
    outq: Vec<u8>,
    /// Prefix of `outq` already written to the socket.
    sent: usize,
    /// `None` until the hello frame is accepted.
    state: Option<C>,
    /// Set on handshake refusal: flush the answering hello, then close.
    close_after_flush: bool,
    /// Interest mask currently registered with the poller.
    interest: u32,
}

impl<C> Conn<C> {
    // lint: no-alloc
    fn pending(&self) -> usize {
        self.outq.len() - self.sent
    }

    /// Write queued bytes until the socket would block. On a complete
    /// drain the queue is reset and its slack capacity released.
    // lint: no-alloc
    fn flush_out(&mut self) -> io::Result<()> {
        while self.sent < self.outq.len() {
            match self.stream.write(&self.outq[self.sent..]) {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(n) => self.sent += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        if self.sent == self.outq.len() {
            self.outq.clear();
            self.sent = 0;
            if self.outq.capacity() > IDLE_BUF_BYTES {
                self.outq.shrink_to(IDLE_BUF_BYTES);
            }
        }
        Ok(())
    }

    /// Is this connection under write backpressure (reads paused)?
    // lint: no-alloc
    fn backpressured(&self) -> bool {
        self.pending() > HIGH_WATER
    }
}

/// Append one length-prefixed frame to a connection's write queue.
// lint: no-alloc
fn queue_frame(outq: &mut Vec<u8>, payload: &[u8]) {
    outq.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    outq.extend_from_slice(payload);
}

// ------------------------------------------------------------ the loop

/// Spawn `threads` event-loop threads serving `listener` with
/// `service`. Returns the join handles; the loops exit once `stop` is
/// set (checked every [`WAIT_MS`]). Each loop owns an epoll instance;
/// the shared listener is registered `EPOLLEXCLUSIVE` in all of them
/// so one connection wakes one loop. Accepted sockets are wrapped in
/// [`FaultyStream`] keyed by a process-wide connection counter.
pub fn spawn_loops<S: Service>(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    faults: Option<FaultPlan>,
    service: S,
    threads: usize,
) -> io::Result<Vec<JoinHandle<()>>> {
    listener.set_nonblocking(true)?;
    let listener = Arc::new(listener);
    let conn_seq = Arc::new(AtomicU64::new(0));
    let threads = threads.max(1);
    let mut handles = Vec::with_capacity(threads);
    for _ in 0..threads {
        // Create + register before spawning so setup errors surface
        // from the constructor, not from a dying thread.
        let poller = Poller::new()?;
        poller.add(
            listener.as_raw_fd(),
            LISTENER_TOKEN,
            sys::EPOLLIN | sys::EPOLLEXCLUSIVE,
        )?;
        let (listener, stop) = (Arc::clone(&listener), Arc::clone(&stop));
        let (faults, seq, svc) = (faults.clone(), Arc::clone(&conn_seq), service.clone());
        handles.push(std::thread::spawn(move || {
            run_loop(poller, listener, stop, faults, seq, svc);
        }));
    }
    Ok(handles)
}

fn run_loop<S: Service>(
    poller: Poller,
    listener: Arc<TcpListener>,
    stop: Arc<AtomicBool>,
    faults: Option<FaultPlan>,
    conn_seq: Arc<AtomicU64>,
    service: S,
) {
    let mut conns: Vec<Option<Conn<S::Conn>>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut events = [sys::EpollEvent { events: 0, data: 0 }; EVENT_BATCH];
    let mut chunk = vec![0u8; READ_CHUNK];
    let mut resp: Vec<u8> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        let n = match poller.wait(&mut events, WAIT_MS) {
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        for ev in events.iter().take(n) {
            // Copy packed fields out by value; references into a
            // packed struct are unaligned and rejected by rustc.
            let (token, mask) = (ev.data, ev.events);
            if token == LISTENER_TOKEN {
                accept_ready(&poller, &listener, faults.as_ref(), &conn_seq, &mut conns, &mut free);
                continue;
            }
            let slot = token as usize;
            // The slot may have been vacated earlier in this batch.
            let Some(conn) = conns.get_mut(slot).and_then(|s| s.as_mut()) else {
                continue;
            };
            if !step_conn(&poller, &service, conn, mask, &mut chunk, &mut resp) {
                close_conn(&poller, &mut conns, &mut free, slot);
            }
        }
    }
}

/// Accept until the listener would block. Setup failures drop the one
/// socket; accept failures (e.g. EMFILE under a connection storm) end
/// the pass — level-triggered epoll re-reports readiness next wake-up.
fn accept_ready<C>(
    poller: &Poller,
    listener: &TcpListener,
    faults: Option<&FaultPlan>,
    conn_seq: &AtomicU64,
    conns: &mut Vec<Option<Conn<C>>>,
    free: &mut Vec<usize>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        };
        if stream.set_nonblocking(true).is_err() || stream.set_nodelay(true).is_err() {
            continue;
        }
        let conn_id = conn_seq.fetch_add(1, Ordering::Relaxed);
        let stream = FaultyStream::new(stream, faults, conn_id);
        let fd = stream.as_raw_fd();
        let slot = free.pop().unwrap_or_else(|| {
            conns.push(None);
            conns.len() - 1
        });
        let token = slot as u64;
        let interest = sys::EPOLLIN | sys::EPOLLRDHUP;
        if poller.add(fd, token, interest).is_err() {
            free.push(slot);
            continue;
        }
        conns[slot] = Some(Conn {
            stream,
            fd,
            token,
            conn_id,
            asm: FrameAssembler::new(),
            outq: Vec::new(),
            sent: 0,
            state: None,
            close_after_flush: false,
            interest,
        });
    }
}

/// Drive one connection through one readiness event. Returns `false`
/// when the connection should be closed.
fn step_conn<S: Service>(
    poller: &Poller,
    service: &S,
    conn: &mut Conn<S::Conn>,
    mask: u32,
    chunk: &mut [u8],
    resp: &mut Vec<u8>,
) -> bool {
    if mask & sys::EPOLLERR != 0 {
        return false;
    }
    if mask & sys::EPOLLOUT != 0 && conn.flush_out().is_err() {
        return false;
    }
    // Frames parked by backpressure drain first (write readiness just
    // made room), then fresh socket bytes.
    let served = drain_frames(service, conn, resp).and_then(|()| {
        if mask & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0 {
            pump_reads(service, conn, chunk, resp)?;
        }
        Ok(())
    });
    if served.is_err() || conn.flush_out().is_err() {
        return false;
    }
    if conn.close_after_flush && conn.pending() == 0 {
        return false;
    }
    update_interest(poller, conn)
}

/// Read until the socket would block, handing complete frames to the
/// service after every chunk so buffered input stays bounded by one
/// partial frame plus one read chunk.
fn pump_reads<S: Service>(
    service: &S,
    conn: &mut Conn<S::Conn>,
    chunk: &mut [u8],
    resp: &mut Vec<u8>,
) -> io::Result<()> {
    loop {
        if conn.backpressured() || conn.close_after_flush {
            break;
        }
        match conn.stream.read(chunk) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => {
                conn.asm.push(&chunk[..n]);
                drain_frames(service, conn, resp)?;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    conn.asm.compact();
    Ok(())
}

/// Feed every complete buffered frame through the connection's state
/// machine: the first frame is the hello, the rest go to the service.
/// Stops early under write backpressure.
fn drain_frames<S: Service>(
    service: &S,
    conn: &mut Conn<S::Conn>,
    resp: &mut Vec<u8>,
) -> io::Result<()> {
    loop {
        if conn.backpressured() || conn.close_after_flush {
            return Ok(());
        }
        // Split borrows: `frame` borrows `conn.asm`; the arms below
        // touch only `conn.state` / `conn.outq`.
        let c = &mut *conn;
        let frame = match c.asm.next_frame() {
            Ok(Some(frame)) => frame,
            Ok(None) => return Ok(()),
            Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
        };
        match c.state.as_mut() {
            None => {
                let magic = service.magic();
                match check_hello(frame, magic) {
                    Ok(hello) => {
                        queue_frame(&mut c.outq, &hello_payload(magic));
                        c.state = Some(service.open_conn(c.conn_id, hello));
                    }
                    Err(_) => {
                        // Same contract as the blocking handshake:
                        // answer with our hello even on mismatch so
                        // the peer reports plane/version clearly,
                        // then close once it has flushed.
                        queue_frame(&mut c.outq, &hello_payload(magic));
                        c.close_after_flush = true;
                    }
                }
            }
            Some(state) => {
                resp.clear();
                service.on_frame(state, frame, resp);
                queue_frame(&mut c.outq, resp);
            }
        }
    }
}

/// Re-register the poller interest mask if it changed: `EPOLLOUT` only
/// while bytes are pending, `EPOLLIN` only while not backpressured.
fn update_interest<C>(poller: &Poller, conn: &mut Conn<C>) -> bool {
    let mut want = sys::EPOLLRDHUP;
    if conn.pending() > 0 {
        want |= sys::EPOLLOUT;
    }
    if !conn.backpressured() && !conn.close_after_flush {
        want |= sys::EPOLLIN;
    }
    if want != conn.interest {
        if poller.modify(conn.fd, conn.token, want).is_err() {
            return false;
        }
        conn.interest = want;
    }
    true
}

fn close_conn<C>(
    poller: &Poller,
    conns: &mut Vec<Option<Conn<C>>>,
    free: &mut Vec<usize>,
    slot: usize,
) {
    if let Some(entry) = conns.get_mut(slot) {
        if let Some(conn) = entry.take() {
            // Deregister before the socket drops and the fd number can
            // be reused by a new accept on another loop thread.
            poller.remove(conn.fd);
            free.push(slot);
            drop(conn);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::control::client_handshake;
    use crate::net::wire::{read_frame_into, write_frame};
    use std::io::BufReader;
    use std::net::TcpStream;
    use std::time::Duration;

    fn wire_bytes(frames: &[&[u8]]) -> Vec<u8> {
        let mut out = Vec::new();
        for f in frames {
            queue_frame(&mut out, f);
        }
        out
    }

    fn collect_frames(asm: &mut FrameAssembler) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(f) = asm.next_frame().expect("well-formed stream") {
            out.push(f.to_vec());
        }
        out
    }

    /// The reassembly property test the ISSUE asks for: any split of
    /// the byte stream — every single cut point, plus byte-at-a-time —
    /// yields exactly the original frames in order.
    #[test]
    fn reassembles_frames_split_at_every_byte_offset() {
        let frames: Vec<&[u8]> = vec![b"", b"a", b"hello world", &[0u8; 300], b"\x00\xff\x7f"];
        let wire = wire_bytes(&frames);
        let want: Vec<Vec<u8>> = frames.iter().map(|f| f.to_vec()).collect();

        for cut in 0..=wire.len() {
            let mut asm = FrameAssembler::new();
            let mut got = Vec::new();
            asm.push(&wire[..cut]);
            got.extend(collect_frames(&mut asm));
            asm.compact();
            asm.push(&wire[cut..]);
            got.extend(collect_frames(&mut asm));
            assert_eq!(got, want, "split at byte {cut}");
        }

        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for b in &wire {
            asm.push(std::slice::from_ref(b));
            got.extend(collect_frames(&mut asm));
        }
        assert_eq!(got, want, "byte-at-a-time");
        asm.compact();
        assert_eq!(asm.buffered(), 0);
    }

    /// Hostile declared lengths are rejected from the 4-byte prefix
    /// alone — no body bytes are ever buffered or allocated for.
    #[test]
    fn rejects_hostile_length_before_buffering_the_body() {
        let mut asm = FrameAssembler::new();
        asm.push(&((MAX_FRAME as u32) + 1).to_le_bytes());
        match asm.next_frame() {
            Err(CodecError::FrameTooLarge(n)) => assert_eq!(n, MAX_FRAME + 1),
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        // A frame of exactly MAX_FRAME is legal and stays pending.
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME as u32).to_le_bytes());
        assert!(matches!(asm.next_frame(), Ok(None)));
    }

    /// The slow-loris bound: memory tracks bytes received, not bytes
    /// declared. A peer claiming a 16 MiB frame but sending 100 bytes
    /// pins ~100 bytes.
    #[test]
    fn buffers_only_received_bytes_never_declared_length() {
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME as u32).to_le_bytes());
        asm.push(&[7u8; 100]);
        assert!(matches!(asm.next_frame(), Ok(None)));
        assert_eq!(asm.buffered(), 104);
        assert!(
            asm.capacity() < 64 << 10,
            "capacity {} must track received bytes, not the 16 MiB declared",
            asm.capacity()
        );
    }

    /// After a large burst drains, compact releases the slack.
    #[test]
    fn compact_reclaims_consumed_prefix_and_slack() {
        let big = vec![42u8; 256 << 10];
        let mut asm = FrameAssembler::new();
        asm.push(&wire_bytes(&[&big]));
        assert_eq!(collect_frames(&mut asm), vec![big]);
        asm.compact();
        assert_eq!(asm.buffered(), 0);
        assert!(asm.capacity() <= IDLE_BUF_BYTES, "capacity {}", asm.capacity());
    }

    /// Minimal end-to-end service: the loop handshakes, frames, and
    /// echoes over a real socket, across partial writes and multiple
    /// sequential frames.
    #[derive(Clone)]
    struct Echo;

    impl Service for Echo {
        type Conn = u64;
        fn magic(&self) -> [u8; 4] {
            crate::net::control::DATA_MAGIC
        }
        fn open_conn(&self, conn: u64, _hello: HelloInfo) -> u64 {
            conn
        }
        fn on_frame(&self, _conn: &mut u64, frame: &[u8], out: &mut Vec<u8>) {
            out.extend_from_slice(frame);
        }
    }

    #[test]
    fn echo_service_over_a_real_epoll_loop() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let handles = spawn_loops(listener, Arc::clone(&stop), None, Echo, 2).unwrap();

        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        client_handshake(&mut reader, &mut writer, crate::net::control::DATA_MAGIC).unwrap();

        let mut buf = Vec::new();
        for i in 0u32..32 {
            let payload = vec![i as u8; (i as usize) * 37 + 1];
            write_frame(&mut writer, &payload).unwrap();
            read_frame_into(&mut reader, &mut buf).unwrap();
            assert_eq!(buf, payload, "frame {i}");
        }

        // A second client on a wrong plane still gets a hello back
        // (so it can report the mismatch), then the server closes.
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let err = client_handshake(&mut reader, &mut writer, crate::net::control::CONTROL_MAGIC)
            .unwrap_err();
        assert!(err.to_string().contains("plane"), "{err}");

        stop.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().unwrap();
        }
    }
}

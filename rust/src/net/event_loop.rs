//! Readiness-driven (epoll) server substrate shared by both planes.
//!
//! One producer VM must hold thousands of consumer connections (the
//! paper's whole economic argument: spot-block pricing only beats
//! dedicated instances when a harvested VM is shared wide), and the
//! broker must hold heartbeats from every producer agent in the
//! cluster. Thread-per-connection tops out far earlier, so both
//! servers run on this hand-rolled epoll loop instead: a few loop
//! threads, each owning an epoll instance, multiplex nonblocking
//! sockets through per-connection state machines.
//!
//! The loop is deliberately small and zero-dependency — raw
//! `epoll_create1`/`epoll_ctl`/`epoll_wait` (plus `accept4`,
//! `eventfd`, and `timerfd`) through `extern "C"` glibc bindings, no
//! reactor framework. Pieces:
//!
//! - [`Poller`]: thin RAII wrapper over one epoll file descriptor.
//! - [`FrameAssembler`]: incremental reassembly of the u32-LE
//!   length-prefixed frames described in PROTOCOL.md. It buffers only
//!   bytes actually received — a peer declaring a 16 MiB frame and
//!   then stalling (slow loris) pins a 4-byte header, not 16 MiB —
//!   and rejects hostile lengths (`> MAX_FRAME`) as soon as the
//!   prefix arrives, before any body byte is stored.
//! - [`WriteQueue`]: the connection's pending responses as a list of
//!   encoded frames, flushed with one vectored `writev` per syscall
//!   instead of one `write` per response. The partial-write cursor
//!   (`head_sent`) and the [`HIGH_WATER`] backpressure contract are
//!   unchanged from the single-buffer design it replaces.
//! - [`Conn`]: per-connection state machine. A connection is born in
//!   the *hello* state (first frame must be the 11-byte handshake,
//!   answered in kind even on plane/version mismatch so the peer can
//!   print a useful error), then moves to *serving*, where every
//!   complete frame is handed to the [`Service`] and the response is
//!   queued on the connection's write queue. When the queue passes
//!   [`HIGH_WATER`] the loop stops reading (and decoding) for that
//!   connection until the peer drains it — backpressure, not
//!   buffering.
//! - [`Service`]: what a plane plugs in — its hello magic, its
//!   per-connection state, a frame handler, and (optionally) a
//!   periodic tick for time-based housekeeping such as token-bucket
//!   refill, delivered by a per-loop `timerfd` in the same epoll set.
//!
//! Connection fds are registered **edge-triggered** (`EPOLLET`) by
//! default: one `epoll_ctl` at accept time, never re-armed, with
//! drain-until-`WouldBlock` read and write loops and per-connection
//! `can_read`/`can_write` readiness flags. A hot connection yields
//! after [`FAIR_FRAMES`] frames and is re-queued on the loop's local
//! ready-list (no kernel round-trip), so it cannot starve its
//! siblings. An idle loop parks in `epoll_wait(-1)` with its timer
//! disarmed — zero syscalls until the kernel has news. Set
//! `MEMTRADE_EVENT_MODE=level` to fall back to the level-triggered
//! `EPOLL_CTL_MOD` interest machine (kept for one release as the
//! bench comparison anchor).
//!
//! Chaos parity: accepted sockets are wrapped in
//! [`FaultyStream`](crate::net::faults::FaultyStream) exactly like
//! the threaded path, keyed by the same global connection index, so a
//! fault schedule is still a pure function of `(seed, conn)`. A
//! would-block inner read or write restores the fault RNG, so edge
//! retries do not skew the schedule. Chaos write faults
//! (flip/truncate/duplicate) are each bounded to one partial-accept
//! write, so a full send buffer mid-fault surfaces as an ordinary
//! `WouldBlock` (RNG restored, retried by the write queue) rather
//! than an error that would close the connection and desync the
//! seeded schedule. A short accept can shrink a fault — a flip or
//! duplicate that fails to stick — but can only corrupt or drop
//! *unacked* bytes, which the envelope already allows; it can never
//! fabricate an ack, so the chaos invariants (100% envelope catch,
//! no lost acked writes) hold.
//!
//! This file stays off the `Instant::now` allowlist on purpose: the
//! loop itself never reads a clock. Time-dependent behavior (token
//! buckets, lease expiry) takes time as a value inside the service —
//! the timerfd tick tells the service *that* time passed, the service
//! decides what that means — which keeps the loop replayable and the
//! clock lint meaningful.

use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::net::TcpListener;
use std::os::fd::{AsRawFd, FromRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::control::{check_hello, hello_payload, HelloInfo};
use super::faults::{FaultPlan, FaultyStream};
use super::wire::{CodecError, MAX_FRAME};
use crate::metrics::Counter;

/// Level-mode epoll wait granularity: how often an idle level-mode
/// loop rechecks `stop`. Edge mode blocks indefinitely and is woken by
/// the stop eventfd instead.
const WAIT_MS: i32 = 50;
/// Readiness events drained per `epoll_wait` call.
const EVENT_BATCH: usize = 256;
/// Read chunk size; also the slack a connection may hold beyond one
/// partial frame (complete frames are consumed after every chunk).
const READ_CHUNK: usize = 64 << 10;
/// Write-queue backpressure threshold: past this many pending bytes
/// the loop stops reading/decoding for the connection until the peer
/// drains its responses.
const HIGH_WATER: usize = 1 << 20;
/// Idle buffers are shrunk back to at most this capacity (mirrors
/// `CONN_BUF_BYTES` on the threaded path) so one large frame does not
/// pin megabytes for a connection's lifetime.
const IDLE_BUF_BYTES: usize = 32 << 10;
/// Fairness budget: frames one connection may consume per scheduling
/// turn before it must yield to its loop siblings (re-queued on the
/// loop-local ready-list, not re-armed through the kernel).
const FAIR_FRAMES: u32 = 32;
/// Most response frames coalesced into one `writev` call.
const MAX_IOV: usize = 64;
/// Recycled response buffers kept per connection.
const POOL_BUFS: usize = 8;
/// How long an otherwise-idle loop sleeps when `accept4` fails with
/// fd exhaustion. The listener is level-triggered, so without a pause
/// `epoll_wait` re-reports the nonempty backlog instantly and the
/// loop spins at 100% CPU for the whole EMFILE episode.
const ACCEPT_BACKOFF_MS: u64 = 10;
/// epoll token reserved for the shared listener.
const LISTENER_TOKEN: u64 = u64::MAX;
/// epoll token reserved for the stop-wakeup eventfd.
const WAKER_TOKEN: u64 = u64::MAX - 1;
/// epoll token reserved for the per-loop service-tick timerfd.
const TIMER_TOKEN: u64 = u64::MAX - 2;

// ------------------------------------------------------------- syscalls

/// Raw bindings for the readiness plane. `std::net` exposes no
/// readiness API, and the crate takes no dependencies, so epoll,
/// `accept4`, `eventfd`, and `timerfd` come straight from glibc. This
/// module and `util/{clock,bench}.rs` are the only files the
/// `syscall-site` lint rule allows to declare externs.
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const EPOLL_CLOEXEC: c_int = 0o2000000;
    pub const EPOLL_CTL_ADD: c_int = 1;
    pub const EPOLL_CTL_DEL: c_int = 2;
    pub const EPOLL_CTL_MOD: c_int = 3;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;
    /// Kernel ≥ 4.5: wake one loop per listener readiness instead of
    /// the whole herd. Valid only at ADD time, which is the only way
    /// this module registers the listener.
    pub const EPOLLEXCLUSIVE: u32 = 1 << 28;
    /// Edge-triggered delivery: one event per readiness *transition*.
    pub const EPOLLET: u32 = 1 << 31;

    /// `SOCK_NONBLOCK | SOCK_CLOEXEC` for `accept4`: the accepted fd
    /// is born nonblocking, killing the two-`fcntl` dance per accept.
    pub const SOCK_NONBLOCK: c_int = 0o4000;
    pub const SOCK_CLOEXEC: c_int = 0o2000000;

    pub const EFD_CLOEXEC: c_int = 0o2000000;
    pub const EFD_NONBLOCK: c_int = 0o4000;

    /// Process fd limit reached (`accept4` under fd exhaustion).
    pub const EMFILE: i32 = 24;
    /// System-wide fd limit reached.
    pub const ENFILE: i32 = 23;

    /// Socket-buffer knobs for tests that need a known amount of
    /// kernel-side send capacity (the backpressure-lift regression).
    #[cfg(test)]
    pub const SOL_SOCKET: c_int = 1;
    #[cfg(test)]
    pub const SO_RCVBUF: c_int = 8;
    #[cfg(test)]
    pub const SO_SNDBUF: c_int = 7;

    pub const CLOCK_MONOTONIC: c_int = 1;
    pub const TFD_CLOEXEC: c_int = 0o2000000;
    pub const TFD_NONBLOCK: c_int = 0o4000;

    /// Matches the kernel's `struct epoll_event`, which is packed on
    /// x86-64 (and only there) for historical 32/64-bit compat.
    #[derive(Clone, Copy)]
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    /// `struct timespec` as `timerfd_settime` wants it.
    #[derive(Clone, Copy, Default)]
    #[repr(C)]
    pub struct Timespec {
        pub sec: i64,
        pub nsec: i64,
    }

    /// `struct itimerspec`: first expiry (`value`) plus period
    /// (`interval`); all-zero disarms the timer.
    #[derive(Clone, Copy, Default)]
    #[repr(C)]
    pub struct Itimerspec {
        pub interval: Timespec,
        pub value: Timespec,
    }

    extern "C" {
        pub fn epoll_create1(flags: c_int) -> c_int;
        pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        pub fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        pub fn accept4(
            sockfd: c_int,
            addr: *mut c_void,
            addrlen: *mut c_void,
            flags: c_int,
        ) -> c_int;
        pub fn eventfd(initval: u32, flags: c_int) -> c_int;
        pub fn timerfd_create(clockid: c_int, flags: c_int) -> c_int;
        pub fn timerfd_settime(
            fd: c_int,
            flags: c_int,
            new_value: *const Itimerspec,
            old_value: *mut Itimerspec,
        ) -> c_int;
        pub fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        pub fn close(fd: c_int) -> c_int;
        #[cfg(test)]
        pub fn setsockopt(
            sockfd: c_int,
            level: c_int,
            optname: c_int,
            optval: *const c_void,
            optlen: u32,
        ) -> c_int;
    }
}

/// RAII handle over one epoll instance.
struct Poller {
    epfd: RawFd,
}

impl Poller {
    fn new() -> io::Result<Poller> {
        // SAFETY: plain syscall with no pointer arguments; the result
        // is checked before use.
        let epfd = unsafe { sys::epoll_create1(sys::EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        let mut ev = sys::EpollEvent { events: interest, data: token };
        // SAFETY: `epfd` and `fd` are open descriptors owned by this
        // loop, and `ev` is a valid epoll_event for the kernel to read
        // (DEL ignores it but pre-2.6.9 kernels want it non-null).
        let rc = unsafe { sys::epoll_ctl(self.epfd, op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_ADD, fd, token, interest)
    }

    // lint: no-alloc
    fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
        self.ctl(sys::EPOLL_CTL_MOD, fd, token, interest)
    }

    fn remove(&self, fd: RawFd) {
        let _ = self.ctl(sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    /// Wait for readiness into the caller-owned `events` buffer.
    // lint: no-alloc
    fn wait(&self, events: &mut [sys::EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        // SAFETY: `events` is a live, writable buffer of `len`
        // epoll_event structs and the kernel fills at most that many.
        let n = unsafe {
            sys::epoll_wait(self.epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
        };
        if n < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(n as usize)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: closing the epoll descriptor this struct exclusively
        // owns; no other handle refers to it.
        let _ = unsafe { sys::close(self.epfd) };
    }
}

// --------------------------------------------------------- waker, timer

/// Stop-wakeup eventfd, registered level-triggered in every loop's
/// epoll set. Written exactly once, by [`EventLoops::stop_and_join`]:
/// an idle edge-mode loop parks in `epoll_wait(-1)`, so without this
/// it would only notice `stop` on the next unrelated event. Because
/// it is written only at shutdown it costs zero syscalls in steady
/// state (the loops exit without draining it).
pub struct LoopWaker {
    fd: RawFd,
}

impl LoopWaker {
    fn new() -> io::Result<LoopWaker> {
        // SAFETY: plain syscall with no pointer arguments; the result
        // is checked before use.
        let fd = unsafe { sys::eventfd(0, sys::EFD_CLOEXEC | sys::EFD_NONBLOCK) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(LoopWaker { fd })
    }

    /// Wake every loop watching this eventfd (level-triggered: one
    /// write is seen by all pollers).
    fn wake(&self) {
        let one: u64 = 1;
        // SAFETY: `fd` is the open eventfd this struct owns and the
        // buffer is a live 8-byte value, the size eventfd requires.
        let _ = unsafe {
            sys::write(self.fd, (&one as *const u64).cast(), std::mem::size_of::<u64>())
        };
    }
}

impl Drop for LoopWaker {
    fn drop(&mut self) {
        // SAFETY: closing the eventfd this struct exclusively owns.
        let _ = unsafe { sys::close(self.fd) };
    }
}

/// Per-loop periodic timer (CLOCK_MONOTONIC timerfd) carrying the
/// service tick. Created lazily the first time the service asks for
/// ticks and disarmed whenever it stops asking, so a loop with no
/// time-based work (or a full token bucket) keeps a dead-silent fd.
struct TimerFd {
    fd: RawFd,
}

impl TimerFd {
    fn new() -> io::Result<TimerFd> {
        // SAFETY: plain syscall with no pointer arguments; the result
        // is checked before use.
        let fd = unsafe {
            sys::timerfd_create(sys::CLOCK_MONOTONIC, sys::TFD_CLOEXEC | sys::TFD_NONBLOCK)
        };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(TimerFd { fd })
    }

    /// Arm as a periodic timer firing every `interval_us` (0 disarms).
    fn set_interval_us(&self, interval_us: u64) -> io::Result<()> {
        let ts = sys::Timespec {
            sec: (interval_us / 1_000_000) as i64,
            nsec: ((interval_us % 1_000_000) * 1_000) as i64,
        };
        let spec = sys::Itimerspec { interval: ts, value: ts };
        // SAFETY: `fd` is the open timerfd this struct owns; `spec` is
        // a live itimerspec; the old-value out pointer may be null.
        let rc = unsafe { sys::timerfd_settime(self.fd, 0, &spec, std::ptr::null_mut()) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    /// Expirations since the last read (0 if none — the fd is
    /// nonblocking, so a spurious wakeup costs one failed read).
    fn read_ticks(&self) -> u64 {
        let mut ticks: u64 = 0;
        // SAFETY: `fd` is the open timerfd this struct owns and the
        // buffer is a live 8-byte value, the size timerfd requires.
        let n = unsafe {
            sys::read(self.fd, (&mut ticks as *mut u64).cast(), std::mem::size_of::<u64>())
        };
        if n == std::mem::size_of::<u64>() as isize {
            ticks
        } else {
            0
        }
    }
}

impl Drop for TimerFd {
    fn drop(&mut self) {
        // SAFETY: closing the timerfd this struct exclusively owns.
        let _ = unsafe { sys::close(self.fd) };
    }
}

// ------------------------------------------------------------- metrics

/// Loop-plane instrumentation, shared by every loop thread of one
/// server. `syscalls` counts the calls this module issues at its own
/// call sites (epoll_wait/ctl, accept4, reads, vectored writes, timer
/// programming) — an estimate by construction, but a faithful one,
/// and the numerator of the `net.syscalls_per_op` gauge the data
/// plane exports.
#[derive(Default)]
pub struct LoopMetrics {
    /// `epoll_wait` returns.
    pub wakeups: Counter,
    /// Readiness events delivered across all wakeups.
    pub events: Counter,
    /// Syscalls issued at this module's own call sites.
    pub syscalls: Counter,
    /// Connections accepted.
    pub accepts: Counter,
    /// Fairness-budget exhaustions (a hot connection yielded and was
    /// re-queued on the loop-local ready-list).
    pub yields: Counter,
    /// Frames handed to the service (hello frames included).
    pub frames: Counter,
}

// ------------------------------------------------------ frame assembly

/// Incremental reassembly of u32-LE length-prefixed frames from a
/// nonblocking byte stream.
///
/// Allocation is bounded by bytes *received*, never by lengths
/// *declared*: the buffer grows only via `push` of real socket bytes,
/// and a declared length over [`MAX_FRAME`] is rejected as soon as the
/// 4-byte prefix arrives — the body is never buffered. This is the
/// event-loop twin of the `read_frame_into` bound on the blocking
/// path, and it is what makes a slow-loris peer cost a few bytes
/// instead of 16 MiB (see `tests/chaos.rs::half_open_connections_*`).
pub struct FrameAssembler {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before `head` belong to frames already
    /// yielded and are reclaimed by [`FrameAssembler::compact`].
    head: usize,
}

impl Default for FrameAssembler {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameAssembler {
    pub fn new() -> FrameAssembler {
        FrameAssembler { buf: Vec::new(), head: 0 }
    }

    /// Buffer freshly received bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes currently buffered (received but not yet yielded).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.head
    }

    /// Does the buffer hold runnable work right now: a complete frame,
    /// or a prefix whose declared length is already known hostile (the
    /// next [`FrameAssembler::next_frame`] will error, which is also
    /// work)? A partial frame is *not* runnable — serving it needs
    /// bytes the kernel will edge-notify about.
    // lint: no-alloc
    pub fn has_frame(&self) -> bool {
        let avail = &self.buf[self.head..];
        if avail.len() < 4 {
            return false;
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        len > MAX_FRAME || avail.len() >= 4 + len
    }

    /// Bytes of heap the assembler is pinning right now.
    pub fn capacity(&self) -> usize {
        self.buf.capacity()
    }

    /// Pop the next complete frame, if one has fully arrived.
    ///
    /// `Ok(None)` means "need more bytes". A declared length over
    /// [`MAX_FRAME`] errors immediately — before the body exists.
    // lint: no-alloc
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, CodecError> {
        let avail = &self.buf[self.head..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len > MAX_FRAME {
            return Err(CodecError::FrameTooLarge(len));
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let start = self.head + 4;
        self.head = start + len;
        Ok(Some(&self.buf[start..start + len]))
    }

    /// Reclaim the consumed prefix and release slack capacity, keeping
    /// any partial frame in place. Called once per readiness pass, not
    /// per frame, so steady-state serving does no copying.
    pub fn compact(&mut self) {
        if self.head > 0 {
            self.buf.drain(..self.head);
            self.head = 0;
        }
        if self.buf.capacity() > IDLE_BUF_BYTES && self.buf.capacity() / 2 > self.buf.len() {
            self.buf.shrink_to(IDLE_BUF_BYTES.max(self.buf.len()));
        }
    }
}

// --------------------------------------------------------- write queue

/// Flush outcome: did the socket absorb everything, or block?
#[derive(PartialEq, Eq, Debug)]
enum Flush {
    Drained,
    Blocked,
}

/// A connection's pending responses, kept as individual encoded
/// frames so a flush coalesces up to [`MAX_IOV`] of them into **one**
/// vectored write instead of one syscall per response (or one big
/// memcpy into a staging buffer). The head frame's partial-write
/// cursor (`head_sent`) survives across flushes, so a short `writev`
/// resumes mid-frame at the exact byte it stopped — the same contract
/// the single-buffer `sent` cursor used to provide. Fully-sent frame
/// buffers are recycled through a small pool to keep steady-state
/// serving allocation-free.
pub struct WriteQueue {
    bufs: VecDeque<Vec<u8>>,
    /// Bytes of the head frame already written to the socket.
    head_sent: usize,
    /// Total unsent bytes across all queued frames.
    pending: usize,
    pool: Vec<Vec<u8>>,
}

impl Default for WriteQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl WriteQueue {
    pub fn new() -> WriteQueue {
        WriteQueue { bufs: VecDeque::new(), head_sent: 0, pending: 0, pool: Vec::new() }
    }

    /// Unsent bytes queued (length prefixes included).
    // lint: no-alloc
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Queue one length-prefixed frame.
    pub fn push_frame(&mut self, payload: &[u8]) {
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(payload);
        self.pending += buf.len();
        self.bufs.push_back(buf);
    }

    /// Write queued frames until drained or the writer would block,
    /// coalescing up to [`MAX_IOV`] frames per vectored call. Each
    /// vectored call is counted as one syscall in `metrics`.
    pub fn flush<W: Write>(&mut self, w: &mut W, metrics: &LoopMetrics) -> io::Result<Flush> {
        while self.pending > 0 {
            let res = {
                let mut iov = [IoSlice::new(&[]); MAX_IOV];
                let mut n = 0;
                for (i, b) in self.bufs.iter().enumerate().take(MAX_IOV) {
                    iov[n] = IoSlice::new(if i == 0 { &b[self.head_sent..] } else { b });
                    n += 1;
                }
                metrics.syscalls.inc();
                w.write_vectored(&iov[..n])
            };
            match res {
                Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                Ok(written) => self.consume(written),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(Flush::Blocked),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(Flush::Drained)
    }

    /// Advance the partial-write cursor past `written` bytes,
    /// recycling fully-sent frame buffers.
    // lint: no-alloc
    fn consume(&mut self, written: usize) {
        let mut left = written;
        while left > 0 {
            let head_len = match self.bufs.front() {
                Some(b) => b.len(),
                None => break,
            };
            let rem = head_len - self.head_sent;
            if left >= rem {
                left -= rem;
                self.pending -= rem;
                self.head_sent = 0;
                if let Some(mut b) = self.bufs.pop_front() {
                    b.clear();
                    if b.capacity() <= IDLE_BUF_BYTES && self.pool.len() < POOL_BUFS {
                        self.pool.push(b);
                    }
                }
            } else {
                self.head_sent += left;
                self.pending -= left;
                left = 0;
            }
        }
    }
}

// -------------------------------------------------------- service trait

/// What a plane plugs into the loop: its handshake magic, its
/// per-connection state, and a handler turning one request frame into
/// one response payload.
///
/// One clone of the service lives on each loop thread; shared state
/// goes behind `Arc`s inside the implementor. Handlers run inline on
/// the loop thread, so they must not block on the network (blocking on
/// a shard mutex is fine — that is the same contention the threaded
/// path has).
pub trait Service: Clone + Send + 'static {
    /// Per-connection handler state, created once the hello completes.
    type Conn: Send;

    /// The 4-byte plane magic this service answers with and requires.
    fn magic(&self) -> [u8; 4];

    /// Build per-connection state for a handshaken peer. `conn` is the
    /// process-wide connection index — the same index that keys the
    /// connection's fault/tamper schedule, so byzantine state derived
    /// from it matches the threaded path exactly.
    fn open_conn(&self, conn: u64, hello: HelloInfo) -> Self::Conn;

    /// Handle one complete request frame, appending exactly one
    /// response payload to `out` (the loop adds the length prefix).
    fn on_frame(&self, conn: &mut Self::Conn, frame: &[u8], out: &mut Vec<u8>);

    /// Ask for a periodic tick every `Some(us)` microseconds, or
    /// `None` for no tick *right now*. Re-queried after every wakeup
    /// round: returning `None` disarms the loop's timerfd entirely,
    /// so a service with nothing time-based to do (or a token bucket
    /// already at burst) costs an idle process zero syscalls.
    fn tick_interval_us(&self) -> Option<u64> {
        None
    }

    /// Called from a loop thread when its timer fired. `ticks` is the
    /// number of whole intervals since the last delivery (≥ 1; > 1
    /// under scheduling delay). The loop never reads a clock — what a
    /// tick *means* (e.g. token-bucket refill) is the service's call.
    fn on_tick(&self, _ticks: u64, _interval_us: u64) {}
}

// --------------------------------------------------- connection machine

/// Per-connection state: socket, reassembly buffer, write queue, the
/// hello→serving handshake state, and the edge-mode readiness flags.
struct Conn<C> {
    stream: FaultyStream,
    fd: RawFd,
    token: u64,
    conn_id: u64,
    asm: FrameAssembler,
    wq: WriteQueue,
    /// `None` until the hello frame is accepted.
    state: Option<C>,
    /// Set on handshake refusal: flush the answering hello, then close.
    close_after_flush: bool,
    /// Interest mask currently registered with the poller (level mode
    /// only; edge mode registers once and never modifies).
    interest: u32,
    /// Edge mode: the socket may have unread bytes (set by
    /// `EPOLLIN`/HUP events, cleared on `WouldBlock`).
    can_read: bool,
    /// Edge mode: the socket may accept writes (set by `EPOLLOUT`,
    /// cleared on `WouldBlock`).
    can_write: bool,
    /// Edge mode: already on the loop's ready-list.
    queued: bool,
}

impl<C> Conn<C> {
    /// Is this connection under write backpressure (reads paused)?
    // lint: no-alloc
    fn backpressured(&self) -> bool {
        self.wq.pending() > HIGH_WATER
    }
}

/// What one scheduling turn decided about a connection.
#[derive(PartialEq, Eq, Debug)]
enum Step {
    /// No runnable work left; the kernel will edge-notify.
    Idle,
    /// Fairness budget exhausted with work remaining: re-queue.
    Again,
    /// Connection is done (EOF, error, or post-hello refusal).
    Close,
}

// ------------------------------------------------------------ the loop

/// Which delivery semantics connection fds use. Edge is the default;
/// level survives one release behind `MEMTRADE_EVENT_MODE=level` as
/// the bench comparison anchor.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum EventMode {
    Edge,
    Level,
}

fn event_mode_from_env() -> EventMode {
    match std::env::var("MEMTRADE_EVENT_MODE") {
        Ok(v) if v == "level" => EventMode::Level,
        _ => EventMode::Edge,
    }
}

/// Running event-loop threads plus the handle that can wake and join
/// them. Replaces the bare `Vec<JoinHandle>` return: an idle edge-mode
/// loop parks in `epoll_wait(-1)` and must be woken through the
/// eventfd to observe `stop` — [`EventLoops::stop_and_join`] does
/// both.
pub struct EventLoops {
    handles: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    waker: Arc<LoopWaker>,
    metrics: Arc<LoopMetrics>,
}

impl EventLoops {
    /// Loop-plane counters (shared across this server's loop threads).
    pub fn metrics(&self) -> &Arc<LoopMetrics> {
        &self.metrics
    }

    /// Set the stop flag, wake every loop, and join them.
    pub fn stop_and_join(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Everything a loop thread shares read-only (or via interior
/// mutability) with its siblings.
struct Ctx<S: Service> {
    poller: Poller,
    listener: Arc<TcpListener>,
    faults: Option<FaultPlan>,
    conn_seq: Arc<AtomicU64>,
    service: S,
    metrics: Arc<LoopMetrics>,
    mode: EventMode,
}

/// Spawn `threads` event-loop threads serving `listener` with
/// `service`. Each loop owns an epoll instance; the shared listener is
/// registered `EPOLLIN | EPOLLEXCLUSIVE` (level-triggered — an
/// `EMFILE` storm must re-report) in all of them so one connection
/// wakes one loop. Accepted sockets are wrapped in [`FaultyStream`]
/// keyed by a process-wide connection counter. The loops exit once
/// `stop` is set and the returned handle's waker fires (or, in level
/// mode, within [`WAIT_MS`]).
pub fn spawn_loops<S: Service>(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    faults: Option<FaultPlan>,
    service: S,
    threads: usize,
) -> io::Result<EventLoops> {
    spawn_loops_mode(listener, stop, faults, service, threads, event_mode_from_env())
}

/// [`spawn_loops`] with the delivery mode pinned, bypassing the
/// `MEMTRADE_EVENT_MODE` env toggle (tests must not race on process
/// environment).
pub(crate) fn spawn_loops_mode<S: Service>(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    faults: Option<FaultPlan>,
    service: S,
    threads: usize,
    mode: EventMode,
) -> io::Result<EventLoops> {
    listener.set_nonblocking(true)?;
    let listener = Arc::new(listener);
    let conn_seq = Arc::new(AtomicU64::new(0));
    let waker = Arc::new(LoopWaker::new()?);
    let metrics = Arc::new(LoopMetrics::default());
    let threads = threads.max(1);
    let mut handles = Vec::with_capacity(threads);
    for _ in 0..threads {
        // Create + register before spawning so setup errors surface
        // from the constructor, not from a dying thread.
        let poller = Poller::new()?;
        poller.add(
            listener.as_raw_fd(),
            LISTENER_TOKEN,
            sys::EPOLLIN | sys::EPOLLEXCLUSIVE,
        )?;
        poller.add(waker.fd, WAKER_TOKEN, sys::EPOLLIN)?;
        let ctx = Ctx {
            poller,
            listener: Arc::clone(&listener),
            faults: faults.clone(),
            conn_seq: Arc::clone(&conn_seq),
            service: service.clone(),
            metrics: Arc::clone(&metrics),
            mode,
        };
        let stop = Arc::clone(&stop);
        handles.push(std::thread::spawn(move || {
            run_loop(ctx, stop);
        }));
    }
    Ok(EventLoops { handles, stop, waker, metrics })
}

fn run_loop<S: Service>(ctx: Ctx<S>, stop: Arc<AtomicBool>) {
    let mut conns: Vec<Option<Conn<S::Conn>>> = Vec::new();
    let mut free: Vec<usize> = Vec::new();
    let mut ready: VecDeque<usize> = VecDeque::new();
    let mut events = [sys::EpollEvent { events: 0, data: 0 }; EVENT_BATCH];
    let mut chunk = vec![0u8; READ_CHUNK];
    let mut resp: Vec<u8> = Vec::new();
    let mut timer: Option<TimerFd> = None;
    let mut armed_us: Option<u64> = None;
    loop {
        arm_tick(&ctx, &mut timer, &mut armed_us);
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Edge mode with nothing runnable parks indefinitely (the
        // stop eventfd and the timerfd are both in the set); with a
        // nonempty ready-list it only polls the kernel.
        let timeout = match ctx.mode {
            EventMode::Edge if ready.is_empty() => -1,
            EventMode::Edge => 0,
            EventMode::Level => WAIT_MS,
        };
        ctx.metrics.syscalls.inc();
        let n = match ctx.poller.wait(&mut events, timeout) {
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        };
        ctx.metrics.wakeups.inc();
        ctx.metrics.events.add(n as u64);
        for ev in events.iter().take(n) {
            // Copy packed fields out by value; references into a
            // packed struct are unaligned and rejected by rustc.
            let (token, mask) = (ev.data, ev.events);
            match token {
                LISTENER_TOKEN => {
                    accept_ready(&ctx, &mut conns, &mut free, &mut ready);
                }
                WAKER_TOKEN => {} // stop wake: the loop head re-checks
                TIMER_TOKEN => {
                    if let (Some(t), Some(us)) = (&timer, armed_us) {
                        ctx.metrics.syscalls.inc();
                        let ticks = t.read_ticks();
                        if ticks > 0 {
                            ctx.service.on_tick(ticks, us);
                        }
                    }
                }
                _ => {
                    let slot = token as usize;
                    // The slot may have been vacated earlier in this
                    // batch.
                    let Some(conn) = conns.get_mut(slot).and_then(|s| s.as_mut()) else {
                        continue;
                    };
                    match ctx.mode {
                        EventMode::Edge => {
                            // EPOLLERR routes through the read path:
                            // the next read returns the socket error
                            // and the turn closes the connection.
                            let readable = sys::EPOLLIN
                                | sys::EPOLLHUP
                                | sys::EPOLLRDHUP
                                | sys::EPOLLERR;
                            if mask & readable != 0 {
                                conn.can_read = true;
                            }
                            if mask & sys::EPOLLOUT != 0 {
                                conn.can_write = true;
                            }
                            if !conn.queued {
                                conn.queued = true;
                                ready.push_back(slot);
                            }
                        }
                        EventMode::Level => {
                            if !step_level(&ctx, conn, mask, &mut chunk, &mut resp) {
                                close_conn(&ctx.poller, &mut conns, &mut free, slot);
                            }
                        }
                    }
                }
            }
        }
        // One scheduling round over the ready-list snapshot: every
        // queued connection gets one budgeted turn; a turn that
        // exhausts its budget re-queues *behind* its siblings.
        if ctx.mode == EventMode::Edge {
            let turns = ready.len();
            for _ in 0..turns {
                let Some(slot) = ready.pop_front() else {
                    break;
                };
                let step = {
                    let Some(conn) = conns.get_mut(slot).and_then(|s| s.as_mut()) else {
                        continue;
                    };
                    conn.queued = false;
                    step_edge(&ctx, conn, &mut chunk, &mut resp)
                };
                match step {
                    Step::Close => close_conn(&ctx.poller, &mut conns, &mut free, slot),
                    Step::Again => {
                        if let Some(conn) = conns.get_mut(slot).and_then(|s| s.as_mut()) {
                            conn.queued = true;
                            ready.push_back(slot);
                        }
                    }
                    Step::Idle => {}
                }
            }
        }
    }
}

/// Reconcile the loop's timerfd with what the service wants right
/// now: arm on `Some` (creating the fd on first use), disarm on
/// `None`. Steady states — idle with a disarmed timer, or serving
/// with an armed one — cost zero `timerfd_settime` calls.
fn arm_tick<S: Service>(ctx: &Ctx<S>, timer: &mut Option<TimerFd>, armed_us: &mut Option<u64>) {
    let want = ctx.service.tick_interval_us();
    if want == *armed_us {
        return;
    }
    if timer.is_none() {
        if want.is_none() {
            return;
        }
        ctx.metrics.syscalls.add(2); // timerfd_create + epoll_ctl
        let Ok(t) = TimerFd::new() else {
            return;
        };
        if ctx.poller.add(t.fd, TIMER_TOKEN, sys::EPOLLIN).is_err() {
            return;
        }
        *timer = Some(t);
    }
    if let Some(t) = timer {
        ctx.metrics.syscalls.inc();
        if t.set_interval_us(want.unwrap_or(0)).is_ok() {
            *armed_us = want;
        }
    }
}

/// Accept until the listener would block, via `accept4` so the socket
/// is born nonblocking (no per-accept `fcntl` pair). Setup failures
/// drop the one socket; accept failures end the pass — the listener
/// is registered level-triggered, so readiness re-reports next
/// wake-up. Fd exhaustion (`EMFILE`/`ENFILE`) additionally backs off
/// when the loop has nothing else runnable: level-triggered
/// re-reporting is *instant*, and without the pause an otherwise-idle
/// loop would spin `epoll_wait`/`accept4` at 100% CPU until fds free
/// up.
fn accept_ready<S: Service>(
    ctx: &Ctx<S>,
    conns: &mut Vec<Option<Conn<S::Conn>>>,
    free: &mut Vec<usize>,
    ready: &mut VecDeque<usize>,
) {
    loop {
        ctx.metrics.syscalls.inc();
        // SAFETY: the listener fd is open for the loop's lifetime; the
        // null addr/addrlen pointers are the documented "don't care"
        // form of accept4.
        let fd = unsafe {
            sys::accept4(
                ctx.listener.as_raw_fd(),
                std::ptr::null_mut(),
                std::ptr::null_mut(),
                sys::SOCK_NONBLOCK | sys::SOCK_CLOEXEC,
            )
        };
        if fd < 0 {
            let e = io::Error::last_os_error();
            match e.kind() {
                io::ErrorKind::WouldBlock => return,
                io::ErrorKind::Interrupted => continue,
                _ => {
                    // A nonempty ready-list means the pause would
                    // stall real work — let the loop serve it and
                    // come back; serving is what frees fds anyway.
                    if matches!(e.raw_os_error(), Some(sys::EMFILE) | Some(sys::ENFILE))
                        && ready.is_empty()
                    {
                        std::thread::sleep(Duration::from_millis(ACCEPT_BACKOFF_MS));
                    }
                    return;
                }
            }
        }
        // SAFETY: `fd` was just returned by accept4 and is owned by
        // nothing else; from_raw_fd transfers that ownership to the
        // TcpStream exactly once.
        let stream = unsafe { std::net::TcpStream::from_raw_fd(fd) };
        ctx.metrics.syscalls.inc(); // TCP_NODELAY setsockopt
        if stream.set_nodelay(true).is_err() {
            continue;
        }
        let conn_id = ctx.conn_seq.fetch_add(1, Ordering::Relaxed);
        let stream = FaultyStream::new(stream, ctx.faults.as_ref(), conn_id);
        let fd = stream.as_raw_fd();
        let slot = free.pop().unwrap_or_else(|| {
            conns.push(None);
            conns.len() - 1
        });
        let token = slot as u64;
        // Edge mode registers the full mask once and never touches
        // epoll_ctl again for this fd; level mode starts read-only and
        // re-arms through `update_interest`.
        let interest = match ctx.mode {
            EventMode::Edge => {
                sys::EPOLLIN | sys::EPOLLOUT | sys::EPOLLRDHUP | sys::EPOLLET
            }
            EventMode::Level => sys::EPOLLIN | sys::EPOLLRDHUP,
        };
        ctx.metrics.syscalls.inc();
        if ctx.poller.add(fd, token, interest).is_err() {
            free.push(slot);
            continue;
        }
        ctx.metrics.accepts.inc();
        conns[slot] = Some(Conn {
            stream,
            fd,
            token,
            conn_id,
            asm: FrameAssembler::new(),
            wq: WriteQueue::new(),
            state: None,
            close_after_flush: false,
            interest,
            // A fresh socket is writable, and bytes may have raced in
            // before registration: assume both and let the first turn
            // discover the truth (a would-block read just clears the
            // flag). Edge delivery only reports *transitions*, so
            // assuming not-ready here could lose the race forever.
            can_read: true,
            can_write: true,
            queued: false,
        });
        if ctx.mode == EventMode::Edge {
            if let Some(conn) = conns.get_mut(slot).and_then(|s| s.as_mut()) {
                conn.queued = true;
                ready.push_back(slot);
            }
        }
    }
}

/// One budgeted edge-mode scheduling turn: flush what the socket will
/// take, serve parked frames, read until the socket runs dry or the
/// budget does, flush again, then report whether the connection still
/// has runnable work.
fn step_edge<S: Service>(
    ctx: &Ctx<S>,
    conn: &mut Conn<S::Conn>,
    chunk: &mut [u8],
    resp: &mut Vec<u8>,
) -> Step {
    let mut budget = FAIR_FRAMES;
    // Write first: readiness to write is what un-backpressures the
    // read path below.
    if conn.can_write && conn.wq.pending() > 0 {
        match conn.wq.flush(&mut conn.stream, &ctx.metrics) {
            Ok(Flush::Blocked) => conn.can_write = false,
            Ok(Flush::Drained) => {}
            Err(_) => return Step::Close,
        }
    }
    // Frames parked by backpressure or a spent budget drain first,
    // then fresh socket bytes.
    let served = drain_frames(&ctx.service, conn, resp, &mut budget, &ctx.metrics)
        .and_then(|()| {
            if conn.can_read {
                pump_reads(ctx, conn, chunk, resp, &mut budget)
            } else {
                Ok(())
            }
        });
    if served.is_err() {
        return Step::Close;
    }
    if conn.can_write && conn.wq.pending() > 0 {
        match conn.wq.flush(&mut conn.stream, &ctx.metrics) {
            Ok(Flush::Blocked) => conn.can_write = false,
            Ok(Flush::Drained) => {}
            Err(_) => return Step::Close,
        }
    }
    if conn.close_after_flush && conn.wq.pending() == 0 {
        return Step::Close;
    }
    edge_outcome(conn, budget, &ctx.metrics)
}

/// Decide what a finished edge turn reports. A spent budget always
/// re-queues, but a leftover budget is *not* proof of idleness: the
/// turn's final flush may have just drained the write queue and lifted
/// the backpressure that stopped `drain_frames`/`pump_reads` early,
/// leaving complete frames parked in `asm` (or unread socket bytes
/// behind `can_read`) with no further edge owed by the kernel — the
/// peer's bytes already arrived (no `EPOLLIN` edge coming) and the
/// socket never returned `WouldBlock` (no `EPOLLOUT` edge coming).
/// Parking such a connection as Idle strands it until the client times
/// out, so re-check for runnable work and re-queue on the loop-local
/// ready-list instead.
fn edge_outcome<C>(conn: &Conn<C>, budget: u32, metrics: &LoopMetrics) -> Step {
    if budget == 0 {
        // Work may remain (buffered frames or an undrained socket):
        // yield the loop to siblings and come back around.
        metrics.yields.inc();
        return Step::Again;
    }
    if !conn.backpressured()
        && !conn.close_after_flush
        && (conn.asm.has_frame() || conn.can_read)
    {
        return Step::Again;
    }
    Step::Idle
}

/// Drive one level-mode connection through one readiness event.
/// Returns `false` when the connection should be closed.
fn step_level<S: Service>(
    ctx: &Ctx<S>,
    conn: &mut Conn<S::Conn>,
    mask: u32,
    chunk: &mut [u8],
    resp: &mut Vec<u8>,
) -> bool {
    if mask & sys::EPOLLERR != 0 {
        return false;
    }
    if mask & sys::EPOLLOUT != 0
        && conn.wq.flush(&mut conn.stream, &ctx.metrics).is_err()
    {
        return false;
    }
    // Frames parked by backpressure drain first (write readiness just
    // made room), then fresh socket bytes. Level mode never yields:
    // the budget is effectively unbounded.
    let mut budget = u32::MAX;
    let served = drain_frames(&ctx.service, conn, resp, &mut budget, &ctx.metrics)
        .and_then(|()| {
            if mask & (sys::EPOLLIN | sys::EPOLLHUP | sys::EPOLLRDHUP) != 0 {
                pump_reads(ctx, conn, chunk, resp, &mut budget)?;
            }
            Ok(())
        });
    if served.is_err() || conn.wq.flush(&mut conn.stream, &ctx.metrics).is_err() {
        return false;
    }
    if conn.close_after_flush && conn.wq.pending() == 0 {
        return false;
    }
    update_interest(ctx, conn)
}

/// Read until the socket would block or the budget runs out, handing
/// complete frames to the service after every chunk so buffered input
/// stays bounded by one partial frame plus one read chunk. In edge
/// mode a would-block read clears `can_read` — the kernel owes us an
/// event before the socket has bytes again.
fn pump_reads<S: Service>(
    ctx: &Ctx<S>,
    conn: &mut Conn<S::Conn>,
    chunk: &mut [u8],
    resp: &mut Vec<u8>,
    budget: &mut u32,
) -> io::Result<()> {
    loop {
        if *budget == 0 || conn.backpressured() || conn.close_after_flush {
            break;
        }
        ctx.metrics.syscalls.inc();
        match conn.stream.read(chunk) {
            Ok(0) => return Err(io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => {
                conn.asm.push(&chunk[..n]);
                drain_frames(&ctx.service, conn, resp, budget, &ctx.metrics)?;
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if ctx.mode == EventMode::Edge {
                    conn.can_read = false;
                }
                break;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    conn.asm.compact();
    Ok(())
}

/// Feed buffered complete frames through the connection's state
/// machine: the first frame is the hello, the rest go to the service.
/// Stops early under write backpressure or a spent fairness budget.
fn drain_frames<S: Service>(
    service: &S,
    conn: &mut Conn<S::Conn>,
    resp: &mut Vec<u8>,
    budget: &mut u32,
    metrics: &LoopMetrics,
) -> io::Result<()> {
    loop {
        if *budget == 0 || conn.backpressured() || conn.close_after_flush {
            return Ok(());
        }
        // Split borrows: `frame` borrows `conn.asm`; the arms below
        // touch only `conn.state` / `conn.wq`.
        let c = &mut *conn;
        let frame = match c.asm.next_frame() {
            Ok(Some(frame)) => frame,
            Ok(None) => return Ok(()),
            Err(e) => return Err(io::Error::new(io::ErrorKind::InvalidData, e.to_string())),
        };
        *budget -= 1;
        metrics.frames.inc();
        match c.state.as_mut() {
            None => {
                let magic = service.magic();
                match check_hello(frame, magic) {
                    Ok(hello) => {
                        c.wq.push_frame(&hello_payload(magic));
                        c.state = Some(service.open_conn(c.conn_id, hello));
                    }
                    Err(_) => {
                        // Same contract as the blocking handshake:
                        // answer with our hello even on mismatch so
                        // the peer reports plane/version clearly,
                        // then close once it has flushed.
                        c.wq.push_frame(&hello_payload(magic));
                        c.close_after_flush = true;
                    }
                }
            }
            Some(state) => {
                resp.clear();
                service.on_frame(state, frame, resp);
                c.wq.push_frame(resp);
            }
        }
    }
}

/// Level mode only: re-register the poller interest mask if it
/// changed — `EPOLLOUT` only while bytes are pending, `EPOLLIN` only
/// while not backpressured.
fn update_interest<S: Service>(ctx: &Ctx<S>, conn: &mut Conn<S::Conn>) -> bool {
    let mut want = sys::EPOLLRDHUP;
    if conn.wq.pending() > 0 {
        want |= sys::EPOLLOUT;
    }
    if !conn.backpressured() && !conn.close_after_flush {
        want |= sys::EPOLLIN;
    }
    if want != conn.interest {
        ctx.metrics.syscalls.inc();
        if ctx.poller.modify(conn.fd, conn.token, want).is_err() {
            return false;
        }
        conn.interest = want;
    }
    true
}

fn close_conn<C>(
    poller: &Poller,
    conns: &mut Vec<Option<Conn<C>>>,
    free: &mut Vec<usize>,
    slot: usize,
) {
    if let Some(entry) = conns.get_mut(slot) {
        if let Some(conn) = entry.take() {
            // Deregister before the socket drops and the fd number can
            // be reused by a new accept on another loop thread.
            poller.remove(conn.fd);
            free.push(slot);
            drop(conn);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::control::client_handshake;
    use crate::net::wire::{read_frame_into, write_frame};
    use std::io::BufReader;
    use std::net::TcpStream;
    use std::time::{Duration, Instant};

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(payload);
        out
    }

    fn wire_bytes(frames: &[&[u8]]) -> Vec<u8> {
        let mut out = Vec::new();
        for f in frames {
            out.extend_from_slice(&frame_bytes(f));
        }
        out
    }

    fn collect_frames(asm: &mut FrameAssembler) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(f) = asm.next_frame().expect("well-formed stream") {
            out.push(f.to_vec());
        }
        out
    }

    /// The reassembly property test: any split of the byte stream —
    /// every single cut point, plus byte-at-a-time — yields exactly
    /// the original frames in order.
    #[test]
    fn reassembles_frames_split_at_every_byte_offset() {
        let frames: Vec<&[u8]> = vec![b"", b"a", b"hello world", &[0u8; 300], b"\x00\xff\x7f"];
        let wire = wire_bytes(&frames);
        let want: Vec<Vec<u8>> = frames.iter().map(|f| f.to_vec()).collect();

        for cut in 0..=wire.len() {
            let mut asm = FrameAssembler::new();
            let mut got = Vec::new();
            asm.push(&wire[..cut]);
            got.extend(collect_frames(&mut asm));
            asm.compact();
            asm.push(&wire[cut..]);
            got.extend(collect_frames(&mut asm));
            assert_eq!(got, want, "split at byte {cut}");
        }

        let mut asm = FrameAssembler::new();
        let mut got = Vec::new();
        for b in &wire {
            asm.push(std::slice::from_ref(b));
            got.extend(collect_frames(&mut asm));
        }
        assert_eq!(got, want, "byte-at-a-time");
        asm.compact();
        assert_eq!(asm.buffered(), 0);
    }

    /// Hostile declared lengths are rejected from the 4-byte prefix
    /// alone — no body bytes are ever buffered or allocated for.
    #[test]
    fn rejects_hostile_length_before_buffering_the_body() {
        let mut asm = FrameAssembler::new();
        asm.push(&((MAX_FRAME as u32) + 1).to_le_bytes());
        match asm.next_frame() {
            Err(CodecError::FrameTooLarge(n)) => assert_eq!(n, MAX_FRAME + 1),
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        // A frame of exactly MAX_FRAME is legal and stays pending.
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME as u32).to_le_bytes());
        assert!(matches!(asm.next_frame(), Ok(None)));
    }

    /// The slow-loris bound: memory tracks bytes received, not bytes
    /// declared. A peer claiming a 16 MiB frame but sending 100 bytes
    /// pins ~100 bytes.
    #[test]
    fn buffers_only_received_bytes_never_declared_length() {
        let mut asm = FrameAssembler::new();
        asm.push(&(MAX_FRAME as u32).to_le_bytes());
        asm.push(&[7u8; 100]);
        assert!(matches!(asm.next_frame(), Ok(None)));
        assert_eq!(asm.buffered(), 104);
        assert!(
            asm.capacity() < 64 << 10,
            "capacity {} must track received bytes, not the 16 MiB declared",
            asm.capacity()
        );
    }

    /// After a large burst drains, compact releases the slack.
    #[test]
    fn compact_reclaims_consumed_prefix_and_slack() {
        let big = vec![42u8; 256 << 10];
        let mut asm = FrameAssembler::new();
        asm.push(&wire_bytes(&[&big]));
        assert_eq!(collect_frames(&mut asm), vec![big]);
        asm.compact();
        assert_eq!(asm.buffered(), 0);
        assert!(asm.capacity() <= IDLE_BUF_BYTES, "capacity {}", asm.capacity());
    }

    /// A writer that accepts exactly `limit` bytes per call — the
    /// adversarial short-write kernel for the writev resume property.
    struct LimitedWriter {
        out: Vec<u8>,
        limit: usize,
    }

    impl Write for LimitedWriter {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            let n = buf.len().min(self.limit);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }
        fn write_vectored(&mut self, bufs: &[IoSlice<'_>]) -> io::Result<usize> {
            let mut left = self.limit;
            let before = self.out.len();
            for b in bufs {
                if left == 0 {
                    break;
                }
                let n = b.len().min(left);
                self.out.extend_from_slice(&b[..n]);
                left -= n;
            }
            Ok(self.out.len() - before)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    /// Writev partial-write resume property: for *every* per-call
    /// byte limit k — which lands the short-write boundary inside
    /// every frame and on every iovec edge, across more frames than
    /// one iovec batch holds — the queue emits exactly the encoded
    /// frame stream, in order.
    #[test]
    fn write_queue_resumes_partial_writes_at_every_boundary() {
        let payloads: Vec<Vec<u8>> = (0..(MAX_IOV + 9))
            .map(|i| vec![i as u8; (i * 7) % 23 + 1])
            .collect();
        let mut want = Vec::new();
        for p in &payloads {
            want.extend_from_slice(&frame_bytes(p));
        }
        let metrics = LoopMetrics::default();
        for k in 1..=want.len() {
            let mut wq = WriteQueue::new();
            for p in &payloads {
                wq.push_frame(p);
            }
            assert_eq!(wq.pending(), want.len());
            let mut w = LimitedWriter { out: Vec::new(), limit: k };
            while wq.pending() > 0 {
                assert_eq!(
                    wq.flush(&mut w, &metrics).expect("flush"),
                    Flush::Drained,
                    "limit {k}"
                );
            }
            assert_eq!(w.out, want, "limit {k}");
        }
    }

    /// A would-block writer parks the queue without losing the
    /// cursor; the retry resumes mid-frame.
    #[test]
    fn write_queue_survives_would_block_mid_frame() {
        struct Half {
            out: Vec<u8>,
            calls: u32,
        }
        impl Write for Half {
            fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
                self.calls += 1;
                if self.calls % 2 == 0 {
                    return Err(io::ErrorKind::WouldBlock.into());
                }
                let n = buf.len().min(3);
                self.out.extend_from_slice(&buf[..n]);
                Ok(n)
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let metrics = LoopMetrics::default();
        let mut wq = WriteQueue::new();
        wq.push_frame(b"abcdefgh");
        let want = frame_bytes(b"abcdefgh");
        let mut w = Half { out: Vec::new(), calls: 0 };
        let mut blocked = 0;
        while wq.pending() > 0 {
            if wq.flush(&mut w, &metrics).expect("flush") == Flush::Blocked {
                blocked += 1;
            }
        }
        assert!(blocked > 0, "the writer did block");
        assert_eq!(w.out, want);
    }

    /// Minimal end-to-end service: the loop handshakes, frames, and
    /// echoes over a real socket, across partial writes and multiple
    /// sequential frames.
    #[derive(Clone)]
    struct Echo;

    impl Service for Echo {
        type Conn = u64;
        fn magic(&self) -> [u8; 4] {
            crate::net::control::DATA_MAGIC
        }
        fn open_conn(&self, conn: u64, _hello: HelloInfo) -> u64 {
            conn
        }
        fn on_frame(&self, _conn: &mut u64, frame: &[u8], out: &mut Vec<u8>) {
            out.extend_from_slice(frame);
        }
    }

    fn echo_round_trips(mode: EventMode) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let loops = spawn_loops_mode(listener, Arc::clone(&stop), None, Echo, 2, mode).unwrap();

        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        client_handshake(&mut reader, &mut writer, crate::net::control::DATA_MAGIC).unwrap();

        let mut buf = Vec::new();
        for i in 0u32..32 {
            let payload = vec![i as u8; (i as usize) * 37 + 1];
            write_frame(&mut writer, &payload).unwrap();
            read_frame_into(&mut reader, &mut buf).unwrap();
            assert_eq!(buf, payload, "frame {i}");
        }

        // A second client on a wrong plane still gets a hello back
        // (so it can report the mismatch), then the server closes.
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        let err = client_handshake(&mut reader, &mut writer, crate::net::control::CONTROL_MAGIC)
            .unwrap_err();
        assert!(err.to_string().contains("plane"), "{err}");

        assert!(loops.metrics().accepts.get() >= 2);
        loops.stop_and_join();
    }

    #[test]
    fn echo_service_over_a_real_epoll_loop() {
        echo_round_trips(EventMode::Edge);
    }

    #[test]
    fn level_triggered_fallback_still_serves() {
        echo_round_trips(EventMode::Level);
    }

    /// ET edge case: a frame split across two readiness events (the
    /// prefix+half, a pause long enough for the first edge to drain to
    /// WouldBlock, then the rest) reassembles and answers.
    #[test]
    fn edge_mode_reassembles_frame_split_across_two_readiness_events() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let loops =
            spawn_loops_mode(listener, Arc::clone(&stop), None, Echo, 1, EventMode::Edge)
                .unwrap();

        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        client_handshake(&mut reader, &mut writer, crate::net::control::DATA_MAGIC).unwrap();

        let payload = vec![0xabu8; 1000];
        let wire = frame_bytes(&payload);
        writer.write_all(&wire[..500]).unwrap();
        writer.flush().unwrap();
        // Long enough that the server's first edge drains to
        // WouldBlock and parks the connection as Idle.
        std::thread::sleep(Duration::from_millis(100));
        writer.write_all(&wire[500..]).unwrap();
        writer.flush().unwrap();

        let mut buf = Vec::new();
        read_frame_into(&mut reader, &mut buf).unwrap();
        assert_eq!(buf, payload);
        loops.stop_and_join();
    }

    /// Build a served `Conn` + `Ctx` pair over a real loopback socket
    /// so a scheduling turn can be driven by hand.
    fn hand_built_conn() -> (Ctx<Echo>, Conn<u64>, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peer = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        accepted.set_nonblocking(true).unwrap();
        let stream = FaultyStream::new(accepted, None, 0);
        let fd = stream.as_raw_fd();
        let ctx = Ctx {
            poller: Poller::new().unwrap(),
            listener: Arc::new(listener),
            faults: None,
            conn_seq: Arc::new(AtomicU64::new(1)),
            service: Echo,
            metrics: Arc::new(LoopMetrics::default()),
            mode: EventMode::Edge,
        };
        let conn = Conn {
            stream,
            fd,
            token: 0,
            conn_id: 0,
            asm: FrameAssembler::new(),
            wq: WriteQueue::new(),
            state: Some(0),
            close_after_flush: false,
            interest: 0,
            can_read: true,
            can_write: true,
            queued: false,
        };
        (ctx, conn, peer)
    }

    /// ET edge case: a spurious wakeup — readiness flags set, socket
    /// empty — must park the connection as Idle, not close it or
    /// spin. The would-block read clears `can_read`.
    #[test]
    fn spurious_wakeup_with_empty_socket_parks_idle() {
        let (ctx, mut conn, _peer) = hand_built_conn();
        let mut chunk = vec![0u8; READ_CHUNK];
        let mut resp = Vec::new();
        assert_eq!(step_edge(&ctx, &mut conn, &mut chunk, &mut resp), Step::Idle);
        assert!(!conn.can_read, "would-block read must clear can_read");
        // A second spurious turn (can_read already false) is a no-op.
        let before = ctx.metrics.syscalls.get();
        assert_eq!(step_edge(&ctx, &mut conn, &mut chunk, &mut resp), Step::Idle);
        assert_eq!(ctx.metrics.syscalls.get(), before, "no syscalls when nothing is ready");
    }

    /// `has_frame` is the end-of-turn runnability probe: complete and
    /// hostile-length prefixes are runnable, partials are not.
    #[test]
    fn has_frame_tracks_complete_hostile_and_partial_prefixes() {
        let mut asm = FrameAssembler::new();
        assert!(!asm.has_frame());
        let wire = frame_bytes(b"abc");
        asm.push(&wire[..4]);
        assert!(!asm.has_frame(), "a length prefix alone is not runnable");
        asm.push(&wire[4..6]);
        assert!(!asm.has_frame(), "a partial body is not runnable");
        asm.push(&wire[6..]);
        assert!(asm.has_frame());
        assert!(asm.next_frame().unwrap().is_some());
        assert!(!asm.has_frame(), "the frame was consumed");
        // A hostile declared length is runnable work: the next
        // `next_frame` errors, which closes the connection.
        asm.compact();
        asm.push(&((MAX_FRAME as u32) + 1).to_le_bytes());
        assert!(asm.has_frame());
    }

    /// The end-of-turn verdict: a leftover fairness budget is not
    /// proof of idleness. A connection whose final flush just lifted
    /// backpressure still holds runnable work (parked frames, an
    /// undrained socket) and must be re-queued — the kernel owes it
    /// no further edge. Each row builds the post-flush state directly.
    #[test]
    fn edge_outcome_requeues_runnable_work_and_parks_true_idle() {
        let metrics = LoopMetrics::default();
        let (_ctx, mut conn, _peer) = hand_built_conn();
        conn.can_read = false;

        // Truly idle: no frames, nothing pending, socket drained.
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Idle);

        // A parked complete frame is runnable → re-queue (this is the
        // stranded-connection regression: Idle here hangs the client).
        conn.asm.push(&frame_bytes(b"parked"));
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Again);

        // A *partial* frame is not runnable (serving it needs bytes
        // the kernel will edge-notify about): re-queuing would spin.
        conn.asm = FrameAssembler::new();
        conn.asm.push(&frame_bytes(b"partial")[..5]);
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Idle);

        // An undrained socket (`can_read` survived the turn, which
        // only happens when backpressure stopped the read pump) is
        // runnable once that backpressure has lifted.
        conn.can_read = true;
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Again);
        conn.can_read = false;

        // Still-standing backpressure parks: the EPOLLOUT edge (or a
        // later drained flush) is what re-schedules this connection.
        conn.asm = FrameAssembler::new();
        conn.asm.push(&frame_bytes(b"parked"));
        conn.wq.push_frame(&vec![0u8; HIGH_WATER + 1]);
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Idle);
        conn.wq = WriteQueue::new();

        // A refused handshake only flushes and closes — its parked
        // bytes are never served, so they are not runnable work.
        conn.close_after_flush = true;
        assert_eq!(edge_outcome(&conn, FAIR_FRAMES, &metrics), Step::Idle);
        conn.close_after_flush = false;

        // A spent budget always re-queues (and counts the yield).
        let before = metrics.yields.get();
        assert_eq!(edge_outcome(&conn, 0, &metrics), Step::Again);
        assert_eq!(metrics.yields.get(), before + 1);
    }

    /// Best-effort: ask the kernel for large socket buffers (clamped
    /// by `wmem_max`/`rmem_max`) so a regression test can count on a
    /// flush draining without the peer racing the writer byte-for-byte.
    fn grow_socket_bufs(fd: RawFd) {
        let sz: i32 = 4 << 20;
        for opt in [sys::SO_SNDBUF, sys::SO_RCVBUF] {
            // SAFETY: `fd` is an open socket owned by the caller and
            // `optval` points at a live i32 of the length passed.
            unsafe {
                sys::setsockopt(
                    fd,
                    sys::SOL_SOCKET,
                    opt,
                    (&sz as *const i32).cast(),
                    std::mem::size_of::<i32>() as u32,
                );
            }
        }
    }

    /// Regression (ET strand): when a turn's *final* flush drains the
    /// write queue — lifting the backpressure that parked complete
    /// frames in `asm` — the connection must be re-queued, not parked
    /// Idle. The peer's bytes already arrived (no EPOLLIN edge coming)
    /// and the socket never blocked (no EPOLLOUT edge coming), so an
    /// Idle verdict strands the parked requests until the client
    /// times out. Reachable whenever < FAIR_FRAMES requests produce
    /// > HIGH_WATER of responses and the send buffer absorbs the
    /// flush.
    #[test]
    fn backpressure_lift_on_final_flush_requeues_parked_frames() {
        let (ctx, mut conn, peer) = hand_built_conn();
        grow_socket_bufs(conn.fd);
        grow_socket_bufs(peer.as_raw_fd());
        // Three pre-buffered requests whose echoes total > HIGH_WATER:
        // serving parks the third under backpressure, and the final
        // flush (peer draining concurrently, buffers grown above) can
        // drain the whole queue within the same turn.
        let payload = vec![0x5au8; 600 << 10];
        const ECHOES: usize = 3;
        for _ in 0..ECHOES {
            conn.asm.push(&frame_bytes(&payload));
        }
        conn.can_read = false; // the socket itself is empty
        let drain = {
            let peer = peer.try_clone().unwrap();
            std::thread::spawn(move || {
                peer.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                let mut reader = BufReader::new(peer);
                let mut buf = Vec::new();
                for i in 0..ECHOES {
                    read_frame_into(&mut reader, &mut buf)
                        .unwrap_or_else(|e| panic!("echo {i} never arrived: {e}"));
                }
            })
        };
        let mut chunk = vec![0u8; READ_CHUNK];
        let mut resp = Vec::new();
        // Mimic run_loop's scheduler: keep stepping while the turn
        // reports Again; on Idle the only legitimate reason work
        // remains is a blocked write, where the kernel owes EPOLLOUT
        // (simulated here after the peer drains for a moment).
        for _ in 0..10_000 {
            match step_edge(&ctx, &mut conn, &mut chunk, &mut resp) {
                Step::Again => {}
                Step::Close => panic!("unexpected close"),
                Step::Idle => {
                    if conn.asm.buffered() == 0 && conn.wq.pending() == 0 {
                        break;
                    }
                    assert!(
                        !conn.can_write,
                        "stranded: Idle with {} buffered / {} pending and no edge owed",
                        conn.asm.buffered(),
                        conn.wq.pending()
                    );
                    std::thread::sleep(Duration::from_millis(1));
                    conn.can_write = true;
                }
            }
        }
        assert_eq!(conn.asm.buffered(), 0, "parked frames were never served");
        assert_eq!(conn.wq.pending(), 0);
        drain.join().unwrap();
    }

    /// ET fairness: one flooding connection must not stall nine
    /// polite request/response peers sharing its (single) loop
    /// thread. The budget forces yields, and every polite RTT stays
    /// bounded.
    #[test]
    fn fairness_budget_keeps_polite_connections_responsive() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let loops =
            spawn_loops_mode(listener, Arc::clone(&stop), None, Echo, 1, EventMode::Edge)
                .unwrap();

        // The flooder pipelines tiny frames as fast as the socket
        // takes them and drains responses on a second thread, so it
        // is permanently hot without ever tripping backpressure.
        let flood_stop = Arc::new(AtomicBool::new(false));
        let flooder = {
            let stop = Arc::clone(&flood_stop);
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            client_handshake(&mut reader, &mut writer, crate::net::control::DATA_MAGIC)
                .unwrap();
            let drain = std::thread::spawn(move || {
                let mut buf = Vec::new();
                while read_frame_into(&mut reader, &mut buf).is_ok() {}
            });
            let write = std::thread::spawn(move || {
                let frame = frame_bytes(&[9u8; 16]);
                while !stop.load(Ordering::Relaxed) {
                    for _ in 0..64 {
                        if writer.write_all(&frame).is_err() {
                            return;
                        }
                    }
                    let _ = writer.flush();
                }
            });
            (drain, write)
        };

        let polite: Vec<_> = (0..9)
            .map(|_| {
                std::thread::spawn(move || {
                    let stream = TcpStream::connect(addr).unwrap();
                    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
                    let mut reader = BufReader::new(stream.try_clone().unwrap());
                    let mut writer = stream;
                    client_handshake(
                        &mut reader,
                        &mut writer,
                        crate::net::control::DATA_MAGIC,
                    )
                    .unwrap();
                    let mut buf = Vec::new();
                    let mut rtts_us: Vec<u64> = Vec::new();
                    for i in 0..50u32 {
                        let payload = i.to_le_bytes();
                        // lint: allow-clock — test-harness RTT stopwatch
                        let t = Instant::now();
                        write_frame(&mut writer, &payload).unwrap();
                        read_frame_into(&mut reader, &mut buf).unwrap();
                        rtts_us.push(t.elapsed().as_micros() as u64);
                        assert_eq!(buf, payload);
                    }
                    rtts_us.sort_unstable();
                    rtts_us[rtts_us.len() * 99 / 100]
                })
            })
            .collect();

        let p99s: Vec<u64> = polite.into_iter().map(|h| h.join().unwrap()).collect();
        flood_stop.store(true, Ordering::Relaxed);
        let (drain, write) = flooder;
        write.join().unwrap();

        let worst = *p99s.iter().max().unwrap();
        assert!(
            worst < 2_000_000,
            "polite p99 spread {p99s:?} µs — a flooder must not stall siblings"
        );
        assert!(
            loops.metrics().yields.get() > 0,
            "the flooder never exhausted a fairness budget"
        );
        loops.stop_and_join();
        drain.join().unwrap();
    }

    /// The service tick rides a per-loop timerfd: it fires while the
    /// service asks for it and the loop stays otherwise idle.
    #[derive(Clone)]
    struct Ticker {
        ticks: Arc<AtomicU64>,
    }

    impl Service for Ticker {
        type Conn = ();
        fn magic(&self) -> [u8; 4] {
            crate::net::control::DATA_MAGIC
        }
        fn open_conn(&self, _conn: u64, _hello: HelloInfo) {}
        fn on_frame(&self, _conn: &mut (), _frame: &[u8], _out: &mut Vec<u8>) {}
        fn tick_interval_us(&self) -> Option<u64> {
            Some(2_000)
        }
        fn on_tick(&self, ticks: u64, interval_us: u64) {
            assert_eq!(interval_us, 2_000);
            self.ticks.fetch_add(ticks, Ordering::Relaxed);
        }
    }

    #[test]
    fn timerfd_delivers_service_ticks_without_traffic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let ticks = Arc::new(AtomicU64::new(0));
        let svc = Ticker { ticks: Arc::clone(&ticks) };
        let loops =
            spawn_loops_mode(listener, Arc::clone(&stop), None, svc, 1, EventMode::Edge)
                .unwrap();
        // lint: allow-clock — test-harness deadline, not loop logic
        let deadline = Instant::now() + Duration::from_secs(5);
        // lint: allow-clock — test-harness deadline, not loop logic
        while ticks.load(Ordering::Relaxed) < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(ticks.load(Ordering::Relaxed) >= 3, "timer ticks never arrived");
        loops.stop_and_join();
    }
}

//! Real TCP transport: the producer-store server exposing one
//! [`ShardedKvStore`] per listener, and a blocking client. Used by the
//! runnable examples and integration tests so the consumer request path
//! is exercised over real sockets with the real wire codec. (The
//! cluster-scale experiments run on the in-process simulator instead.)
//!
//! The server runs on the epoll readiness loop in
//! [`crate::net::event_loop`]: a few loop threads multiplex thousands
//! of nonblocking connections, which is what lets one harvested
//! producer VM serve the wide consumer fan-out the paper's economics
//! assume (DESIGN.md "Async data plane"). The frame semantics live in
//! [`DataPlane::serve_frame`], shared verbatim with the legacy
//! thread-per-connection path ([`ProducerStoreServer::start_threaded`])
//! that survives as the benchmark baseline for the `bench_e2e`
//! connection sweep.
//!
//! Request-path discipline (the system's hottest path):
//! * connections hit independently locked store shards, not one
//!   global `Mutex<KvStore>`;
//! * rate limiting is a lock-free [`AtomicTokenBucket`] — no shared
//!   mutex re-serializing what sharding parallelized;
//! * requests decode as borrowed [`RequestRef`]s into reused scratch
//!   buffers, and GET hits encode straight from the shard into the
//!   output buffer — a steady-state GET performs zero transient heap
//!   allocations server-side;
//! * batch frames (`MultiGet`/`MultiPut`/`MultiDelete`) execute
//!   shard-grouped: the ops are bucketed per shard, every involved
//!   shard is locked exactly once (ascending index order), and results
//!   encode straight into the reusable output buffer in request order —
//!   one lock acquisition per shard per batch instead of one per op.
//!
//! [`KvClient`] is the matching blocking client: one-shot calls, true
//! batch frames, and pipelined singles with a configurable in-flight
//! window (the one-shot API is exactly the window = 1 case).

use crate::consumer::client::KvTransport;
use crate::kv::{KvStats, ShardGuard, ShardedKvStore};
use crate::metrics::{Counter, Histogram, MetricSet, Observe, Registry};
use crate::net::control::{client_handshake, server_handshake_patient, HelloInfo, DATA_MAGIC};
use crate::net::event_loop::{spawn_loops, EventLoops, LoopMetrics, Service};
use crate::net::faults::{ByzantineSpec, ByzantineState, FaultPlan, FaultyStream};
use crate::net::wire::{
    append_trace_ctx, decode_batch_request, decode_batch_response,
    encode_batch_response_header, encode_multi_delete_into, encode_multi_get_into,
    encode_multi_put_into, encode_value_response, is_batch_request, read_frame_into,
    read_frame_into_patient, split_trace_ctx, write_frame, write_frame_noflush, BatchKind,
    BatchOpRef, Request, RequestRef, Response, MAX_BATCH_OPS,
};
use crate::trace::{self, Op as TraceOp, Role, SpanGuard};
use crate::util::token_bucket::AtomicTokenBucket;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Per-connection buffered-I/O capacity.
const CONN_BUF_BYTES: usize = 32 << 10;

/// Token-bucket refill period on the event-loop path: the per-loop
/// timerfd credits the bucket every 10 ms, so admission
/// ([`AtomicTokenBucket::try_consume_unrefilled`]) never reads a
/// clock. Coarse enough to be noise-free on the syscall budget, fine
/// enough that a refused op's `retry_us` hint stays honest.
const REFILL_TICK_US: u64 = 10_000;

/// Bound a reused scratch buffer's slack: keep capacity for steady-state
/// frames, but don't let one oversized frame (up to `MAX_FRAME` = 16 MiB)
/// pin megabytes of unaccounted heap for the connection's lifetime.
fn bound_scratch(buf: &mut Vec<u8>) {
    if buf.capacity() > CONN_BUF_BYTES && buf.capacity() / 2 > buf.len() {
        buf.shrink_to(CONN_BUF_BYTES.max(buf.len()));
    }
}

/// Default shard count: one per available core, clamped to a sane range.
pub fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 16)
}

/// A producer store served over TCP: one sharded KvStore + one lock-free
/// rate limiter, shared across client connections (a few epoll loop
/// threads by default, one thread per connection on the
/// [`Self::start_threaded`] baseline).
pub struct ProducerStoreServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    serve_handles: Vec<JoinHandle<()>>,
    /// The event-loop handle (None on the threaded baseline): owns the
    /// loop threads, their stop waker, and the loop-plane counters.
    loops: Option<EventLoops>,
    store: Arc<ShardedKvStore>,
    /// Byzantine-mode responses served tampered (0 unless started via
    /// [`Self::start_chaotic`] with a [`ByzantineSpec`]).
    tampered: Arc<AtomicU64>,
    /// Live telemetry: `op_us` (per-frame service latency, µs, the
    /// producer's *observed* data-plane latency that heartbeats feed to
    /// broker placement), `ops` (ops served; batches count per op), and
    /// `shard.lock_hold_us` (from the instrumented store).
    telemetry: Arc<Registry>,
    /// Producer id stamped on this server's shard spans (0 until the
    /// owning agent calls [`Self::set_producer_id`]).
    producer_id: Arc<AtomicU64>,
}

/// Constructor knobs, bundled so the internal entry point stays one
/// call regardless of which public constructor was used.
struct ServeOpts {
    max_bytes: usize,
    rate_bps: Option<u64>,
    seed: u64,
    n_shards: usize,
    faults: Option<FaultPlan>,
    byzantine: Option<ByzantineSpec>,
    /// Serve thread-per-connection instead of on the epoll loop (the
    /// benchmark baseline; frame semantics are identical either way).
    threaded: bool,
}

/// The data plane as a [`Service`]: everything shared across
/// connections, cheaply cloned onto each serving thread. The actual
/// request semantics live in [`Self::serve_frame`], which both the
/// epoll loop and the threaded baseline call — there is exactly one
/// implementation of the protocol.
#[derive(Clone)]
struct DataPlane {
    store: Arc<ShardedKvStore>,
    bucket: Option<Arc<AtomicTokenBucket>>,
    /// Epoch for token-bucket time: shared by every serving thread so
    /// `now_us` is monotonic across the whole server.
    start: Instant,
    byzantine: Option<ByzantineSpec>,
    tampered: Arc<AtomicU64>,
    op_us: Arc<Histogram>,
    ops: Arc<Counter>,
    producer_id: Arc<AtomicU64>,
    /// Event-loop path: bucket refill rides the loop's timerfd tick
    /// and admission never reads a clock. The threaded baseline keeps
    /// the inline clock+refill path, byte-identical to before.
    tick_refill: bool,
}

/// Per-connection data-plane state (what used to live on a connection
/// thread's stack).
struct DataConn {
    /// Both hellos advertised tracing ⇒ every frame carries the
    /// 16-byte trace-context suffix.
    tracing: bool,
    byz: Option<ByzantineState>,
}

impl Service for DataPlane {
    type Conn = DataConn;

    fn magic(&self) -> [u8; 4] {
        DATA_MAGIC
    }

    fn open_conn(&self, conn: u64, hello: HelloInfo) -> DataConn {
        DataConn {
            tracing: hello.tracing && trace::enabled(),
            // Byzantine state keyed by the same global connection index
            // the fault plan uses: the tamper schedule stays a pure
            // function of (seed, conn) on both serving paths.
            byz: self.byzantine.as_ref().map(|b| b.state_for(conn)),
        }
    }

    fn on_frame(&self, conn: &mut DataConn, frame: &[u8], out: &mut Vec<u8>) {
        // Observed per-op service latency (see `serve_frame` for what
        // counts): on the epoll path the window closes when the
        // response is encoded — the socket write happens later, when
        // the peer is writable, and a slow *peer* must not inflate the
        // producer's observed latency signal.
        let t_op = Instant::now();
        let (frame_ops, ctx_trace) = self.serve_frame(conn, frame, out);
        if frame_ops > 0 {
            self.op_us.record_traced(t_op.elapsed().as_micros() as u64, ctx_trace);
            self.ops.add(frame_ops);
        }
    }

    /// Ask the loop for refill ticks only while there is refilling to
    /// do: no bucket, or a bucket already at burst, disarms the timer
    /// entirely — that is the zero-syscall idle path.
    fn tick_interval_us(&self) -> Option<u64> {
        if !self.tick_refill {
            return None;
        }
        match self.bucket.as_ref() {
            Some(b) if !b.is_full() => Some(REFILL_TICK_US),
            _ => None,
        }
    }

    /// One clock read per tick (not per op): credit the bucket for
    /// the elapsed interval. The CAS interval claim inside `refill`
    /// makes concurrent ticks from several loop threads safe.
    fn on_tick(&self, _ticks: u64, _interval_us: u64) {
        if let Some(b) = self.bucket.as_ref() {
            b.refill(self.start.elapsed().as_micros() as u64);
        }
    }
}

impl ProducerStoreServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) serving a store
    /// of `max_bytes`, rate limited to `rate_bps` bytes/sec (None = off),
    /// with [`default_shards`] store shards.
    ///
    /// Sharding trade-off: the largest storable key+value pair is
    /// bounded by one *shard's* budget (~`max_bytes / shards`), not the
    /// whole store. Pass `n_shards = 1` to [`Self::start_sharded`] for
    /// the unsharded bound (at the cost of a single global lock).
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
    ) -> io::Result<Self> {
        Self::start_sharded(addr, max_bytes, rate_bps, seed, default_shards())
    }

    /// [`Self::start`] with an explicit shard count (1 = the old
    /// single-mutex behavior, used as the benchmark baseline).
    pub fn start_sharded<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
        n_shards: usize,
    ) -> io::Result<Self> {
        Self::start_chaotic(addr, max_bytes, rate_bps, seed, n_shards, None, None)
    }

    /// [`Self::start_sharded`] with the chaos plane installed: every
    /// accepted connection is wrapped in a [`FaultyStream`] under
    /// `faults`, and `byzantine` turns the store hostile — a seeded
    /// fraction of GET hits is answered corrupted, stale, or truncated
    /// (the §6.1 envelope must catch every one). With both `None` this
    /// is byte-identical to [`Self::start_sharded`].
    pub fn start_chaotic<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
        n_shards: usize,
        faults: Option<FaultPlan>,
        byzantine: Option<ByzantineSpec>,
    ) -> io::Result<Self> {
        Self::start_inner(
            addr,
            ServeOpts { max_bytes, rate_bps, seed, n_shards, faults, byzantine, threaded: false },
        )
    }

    /// [`Self::start`] on the legacy thread-per-connection serving path.
    ///
    /// Kept as the baseline the `bench_e2e` connection sweep compares
    /// the epoll loop against, and as a second, structurally different
    /// driver of the exact same frame semantics
    /// ([`DataPlane::serve_frame`] is shared). Not for production use:
    /// it tops out at a few hundred connections.
    pub fn start_threaded<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
    ) -> io::Result<Self> {
        Self::start_threaded_sharded(addr, max_bytes, rate_bps, seed, default_shards())
    }

    /// [`Self::start_threaded`] with an explicit shard count.
    pub fn start_threaded_sharded<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
        n_shards: usize,
    ) -> io::Result<Self> {
        Self::start_inner(
            addr,
            ServeOpts {
                max_bytes,
                rate_bps,
                seed,
                n_shards,
                faults: None,
                byzantine: None,
                threaded: true,
            },
        )
    }

    fn start_inner<A: ToSocketAddrs>(addr: A, opts: ServeOpts) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        if let Some(plan) = opts.faults.as_ref() {
            plan.log_banner("producer-store");
        }
        let stop = Arc::new(AtomicBool::new(false));
        let telemetry = Arc::new(Registry::new());
        let store = {
            let mut store = ShardedKvStore::new(opts.max_bytes, opts.n_shards, opts.seed);
            store.instrument_locks(telemetry.histogram("shard.lock_hold_us"));
            Arc::new(store)
        };
        let tampered = Arc::new(AtomicU64::new(0));
        let producer_id = Arc::new(AtomicU64::new(0));
        let plane = DataPlane {
            store: store.clone(),
            bucket: opts
                .rate_bps
                .map(|bps| Arc::new(AtomicTokenBucket::new(bps, bps / 4))),
            start: Instant::now(),
            byzantine: opts.byzantine,
            tampered: tampered.clone(),
            op_us: telemetry.histogram("op_us"),
            ops: telemetry.counter("ops"),
            producer_id: producer_id.clone(),
            tick_refill: !opts.threaded,
        };

        let (serve_handles, loops) = if opts.threaded {
            let h = Self::spawn_threaded_accept(listener, stop.clone(), opts.faults, plane);
            (vec![h], None)
        } else {
            // A handful of loop threads carries thousands of consumers;
            // shard parallelism is preserved because batch execution
            // happens on the loop thread that owns the readiness event,
            // and distinct connections land on distinct loops.
            let threads = default_shards().min(8);
            let loops = spawn_loops(listener, stop.clone(), opts.faults, plane, threads)?;
            (Vec::new(), Some(loops))
        };

        Ok(ProducerStoreServer {
            local_addr,
            stop,
            serve_handles,
            loops,
            store,
            tampered,
            telemetry,
            producer_id,
        })
    }

    /// The legacy accept loop: one OS thread per accepted connection.
    fn spawn_threaded_accept(
        listener: TcpListener,
        stop: Arc<AtomicBool>,
        faults: Option<FaultPlan>,
        plane: DataPlane,
    ) -> JoinHandle<()> {
        std::thread::spawn(move || {
            let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
            // Per-plan connection index: the fault/tamper schedule of
            // connection k is a pure function of (seed, k).
            let mut conn_idx: u64 = 0;
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Long-lived servers see endless reconnects; reap
                        // finished connection threads as we go.
                        conn_handles.retain(|h| !h.is_finished());
                        stream.set_nodelay(true).ok();
                        let stream = FaultyStream::new(stream, faults.as_ref(), conn_idx);
                        let (plane, stop) = (plane.clone(), stop.clone());
                        let conn = conn_idx;
                        conn_idx += 1;
                        conn_handles.push(std::thread::spawn(move || {
                            let _ = serve_conn(stream, plane, conn, stop);
                        }));
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            for h in conn_handles {
                let _ = h.join();
            }
        })
    }

    /// Stamp this data plane's spans with the marketplace producer id,
    /// so a consumer-side trace names the offending producer (the agent
    /// calls this right after start — 0 means "not a market producer").
    pub fn set_producer_id(&self, id: u64) {
        self.producer_id.store(id, Ordering::Relaxed);
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The served store (shard-partitioned; all methods take `&self`).
    pub fn store(&self) -> &Arc<ShardedKvStore> {
        &self.store
    }

    /// Snapshot of store statistics, aggregated across shards.
    pub fn stats(&self) -> KvStats {
        self.store.stats()
    }

    /// The live telemetry registry (`op_us`, `ops`,
    /// `shard.lock_hold_us`). The producer agent reads windowed deltas
    /// of `op_us` to put observed p99 + ops/sec on its heartbeats.
    pub fn telemetry(&self) -> &Arc<Registry> {
        &self.telemetry
    }

    /// Full metrics snapshot: live registry + store counters/gauges —
    /// what the agent's stats endpoint serves for this data plane.
    pub fn metrics(&self) -> MetricSet {
        let mut out = MetricSet::new();
        self.telemetry.observe("data", &mut out);
        self.store.stats().observe("store", &mut out);
        out.set_gauge("store.used_bytes", self.store.used_bytes() as i64);
        out.set_gauge("store.max_bytes", self.store.max_bytes() as i64);
        out.set_gauge("store.keys", self.store.len() as i64);
        out.set_counter("byzantine.tampered", self.tampered.load(Ordering::Relaxed));
        if let Some(loops) = self.loops.as_ref() {
            let m = loops.metrics();
            out.set_counter("net.wakeups", m.wakeups.get());
            out.set_counter("net.events", m.events.get());
            out.set_counter("net.syscalls", m.syscalls.get());
            out.set_counter("net.accepts", m.accepts.get());
            out.set_counter("net.yields", m.yields.get());
            out.set_counter("net.frames", m.frames.get());
            // Milli-syscalls per op served: the loop-plane efficiency
            // headline (2500 = 2.5 syscalls/op). Includes accept and
            // idle wakeup overhead by design — it is the whole plane's
            // budget, not a per-op microcount.
            let ops = self.telemetry.counter("ops").get();
            if ops > 0 {
                let per_milli = m.syscalls.get().saturating_mul(1000) / ops;
                out.set_gauge("net.syscalls_per_op_milli", per_milli as i64);
            }
        }
        out
    }

    /// Loop-plane counters (None on the threaded baseline). The bench
    /// sweep reads windowed deltas of `syscalls` against served ops to
    /// report syscalls/op per mode.
    pub fn loop_metrics(&self) -> Option<&Arc<LoopMetrics>> {
        self.loops.as_ref().map(|l| l.metrics())
    }

    /// Responses served tampered by the Byzantine mode so far (for
    /// asserting the envelope caught every one of them).
    pub fn byzantine_tampered(&self) -> u64 {
        self.tampered.load(Ordering::Relaxed)
    }

    /// Harvester-initiated reclaim on a live store (proportional across
    /// shards).
    pub fn shrink_to(&self, new_max: usize) -> usize {
        self.store.shrink_to(new_max)
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(loops) = self.loops.take() {
            loops.stop_and_join();
        }
        for h in self.serve_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ProducerStoreServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl DataPlane {
    /// Serve one request frame: peel the trace suffix, decode, throttle,
    /// execute, and append exactly one response payload to `out`.
    /// Returns `(ops served, trace id)` — `ops == 0` means the frame was
    /// refused (throttled) or failed to decode.
    ///
    /// This is *the* data-plane semantics; the epoll loop and the
    /// threaded baseline both call it, so the two serving paths cannot
    /// drift apart.
    fn serve_frame(&self, c: &mut DataConn, frame: &[u8], out: &mut Vec<u8>) -> (u64, u64) {
        let mut frame_ops: u64 = 0;
        // On a tracing connection every frame ends in the trace-context
        // suffix; peel it off before the codec sees the payload (the
        // codec's strict trailing-bytes discipline stays intact).
        let (mut ctx_trace, mut ctx_parent) = (0u64, 0u64);
        let mut body_ok = true;
        let body: &[u8] = if c.tracing {
            match split_trace_ctx(frame) {
                Ok((b, t, p)) => {
                    ctx_trace = t;
                    ctx_parent = p;
                    b
                }
                Err(e) => {
                    body_ok = false;
                    Response::Error(e.to_string()).encode_into(out);
                    &[]
                }
            }
        } else {
            frame
        };
        // Rate limiting (paper §4.2): refuse oversized I/O, priced by
        // frame bytes (one draw covers a whole batch). The bucket is
        // lock-free, so throttling accounting never serializes
        // connections. Tokens are only drawn for frames that decode.
        let throttle = |frame_len: usize| {
            self.bucket.as_ref().and_then(|b| {
                let io_bytes = frame_len as u64;
                if self.tick_refill {
                    // Event-loop path: refill rides the timerfd tick,
                    // so admission is two atomics and zero clock
                    // reads. At most one tick-interval conservative;
                    // never over-admits.
                    if b.try_consume_unrefilled(io_bytes) {
                        None
                    } else {
                        Some(b.time_until_us_unrefilled(io_bytes).unwrap_or(1_000_000))
                    }
                } else {
                    let now_us = self.start.elapsed().as_micros() as u64;
                    if b.try_consume(now_us, io_bytes) {
                        None
                    } else {
                        Some(b.time_until_us(now_us, io_bytes).unwrap_or(1_000_000))
                    }
                }
            })
        };
        // Adopt the caller's trace for the rest of this frame: the shard
        // span below chains to the consumer's wire span, so one trace id
        // follows the op across the role boundary. Both guards are no-ops
        // (nothing recorded) on untraced frames, and both release at the
        // end of this call — on the epoll path many connections share a
        // loop thread, so per-frame scoping is what keeps traces from
        // bleeding between connections.
        let _adopt = (ctx_trace != 0).then(|| trace::adopt(ctx_trace, ctx_parent));
        let mut shard_span = SpanGuard::child(Role::Producer, TraceOp::Shard);
        shard_span.set_producer(self.producer_id.load(Ordering::Relaxed));
        if body_ok && is_batch_request(body) {
            let mut ops: Vec<BatchOpRef<'_>> = Vec::new();
            match decode_batch_request(body, &mut ops) {
                Err(e) => Response::Error(e.to_string()).encode_into(out),
                Ok(()) => match throttle(frame.len()) {
                    Some(retry_after_us) => {
                        // Per-op status even when throttled: the batch
                        // contract is one status per op, always.
                        encode_batch_response_header(out, ops.len() as u32);
                        for _ in &ops {
                            Response::Throttled { retry_after_us }.encode_into(out);
                        }
                    }
                    None => {
                        frame_ops = ops.len() as u64;
                        serve_batch(&self.store, &ops, out, &mut c.byz, &self.tampered);
                    }
                },
            }
        } else if body_ok {
            match RequestRef::decode(body) {
                Err(e) => Response::Error(e.to_string()).encode_into(out),
                Ok(req) => match throttle(frame.len()) {
                    Some(retry_after_us) => {
                        Response::Throttled { retry_after_us }.encode_into(out)
                    }
                    None => {
                        frame_ops = 1;
                        match req {
                            RequestRef::Get { key } => {
                                // Zero-copy hit: the value is encoded
                                // from the shard entry straight into the
                                // reused output frame, under the lock.
                                let hit = self
                                    .store
                                    .get_with(key, |v| encode_value_response(out, v));
                                if hit.is_none() {
                                    Response::NotFound.encode_into(out);
                                } else if let Some(b) = c.byz.as_mut() {
                                    // Byzantine mode: maybe corrupt,
                                    // replay, or truncate this hit
                                    // (chaos-only path).
                                    if b.process_value_response(out) {
                                        self.tampered.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                            }
                            RequestRef::Put { key, value } => {
                                if self.store.put(key, value) {
                                    Response::Stored.encode_into(out)
                                } else {
                                    Response::Rejected.encode_into(out)
                                }
                            }
                            RequestRef::Delete { key } => {
                                Response::Deleted(self.store.delete(key)).encode_into(out)
                            }
                            RequestRef::Ping => Response::Pong.encode_into(out),
                        }
                    }
                },
            }
        }
        (frame_ops, ctx_trace)
    }
}

/// Thread-per-connection driver (the [`ProducerStoreServer::
/// start_threaded`] baseline): blocking frame reads on an owned thread,
/// same [`DataPlane::serve_frame`] semantics as the epoll loop.
fn serve_conn(
    stream: FaultyStream,
    plane: DataPlane,
    conn: u64,
    stop: Arc<AtomicBool>,
) -> io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut reader = BufReader::with_capacity(CONN_BUF_BYTES, stream.try_clone()?);
    let mut writer = BufWriter::with_capacity(CONN_BUF_BYTES, stream);
    // Magic/version handshake before any data frame: a control-plane (or
    // stale, pre-batching) peer gets a clear refusal instead of desynced
    // garbage. The hello also carries the batch cap, so a peer never
    // sends batches we would refuse to decode.
    let Some(hello) = server_handshake_patient(&mut reader, &mut writer, DATA_MAGIC, || {
        !stop.load(Ordering::Relaxed)
    })?
    else {
        return Ok(());
    };
    let mut dc = plane.open_conn(conn, hello);
    // Reused for every request on this connection: the single-op steady
    // state allocates nothing (batches allocate one bounded op table +
    // lock table per frame, amortized over up to MAX_BATCH_OPS ops).
    let mut frame: Vec<u8> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    loop {
        // Timeout-tolerant frame read: mid-frame stalls never lose
        // consumed bytes (no desync), and the stop flag is polled at
        // every 100ms timeout tick.
        let keep_going = || !stop.load(Ordering::Relaxed);
        match read_frame_into_patient(&mut reader, &mut frame, keep_going) {
            Ok(true) => {}
            Ok(false) => return Ok(()), // server stopping
            Err(_) => return Ok(()),    // disconnect / hostile length
        }
        out.clear();
        // Observed per-op service latency: decode → execute → response
        // bytes written. Injected I/O stalls (chaos write delays) land
        // inside this window on purpose — the histogram is this
        // producer's *observed* data-plane latency, the very number its
        // heartbeats feed to broker placement. Only frames that were
        // actually *served* count (`frame_ops > 0`): throttle refusals
        // and decode errors answer in microseconds, and recording them
        // would make an overloaded or garbage-fed producer look fast —
        // inverting the placement feedback this signal exists for.
        let t_op = Instant::now();
        let (frame_ops, ctx_trace) = plane.serve_frame(&mut dc, &frame, &mut out);
        write_frame(&mut writer, &out)?;
        if frame_ops > 0 {
            // Traced variant of the one-relaxed-add record: a sample that
            // lands in a top bucket pins this frame's trace id as the
            // bucket's exemplar, so `memtrade top` can name a worst
            // offender by trace (untraced frames pass id 0 = no pin).
            plane.op_us.record_traced(t_op.elapsed().as_micros() as u64, ctx_trace);
            plane.ops.add(frame_ops);
        }
        bound_scratch(&mut frame);
        bound_scratch(&mut out);
    }
}

/// Execute one decoded batch against the sharded store, appending one
/// status per op (request order) to `out`.
///
/// Lock discipline: ops are bucketed by owning shard up front, then
/// every involved shard is locked exactly once, in ascending index
/// order — the same total order `shrink_to`/`grow_to` use, so the batch
/// path cannot deadlock against budget operations or other batches.
/// Holding the group of locks while executing lets every GET hit encode
/// zero-copy from its shard straight into the shared output buffer.
fn serve_batch(
    store: &ShardedKvStore,
    ops: &[BatchOpRef<'_>],
    out: &mut Vec<u8>,
    byz: &mut Option<ByzantineState>,
    tampered: &AtomicU64,
) {
    encode_batch_response_header(out, ops.len() as u32);
    if ops.is_empty() {
        return;
    }
    let n_shards = store.num_shards();
    let mut needed = vec![false; n_shards];
    let mut op_shard: Vec<u32> = Vec::with_capacity(ops.len());
    for op in ops {
        let s = store.shard_index(op.key());
        op_shard.push(s as u32);
        needed[s] = true;
    }
    let mut guards: Vec<Option<ShardGuard<'_>>> = needed
        .iter()
        .enumerate()
        .map(|(i, &need)| need.then(|| store.lock_shard(i)))
        .collect();
    for (op, &s) in ops.iter().zip(&op_shard) {
        let kv = guards[s as usize].as_mut().expect("owning shard is locked");
        match *op {
            BatchOpRef::Get { key } => match kv.get(key) {
                Some(v) => {
                    let at = out.len();
                    encode_value_response(out, v);
                    if let Some(b) = byz.as_mut() {
                        // Byzantine mode tampers per op inside the
                        // batch — the envelope must catch each one.
                        if b.process_value_response_at(out, at) {
                            tampered.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                None => Response::NotFound.encode_into(out),
            },
            BatchOpRef::Put { key, value } => {
                if kv.put(key, value) {
                    Response::Stored.encode_into(out)
                } else {
                    Response::Rejected.encode_into(out)
                }
            }
            BatchOpRef::Delete { key } => Response::Deleted(kv.delete(key)).encode_into(out),
        }
    }
}

/// Blocking client for one producer store. Owns buffered reader/writer
/// halves plus reusable send/receive scratch buffers, so a steady-state
/// call allocates only what the response forces (a `Value` payload).
///
/// Three calling modes, all over the same two buffered halves:
///
///  * **one-shot** (`call`/`get`/`put`/`delete`): send one frame, read
///    one response — exactly the pipelined path at window = 1;
///  * **pipelined** ([`Self::call_many`], or raw
///    [`Self::send_request`]/[`Self::recv_response`]): up to `window`
///    request frames in flight before the first response is read,
///    hiding the per-request RTT;
///  * **batched** ([`Self::multi_get`]/[`Self::multi_put`]/
///    [`Self::multi_delete`]/[`Self::call_batch`]): many ops per
///    *frame*, chunked to the handshake-negotiated cap, chunks
///    themselves pipelined up to `window`.
///
/// Responses always arrive in request order. After any I/O or protocol
/// error the stream may be desynced (frames can be mid-flight), so the
/// connection **poisons itself**: every later call fails fast with
/// `BrokenPipe` instead of reading another request's response as its
/// own. Reconnect to recover.
///
/// # Example
///
/// Boot a producer store on an ephemeral port, then talk to it over
/// the real wire protocol — single ops and a batch frame:
///
/// ```
/// use memtrade::net::tcp::{KvClient, ProducerStoreServer};
///
/// let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 7).unwrap();
/// let mut kv = KvClient::connect(server.addr()).unwrap();
/// assert!(kv.put(b"key", b"value").unwrap());
/// assert_eq!(kv.get(b"key").unwrap(), Some(b"value".to_vec()));
/// let keys: [&[u8]; 2] = [b"key", b"missing"];
/// assert_eq!(kv.multi_get(&keys).unwrap(), vec![Some(b"value".to_vec()), None]);
/// drop(kv);
/// server.stop();
/// ```
pub struct KvClient {
    reader: BufReader<FaultyStream>,
    writer: BufWriter<FaultyStream>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
    /// `min(our MAX_BATCH_OPS, peer's advertised cap)`, ≥ 1.
    max_batch: usize,
    /// In-flight frame window for pipelined paths (1 = one-shot).
    window: usize,
    /// Both sides advertised tracing in the hello: append the 16-byte
    /// trace-context suffix to every request frame.
    trace_wire: bool,
    /// An I/O or protocol error desynced the stream; refuse further use.
    poisoned: bool,
    /// Wire flushes actually issued (buffer was non-empty). One flush
    /// is one `write` syscall on the hot path, so pipelined callers
    /// are graded on this: a window of W requests must cost one flush.
    wire_flushes: u64,
}

impl KvClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        Self::from_stream(
            FaultyStream::clean(TcpStream::connect(addr)?),
            crate::net::control::HANDSHAKE_TIMEOUT,
        )
    }

    /// [`Self::connect`] with the whole attempt bounded — dial *and*
    /// handshake — for reconnect paths (e.g. the consumer pool) that
    /// must not stall.
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> io::Result<Self> {
        let stream = crate::net::control::connect_with_timeout(addr, timeout)?;
        Self::from_stream(
            FaultyStream::clean(stream),
            timeout.min(crate::net::control::HANDSHAKE_TIMEOUT),
        )
    }

    /// [`Self::connect_timeout`] with a fault schedule installed: the
    /// connection becomes `plan`'s `conn`-th deterministic stream.
    pub fn connect_faulty(
        addr: &str,
        timeout: std::time::Duration,
        plan: &FaultPlan,
        conn: u64,
    ) -> io::Result<Self> {
        let stream = crate::net::control::connect_with_timeout(addr, timeout)?;
        Self::from_stream(
            FaultyStream::new(stream, Some(plan), conn),
            timeout.min(crate::net::control::HANDSHAKE_TIMEOUT),
        )
    }

    fn from_stream(
        stream: FaultyStream,
        handshake_timeout: std::time::Duration,
    ) -> io::Result<Self> {
        stream.set_nodelay(true)?;
        // Bounded handshake: a silent or non-memtrade peer errors out
        // instead of hanging connect forever. Steady-state data calls
        // revert to blocking reads.
        stream.set_read_timeout(Some(handshake_timeout))?;
        let mut reader = BufReader::with_capacity(CONN_BUF_BYTES, stream.try_clone()?);
        let mut writer = BufWriter::with_capacity(CONN_BUF_BYTES, stream);
        let hello = client_handshake(&mut reader, &mut writer, DATA_MAGIC)?;
        reader.get_ref().set_read_timeout(None)?;
        Ok(KvClient {
            reader,
            writer,
            send_buf: Vec::new(),
            recv_buf: Vec::new(),
            max_batch: (hello.max_batch_ops as usize).clamp(1, MAX_BATCH_OPS),
            window: 1,
            trace_wire: hello.tracing && trace::enabled(),
            poisoned: false,
            wire_flushes: 0,
        })
    }

    /// True once an I/O or protocol error has desynced this connection;
    /// every call now fails fast (reconnect to recover).
    pub fn is_poisoned(&self) -> bool {
        self.poisoned
    }

    fn check_live(&self) -> io::Result<()> {
        if self.poisoned {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "connection poisoned by an earlier I/O error; reconnect",
            ));
        }
        Ok(())
    }

    /// Most ops this connection may put in one batch frame (the
    /// pairwise minimum negotiated in the handshake). Larger batches
    /// are chunked transparently.
    pub fn negotiated_max_batch(&self) -> usize {
        self.max_batch
    }

    /// Set the in-flight frame window for pipelined paths (clamped
    /// ≥ 1; 1 restores strict one-shot request/response). Keep windows
    /// modest (≤ 32): both sides buffer in-flight frames, and a huge
    /// window of huge responses can fill both TCP directions at once.
    pub fn set_window(&mut self, window: usize) {
        self.window = window.max(1);
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Bound how long any later call may wait for a response. A stalled
    /// or wedged producer then surfaces as an error instead of blocking
    /// the caller forever; after a timeout the connection is desynced
    /// and must be dropped (the consumer pool kills the slot — chaos
    /// flushed this out: a producer that stops answering mid-stream
    /// used to wedge the consumer data path indefinitely).
    pub fn set_call_timeout(&mut self, timeout: Option<std::time::Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// Queue one request without waiting for its response — the raw
    /// pipelining primitive. Frames are buffered; they reach the wire
    /// when the buffer fills or on the next [`Self::recv_response`].
    /// Responses come back in send order.
    pub fn send_request(&mut self, req: RequestRef<'_>) -> io::Result<()> {
        self.check_live()?;
        self.send_buf.clear();
        req.encode_into(&mut self.send_buf);
        if self.trace_wire {
            let (t, p) = trace::current();
            append_trace_ctx(&mut self.send_buf, t, p);
        }
        if let Err(e) = write_frame_noflush(&mut self.writer, &self.send_buf) {
            self.poisoned = true;
            return Err(e);
        }
        bound_scratch(&mut self.send_buf);
        Ok(())
    }

    /// Receive the next in-order response (flushing queued requests
    /// first, so send/recv can never deadlock on a buffered frame).
    pub fn recv_response(&mut self) -> io::Result<Response> {
        self.check_live()?;
        let resp = self.recv_response_inner();
        if resp.is_err() {
            // A failed read leaves the response stream position unknown
            // (a timeout may have consumed part of a frame): never let
            // a later call read some other request's response.
            self.poisoned = true;
        }
        resp
    }

    /// Flush queued frames iff there is anything buffered, counting
    /// the syscall. Draining a pipelined window calls this once per
    /// window fill, not once per response.
    fn flush_writer(&mut self) -> io::Result<()> {
        if self.writer.buffer().is_empty() {
            return Ok(());
        }
        self.wire_flushes += 1;
        self.writer.flush()
    }

    /// Wire flushes issued so far (test/bench instrumentation).
    pub fn wire_flushes(&self) -> u64 {
        self.wire_flushes
    }

    fn recv_response_inner(&mut self) -> io::Result<Response> {
        self.flush_writer()?;
        read_frame_into(&mut self.reader, &mut self.recv_buf)?;
        let resp = Response::decode(&self.recv_buf)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e));
        bound_scratch(&mut self.recv_buf);
        resp
    }

    /// One request/response exchange from a borrowed request — the
    /// allocation-free client path (`get`/`put`/`delete` use it so no
    /// owned `Request` is built per call). Exactly the pipelined path
    /// at window = 1.
    pub fn call_ref(&mut self, req: RequestRef<'_>) -> io::Result<Response> {
        // Wire span: the on-the-wire window of the ambient trace; the
        // trace-context suffix sent below names it as the parent of the
        // producer's shard span. No-op when no trace is live.
        let _wire = SpanGuard::child(Role::Consumer, TraceOp::Wire);
        self.send_request(req)?;
        self.recv_response()
    }

    pub fn call(&mut self, req: &Request) -> io::Result<Response> {
        self.call_ref(req.to_ref())
    }

    /// Pipelined single-op calls: queue `window` requests, flush them
    /// to the wire as **one** syscall, then drain their responses
    /// (which arrive in request order) before filling the next window.
    /// `window = 1` degenerates to sequential one-shot calls.
    pub fn call_many(&mut self, reqs: &[Request], window: usize) -> io::Result<Vec<Response>> {
        let _wire = SpanGuard::child(Role::Consumer, TraceOp::Wire);
        let window = window.max(1);
        let mut resps = Vec::with_capacity(reqs.len());
        let mut sent = 0usize;
        while resps.len() < reqs.len() {
            while sent < reqs.len() && sent - resps.len() < window {
                self.send_request(reqs[sent].to_ref())?;
                sent += 1;
            }
            // The first recv flushes the whole window (one write); the
            // rest of the drain finds the buffer empty and just reads.
            while resps.len() < sent {
                resps.push(self.recv_response()?);
            }
        }
        Ok(resps)
    }

    /// Exchange `total` ops as ⌈total / max_batch⌉ batch frames, with up
    /// to `window` frames in flight; `encode_chunk` appends the frame
    /// payload for one op range. Returns per-op responses in op order.
    /// Any failure poisons the connection: frames may still be in
    /// flight, so a later read could otherwise misattribute responses.
    fn exchange_batches(
        &mut self,
        total: usize,
        encode_chunk: impl FnMut(&mut Vec<u8>, std::ops::Range<usize>),
    ) -> io::Result<Vec<Response>> {
        if total == 0 {
            return Ok(Vec::new());
        }
        self.check_live()?;
        let _wire = SpanGuard::child(Role::Consumer, TraceOp::Wire);
        let out = self.exchange_batches_inner(total, encode_chunk);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn exchange_batches_inner(
        &mut self,
        total: usize,
        mut encode_chunk: impl FnMut(&mut Vec<u8>, std::ops::Range<usize>),
    ) -> io::Result<Vec<Response>> {
        let max = self.max_batch.max(1);
        let window = self.window.max(1);
        let n_chunks = total.div_ceil(max);
        let chunk_range = |i: usize| (i * max)..(i * max + max).min(total);
        let mut resps = Vec::with_capacity(total);
        let (mut sent, mut recvd) = (0usize, 0usize);
        while recvd < n_chunks {
            while sent < n_chunks && sent - recvd < window {
                self.send_buf.clear();
                encode_chunk(&mut self.send_buf, chunk_range(sent));
                if self.trace_wire {
                    let (t, p) = trace::current();
                    append_trace_ctx(&mut self.send_buf, t, p);
                }
                write_frame_noflush(&mut self.writer, &self.send_buf)?;
                sent += 1;
            }
            self.flush_writer()?;
            read_frame_into(&mut self.reader, &mut self.recv_buf)?;
            let got = decode_batch_response(&self.recv_buf).map_err(|e| {
                // Not a batch response: either the server's decode-error
                // report or a desynced stream — surface it; the caller
                // must drop the connection.
                let msg = match Response::decode(&self.recv_buf) {
                    Ok(Response::Error(m)) => format!("batch refused: {m}"),
                    Ok(other) => format!("non-batch response {other:?} to a batch request"),
                    Err(_) => e.to_string(),
                };
                io::Error::new(io::ErrorKind::InvalidData, msg)
            })?;
            let expect = chunk_range(recvd).len();
            if got.len() != expect {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("batch answered {} of {expect} ops", got.len()),
                ));
            }
            resps.extend(got);
            recvd += 1;
        }
        bound_scratch(&mut self.send_buf);
        bound_scratch(&mut self.recv_buf);
        Ok(resps)
    }

    /// Batched GET: one status per key, in order (`None` = miss).
    pub fn multi_get(&mut self, keys: &[&[u8]]) -> io::Result<Vec<Option<Vec<u8>>>> {
        let resps =
            self.exchange_batches(keys.len(), |out, r| encode_multi_get_into(out, &keys[r]))?;
        resps
            .into_iter()
            .map(|r| match r {
                Response::Value(v) => Ok(Some(v)),
                Response::NotFound => Ok(None),
                other => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unexpected batch-get status {other:?}"),
                )),
            })
            .collect()
    }

    /// Batched PUT: true per stored pair; a rejected or throttled op is
    /// false without failing its siblings.
    pub fn multi_put(&mut self, pairs: &[(&[u8], &[u8])]) -> io::Result<Vec<bool>> {
        let resps =
            self.exchange_batches(pairs.len(), |out, r| encode_multi_put_into(out, &pairs[r]))?;
        resps
            .into_iter()
            .map(|r| match r {
                Response::Stored => Ok(true),
                Response::Rejected | Response::Throttled { .. } => Ok(false),
                other => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unexpected batch-put status {other:?}"),
                )),
            })
            .collect()
    }

    /// Batched DELETE: per-key "existed" flags.
    pub fn multi_delete(&mut self, keys: &[&[u8]]) -> io::Result<Vec<bool>> {
        let resps = self
            .exchange_batches(keys.len(), |out, r| encode_multi_delete_into(out, &keys[r]))?;
        resps
            .into_iter()
            .map(|r| match r {
                Response::Deleted(ok) => Ok(ok),
                other => Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unexpected batch-delete status {other:?}"),
                )),
            })
            .collect()
    }

    /// Execute owned single-op requests as true batch frames when they
    /// are homogeneous (all GET / all PUT / all DELETE — what
    /// [`crate::consumer::SecureKv`]'s multi-ops produce), falling back
    /// to pipelined singles otherwise. One response per request, in
    /// order.
    pub fn call_batch(&mut self, reqs: &[Request]) -> io::Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let Some(kind) = reqs[0].batch_kind() else {
            return self.call_many(reqs, self.window);
        };
        if reqs.iter().any(|r| r.batch_kind() != Some(kind)) {
            return self.call_many(reqs, self.window);
        }
        self.exchange_batches(reqs.len(), |out, range| match kind {
            BatchKind::Get | BatchKind::Delete => {
                let keys: Vec<&[u8]> = reqs[range]
                    .iter()
                    .map(|r| match r {
                        Request::Get { key } | Request::Delete { key } => key.as_slice(),
                        _ => unreachable!("homogeneity checked"),
                    })
                    .collect();
                if kind == BatchKind::Get {
                    encode_multi_get_into(out, &keys)
                } else {
                    encode_multi_delete_into(out, &keys)
                }
            }
            BatchKind::Put => {
                let pairs: Vec<(&[u8], &[u8])> = reqs[range]
                    .iter()
                    .map(|r| match r {
                        Request::Put { key, value } => (key.as_slice(), value.as_slice()),
                        _ => unreachable!("homogeneity checked"),
                    })
                    .collect();
                encode_multi_put_into(out, &pairs)
            }
        })
    }

    pub fn get(&mut self, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        match self.call_ref(RequestRef::Get { key })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) -> io::Result<bool> {
        match self.call_ref(RequestRef::Put { key, value })? {
            Response::Stored => Ok(true),
            Response::Rejected | Response::Throttled { .. } => Ok(false),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn delete(&mut self, key: &[u8]) -> io::Result<bool> {
        match self.call_ref(RequestRef::Delete { key })? {
            Response::Deleted(ok) => Ok(ok),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }
}

/// A `KvClient` is itself a single-producer [`KvTransport`], so
/// [`crate::consumer::SecureKv`] (including its multi-ops) can run
/// directly over one TCP connection: batches become real batch frames,
/// and I/O errors surface as `Response::Error` — which the secure layer
/// treats as a miss, same as every other transport. The first error
/// poisons the connection, so every later call through this impl is an
/// instant per-op `Error` (more misses) rather than a desynced read of
/// some other request's response; callers that want to recover
/// reconnect, exactly like [`crate::market::RemotePool`] killing a
/// slot.
impl KvTransport for KvClient {
    fn call(&mut self, _producer_index: u32, req: Request) -> Response {
        KvClient::call(self, &req).unwrap_or_else(|e| Response::Error(e.to_string()))
    }

    fn call_multi(&mut self, _producer_index: u32, reqs: Vec<Request>) -> Vec<Response> {
        let n = reqs.len();
        self.call_batch(&reqs)
            .unwrap_or_else(|e| vec![Response::Error(e.to_string()); n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 1).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"alpha", b"beta").unwrap());
        assert_eq!(client.get(b"alpha").unwrap(), Some(b"beta".to_vec()));
        assert_eq!(client.get(b"missing").unwrap(), None);
        assert!(client.delete(b"alpha").unwrap());
        assert!(!client.delete(b"alpha").unwrap());
        let stats = server.stats();
        assert_eq!(stats.puts, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        server.stop();
    }

    #[test]
    fn tcp_many_clients() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 4 << 20, None, 2).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = KvClient::connect(addr).unwrap();
                    for i in 0..50 {
                        let key = format!("t{t}-k{i}");
                        assert!(c.put(key.as_bytes(), &vec![t as u8; 256]).unwrap());
                        assert_eq!(
                            c.get(key.as_bytes()).unwrap(),
                            Some(vec![t as u8; 256])
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().puts, 200);
        server.stop();
    }

    #[test]
    fn tcp_single_shard_baseline_still_works() {
        let server =
            ProducerStoreServer::start_sharded("127.0.0.1:0", 1 << 20, None, 4, 1).unwrap();
        assert_eq!(server.store().num_shards(), 1);
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", b"v").unwrap());
        assert_eq!(client.get(b"k").unwrap(), Some(b"v".to_vec()));
        server.stop();
    }

    #[test]
    fn byzantine_server_tampers_every_hit_but_stays_decodable() {
        let byz = crate::net::faults::ByzantineSpec::new(5, 1.0);
        let server =
            ProducerStoreServer::start_chaotic("127.0.0.1:0", 1 << 20, None, 1, 2, None, Some(byz))
                .unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", &[7u8; 64]).unwrap());
        // A raw client happily accepts the tampered bytes — catching
        // them is the consumer envelope's job (see tests/chaos.rs).
        for _ in 0..10 {
            let v = client.get(b"k").unwrap().expect("tampered hits still decode");
            assert_ne!(v, vec![7u8; 64], "tampering must never be a no-op");
        }
        assert_eq!(server.byzantine_tampered(), 10);
        server.stop();
    }

    #[test]
    fn tcp_batch_round_trip() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 4 << 20, None, 9).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert_eq!(client.negotiated_max_batch(), MAX_BATCH_OPS);

        let keys: Vec<Vec<u8>> = (0..40).map(|i| format!("bk{i}").into_bytes()).collect();
        let vals: Vec<Vec<u8>> = (0..40).map(|i| vec![i as u8; 200]).collect();
        let pairs: Vec<(&[u8], &[u8])> =
            keys.iter().zip(&vals).map(|(k, v)| (k.as_slice(), v.as_slice())).collect();
        assert_eq!(client.multi_put(&pairs).unwrap(), vec![true; 40]);

        // Mixed hits and misses in one batch: per-op status, in order.
        let mut get_keys: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        get_keys.insert(7, b"absent");
        let got = client.multi_get(&get_keys).unwrap();
        assert_eq!(got.len(), 41);
        assert_eq!(got[7], None, "the miss must not fail its siblings");
        for (i, v) in got.iter().enumerate().filter(|(i, _)| *i != 7) {
            let j = if i < 7 { i } else { i - 1 };
            assert_eq!(v.as_deref(), Some(vals[j].as_slice()), "op {i}");
        }

        // Empty batch: legal, answered empty.
        assert_eq!(client.multi_get(&[]).unwrap(), vec![]);

        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        let deleted = client.multi_delete(&key_refs).unwrap();
        assert_eq!(deleted, vec![true; 40]);
        assert_eq!(client.multi_delete(&key_refs).unwrap(), vec![false; 40]);

        let stats = server.stats();
        assert_eq!(stats.puts, 40);
        assert_eq!(stats.hits, 40);
        assert_eq!(stats.misses, 1);
        server.stop();
    }

    #[test]
    fn tcp_batches_chunk_to_the_negotiated_cap_and_pipeline() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 4 << 20, None, 10).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        // Force tiny chunks and a >1 window so chunking + in-flight
        // pipelining are both exercised on a real socket.
        client.max_batch = 8;
        client.set_window(3);
        assert_eq!(client.window(), 3);
        let keys: Vec<Vec<u8>> = (0..100).map(|i| format!("ck{i}").into_bytes()).collect();
        let vals: Vec<Vec<u8>> = (0..100).map(|i| vec![(i % 251) as u8; 64]).collect();
        let pairs: Vec<(&[u8], &[u8])> =
            keys.iter().zip(&vals).map(|(k, v)| (k.as_slice(), v.as_slice())).collect();
        assert_eq!(client.multi_put(&pairs).unwrap(), vec![true; 100]);
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        let got = client.multi_get(&key_refs).unwrap();
        for (i, v) in got.iter().enumerate() {
            assert_eq!(v.as_deref(), Some(vals[i].as_slice()), "op {i} out of order");
        }
        server.stop();
    }

    #[test]
    fn tcp_pipelined_call_many_keeps_response_order() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 11).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"present", b"yes").unwrap());
        let reqs: Vec<Request> = (0..60)
            .map(|i| {
                if i % 2 == 0 {
                    Request::Get { key: b"present".to_vec() }
                } else {
                    Request::Get { key: format!("absent{i}").into_bytes() }
                }
            })
            .collect();
        for window in [1usize, 4, 16] {
            let resps = client.call_many(&reqs, window).unwrap();
            assert_eq!(resps.len(), 60);
            for (i, r) in resps.iter().enumerate() {
                if i % 2 == 0 {
                    assert_eq!(*r, Response::Value(b"yes".to_vec()), "w={window} op {i}");
                } else {
                    assert_eq!(*r, Response::NotFound, "w={window} op {i}");
                }
            }
        }
        // A heterogeneous call_batch (Ping mixed in) falls back to the
        // pipelined path and still answers per op, in order.
        let mixed = vec![
            Request::Get { key: b"present".to_vec() },
            Request::Ping,
            Request::Delete { key: b"present".to_vec() },
        ];
        let resps = client.call_batch(&mixed).unwrap();
        assert_eq!(resps[0], Response::Value(b"yes".to_vec()));
        assert_eq!(resps[1], Response::Pong);
        assert_eq!(resps[2], Response::Deleted(true));
        server.stop();
    }

    #[test]
    fn tcp_call_many_flushes_once_per_window() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 13).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let reqs: Vec<Request> =
            (0..32).map(|i| Request::Get { key: format!("fk{i}").into_bytes() }).collect();
        let before = client.wire_flushes();
        let resps = client.call_many(&reqs, 8).unwrap();
        assert_eq!(resps.len(), 32);
        assert!(resps.iter().all(|r| *r == Response::NotFound));
        // 32 requests at window 8 = 4 window fills = exactly 4 wire
        // flushes (one write syscall each), not one per request.
        assert_eq!(client.wire_flushes() - before, 4);
        // A one-shot call costs exactly one more flush.
        client.call(&Request::Ping).unwrap();
        assert_eq!(client.wire_flushes() - before, 5);
        server.stop();
    }

    #[test]
    fn tcp_batch_throttle_is_per_op() {
        // 1 KB/s with a tiny burst: a 4-op batch of 1 KB values cannot
        // fit the bucket, and every op must report Throttled — the
        // batch contract is one status per op even when refused.
        let server = ProducerStoreServer::start("127.0.0.1:0", 4 << 20, Some(1024), 12).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let val = vec![0u8; 1024];
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request::Put { key: format!("t{i}").into_bytes(), value: val.clone() })
            .collect();
        let resps = client.call_batch(&reqs).unwrap();
        assert_eq!(resps.len(), 4);
        assert!(
            resps.iter().all(|r| matches!(r, Response::Throttled { .. })),
            "got {resps:?}"
        );
        // The mapped API degrades the same ops to false, not errors.
        let pairs: Vec<(&[u8], &[u8])> = reqs
            .iter()
            .map(|r| match r {
                Request::Put { key, value } => (key.as_slice(), value.as_slice()),
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(client.multi_put(&pairs).unwrap(), vec![false; 4]);
        // Throttle refusals answer in microseconds and serve nothing:
        // they must NOT pollute the observed-latency/throughput signal
        // placement ranks by, or an overloaded producer looks fast.
        let m = server.metrics();
        assert_eq!(m.counter("data.ops"), Some(0), "throttled frames counted as served");
        assert_eq!(m.histogram("data.op_us").unwrap().count(), 0);
        server.stop();
    }

    #[test]
    fn tcp_byzantine_tampers_batched_hits_per_op() {
        let byz = crate::net::faults::ByzantineSpec::new(6, 1.0);
        let server = ProducerStoreServer::start_chaotic(
            "127.0.0.1:0",
            1 << 20,
            None,
            7,
            2,
            None,
            Some(byz),
        )
        .unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let keys: Vec<Vec<u8>> = (0..12).map(|i| format!("zk{i}").into_bytes()).collect();
        let pairs: Vec<(&[u8], &[u8])> =
            keys.iter().map(|k| (k.as_slice(), [0x44u8; 64].as_slice())).collect();
        assert_eq!(client.multi_put(&pairs).unwrap(), vec![true; 12]);
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        // Every batched hit is tampered independently — and still
        // decodes, so the corruption reaches the envelope layer.
        let got = client.multi_get(&key_refs).unwrap();
        for (i, v) in got.iter().enumerate() {
            let v = v.as_ref().expect("tampered hits still decode");
            assert_ne!(v, &vec![0x44u8; 64], "op {i} tamper was a no-op");
        }
        assert_eq!(server.byzantine_tampered(), 12);
        server.stop();
    }

    #[test]
    fn client_poisons_after_io_error_and_refuses_reuse() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 13).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", b"v").unwrap());
        assert!(!client.is_poisoned());
        // Kill the server: the next call hits a real I/O error...
        server.stop();
        assert!(client.get(b"k").is_err());
        assert!(client.is_poisoned());
        // ...and the connection is now poisoned: refused fast with
        // BrokenPipe, never a desynced read of a stale response.
        let err = client.get(b"k").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
        let keys: [&[u8]; 2] = [b"k", b"k2"];
        assert_eq!(client.multi_get(&keys).unwrap_err().kind(), io::ErrorKind::BrokenPipe);
        // The infallible transport face degrades to per-op errors (the
        // secure layer sees misses), not misattributed responses.
        let resps = KvTransport::call_multi(&mut client, 0, vec![Request::Ping]);
        assert!(matches!(resps[0], Response::Error(_)), "got {resps:?}");
    }

    #[test]
    fn server_telemetry_counts_ops_and_latency() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 5).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", b"v").unwrap());
        assert_eq!(client.get(b"k").unwrap(), Some(b"v".to_vec()));
        let keys: [&[u8]; 3] = [b"k", b"k", b"absent"];
        client.multi_get(&keys).unwrap();
        let m = server.metrics();
        // 2 single-op frames + one 3-op batch frame.
        assert_eq!(m.counter("data.ops"), Some(5));
        let h = m.histogram("data.op_us").unwrap();
        assert_eq!(h.count(), 3, "one service-latency sample per frame");
        assert!(m.histogram("data.shard.lock_hold_us").unwrap().count() >= 3);
        assert_eq!(m.counter("store.puts"), Some(1));
        assert!(m.gauge("store.used_bytes").unwrap() > 0);
        server.stop();
    }

    #[test]
    fn tcp_propagates_trace_context_to_the_server_shard_span() {
        let server = ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 21).unwrap();
        server.set_producer_id(77);
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", b"v").unwrap());
        let trace_id = {
            let root = SpanGuard::root(Role::Consumer, TraceOp::Get);
            let id = root.trace_id();
            assert_eq!(client.get(b"k").unwrap(), Some(b"v".to_vec()));
            id
        };
        // Fence: the server records the traced frame's shard span at the
        // end of its loop iteration, strictly before answering the next
        // frame on the same connection — so after this untraced ping
        // round-trips, the span above is visible.
        assert_eq!(client.call_ref(RequestRef::Ping).unwrap(), Response::Pong);
        let spans = trace::recent_spans(4096);
        let wire = spans
            .iter()
            .find(|s| s.trace_id == trace_id && s.op == TraceOp::Wire)
            .expect("client wire span recorded");
        let shard = spans
            .iter()
            .find(|s| s.trace_id == trace_id && s.op == TraceOp::Shard)
            .expect("server shard span shares the client's trace id");
        assert_eq!(shard.role, Role::Producer);
        assert_eq!(shard.parent, wire.span_id, "shard span chains to the wire span");
        assert_eq!(shard.producer_id, 77);
        // The traced frame's latency sample pinned its trace id as the
        // bucket exemplar in the placement-facing histogram.
        let h = server.metrics().histogram("data.op_us").unwrap().clone();
        assert!(h.exemplars.contains(&trace_id), "op_us pins the trace id");
        server.stop();
    }

    #[test]
    fn tcp_rate_limit_throttles() {
        // 1 KB/s with tiny burst: the second large PUT must be throttled.
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, Some(1024), 3).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let _ = client.put(b"k1", &vec![0u8; 200]); // may pass (burst)
        let resp = client
            .call(&Request::Put { key: b"k2".to_vec(), value: vec![0u8; 4096] })
            .unwrap();
        assert!(matches!(resp, Response::Throttled { .. }), "got {resp:?}");
        server.stop();
    }

    #[test]
    fn tcp_shrink_on_live_server() {
        let server =
            ProducerStoreServer::start_sharded("127.0.0.1:0", 8 << 20, None, 6, 4).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        for i in 0..2000u32 {
            assert!(client.put(format!("k{i}").as_bytes(), &vec![1u8; 1024]).unwrap());
        }
        let freed = server.shrink_to(1 << 20);
        assert!(freed > 0);
        assert!(server.store().used_bytes() <= 1 << 20);
        // Survivors still readable.
        let mut hits = 0;
        for i in 0..2000u32 {
            if client.get(format!("k{i}").as_bytes()).unwrap().is_some() {
                hits += 1;
            }
        }
        assert!(hits > 0);
        server.stop();
    }
}

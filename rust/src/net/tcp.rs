//! Real TCP transport: a threaded producer-store server exposing one
//! [`ShardedKvStore`] per listener, and a blocking client. Used by the
//! runnable examples and integration tests so the consumer request path
//! is exercised over real sockets with the real wire codec. (The
//! cluster-scale experiments run on the in-process simulator instead.)
//!
//! Request-path discipline (the system's hottest path):
//! * connection threads hit independently locked store shards, not one
//!   global `Mutex<KvStore>`;
//! * rate limiting is a lock-free [`AtomicTokenBucket`] — no shared
//!   mutex re-serializing what sharding parallelized;
//! * each connection owns a `BufReader`/`BufWriter` pair plus two
//!   reusable scratch buffers, requests decode as borrowed
//!   [`RequestRef`]s, and GET hits encode straight from the shard into
//!   the output buffer — a steady-state GET performs zero transient heap
//!   allocations server-side.

use crate::kv::{KvStats, ShardedKvStore};
use crate::net::control::{client_handshake, server_handshake_patient, DATA_MAGIC};
use crate::net::faults::{ByzantineSpec, ByzantineState, FaultPlan, FaultyStream};
use crate::net::wire::{
    encode_value_response, read_frame_into, read_frame_into_patient, write_frame, Request,
    RequestRef, Response,
};
use crate::util::token_bucket::AtomicTokenBucket;
use std::io::{self, BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Per-connection buffered-I/O capacity.
const CONN_BUF_BYTES: usize = 32 << 10;

/// Bound a reused scratch buffer's slack: keep capacity for steady-state
/// frames, but don't let one oversized frame (up to `MAX_FRAME` = 16 MiB)
/// pin megabytes of unaccounted heap for the connection's lifetime.
fn bound_scratch(buf: &mut Vec<u8>) {
    if buf.capacity() > CONN_BUF_BYTES && buf.capacity() / 2 > buf.len() {
        buf.shrink_to(CONN_BUF_BYTES.max(buf.len()));
    }
}

/// Default shard count: one per available core, clamped to a sane range.
pub fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(1, 16)
}

/// A producer store served over TCP: one sharded KvStore + one lock-free
/// rate limiter, shared across client connections (one thread per
/// connection).
pub struct ProducerStoreServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    store: Arc<ShardedKvStore>,
    /// Byzantine-mode responses served tampered (0 unless started via
    /// [`Self::start_chaotic`] with a [`ByzantineSpec`]).
    tampered: Arc<AtomicU64>,
}

impl ProducerStoreServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) serving a store
    /// of `max_bytes`, rate limited to `rate_bps` bytes/sec (None = off),
    /// with [`default_shards`] store shards.
    ///
    /// Sharding trade-off: the largest storable key+value pair is
    /// bounded by one *shard's* budget (~`max_bytes / shards`), not the
    /// whole store. Pass `n_shards = 1` to [`Self::start_sharded`] for
    /// the unsharded bound (at the cost of a single global lock).
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
    ) -> io::Result<Self> {
        Self::start_sharded(addr, max_bytes, rate_bps, seed, default_shards())
    }

    /// [`Self::start`] with an explicit shard count (1 = the old
    /// single-mutex behavior, used as the benchmark baseline).
    pub fn start_sharded<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
        n_shards: usize,
    ) -> io::Result<Self> {
        Self::start_chaotic(addr, max_bytes, rate_bps, seed, n_shards, None, None)
    }

    /// [`Self::start_sharded`] with the chaos plane installed: every
    /// accepted connection is wrapped in a [`FaultyStream`] under
    /// `faults`, and `byzantine` turns the store hostile — a seeded
    /// fraction of GET hits is answered corrupted, stale, or truncated
    /// (the §6.1 envelope must catch every one). With both `None` this
    /// is byte-identical to [`Self::start_sharded`].
    pub fn start_chaotic<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
        n_shards: usize,
        faults: Option<FaultPlan>,
        byzantine: Option<ByzantineSpec>,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let store = Arc::new(ShardedKvStore::new(max_bytes, n_shards, seed));
        let bucket = rate_bps.map(|bps| Arc::new(AtomicTokenBucket::new(bps, bps / 4)));
        let tampered = Arc::new(AtomicU64::new(0));

        let stop2 = stop.clone();
        let store2 = store.clone();
        let tampered2 = tampered.clone();
        let start_instant = Instant::now();
        let accept_handle = std::thread::spawn(move || {
            let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
            // Per-plan connection index: the fault/tamper schedule of
            // connection k is a pure function of (seed, k).
            let mut conn_idx: u64 = 0;
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // Long-lived servers see endless reconnects; reap
                        // finished connection threads as we go.
                        conn_handles.retain(|h| !h.is_finished());
                        stream.set_nodelay(true).ok();
                        let stream = FaultyStream::new(stream, faults.as_ref(), conn_idx);
                        let byz = byzantine.as_ref().map(|b| b.state_for(conn_idx));
                        conn_idx += 1;
                        let store = store2.clone();
                        let stop = stop2.clone();
                        let bucket = bucket.clone();
                        let tampered = tampered2.clone();
                        conn_handles.push(std::thread::spawn(move || {
                            let _ = serve_conn(
                                stream,
                                store,
                                stop,
                                bucket,
                                start_instant,
                                byz,
                                tampered,
                            );
                        }));
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            for h in conn_handles {
                let _ = h.join();
            }
        });

        Ok(ProducerStoreServer {
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
            store,
            tampered,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The served store (shard-partitioned; all methods take `&self`).
    pub fn store(&self) -> &Arc<ShardedKvStore> {
        &self.store
    }

    /// Snapshot of store statistics, aggregated across shards.
    pub fn stats(&self) -> KvStats {
        self.store.stats()
    }

    /// Responses served tampered by the Byzantine mode so far (for
    /// asserting the envelope caught every one of them).
    pub fn byzantine_tampered(&self) -> u64 {
        self.tampered.load(Ordering::Relaxed)
    }

    /// Harvester-initiated reclaim on a live store (proportional across
    /// shards).
    pub fn shrink_to(&self, new_max: usize) -> usize {
        self.store.shrink_to(new_max)
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProducerStoreServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    stream: FaultyStream,
    store: Arc<ShardedKvStore>,
    stop: Arc<AtomicBool>,
    bucket: Option<Arc<AtomicTokenBucket>>,
    start: Instant,
    mut byz: Option<ByzantineState>,
    tampered: Arc<AtomicU64>,
) -> io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut reader = BufReader::with_capacity(CONN_BUF_BYTES, stream.try_clone()?);
    let mut writer = BufWriter::with_capacity(CONN_BUF_BYTES, stream);
    // Magic/version handshake before any data frame: a control-plane (or
    // stale) peer gets a clear refusal instead of desynced garbage.
    if !server_handshake_patient(&mut reader, &mut writer, DATA_MAGIC, || {
        !stop.load(Ordering::Relaxed)
    })? {
        return Ok(());
    }
    // Reused for every request on this connection: the steady state
    // allocates nothing.
    let mut frame: Vec<u8> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    loop {
        // Timeout-tolerant frame read: mid-frame stalls never lose
        // consumed bytes (no desync), and the stop flag is polled at
        // every 100ms timeout tick.
        let keep_going = || !stop.load(Ordering::Relaxed);
        match read_frame_into_patient(&mut reader, &mut frame, keep_going) {
            Ok(true) => {}
            Ok(false) => return Ok(()), // server stopping
            Err(_) => return Ok(()),    // disconnect / hostile length
        }
        out.clear();
        match RequestRef::decode(&frame) {
            Err(e) => Response::Error(e.to_string()).encode_into(&mut out),
            Ok(req) => {
                // Rate limiting (paper §4.2): refuse oversized I/O. The
                // bucket is lock-free, so throttling accounting never
                // serializes connections.
                let io_bytes = frame.len() as u64;
                let throttled = bucket.as_ref().and_then(|b| {
                    let now_us = start.elapsed().as_micros() as u64;
                    if b.try_consume(now_us, io_bytes) {
                        None
                    } else {
                        Some(b.time_until_us(now_us, io_bytes).unwrap_or(1_000_000))
                    }
                });
                match throttled {
                    Some(retry_after_us) => {
                        Response::Throttled { retry_after_us }.encode_into(&mut out)
                    }
                    None => match req {
                        RequestRef::Get { key } => {
                            // Zero-copy hit: the value is encoded from the
                            // shard entry straight into the reused output
                            // frame, under the shard lock.
                            let hit =
                                store.get_with(key, |v| encode_value_response(&mut out, v));
                            if hit.is_none() {
                                Response::NotFound.encode_into(&mut out);
                            } else if let Some(b) = byz.as_mut() {
                                // Byzantine mode: maybe corrupt, replay,
                                // or truncate this hit (chaos-only path).
                                if b.process_value_response(&mut out) {
                                    tampered.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        RequestRef::Put { key, value } => {
                            if store.put(key, value) {
                                Response::Stored.encode_into(&mut out)
                            } else {
                                Response::Rejected.encode_into(&mut out)
                            }
                        }
                        RequestRef::Delete { key } => {
                            Response::Deleted(store.delete(key)).encode_into(&mut out)
                        }
                        RequestRef::Ping => Response::Pong.encode_into(&mut out),
                    },
                }
            }
        }
        write_frame(&mut writer, &out)?;
        bound_scratch(&mut frame);
        bound_scratch(&mut out);
    }
}

/// Blocking client for one producer store. Owns buffered reader/writer
/// halves plus reusable send/receive scratch buffers, so a steady-state
/// call allocates only what the response forces (a `Value` payload).
pub struct KvClient {
    reader: BufReader<FaultyStream>,
    writer: BufWriter<FaultyStream>,
    send_buf: Vec<u8>,
    recv_buf: Vec<u8>,
}

impl KvClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        Self::from_stream(
            FaultyStream::clean(TcpStream::connect(addr)?),
            crate::net::control::HANDSHAKE_TIMEOUT,
        )
    }

    /// [`Self::connect`] with the whole attempt bounded — dial *and*
    /// handshake — for reconnect paths (e.g. the consumer pool) that
    /// must not stall.
    pub fn connect_timeout(addr: &str, timeout: std::time::Duration) -> io::Result<Self> {
        let stream = crate::net::control::connect_with_timeout(addr, timeout)?;
        Self::from_stream(
            FaultyStream::clean(stream),
            timeout.min(crate::net::control::HANDSHAKE_TIMEOUT),
        )
    }

    /// [`Self::connect_timeout`] with a fault schedule installed: the
    /// connection becomes `plan`'s `conn`-th deterministic stream.
    pub fn connect_faulty(
        addr: &str,
        timeout: std::time::Duration,
        plan: &FaultPlan,
        conn: u64,
    ) -> io::Result<Self> {
        let stream = crate::net::control::connect_with_timeout(addr, timeout)?;
        Self::from_stream(
            FaultyStream::new(stream, Some(plan), conn),
            timeout.min(crate::net::control::HANDSHAKE_TIMEOUT),
        )
    }

    fn from_stream(
        stream: FaultyStream,
        handshake_timeout: std::time::Duration,
    ) -> io::Result<Self> {
        stream.set_nodelay(true)?;
        // Bounded handshake: a silent or non-memtrade peer errors out
        // instead of hanging connect forever. Steady-state data calls
        // revert to blocking reads.
        stream.set_read_timeout(Some(handshake_timeout))?;
        let mut reader = BufReader::with_capacity(CONN_BUF_BYTES, stream.try_clone()?);
        let mut writer = BufWriter::with_capacity(CONN_BUF_BYTES, stream);
        client_handshake(&mut reader, &mut writer, DATA_MAGIC)?;
        reader.get_ref().set_read_timeout(None)?;
        Ok(KvClient { reader, writer, send_buf: Vec::new(), recv_buf: Vec::new() })
    }

    /// Bound how long any later call may wait for a response. A stalled
    /// or wedged producer then surfaces as an error instead of blocking
    /// the caller forever; after a timeout the connection is desynced
    /// and must be dropped (the consumer pool kills the slot — chaos
    /// flushed this out: a producer that stops answering mid-stream
    /// used to wedge the consumer data path indefinitely).
    pub fn set_call_timeout(&mut self, timeout: Option<std::time::Duration>) -> io::Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)
    }

    /// One request/response exchange from a borrowed request — the
    /// allocation-free client path (`get`/`put`/`delete` use it so no
    /// owned `Request` is built per call).
    pub fn call_ref(&mut self, req: RequestRef<'_>) -> io::Result<Response> {
        self.send_buf.clear();
        req.encode_into(&mut self.send_buf);
        write_frame(&mut self.writer, &self.send_buf)?;
        read_frame_into(&mut self.reader, &mut self.recv_buf)?;
        let resp = Response::decode(&self.recv_buf)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e));
        bound_scratch(&mut self.send_buf);
        bound_scratch(&mut self.recv_buf);
        resp
    }

    pub fn call(&mut self, req: &Request) -> io::Result<Response> {
        self.call_ref(req.to_ref())
    }

    pub fn get(&mut self, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        match self.call_ref(RequestRef::Get { key })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) -> io::Result<bool> {
        match self.call_ref(RequestRef::Put { key, value })? {
            Response::Stored => Ok(true),
            Response::Rejected | Response::Throttled { .. } => Ok(false),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn delete(&mut self, key: &[u8]) -> io::Result<bool> {
        match self.call_ref(RequestRef::Delete { key })? {
            Response::Deleted(ok) => Ok(ok),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 1).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"alpha", b"beta").unwrap());
        assert_eq!(client.get(b"alpha").unwrap(), Some(b"beta".to_vec()));
        assert_eq!(client.get(b"missing").unwrap(), None);
        assert!(client.delete(b"alpha").unwrap());
        assert!(!client.delete(b"alpha").unwrap());
        let stats = server.stats();
        assert_eq!(stats.puts, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        server.stop();
    }

    #[test]
    fn tcp_many_clients() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 4 << 20, None, 2).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = KvClient::connect(addr).unwrap();
                    for i in 0..50 {
                        let key = format!("t{t}-k{i}");
                        assert!(c.put(key.as_bytes(), &vec![t as u8; 256]).unwrap());
                        assert_eq!(
                            c.get(key.as_bytes()).unwrap(),
                            Some(vec![t as u8; 256])
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().puts, 200);
        server.stop();
    }

    #[test]
    fn tcp_single_shard_baseline_still_works() {
        let server =
            ProducerStoreServer::start_sharded("127.0.0.1:0", 1 << 20, None, 4, 1).unwrap();
        assert_eq!(server.store().num_shards(), 1);
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", b"v").unwrap());
        assert_eq!(client.get(b"k").unwrap(), Some(b"v".to_vec()));
        server.stop();
    }

    #[test]
    fn byzantine_server_tampers_every_hit_but_stays_decodable() {
        let byz = crate::net::faults::ByzantineSpec::new(5, 1.0);
        let server =
            ProducerStoreServer::start_chaotic("127.0.0.1:0", 1 << 20, None, 1, 2, None, Some(byz))
                .unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"k", &[7u8; 64]).unwrap());
        // A raw client happily accepts the tampered bytes — catching
        // them is the consumer envelope's job (see tests/chaos.rs).
        for _ in 0..10 {
            let v = client.get(b"k").unwrap().expect("tampered hits still decode");
            assert_ne!(v, vec![7u8; 64], "tampering must never be a no-op");
        }
        assert_eq!(server.byzantine_tampered(), 10);
        server.stop();
    }

    #[test]
    fn tcp_rate_limit_throttles() {
        // 1 KB/s with tiny burst: the second large PUT must be throttled.
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, Some(1024), 3).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let _ = client.put(b"k1", &vec![0u8; 200]); // may pass (burst)
        let resp = client
            .call(&Request::Put { key: b"k2".to_vec(), value: vec![0u8; 4096] })
            .unwrap();
        assert!(matches!(resp, Response::Throttled { .. }), "got {resp:?}");
        server.stop();
    }

    #[test]
    fn tcp_shrink_on_live_server() {
        let server =
            ProducerStoreServer::start_sharded("127.0.0.1:0", 8 << 20, None, 6, 4).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        for i in 0..2000u32 {
            assert!(client.put(format!("k{i}").as_bytes(), &vec![1u8; 1024]).unwrap());
        }
        let freed = server.shrink_to(1 << 20);
        assert!(freed > 0);
        assert!(server.store().used_bytes() <= 1 << 20);
        // Survivors still readable.
        let mut hits = 0;
        for i in 0..2000u32 {
            if client.get(format!("k{i}").as_bytes()).unwrap().is_some() {
                hits += 1;
            }
        }
        assert!(hits > 0);
        server.stop();
    }
}

//! Real TCP transport: a threaded producer-store server exposing one
//! [`KvStore`] per listener, and a blocking client. Used by the runnable
//! examples and integration tests so the consumer request path is
//! exercised over real sockets with the real wire codec. (The cluster-
//! scale experiments run on the in-process simulator instead.)

use crate::core::SimTime;
use crate::kv::KvStore;
use crate::net::wire::{read_frame, write_frame, Request, Response};
use crate::util::token_bucket::TokenBucket;
use std::io;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A producer store served over TCP: one KvStore + one rate limiter,
/// shared across client connections (one thread per connection).
pub struct ProducerStoreServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    store: Arc<Mutex<KvStore>>,
}

impl ProducerStoreServer {
    /// Bind to `addr` (use port 0 for an ephemeral port) serving a store
    /// of `max_bytes`, rate limited to `rate_bps` bytes/sec (None = off).
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        max_bytes: usize,
        rate_bps: Option<u64>,
        seed: u64,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let store = Arc::new(Mutex::new(KvStore::new(max_bytes, seed)));
        let bucket = rate_bps
            .map(|bps| Arc::new(Mutex::new(TokenBucket::new(bps, bps / 4))));

        let stop2 = stop.clone();
        let store2 = store.clone();
        let start_instant = Instant::now();
        let accept_handle = std::thread::spawn(move || {
            let mut conn_handles = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nodelay(true).ok();
                        let store = store2.clone();
                        let stop = stop2.clone();
                        let bucket = bucket.clone();
                        conn_handles.push(std::thread::spawn(move || {
                            let _ = serve_conn(stream, store, stop, bucket, start_instant);
                        }));
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            for h in conn_handles {
                let _ = h.join();
            }
        });

        Ok(ProducerStoreServer { local_addr, stop, accept_handle: Some(accept_handle), store })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Snapshot of store statistics.
    pub fn stats(&self) -> crate::kv::KvStats {
        self.store.lock().unwrap().stats.clone()
    }

    /// Harvester-initiated reclaim on a live store.
    pub fn shrink_to(&self, new_max: usize) -> usize {
        self.store.lock().unwrap().shrink_to(new_max)
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ProducerStoreServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(
    mut stream: TcpStream,
    store: Arc<Mutex<KvStore>>,
    stop: Arc<AtomicBool>,
    bucket: Option<Arc<Mutex<TokenBucket>>>,
    start: Instant,
) -> io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return Ok(()), // disconnect
        };
        let resp = match Request::decode(&frame) {
            Err(e) => Response::Error(e.to_string()),
            Ok(req) => {
                // Rate limiting (paper §4.2): refuse oversized I/O.
                let io_bytes = frame.len() as u64;
                let throttled = bucket.as_ref().and_then(|b| {
                    let now = SimTime::from_micros(start.elapsed().as_micros() as u64);
                    let mut tb = b.lock().unwrap();
                    if tb.try_consume(now, io_bytes) {
                        None
                    } else {
                        let wait = tb
                            .time_until(now, io_bytes)
                            .unwrap_or(SimTime::from_secs(1));
                        Some(Response::Throttled { retry_after_us: wait.as_micros() })
                    }
                });
                match throttled {
                    Some(t) => t,
                    None => {
                        let mut kv = store.lock().unwrap();
                        match req {
                            Request::Get { key } => match kv.get(&key) {
                                Some(v) => Response::Value(v),
                                None => Response::NotFound,
                            },
                            Request::Put { key, value } => {
                                if kv.put(&key, &value) {
                                    Response::Stored
                                } else {
                                    Response::Rejected
                                }
                            }
                            Request::Delete { key } => Response::Deleted(kv.delete(&key)),
                            Request::Ping => Response::Pong,
                        }
                    }
                }
            }
        };
        write_frame(&mut stream, &resp.encode())?;
    }
}

/// Blocking client for one producer store.
pub struct KvClient {
    stream: TcpStream,
}

impl KvClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(KvClient { stream })
    }

    pub fn call(&mut self, req: &Request) -> io::Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let frame = read_frame(&mut self.stream)?;
        Response::decode(&frame)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }

    pub fn get(&mut self, key: &[u8]) -> io::Result<Option<Vec<u8>>> {
        match self.call(&Request::Get { key: key.to_vec() })? {
            Response::Value(v) => Ok(Some(v)),
            Response::NotFound => Ok(None),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) -> io::Result<bool> {
        match self.call(&Request::Put { key: key.to_vec(), value: value.to_vec() })? {
            Response::Stored => Ok(true),
            Response::Rejected | Response::Throttled { .. } => Ok(false),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }

    pub fn delete(&mut self, key: &[u8]) -> io::Result<bool> {
        match self.call(&Request::Delete { key: key.to_vec() })? {
            Response::Deleted(ok) => Ok(ok),
            other => Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unexpected response {other:?}"),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_round_trip() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, None, 1).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        assert!(client.put(b"alpha", b"beta").unwrap());
        assert_eq!(client.get(b"alpha").unwrap(), Some(b"beta".to_vec()));
        assert_eq!(client.get(b"missing").unwrap(), None);
        assert!(client.delete(b"alpha").unwrap());
        assert!(!client.delete(b"alpha").unwrap());
        let stats = server.stats();
        assert_eq!(stats.puts, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        server.stop();
    }

    #[test]
    fn tcp_many_clients() {
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 4 << 20, None, 2).unwrap();
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = KvClient::connect(addr).unwrap();
                    for i in 0..50 {
                        let key = format!("t{t}-k{i}");
                        assert!(c.put(key.as_bytes(), &vec![t as u8; 256]).unwrap());
                        assert_eq!(
                            c.get(key.as_bytes()).unwrap(),
                            Some(vec![t as u8; 256])
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stats().puts, 200);
        server.stop();
    }

    #[test]
    fn tcp_rate_limit_throttles() {
        // 1 KB/s with tiny burst: the second large PUT must be throttled.
        let server =
            ProducerStoreServer::start("127.0.0.1:0", 1 << 20, Some(1024), 3).unwrap();
        let mut client = KvClient::connect(server.addr()).unwrap();
        let _ = client.put(b"k1", &vec![0u8; 200]); // may pass (burst)
        let resp = client
            .call(&Request::Put { key: b"k2".to_vec(), value: vec![0u8; 4096] })
            .unwrap();
        assert!(matches!(resp, Response::Throttled { .. }), "got {resp:?}");
        server.stop();
    }
}

//! The transparent swap interface (paper §6, built on Infiniswap in the
//! original): remote memory consumed via hypervisor paging instead of the
//! KV API. The paper measures that this *loses* to the KV interface on
//! their testbed because every fault traverses the block layer; we model
//! that cost explicitly so Fig 11's swap rows can be reproduced.

use crate::core::SimTime;
use crate::net::model::{Locality, NetworkModel};

/// Latency model for one remote page fault through the swap path.
#[derive(Clone, Debug)]
pub struct SwapInterfaceModel {
    pub net: NetworkModel,
    /// Block-layer + hypervisor paging overhead per fault (the paper's
    /// "hypervisor swapping overhead").
    pub block_layer_us: u64,
    /// Page size moved per fault.
    pub page_bytes: u64,
    /// Crypto overhead per page when running fully secure.
    pub crypto_us: u64,
}

impl Default for SwapInterfaceModel {
    fn default() -> Self {
        SwapInterfaceModel {
            net: NetworkModel::default(),
            block_layer_us: 350,
            page_bytes: 4096,
            crypto_us: 25,
        }
    }
}

impl SwapInterfaceModel {
    /// Remote fault latency via swap (KV-comparable unit: µs).
    pub fn fault_latency(&self, locality: Locality, secure: bool) -> SimTime {
        let mut t = self.net.round_trip(locality, 64, self.page_bytes)
            + SimTime::from_micros(self.block_layer_us);
        if secure {
            t += SimTime::from_micros(self.crypto_us);
        }
        t
    }

    /// Equivalent KV GET latency for the same payload (for the Fig 11
    /// comparison): network + producer store service time, no block layer.
    pub fn kv_get_latency(&self, locality: Locality, store_us: u64, secure: bool) -> SimTime {
        let mut t =
            self.net.round_trip(locality, 64, self.page_bytes) + SimTime::from_micros(store_us);
        if secure {
            t += SimTime::from_micros(self.crypto_us);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn swap_slower_than_kv() {
        let m = SwapInterfaceModel::default();
        let swap = m.fault_latency(Locality::SameDatacenter, true);
        let kv = m.kv_get_latency(Locality::SameDatacenter, 30, true);
        assert!(swap > kv, "swap {swap:?} should exceed kv {kv:?}");
        // Paper: swap path can be slower than even SSD for small pages.
        assert!(swap.as_micros() > 500);
    }

    #[test]
    fn security_adds_cost() {
        let m = SwapInterfaceModel::default();
        assert!(
            m.fault_latency(Locality::SameDatacenter, true)
                > m.fault_latency(Locality::SameDatacenter, false)
        );
    }
}

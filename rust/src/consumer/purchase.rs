//! The consumer purchasing strategy (paper §6.2): value remote memory by
//! the *price-per-hit* derived from the cost of running the VM and the
//! observed hit rate; lease slabs while their marginal hit gain is worth
//! more than the market price (consumer surplus > 0).

use crate::core::Money;
use crate::runtime::arima_fallback::demand_one;
use crate::workload::memcachier::Mrc;

/// A sizing decision for one consumer at one market price.
#[derive(Clone, Debug, PartialEq)]
pub struct PurchasePlan {
    /// Slabs to lease.
    pub slabs: u32,
    /// Expected extra hits/sec from those slabs.
    pub extra_hits_per_sec: f64,
    /// Expected hourly surplus = hit value - lease cost (dollars/hour).
    pub surplus_per_hour: f64,
}

/// Dollar value of one hit/sec sustained for an hour (paper §6.2): the
/// consumer prices a hit from its VM cost and observed hit rate.
///
/// `vm_cost_per_hour`: what the consumer pays for its VM;
/// `baseline_hits_per_sec`: the hit throughput that VM achieves.
pub fn price_per_hit_hour(vm_cost_per_hour: Money, baseline_hits_per_sec: f64) -> f64 {
    if baseline_hits_per_sec <= 0.0 {
        return 0.0;
    }
    vm_cost_per_hour.as_dollars() / baseline_hits_per_sec
}

/// Decide how many slabs to lease (§6.2): maximize
/// `hit_value * gain(s) - price * s` over s, with `gain` derived from the
/// MRC above the consumer's local cache size.
pub fn plan(
    mrc: &Mrc,
    local_bytes: u64,
    slab_bytes: u64,
    max_slabs: usize,
    hit_value_per_hour: f64,
    price_per_slab_hour: Money,
    eviction_probability: f64,
) -> PurchasePlan {
    // Expected gains discounted by the probability leased memory is
    // revoked early (§7.4's "more realistic scenario").
    let discount = (1.0 - eviction_probability).clamp(0.0, 1.0);
    let gain: Vec<f32> = (0..=max_slabs)
        .map(|s| (mrc.gain(local_bytes, s as u64 * slab_bytes) * discount) as f32)
        .collect();
    let slabs = demand_one(&gain, hit_value_per_hour as f32, price_per_slab_hour.as_dollars());
    let extra = gain[slabs as usize] as f64;
    PurchasePlan {
        slabs,
        extra_hits_per_sec: extra,
        surplus_per_hour: hit_value_per_hour * extra
            - price_per_slab_hour.as_dollars() * slabs as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mrc() -> Mrc {
        // Concave: misses fall quickly then flatten.
        let miss: Vec<f64> = (0..65)
            .map(|s| (1.0 - (s as f64 / 32.0).min(1.0).powf(0.5)).max(0.0))
            .collect();
        Mrc { app_id: 0, miss_ratio: miss, granularity_bytes: 64 << 20, req_rate: 1000.0 }
    }

    #[test]
    fn hit_price_from_vm_cost() {
        let v = price_per_hit_hour(Money::from_dollars(0.10), 1000.0);
        assert!((v - 1e-4).abs() < 1e-12);
        assert_eq!(price_per_hit_hour(Money::from_dollars(0.10), 0.0), 0.0);
    }

    #[test]
    fn cheap_memory_is_bought_expensive_is_not() {
        let m = mrc();
        let cheap = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(1e-6), 0.0);
        assert!(cheap.slabs > 10, "cheap plan bought {}", cheap.slabs);
        assert!(cheap.surplus_per_hour > 0.0);
        let dear = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(10.0), 0.0);
        assert_eq!(dear.slabs, 0);
    }

    #[test]
    fn demand_decreases_with_price() {
        let m = mrc();
        let mut last = u32::MAX;
        for p in [1e-7, 1e-6, 1e-5, 1e-4, 1e-3] {
            let got = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(p), 0.0).slabs;
            assert!(got <= last, "price {p}: {got} > {last}");
            last = got;
        }
    }

    #[test]
    fn local_cache_reduces_marginal_demand() {
        let m = mrc();
        let empty = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(1e-5), 0.0);
        let seeded =
            plan(&m, 24 * (64 << 20), 64 << 20, 64, 1e-4, Money::from_dollars(1e-5), 0.0);
        assert!(seeded.slabs < empty.slabs);
    }

    #[test]
    fn eviction_risk_discounts_demand() {
        let m = mrc();
        let sure = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(2e-5), 0.0);
        let risky = plan(&m, 0, 64 << 20, 64, 1e-4, Money::from_dollars(2e-5), 0.5);
        assert!(risky.slabs <= sure.slabs);
    }
}

//! The consumer's secure KV client (paper §6.1).
//!
//! Wraps any transport (simulated manager, TCP producer store) with the
//! paper's confidentiality/integrity construction via [`crate::crypto::
//! Envelope`]: PUT encrypts and substitutes the key; GET verifies the
//! truncated SHA-256 before decrypting; DELETE removes local metadata
//! then synchronizes the producer store. Local metadata (the `(K_C ->
//! M_C)` map) lives in consumer memory and is byte-accounted so the
//! paper's overhead numbers (§7.3) can be reproduced.

use crate::crypto::secure::{Envelope, OpenError, Sealed, SealedValue};
use crate::metrics::{scoped, Histogram, MetricSet, Observe};
use crate::net::wire::{Request, Response};
use crate::trace::{self, Op as TraceOp, Role, SpanGuard, Status};
use std::collections::{BTreeMap, HashMap};
use std::time::Instant;

/// Reserved producer index naming the recorded-miss path: a transport
/// whose [`KvTransport::route_put`] has nowhere live to route a PUT
/// returns this, and its `call` answers deterministically like a miss
/// (`Rejected`). No real slot ever uses this index.
pub const DEAD_ROUTE: u32 = u32::MAX;

/// Anything that can carry a request to one producer store.
pub trait KvTransport {
    fn call(&mut self, producer_index: u32, req: Request) -> Response;

    /// Execute a group of requests against one producer, one response
    /// per request *in request order*; a miss or rejection on one op
    /// must not fail its siblings. The default degrades to sequential
    /// single calls, so every existing transport (closures, the
    /// in-process manager, the simulator) keeps working unchanged;
    /// wire-backed transports ([`crate::net::tcp::KvClient`],
    /// [`crate::market::RemotePool`]) override it with true batch
    /// frames, amortizing the per-request round trip.
    fn call_multi(&mut self, producer_index: u32, reqs: Vec<Request>) -> Vec<Response> {
        reqs.into_iter().map(|r| self.call(producer_index, r)).collect()
    }

    /// Pick the producer index for a *new* PUT of `key`. The default
    /// keeps the caller's round-robin choice; lease-aware transports
    /// (e.g. [`crate::market::RemotePool`]) override it with
    /// deterministic key→slab routing over their live slots, or
    /// [`DEAD_ROUTE`] when nothing is live. GETs and DELETEs never
    /// consult this — they route from stored metadata.
    fn route_put(&mut self, key: &[u8], round_robin_hint: u32) -> u32 {
        let _ = key;
        round_robin_hint
    }
}

/// Blanket impl so closures can act as transports in tests/sims.
impl<F: FnMut(u32, Request) -> Response> KvTransport for F {
    fn call(&mut self, producer_index: u32, req: Request) -> Response {
        self(producer_index, req)
    }
}

#[derive(Clone, Debug, Default)]
pub struct SecureKvStats {
    pub puts: u64,
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub deletes: u64,
    pub integrity_failures: u64,
    pub throttled: u64,
    pub rejected: u64,
    /// Metadata entries dropped because their producer index fell out of
    /// range when the producer count shrank (their remote data is gone).
    pub stranded_drops: u64,
}

impl Observe for SecureKvStats {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        out.set_counter(scoped(prefix, "puts"), self.puts);
        out.set_counter(scoped(prefix, "gets"), self.gets);
        out.set_counter(scoped(prefix, "hits"), self.hits);
        out.set_counter(scoped(prefix, "misses"), self.misses);
        out.set_counter(scoped(prefix, "deletes"), self.deletes);
        out.set_counter(scoped(prefix, "integrity_failures"), self.integrity_failures);
        out.set_counter(scoped(prefix, "throttled"), self.throttled);
        out.set_counter(scoped(prefix, "rejected"), self.rejected);
        out.set_counter(scoped(prefix, "stranded_drops"), self.stranded_drops);
    }
}

/// The secure client's latency instruments, all on the shared
/// [`crate::metrics::Histogram`]. Single-key ops record their whole
/// round trip in `op_us`; multi-ops record one `group_us` sample per
/// per-producer batch plus its occupancy in `batch_ops`; every sealed /
/// opened value records its crypto cost in `seal_ns` / `open_ns`.
#[derive(Debug, Default)]
pub struct ClientTelemetry {
    /// Whole-call latency of single-key get/put/delete (µs).
    pub op_us: Histogram,
    /// Round-trip latency of one multi-op per-producer group (µs).
    pub group_us: Histogram,
    /// Batch-window occupancy: ops per per-producer group.
    pub batch_ops: Histogram,
    /// Envelope seal cost per value (ns).
    pub seal_ns: Histogram,
    /// Envelope verify + decrypt cost per value (ns).
    pub open_ns: Histogram,
}

impl Observe for ClientTelemetry {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        out.set_histogram(scoped(prefix, "op_us"), self.op_us.snapshot());
        out.set_histogram(scoped(prefix, "group_us"), self.group_us.snapshot());
        out.set_histogram(scoped(prefix, "batch_ops"), self.batch_ops.snapshot());
        out.set_histogram(scoped(prefix, "seal_ns"), self.seal_ns.snapshot());
        out.set_histogram(scoped(prefix, "open_ns"), self.open_ns.snapshot());
    }
}

/// The secure consumer-side KV cache over leased remote memory.
pub struct SecureKv {
    envelope: Envelope,
    /// K_C -> M_C (paper §6.1): the local metadata map.
    metadata: HashMap<Vec<u8>, SealedValue>,
    /// Round-robin cursor over producer stores.
    next_producer: u32,
    n_producers: u32,
    pub stats: SecureKvStats,
    pub telemetry: ClientTelemetry,
}

impl SecureKv {
    /// `key = None` disables encryption; `integrity` controls hashing.
    /// `n_producers` is the number of producer stores leased. CBC IVs
    /// are drawn from OS entropy (see [`Envelope::new`]); deterministic
    /// harnesses use [`Self::with_iv_seed`].
    pub fn new(key: Option<[u8; 16]>, integrity: bool, n_producers: u32) -> Self {
        Self::from_envelope(Envelope::new(key, integrity), n_producers)
    }

    /// [`Self::new`] with an explicit IV-stream seed — for tests,
    /// benchmarks, and the simulator, where bit-reproducible runs
    /// matter and the produced ciphertexts never leave the process.
    pub fn with_iv_seed(
        key: Option<[u8; 16]>,
        integrity: bool,
        n_producers: u32,
        seed: u64,
    ) -> Self {
        Self::from_envelope(Envelope::with_iv_seed(key, integrity, seed), n_producers)
    }

    fn from_envelope(envelope: Envelope, n_producers: u32) -> Self {
        SecureKv {
            envelope,
            metadata: HashMap::new(),
            next_producer: 0,
            n_producers: n_producers.max(1),
            stats: SecureKvStats::default(),
            telemetry: ClientTelemetry::default(),
        }
    }

    /// Everything this client observes, on the shared metrics plane:
    /// the op counters plus the latency instruments.
    pub fn metrics(&self) -> MetricSet {
        let mut out = MetricSet::new();
        self.stats.observe("secure", &mut out);
        self.telemetry.observe("secure", &mut out);
        out.set_gauge("secure.metadata_bytes", self.metadata_bytes() as i64);
        out.set_gauge("secure.keys", self.len() as i64);
        out
    }

    pub fn n_producers(&self) -> u32 {
        self.n_producers
    }

    /// Resize the producer table. Shrinking drops metadata whose stored
    /// producer index no longer exists: those stores are gone, so the
    /// keys would otherwise strand — GETs/DELETEs routed at indices the
    /// transport no longer backs (an out-of-bounds panic or permanent
    /// phantom misses, depending on the transport).
    ///
    /// Only meaningful with default-routing (round-robin) transports,
    /// where `producer_index < n_producers` by construction. Transports
    /// that override [`KvTransport::route_put`] (e.g.
    /// [`crate::market::RemotePool`]) own the index space themselves —
    /// do not call this on a `SecureKv` used with one, or valid
    /// metadata at transport-chosen indices would be purged.
    pub fn set_n_producers(&mut self, n: u32) {
        self.n_producers = n.max(1);
        let n = self.n_producers;
        let before = self.metadata.len();
        self.metadata.retain(|_, meta| meta.producer_index < n);
        self.stats.stranded_drops += (before - self.metadata.len()) as u64;
    }

    /// Number of locally cached KV metadata entries.
    pub fn len(&self) -> usize {
        self.metadata.len()
    }
    pub fn is_empty(&self) -> bool {
        self.metadata.is_empty()
    }

    /// Local metadata bytes (paper §6.1 "Metadata Overhead"): per entry,
    /// the key itself plus 24 B (encrypting) or 16 B (integrity-only).
    pub fn metadata_bytes(&self) -> usize {
        let per = SealedValue::metadata_bytes(self.envelope.encrypting());
        self.metadata.keys().map(|k| k.len() + per).sum()
    }

    /// PUT (paper §6.1): seal, pick a producer store, send under K_P.
    /// The store is chosen by the transport's [`KvTransport::route_put`]
    /// (default: our round-robin cursor).
    pub fn put<T: KvTransport>(&mut self, t: &mut T, key: &[u8], value: &[u8]) -> bool {
        // Every public op opens a fresh trace: the root span that the
        // seal/wire/shard child spans (and the data frames' trace-context
        // suffix) all chain back to.
        let mut root = SpanGuard::root(Role::Consumer, TraceOp::Put);
        let t_op = Instant::now();
        self.stats.puts += 1;
        let hint = self.next_producer % self.n_producers;
        self.next_producer = self.next_producer.wrapping_add(1);
        let producer = t.route_put(key, hint);
        let t_seal = Instant::now();
        let Sealed { value_p, meta } = self.envelope.seal(value, producer);
        self.telemetry.seal_ns.record(t_seal.elapsed().as_nanos() as u64);
        let k_p = meta.k_p.to_le_bytes().to_vec();
        let stored = match t.call(producer, Request::Put { key: k_p, value: value_p }) {
            Response::Stored => {
                self.metadata.insert(key.to_vec(), meta);
                true
            }
            Response::Throttled { .. } => {
                self.stats.throttled += 1;
                false
            }
            _ => {
                self.stats.rejected += 1;
                false
            }
        };
        if !stored {
            root.set_status(Status::Error);
        }
        self.telemetry.op_us.record_elapsed_us(t_op);
        stored
    }

    /// GET (paper §6.1): local metadata lookup, fetch under K_P, verify
    /// hash, decrypt. A failed verification discards the value (miss).
    pub fn get<T: KvTransport>(&mut self, t: &mut T, key: &[u8]) -> Option<Vec<u8>> {
        let mut root = SpanGuard::root(Role::Consumer, TraceOp::Get);
        let t_op = Instant::now();
        self.stats.gets += 1;
        let meta = match self.metadata.get(key) {
            Some(m) => m.clone(),
            None => {
                self.stats.misses += 1;
                root.set_status(Status::Miss);
                self.telemetry.op_us.record_elapsed_us(t_op);
                return None;
            }
        };
        let k_p = meta.k_p.to_le_bytes().to_vec();
        let got = match t.call(meta.producer_index, Request::Get { key: k_p }) {
            Response::Value(value_p) => {
                let t_open = Instant::now();
                let opened = self.envelope.open(&value_p, &meta);
                self.telemetry.open_ns.record(t_open.elapsed().as_nanos() as u64);
                match opened {
                    Ok(v) => {
                        self.stats.hits += 1;
                        Some(v)
                    }
                    Err(OpenError::BadHash) | Err(OpenError::BadCiphertext) => {
                        // Corrupted by the untrusted producer: discard,
                        // and dump the flight recorder — the saved spans
                        // name the producer that served the bad bytes.
                        self.stats.integrity_failures += 1;
                        self.stats.misses += 1;
                        self.metadata.remove(key);
                        root.set_status(Status::Error);
                        trace::dump("consumer", "integrity");
                        None
                    }
                }
            }
            Response::Throttled { .. } => {
                self.stats.throttled += 1;
                self.stats.misses += 1;
                root.set_status(Status::Miss);
                None
            }
            _ => {
                // Evicted at the producer (or lease gone): drop metadata.
                self.stats.misses += 1;
                self.metadata.remove(key);
                root.set_status(Status::Miss);
                None
            }
        };
        self.telemetry.op_us.record_elapsed_us(t_op);
        got
    }

    /// Batched GET: one result per key, in order (`None` = miss).
    ///
    /// Keys are grouped by the producer recorded in their metadata and
    /// each group travels as one [`KvTransport::call_multi`] — over a
    /// wire transport that is one batch frame per producer instead of
    /// one round trip per key. Verification stays strictly per op: each
    /// value is checked against its own metadata exactly as in
    /// [`Self::get`] (seal-time counters/IVs are per value, so batching
    /// shares no nonces), and a miss, tamper, or throttle on one key
    /// never fails its siblings.
    pub fn multi_get<T: KvTransport>(&mut self, t: &mut T, keys: &[&[u8]]) -> Vec<Option<Vec<u8>>> {
        let mut root = SpanGuard::root(Role::Consumer, TraceOp::MultiGet);
        let mut results: Vec<Option<Vec<u8>>> = vec![None; keys.len()];
        self.stats.gets += keys.len() as u64;
        // Group by producer; BTreeMap so the fan-out order is
        // deterministic (the chaos plane's schedules stay replayable).
        let mut groups: BTreeMap<u32, Vec<(usize, SealedValue)>> = BTreeMap::new();
        for (i, key) in keys.iter().enumerate() {
            match self.metadata.get(*key) {
                Some(m) => groups.entry(m.producer_index).or_default().push((i, m.clone())),
                None => self.stats.misses += 1,
            }
        }
        for (producer, group) in groups {
            let reqs: Vec<Request> = group
                .iter()
                .map(|(_, m)| Request::Get { key: m.k_p.to_le_bytes().to_vec() })
                .collect();
            self.telemetry.batch_ops.record(group.len() as u64);
            let t_group = Instant::now();
            let mut resps = t.call_multi(producer, reqs).into_iter();
            self.telemetry.group_us.record_elapsed_us(t_group);
            for (i, meta) in group {
                match resps.next() {
                    Some(Response::Value(value_p)) => {
                        let t_open = Instant::now();
                        let opened = self.envelope.open(&value_p, &meta);
                        self.telemetry.open_ns.record(t_open.elapsed().as_nanos() as u64);
                        match opened {
                            Ok(v) => {
                                self.stats.hits += 1;
                                results[i] = Some(v);
                            }
                            Err(OpenError::BadHash) | Err(OpenError::BadCiphertext) => {
                                self.stats.integrity_failures += 1;
                                self.stats.misses += 1;
                                self.metadata.remove(keys[i]);
                                root.set_status(Status::Error);
                                trace::dump("consumer", "integrity");
                            }
                        }
                    }
                    Some(Response::Throttled { .. }) => {
                        self.stats.throttled += 1;
                        self.stats.misses += 1;
                    }
                    Some(_) => {
                        // Evicted at the producer (or lease gone, or the
                        // transport absorbed an error): same as `get`.
                        self.stats.misses += 1;
                        self.metadata.remove(keys[i]);
                    }
                    // Transport answered short (contract violation):
                    // count the miss but keep the metadata — nothing
                    // proved the remote copy is gone.
                    None => self.stats.misses += 1,
                }
            }
        }
        results
    }

    /// Batched PUT: true per stored pair, in order. Every value is
    /// sealed individually ([`Envelope::seal`] draws a fresh IV and
    /// substitute-key counter per op — no cross-op nonce reuse), routed
    /// via [`KvTransport::route_put`] exactly like [`Self::put`], then
    /// grouped per producer into one `call_multi` each.
    pub fn multi_put<T: KvTransport>(&mut self, t: &mut T, items: &[(&[u8], &[u8])]) -> Vec<bool> {
        let _root = SpanGuard::root(Role::Consumer, TraceOp::MultiPut);
        let mut results = vec![false; items.len()];
        self.stats.puts += items.len() as u64;
        let mut groups: BTreeMap<u32, Vec<(usize, Sealed)>> = BTreeMap::new();
        for (i, (key, value)) in items.iter().enumerate() {
            let hint = self.next_producer % self.n_producers;
            self.next_producer = self.next_producer.wrapping_add(1);
            let producer = t.route_put(key, hint);
            let t_seal = Instant::now();
            let sealed = self.envelope.seal(value, producer);
            self.telemetry.seal_ns.record(t_seal.elapsed().as_nanos() as u64);
            groups.entry(producer).or_default().push((i, sealed));
        }
        for (producer, group) in groups {
            let mut metas: Vec<(usize, SealedValue)> = Vec::with_capacity(group.len());
            let reqs: Vec<Request> = group
                .into_iter()
                .map(|(i, Sealed { value_p, meta })| {
                    let req =
                        Request::Put { key: meta.k_p.to_le_bytes().to_vec(), value: value_p };
                    metas.push((i, meta));
                    req
                })
                .collect();
            self.telemetry.batch_ops.record(reqs.len() as u64);
            let t_group = Instant::now();
            let mut resps = t.call_multi(producer, reqs).into_iter();
            self.telemetry.group_us.record_elapsed_us(t_group);
            for (i, meta) in metas {
                match resps.next() {
                    Some(Response::Stored) => {
                        self.metadata.insert(items[i].0.to_vec(), meta);
                        results[i] = true;
                    }
                    Some(Response::Throttled { .. }) => self.stats.throttled += 1,
                    _ => self.stats.rejected += 1,
                }
            }
        }
        results
    }

    /// Batched DELETE: removes local metadata per key, then synchronizes
    /// the producer stores with one grouped `call_multi` per producer.
    pub fn multi_delete<T: KvTransport>(&mut self, t: &mut T, keys: &[&[u8]]) -> Vec<bool> {
        let _root = SpanGuard::root(Role::Consumer, TraceOp::MultiDelete);
        let mut results = vec![false; keys.len()];
        self.stats.deletes += keys.len() as u64;
        let mut groups: BTreeMap<u32, Vec<(usize, SealedValue)>> = BTreeMap::new();
        for (i, key) in keys.iter().enumerate() {
            if let Some(meta) = self.metadata.remove(*key) {
                groups.entry(meta.producer_index).or_default().push((i, meta));
            }
        }
        for (producer, group) in groups {
            let reqs: Vec<Request> = group
                .iter()
                .map(|(_, m)| Request::Delete { key: m.k_p.to_le_bytes().to_vec() })
                .collect();
            self.telemetry.batch_ops.record(reqs.len() as u64);
            let t_group = Instant::now();
            let mut resps = t.call_multi(producer, reqs).into_iter();
            self.telemetry.group_us.record_elapsed_us(t_group);
            for (i, _meta) in group {
                results[i] = matches!(resps.next(), Some(Response::Deleted(true)));
            }
        }
        results
    }

    /// DELETE (paper §6.1): remove local metadata, then synchronize the
    /// producer store.
    pub fn delete<T: KvTransport>(&mut self, t: &mut T, key: &[u8]) -> bool {
        let _root = SpanGuard::root(Role::Consumer, TraceOp::Delete);
        let t_op = Instant::now();
        self.stats.deletes += 1;
        let Some(meta) = self.metadata.remove(key) else {
            self.telemetry.op_us.record_elapsed_us(t_op);
            return false;
        };
        let k_p = meta.k_p.to_le_bytes().to_vec();
        let deleted = matches!(
            t.call(meta.producer_index, Request::Delete { key: k_p }),
            Response::Deleted(true)
        );
        self.telemetry.op_us.record_elapsed_us(t_op);
        deleted
    }

    /// Hit ratio observed so far.
    pub fn hit_ratio(&self) -> f64 {
        if self.stats.gets == 0 {
            0.0
        } else {
            self.stats.hits as f64 / self.stats.gets as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::KvStore;

    /// In-memory transport over N KvStores.
    struct MemTransport {
        stores: Vec<KvStore>,
    }

    impl MemTransport {
        fn new(n: usize) -> Self {
            MemTransport {
                stores: (0..n).map(|i| KvStore::new(16 << 20, i as u64)).collect(),
            }
        }
    }

    impl KvTransport for MemTransport {
        fn call(&mut self, producer: u32, req: Request) -> Response {
            let kv = &mut self.stores[producer as usize];
            match req {
                Request::Get { key } => match kv.get(&key) {
                    Some(v) => Response::Value(v.to_vec()),
                    None => Response::NotFound,
                },
                Request::Put { key, value } => {
                    if kv.put(&key, &value) {
                        Response::Stored
                    } else {
                        Response::Rejected
                    }
                }
                Request::Delete { key } => Response::Deleted(kv.delete(&key)),
                Request::Ping => Response::Pong,
            }
        }
    }

    #[test]
    fn telemetry_records_crypto_and_call_latency() {
        let mut t = MemTransport::new(2);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 2, 42);
        assert!(c.put(&mut t, b"k", b"v"));
        assert_eq!(c.get(&mut t, b"k"), Some(b"v".to_vec()));
        let keys: [&[u8]; 2] = [b"k", b"absent"];
        c.multi_get(&mut t, &keys);
        let m = c.metrics();
        assert!(m.histogram("secure.op_us").unwrap().count() >= 2);
        assert_eq!(m.histogram("secure.seal_ns").unwrap().count(), 1);
        assert_eq!(m.histogram("secure.open_ns").unwrap().count(), 2);
        // One per-producer group: only "k" had metadata to fetch.
        let batches = m.histogram("secure.batch_ops").unwrap();
        assert_eq!(batches.count(), 1);
        assert_eq!(m.histogram("secure.group_us").unwrap().count(), 1);
        assert_eq!(m.counter("secure.puts"), Some(1));
        assert!(m.gauge("secure.metadata_bytes").unwrap() > 0);
    }

    #[test]
    fn put_get_round_trip_encrypted() {
        let mut t = MemTransport::new(2);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 2, 42);
        assert!(c.put(&mut t, b"mykey", b"myvalue"));
        assert_eq!(c.get(&mut t, b"mykey"), Some(b"myvalue".to_vec()));
        assert_eq!(c.hit_ratio(), 1.0);
        // The producer never sees plaintext key or value.
        for store in &mut t.stores {
            assert_eq!(store.get(b"mykey"), None);
            if let Some(k) = store.sample_key() {
                let v = store.get(&k).unwrap();
                assert!(!v.windows(7).any(|w| w == b"myvalue"));
            }
        }
    }

    #[test]
    fn secure_kv_over_sharded_store() {
        use crate::kv::ShardedKvStore;
        let shared = ShardedKvStore::new(16 << 20, 4, 11);
        let mut c = SecureKv::with_iv_seed(Some([9u8; 16]), true, 1, 21);
        {
            let mut t = |_p: u32, req: Request| match req {
                Request::Get { key } => match shared.get_owned(&key) {
                    Some(v) => Response::Value(v),
                    None => Response::NotFound,
                },
                Request::Put { key, value } => {
                    if shared.put(&key, &value) {
                        Response::Stored
                    } else {
                        Response::Rejected
                    }
                }
                Request::Delete { key } => Response::Deleted(shared.delete(&key)),
                Request::Ping => Response::Pong,
            };
            for i in 0..200u32 {
                assert!(c.put(&mut t, format!("k{i}").as_bytes(), &vec![i as u8; 256]));
            }
            for i in 0..200u32 {
                assert_eq!(
                    c.get(&mut t, format!("k{i}").as_bytes()),
                    Some(vec![i as u8; 256])
                );
            }
        }
        assert_eq!(shared.stats().puts, 200);
    }

    #[test]
    fn round_robin_spreads_across_producers() {
        let mut t = MemTransport::new(4);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 4, 1);
        for i in 0..40 {
            assert!(c.put(&mut t, format!("k{i}").as_bytes(), b"v"));
        }
        for store in &t.stores {
            assert!(store.len() >= 5, "store imbalance: {}", store.len());
        }
    }

    #[test]
    fn corruption_detected_and_discarded() {
        let mut t = MemTransport::new(1);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 7);
        assert!(c.put(&mut t, b"key", b"value"));
        // Corrupt the stored bytes.
        let k_p = 0u64.to_le_bytes().to_vec();
        let mut stored = t.stores[0].get(&k_p).unwrap().to_vec();
        stored[3] ^= 0xff;
        t.stores[0].put(&k_p, &stored);
        assert_eq!(c.get(&mut t, b"key"), None);
        assert_eq!(c.stats.integrity_failures, 1);
        // Metadata dropped: subsequent get is a local miss.
        assert_eq!(c.get(&mut t, b"key"), None);
        assert_eq!(c.stats.misses, 2);
    }

    #[test]
    fn remote_eviction_is_a_miss() {
        let mut t = MemTransport::new(1);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 9);
        assert!(c.put(&mut t, b"key", b"value"));
        let k_p = 0u64.to_le_bytes().to_vec();
        t.stores[0].delete(&k_p);
        assert_eq!(c.get(&mut t, b"key"), None);
        assert!(c.is_empty());
    }

    #[test]
    fn delete_synchronizes() {
        let mut t = MemTransport::new(1);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 3);
        assert!(c.put(&mut t, b"key", b"value"));
        assert!(c.delete(&mut t, b"key"));
        assert_eq!(t.stores[0].len(), 0);
        assert!(!c.delete(&mut t, b"key"));
    }

    #[test]
    fn metadata_overhead_accounting() {
        let mut t = MemTransport::new(1);
        let mut enc = SecureKv::with_iv_seed(Some([1u8; 16]), true, 1, 3);
        enc.put(&mut t, b"12345678", b"v");
        assert_eq!(enc.metadata_bytes(), 8 + 24);
        let mut int_only = SecureKv::with_iv_seed(None, true, 1, 3);
        int_only.put(&mut t, b"12345678", b"v");
        assert_eq!(int_only.metadata_bytes(), 8 + 16);
    }

    #[test]
    fn shrinking_producer_count_drops_stranded_metadata() {
        // Regression: shrinking the producer table used to leave
        // metadata routing GETs/DELETEs at indices that no longer exist
        // (an out-of-bounds panic on indexing transports like this one).
        let mut t = MemTransport::new(4);
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 4, 1);
        for i in 0..40 {
            assert!(c.put(&mut t, format!("k{i}").as_bytes(), b"v"));
        }
        t.stores.truncate(2);
        c.set_n_producers(2);
        assert!(c.stats.stranded_drops > 0, "no metadata was stranded");
        let mut hits = 0;
        let mut misses = 0;
        for i in 0..40 {
            // Must not panic, and must never route beyond store 1.
            match c.get(&mut t, format!("k{i}").as_bytes()) {
                Some(v) => {
                    assert_eq!(v, b"v".to_vec());
                    hits += 1;
                }
                None => misses += 1,
            }
            assert!(!c.delete(&mut t, format!("dead{i}").as_bytes()));
        }
        // Keys on surviving stores still hit; stranded ones are misses.
        assert_eq!(hits + misses, 40);
        assert!(hits > 0, "survivors lost");
        assert_eq!(misses as u64, c.stats.stranded_drops);
        // Growing back is metadata-preserving.
        let before = c.len();
        c.set_n_producers(8);
        assert_eq!(c.len(), before);
    }

    #[test]
    fn multi_ops_round_trip_and_group_across_producers() {
        let mut t = MemTransport::new(3);
        let mut c = SecureKv::with_iv_seed(Some([2u8; 16]), true, 3, 5);
        let keys: Vec<Vec<u8>> = (0..30).map(|i| format!("mk{i}").into_bytes()).collect();
        let vals: Vec<Vec<u8>> = (0..30).map(|i| vec![i as u8; 48]).collect();
        let items: Vec<(&[u8], &[u8])> =
            keys.iter().zip(&vals).map(|(k, v)| (k.as_slice(), v.as_slice())).collect();
        assert_eq!(c.multi_put(&mut t, &items), vec![true; 30]);
        assert_eq!(c.stats.puts, 30);
        // Round-robin routing spread the batch across all producers.
        for store in &t.stores {
            assert!(store.len() >= 5, "store imbalance: {}", store.len());
        }
        // One multi_get over keys owned by all three producers, plus a
        // miss in the middle: per-op results, in order.
        let mut get_keys: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        get_keys.insert(11, b"never-put");
        let got = c.multi_get(&mut t, &get_keys);
        assert_eq!(got.len(), 31);
        assert_eq!(got[11], None);
        for (i, g) in got.iter().enumerate().filter(|(i, _)| *i != 11) {
            let j = if i < 11 { i } else { i - 1 };
            assert_eq!(g.as_deref(), Some(vals[j].as_slice()), "op {i}");
        }
        assert_eq!(c.stats.hits, 30);
        assert_eq!(c.stats.misses, 1);
        // Batched deletes synchronize the stores; repeats are false.
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        assert_eq!(c.multi_delete(&mut t, &key_refs), vec![true; 30]);
        assert!(c.is_empty());
        assert_eq!(c.multi_delete(&mut t, &key_refs), vec![false; 30]);
        assert!(t.stores.iter().all(|s| s.len() == 0));
    }

    #[test]
    fn multi_get_detects_corruption_per_op_without_failing_siblings() {
        let mut t = MemTransport::new(1);
        let mut c = SecureKv::with_iv_seed(Some([3u8; 16]), true, 1, 9);
        for i in 0..10u64 {
            assert!(c.put(&mut t, format!("k{i}").as_bytes(), &[i as u8; 32]));
        }
        // Corrupt exactly one stored value (substitute key 4).
        let k_p = 4u64.to_le_bytes().to_vec();
        let mut stored = t.stores[0].get(&k_p).unwrap().to_vec();
        stored[20] ^= 0x80;
        t.stores[0].put(&k_p, &stored);
        let keys: Vec<Vec<u8>> = (0..10).map(|i| format!("k{i}").into_bytes()).collect();
        let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
        let got = c.multi_get(&mut t, &key_refs);
        for (i, g) in got.iter().enumerate() {
            if i == 4 {
                assert_eq!(*g, None, "corrupted op must be a miss");
            } else {
                assert_eq!(g.as_deref(), Some([i as u8; 32].as_slice()), "sibling {i} failed");
            }
        }
        assert_eq!(c.stats.integrity_failures, 1);
        // The corrupted key's metadata is dropped: now a local miss.
        assert_eq!(c.get(&mut t, b"k4"), None);
        assert_eq!(c.len(), 9);
    }

    #[test]
    fn multi_ops_match_single_op_semantics_on_closure_transports() {
        // The default call_multi degrades to per-op calls, so a closure
        // transport sees identical traffic either way.
        let mut c = SecureKv::with_iv_seed(None, true, 1, 3);
        let mut calls = 0u32;
        {
            let mut echo = |_p: u32, req: Request| {
                calls += 1;
                match req {
                    Request::Put { .. } => Response::Stored,
                    Request::Get { .. } => Response::NotFound,
                    _ => Response::Pong,
                }
            };
            let items: [(&[u8], &[u8]); 2] = [(b"a", b"1"), (b"b", b"2")];
            assert_eq!(c.multi_put(&mut echo, &items), vec![true, true]);
        }
        assert_eq!(calls, 2);
        // Stored-then-evicted keys degrade per op.
        let mut gone = |_p: u32, _req: Request| Response::NotFound;
        let keys: [&[u8]; 3] = [b"a", b"b", b"c"];
        assert_eq!(c.multi_get(&mut gone, &keys), vec![None, None, None]);
        assert_eq!(c.stats.misses, 3);
        assert!(c.is_empty(), "eviction answers must drop metadata");
    }

    #[test]
    fn transport_routing_hook_overrides_round_robin() {
        struct FixedRoute(MemTransport);
        impl KvTransport for FixedRoute {
            fn call(&mut self, p: u32, req: Request) -> Response {
                self.0.call(p, req)
            }
            fn route_put(&mut self, _key: &[u8], _hint: u32) -> u32 {
                2 // everything lands on store 2
            }
        }
        let mut t = FixedRoute(MemTransport::new(4));
        let mut c = SecureKv::with_iv_seed(Some([1u8; 16]), true, 4, 1);
        for i in 0..20 {
            assert!(c.put(&mut t, format!("k{i}").as_bytes(), b"v"));
        }
        assert_eq!(t.0.stores[2].len(), 20);
        for (i, store) in t.0.stores.iter().enumerate() {
            if i != 2 {
                assert_eq!(store.len(), 0);
            }
        }
        // GETs follow the stored metadata to store 2.
        for i in 0..20 {
            assert!(c.get(&mut t, format!("k{i}").as_bytes()).is_some());
        }
    }

    #[test]
    fn closure_transport_works() {
        let mut c = SecureKv::with_iv_seed(None, false, 1, 3);
        let mut echo = |_p: u32, req: Request| match req {
            Request::Put { .. } => Response::Stored,
            Request::Get { .. } => Response::NotFound,
            _ => Response::Pong,
        };
        assert!(c.put(&mut echo, b"k", b"v"));
        assert_eq!(c.get(&mut echo, b"k"), None);
    }
}

//! The consumer side of Memtrade (paper §6): the secure KV client
//! (encryption + integrity + key substitution over any transport), the
//! swap-interface model, SHARDS-style MRC profiling, and the §6.2
//! purchasing strategy.

pub mod client;
pub mod mrc;
pub mod purchase;
pub mod swap_iface;

pub use client::{KvTransport, SecureKv, SecureKvStats};
pub use mrc::MrcProfiler;
pub use purchase::PurchasePlan;
pub use swap_iface::SwapInterfaceModel;

//! SHARDS-style miss-ratio-curve profiler (paper §6.2: "lightweight
//! sampling-based techniques [SHARDS] can estimate miss ratio curves
//! accurately, yielding the expected performance benefit from a larger
//! cache size").
//!
//! Spatial hash sampling at rate R: a key is tracked iff
//! `hash(key) mod P < R*P`. For tracked keys we measure LRU reuse
//! distances (distinct tracked keys touched since the previous access,
//! scaled by 1/R) and build a histogram; the MRC is its complementary
//! CDF over cache sizes.

use std::collections::HashMap;

/// Fixed-point modulus for the sampling filter.
const P: u64 = 1 << 24;

fn key_hash(key: &[u8]) -> u64 {
    // Shared FNV-1a 64, plus a final avalanche for better low-bit
    // uniformity (the sampling filter keys off the low bits).
    let mut z = crate::util::hash::fnv1a_64(key);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Online MRC estimator.
pub struct MrcProfiler {
    threshold: u64,
    rate: f64,
    /// Tracked key -> logical time of last access.
    last_access: HashMap<u64, u64>,
    /// Sorted logical times of tracked keys (for reuse-distance ranks).
    /// Kept as a Fenwick tree over time buckets.
    fenwick: Fenwick,
    clock: u64,
    /// Histogram of scaled reuse distances, bucketed by `bucket_keys`.
    pub histogram: Vec<u64>,
    bucket_keys: u64,
    /// Accesses to never-seen tracked keys (cold misses).
    cold: u64,
    total_sampled: u64,
    pub total_accesses: u64,
}

/// Fenwick tree over logical-time slots, supporting point update and
/// suffix count (how many tracked keys were accessed after time t).
struct Fenwick {
    tree: Vec<u64>,
}

impl Fenwick {
    fn new(capacity: usize) -> Self {
        Fenwick { tree: vec![0; capacity + 1] }
    }
    fn ensure(&mut self, idx: usize) {
        if idx + 1 >= self.tree.len() {
            self.tree.resize((idx + 2).next_power_of_two(), 0);
        }
    }
    fn add(&mut self, mut i: usize, delta: i64) {
        self.ensure(i);
        i += 1;
        while i < self.tree.len() {
            self.tree[i] = (self.tree[i] as i64 + delta) as u64;
            i += i & i.wrapping_neg();
        }
    }
    /// Count of live entries with time <= i.
    fn prefix(&self, mut i: usize) -> u64 {
        i = (i + 1).min(self.tree.len() - 1);
        let mut s = 0;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
    fn total(&self) -> u64 {
        self.prefix(self.tree.len() - 2)
    }
}

impl MrcProfiler {
    /// `rate` in (0, 1]: fraction of the key space sampled.
    /// `bucket_keys`: histogram bucket width in (unscaled) key counts.
    pub fn new(rate: f64, bucket_keys: u64, max_buckets: usize) -> Self {
        assert!(rate > 0.0 && rate <= 1.0);
        MrcProfiler {
            threshold: (rate * P as f64) as u64,
            rate,
            last_access: HashMap::new(),
            fenwick: Fenwick::new(1024),
            clock: 0,
            histogram: vec![0; max_buckets + 1],
            bucket_keys,
            cold: 0,
            total_sampled: 0,
            total_accesses: 0,
        }
    }

    /// Record one key access.
    pub fn record(&mut self, key: &[u8]) {
        self.total_accesses += 1;
        let h = key_hash(key);
        if h % P >= self.threshold {
            return;
        }
        self.total_sampled += 1;
        self.clock += 1;
        let t = self.clock;
        match self.last_access.insert(h, t) {
            None => {
                self.cold += 1;
            }
            Some(prev) => {
                // Distinct tracked keys accessed since prev = live entries
                // with last-access time > prev.
                let after = self.fenwick.total() - self.fenwick.prefix(prev as usize);
                let scaled = (after as f64 / self.rate) as u64;
                let bucket =
                    ((scaled / self.bucket_keys) as usize).min(self.histogram.len() - 1);
                self.histogram[bucket] += 1;
                self.fenwick.add(prev as usize, -1);
            }
        }
        self.fenwick.add(t as usize, 1);
    }

    /// Miss ratio curve over cache sizes measured in *keys*:
    /// `mrc[b]` = estimated miss ratio with capacity `b * bucket_keys`.
    pub fn mrc(&self) -> Vec<f64> {
        let reuse_total: u64 = self.histogram.iter().sum();
        let denom = (reuse_total + self.cold) as f64;
        if denom == 0.0 {
            return vec![1.0; self.histogram.len()];
        }
        let mut hits_cum = 0u64;
        self.histogram
            .iter()
            .map(|&c| {
                let mr = 1.0 - hits_cum as f64 / denom;
                hits_cum += c;
                mr
            })
            .collect()
    }

    pub fn sampled_fraction(&self) -> f64 {
        if self.total_accesses == 0 {
            0.0
        } else {
            self.total_sampled as f64 / self.total_accesses as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Zipfian};

    /// Exact LRU stack-distance simulation for comparison.
    fn exact_miss_ratios(accesses: &[u64], capacities: &[usize]) -> Vec<f64> {
        let mut results = Vec::new();
        for &cap in capacities {
            let mut stack: Vec<u64> = Vec::new();
            let mut misses = 0usize;
            for &k in accesses {
                if let Some(pos) = stack.iter().position(|&x| x == k) {
                    if pos >= cap {
                        misses += 1;
                    }
                    stack.remove(pos);
                } else {
                    misses += 1;
                }
                stack.insert(0, k);
            }
            results.push(misses as f64 / accesses.len() as f64);
        }
        results
    }

    #[test]
    fn full_rate_matches_exact_lru() {
        // rate=1.0: the profiler IS an exact reuse-distance counter.
        let mut rng = Rng::new(3);
        let zipf = Zipfian::new(500, 0.8);
        let accesses: Vec<u64> = (0..20_000).map(|_| zipf.sample(&mut rng)).collect();

        let mut prof = MrcProfiler::new(1.0, 10, 100);
        for &k in &accesses {
            prof.record(&k.to_le_bytes());
        }
        let mrc = prof.mrc();
        let caps = [50usize, 100, 200, 400];
        let exact = exact_miss_ratios(&accesses, &caps);
        for (i, &cap) in caps.iter().enumerate() {
            let est = mrc[cap / 10];
            assert!(
                (est - exact[i]).abs() < 0.06,
                "cap {cap}: est {est} exact {}",
                exact[i]
            );
        }
    }

    #[test]
    fn sampled_rate_close_to_exact() {
        let mut rng = Rng::new(9);
        let zipf = Zipfian::new(2000, 0.75);
        let accesses: Vec<u64> = (0..200_000).map(|_| zipf.sample(&mut rng)).collect();

        let mut prof = MrcProfiler::new(0.1, 50, 100);
        for &k in &accesses {
            prof.record(&k.to_le_bytes());
        }
        assert!((prof.sampled_fraction() - 0.1).abs() < 0.03);
        let mrc = prof.mrc();
        let caps = [200usize, 500, 1000];
        let exact = exact_miss_ratios(&accesses, &caps);
        for (i, &cap) in caps.iter().enumerate() {
            let est = mrc[cap / 50];
            assert!(
                (est - exact[i]).abs() < 0.1,
                "cap {cap}: est {est} exact {}",
                exact[i]
            );
        }
    }

    #[test]
    fn mrc_monotone() {
        let mut rng = Rng::new(5);
        let zipf = Zipfian::new(300, 0.7);
        let mut prof = MrcProfiler::new(0.5, 10, 50);
        for _ in 0..50_000 {
            prof.record(&zipf.sample(&mut rng).to_le_bytes());
        }
        let mrc = prof.mrc();
        for w in mrc.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!(mrc[0] > 0.9); // ~no cache -> ~all misses
    }

    #[test]
    fn empty_profile() {
        let prof = MrcProfiler::new(0.1, 10, 10);
        assert_eq!(prof.mrc(), vec![1.0; 11]);
        assert_eq!(prof.sampled_fraction(), 0.0);
    }
}

//! End-to-end request tracing (protocol v6) with a crash-dump flight
//! recorder: the attribution layer the metrics plane cannot provide.
//!
//! Histograms (PR 5) prove *that* a tail exists; this module says
//! *which* consumer call, routed to *which* producer under *which*
//! lease, produced it. Three pieces:
//!
//! * **Span rings** — every thread owns a fixed-capacity lock-free ring
//!   of [`Span`]s. Recording is one relaxed atomic index bump plus eight
//!   relaxed word stores: no locks, no allocation, no syscalls on the
//!   hot path. Old spans are overwritten in place (a flight recorder,
//!   not a log); a concurrent cold read may observe a torn span, which
//!   the read path filters by validating the packed role/op/status word.
//! * **Ambient trace context** — a thread-local `(trace_id,
//!   parent_span)` pair. [`SpanGuard::root`] opens a new trace,
//!   [`SpanGuard::child`] nests under whatever is current (a no-op when
//!   no trace is active, so instrumented layers cost one TLS read when
//!   called outside a trace), and [`adopt`] installs a context received
//!   from the wire — how a producer's shard span ends up parented to
//!   the consumer's wire span. Guards record on drop with the measured
//!   duration and restore the previous context.
//! * **Flight recorder** — on anomaly (integrity failure, `NotPrimary`
//!   storm, broker takeover, p99 SLO breach) a role calls [`dump`]:
//!   the last [`DUMP_SPANS`] spans across all rings are written as one
//!   JSONL file to the configured dir (unset = disabled), throttled per
//!   (role, reason) so an anomaly storm cannot flood the disk. The
//!   `TraceQuery` control verb serves the same rings remotely.
//!
//! Ids are 64-bit, generated from a splitmix-mixed global counter
//! seeded with wall clock and pid, so two processes in one topology do
//! not collide. Id 0 is reserved ("no trace"): frames and control verbs
//! carry 0 when no trace is active, and every consumer treats 0 as
//! "untraced".

use std::cell::Cell;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Spans each per-thread ring holds before wrapping.
pub const RING_SPANS: usize = 1024;

/// Most spans one flight-recorder dump (or `TraceQuery` answer) carries.
pub const DUMP_SPANS: usize = 512;

/// `u64` words in one packed span (the ring slot / wire encoding unit).
pub const SPAN_WORDS: usize = 8;

/// Minimum gap between two dumps for the same (role, reason) pair.
const DUMP_THROTTLE: Duration = Duration::from_millis(250);

/// Which marketplace role recorded a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Role {
    Consumer = 1,
    Producer = 2,
    Broker = 3,
}

impl Role {
    pub fn as_str(self) -> &'static str {
        match self {
            Role::Consumer => "consumer",
            Role::Producer => "producer",
            Role::Broker => "broker",
        }
    }

    fn from_u8(b: u8) -> Option<Role> {
        Some(match b {
            1 => Role::Consumer,
            2 => Role::Producer,
            3 => Role::Broker,
            _ => return None,
        })
    }
}

/// What a span measured. The first six mirror the consumer API; the
/// rest name the causal hops one call fans into: pool route → wire →
/// producer shard → seal/verify, plus the market verbs a trace id rides
/// on the control plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    Get = 1,
    Put = 2,
    Delete = 3,
    MultiGet = 4,
    MultiPut = 5,
    MultiDelete = 6,
    Ping = 7,
    /// Consumer-pool slot routing for one call.
    Route = 8,
    /// One framed exchange on a data-plane connection.
    Wire = 9,
    /// Producer-side service of one data frame (shard lock + execute).
    Shard = 10,
    /// Envelope seal (encrypt + hash) of one value.
    Seal = 11,
    /// Envelope verify (+ decrypt) of one fetched value.
    Verify = 12,
    /// `RequestSlabs` handling (consumer side and broker side).
    Grant = 13,
    /// Lease renewal.
    Renew = 14,
    /// Lease revocation.
    Revoke = 15,
}

impl Op {
    pub fn as_str(self) -> &'static str {
        match self {
            Op::Get => "get",
            Op::Put => "put",
            Op::Delete => "delete",
            Op::MultiGet => "multi_get",
            Op::MultiPut => "multi_put",
            Op::MultiDelete => "multi_delete",
            Op::Ping => "ping",
            Op::Route => "route",
            Op::Wire => "wire",
            Op::Shard => "shard",
            Op::Seal => "seal",
            Op::Verify => "verify",
            Op::Grant => "grant",
            Op::Renew => "renew",
            Op::Revoke => "revoke",
        }
    }

    fn from_u8(b: u8) -> Option<Op> {
        Some(match b {
            1 => Op::Get,
            2 => Op::Put,
            3 => Op::Delete,
            4 => Op::MultiGet,
            5 => Op::MultiPut,
            6 => Op::MultiDelete,
            7 => Op::Ping,
            8 => Op::Route,
            9 => Op::Wire,
            10 => Op::Shard,
            11 => Op::Seal,
            12 => Op::Verify,
            13 => Op::Grant,
            14 => Op::Renew,
            15 => Op::Revoke,
            _ => return None,
        })
    }
}

/// How the spanned operation ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Status {
    Ok = 0,
    Miss = 1,
    Error = 2,
}

impl Status {
    pub fn as_str(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Miss => "miss",
            Status::Error => "error",
        }
    }

    fn from_u8(b: u8) -> Option<Status> {
        Some(match b {
            0 => Status::Ok,
            1 => Status::Miss,
            2 => Status::Error,
            _ => return None,
        })
    }
}

/// One recorded span. Packs to exactly [`SPAN_WORDS`] `u64` words — the
/// ring-slot form, the `Traces` wire form, and (rendered) the JSONL
/// dump form are all this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub trace_id: u64,
    pub span_id: u64,
    /// Parent span id (0 = trace root).
    pub parent: u64,
    pub role: Role,
    pub op: Op,
    pub status: Status,
    /// Start time, µs since this process's trace epoch.
    pub t_start_us: u64,
    pub dur_us: u64,
    /// Lease the op ran under (0 = none/unknown).
    pub lease_id: u64,
    /// Producer the op touched (0 = none/unknown).
    pub producer_id: u64,
}

impl Span {
    /// Pack into the 8-word form: `[trace, span, parent, role|op<<8|
    /// status<<16, t_start_us, dur_us, lease, producer]`.
    #[inline]
    pub fn to_words(&self) -> [u64; SPAN_WORDS] {
        let tags =
            self.role as u64 | (self.op as u64) << 8 | (self.status as u64) << 16;
        [
            self.trace_id,
            self.span_id,
            self.parent,
            tags,
            self.t_start_us,
            self.dur_us,
            self.lease_id,
            self.producer_id,
        ]
    }

    /// Unpack; `None` when the role/op/status byte is invalid or the
    /// tag word carries extra bits (a torn ring slot or hostile frame).
    pub fn from_words(w: &[u64; SPAN_WORDS]) -> Option<Span> {
        if w[3] >> 24 != 0 {
            return None;
        }
        Some(Span {
            trace_id: w[0],
            span_id: w[1],
            parent: w[2],
            role: Role::from_u8(w[3] as u8)?,
            op: Op::from_u8((w[3] >> 8) as u8)?,
            status: Status::from_u8((w[3] >> 16) as u8)?,
            t_start_us: w[4],
            dur_us: w[5],
            lease_id: w[6],
            producer_id: w[7],
        })
    }

    /// One JSONL line, fixed key order (dumps diff cleanly across runs).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"trace_id\":{},\"span_id\":{},\"parent\":{},\"role\":\"{}\",\
             \"op\":\"{}\",\"t_start_us\":{},\"dur_us\":{},\"lease_id\":{},\
             \"producer_id\":{},\"status\":\"{}\"}}",
            self.trace_id,
            self.span_id,
            self.parent,
            self.role.as_str(),
            self.op.as_str(),
            self.t_start_us,
            self.dur_us,
            self.lease_id,
            self.producer_id,
            self.status.as_str()
        )
    }
}

/// One thread's span ring: `RING_SPANS` slots of `SPAN_WORDS` relaxed
/// atomics plus a monotonically increasing write index.
pub struct SpanRing {
    slots: Box<[AtomicU64]>,
    next: AtomicU64,
}

impl SpanRing {
    fn new() -> Arc<SpanRing> {
        Arc::new(SpanRing {
            slots: (0..RING_SPANS * SPAN_WORDS)
                .map(|_| AtomicU64::new(0))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            next: AtomicU64::new(0),
        })
    }

    /// Record one packed span: one index bump, eight word stores.
    // lint: no-alloc
    #[inline]
    fn record(&self, w: &[u64; SPAN_WORDS]) {
        let slot =
            (self.next.fetch_add(1, Ordering::Relaxed) as usize % RING_SPANS) * SPAN_WORDS;
        for (k, v) in w.iter().enumerate() {
            self.slots[slot + k].store(*v, Ordering::Relaxed);
        }
    }

    /// Append every currently readable span (invalid/torn slots are
    /// skipped — the wrap-overwrite race is benign by design).
    fn read_into(&self, out: &mut Vec<Span>) {
        let written = self.next.load(Ordering::Relaxed).min(RING_SPANS as u64) as usize;
        for s in 0..written {
            let mut w = [0u64; SPAN_WORDS];
            for (k, word) in w.iter_mut().enumerate() {
                *word = self.slots[s * SPAN_WORDS + k].load(Ordering::Relaxed);
            }
            if let Some(span) = Span::from_words(&w) {
                if span.span_id != 0 {
                    out.push(span);
                }
            }
        }
    }
}

/// Process-global ring registry: every thread's ring, registered on the
/// thread's first span. `recent_spans`/`dump` read all of them.
fn registry() -> &'static Mutex<Vec<Arc<SpanRing>>> {
    static R: OnceLock<Mutex<Vec<Arc<SpanRing>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    /// This thread's ring (registered globally on first use).
    static RING: Arc<SpanRing> = {
        let ring = SpanRing::new();
        registry().lock().unwrap().push(ring.clone());
        ring
    };
    /// Ambient (trace_id, parent_span) context; (0, 0) = no trace.
    static AMBIENT: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Globally enable/disable span recording (the bench harness measures
/// both states; disabled recording costs one relaxed load).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process's trace epoch: all `t_start_us` values count from here.
fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch.
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Splitmix64 finalizer — full-period mixing of the id counter.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn id_seed() -> u64 {
    static S: OnceLock<u64> = OnceLock::new();
    *S.get_or_init(|| {
        crate::util::clock::unix_nanos() ^ ((std::process::id() as u64) << 32)
    })
}

static NEXT_ID: AtomicU64 = AtomicU64::new(0);

/// A fresh nonzero 64-bit id (trace or span).
#[inline]
pub fn new_id() -> u64 {
    let n = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let id = mix(id_seed().wrapping_add(n.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    if id == 0 {
        1
    } else {
        id
    }
}

/// Record one finished span into this thread's ring (no-op when
/// disabled). Allocation-free after the thread's first span.
// lint: no-alloc
#[inline]
pub fn record(span: &Span) {
    if !enabled() {
        return;
    }
    let w = span.to_words();
    RING.with(|r| r.record(&w));
}

/// The ambient `(trace_id, parent_span)` — what an outgoing data frame
/// or control verb stamps as its trace context. `(0, 0)` = untraced.
#[inline]
pub fn current() -> (u64, u64) {
    AMBIENT.with(Cell::get)
}

/// Restores the previous ambient context on drop (see [`adopt`]).
pub struct AdoptGuard {
    prev: (u64, u64),
}

impl Drop for AdoptGuard {
    fn drop(&mut self) {
        AMBIENT.with(|c| c.set(self.prev));
    }
}

/// Install a trace context received from the wire as this thread's
/// ambient context — the server-side half of propagation: spans opened
/// while the guard lives parent under `(trace_id, parent_span)`.
pub fn adopt(trace_id: u64, parent_span: u64) -> AdoptGuard {
    let prev = current();
    AMBIENT.with(|c| c.set((trace_id, parent_span)));
    AdoptGuard { prev }
}

/// An open span: measures from construction to drop, then records and
/// restores the previous ambient context. While it lives, the ambient
/// parent is this span — children nest automatically.
pub struct SpanGuard {
    span: Option<Span>,
    prev: (u64, u64),
    t0: Instant,
}

impl SpanGuard {
    /// Open a new trace: fresh trace id, parent 0. Records even when no
    /// trace was active (this *starts* the causal chain).
    pub fn root(role: Role, op: Op) -> SpanGuard {
        Self::start(role, op, true)
    }

    /// Open a child of the ambient context. When no trace is active (or
    /// tracing is disabled) this is a recorded-nothing no-op, so
    /// instrumented inner layers cost one TLS read outside a trace.
    pub fn child(role: Role, op: Op) -> SpanGuard {
        Self::start(role, op, false)
    }

    fn start(role: Role, op: Op, is_root: bool) -> SpanGuard {
        let t0 = Instant::now();
        if !enabled() {
            return SpanGuard { span: None, prev: (0, 0), t0 };
        }
        let (ambient_trace, ambient_parent) = current();
        let (trace_id, parent) = if is_root {
            (new_id(), 0)
        } else if ambient_trace != 0 {
            (ambient_trace, ambient_parent)
        } else {
            return SpanGuard { span: None, prev: (0, 0), t0 };
        };
        let span_id = new_id();
        let prev = (ambient_trace, ambient_parent);
        AMBIENT.with(|c| c.set((trace_id, span_id)));
        SpanGuard {
            span: Some(Span {
                trace_id,
                span_id,
                parent,
                role,
                op,
                status: Status::Ok,
                t_start_us: now_us(),
                dur_us: 0,
                lease_id: 0,
                producer_id: 0,
            }),
            prev,
            t0,
        }
    }

    /// True when this guard will record a span on drop.
    pub fn is_active(&self) -> bool {
        self.span.is_some()
    }

    /// This guard's trace id (0 when inactive) — what control verbs and
    /// data frames carry.
    pub fn trace_id(&self) -> u64 {
        self.span.as_ref().map_or(0, |s| s.trace_id)
    }

    /// This guard's span id (0 when inactive).
    pub fn span_id(&self) -> u64 {
        self.span.as_ref().map_or(0, |s| s.span_id)
    }

    pub fn set_lease(&mut self, lease_id: u64) {
        if let Some(s) = self.span.as_mut() {
            s.lease_id = lease_id;
        }
    }

    pub fn set_producer(&mut self, producer_id: u64) {
        if let Some(s) = self.span.as_mut() {
            s.producer_id = producer_id;
        }
    }

    pub fn set_status(&mut self, status: Status) {
        if let Some(s) = self.span.as_mut() {
            s.status = status;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(mut s) = self.span.take() {
            s.dur_us = self.t0.elapsed().as_micros() as u64;
            record(&s);
            AMBIENT.with(|c| c.set(self.prev));
        }
    }
}

/// The newest `max` spans across every thread's ring, sorted by
/// `(t_start_us, span_id)`. Cold path: allocates and locks the
/// registry; serves `TraceQuery` and the flight recorder.
pub fn recent_spans(max: usize) -> Vec<Span> {
    let rings: Vec<Arc<SpanRing>> = registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in rings {
        ring.read_into(&mut out);
    }
    out.sort_by_key(|s| (s.t_start_us, s.span_id));
    if out.len() > max {
        out.drain(..out.len() - max);
    }
    out
}

struct DumpState {
    dir: Option<PathBuf>,
    last: BTreeMap<String, Instant>,
}

fn dump_state() -> &'static Mutex<DumpState> {
    static D: OnceLock<Mutex<DumpState>> = OnceLock::new();
    D.get_or_init(|| Mutex::new(DumpState { dir: None, last: BTreeMap::new() }))
}

static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Configure (or disable, with `None`) the flight-recorder dump dir.
/// The dir is created eagerly so a dump at anomaly time only writes.
pub fn set_dump_dir(dir: Option<&Path>) {
    if let Some(d) = dir {
        let _ = std::fs::create_dir_all(d);
    }
    dump_state().lock().unwrap().dir = dir.map(Path::to_path_buf);
}

/// The currently configured dump dir, if any.
pub fn dump_dir() -> Option<PathBuf> {
    dump_state().lock().unwrap().dir.clone()
}

/// Flight-recorder dump: write the last [`DUMP_SPANS`] spans as JSONL
/// to `{role}-{reason}-{seq}.jsonl` in the configured dir. Returns the
/// written path, or `None` when no dir is configured, the (role,
/// reason) pair dumped within [`DUMP_THROTTLE`], or the write failed
/// (an anomaly handler must never take its role down over a dump).
pub fn dump(role: &str, reason: &str) -> Option<PathBuf> {
    let dir = {
        let mut st = dump_state().lock().unwrap();
        let dir = st.dir.clone()?;
        let key = format!("{role}/{reason}");
        if let Some(t) = st.last.get(&key) {
            if t.elapsed() < DUMP_THROTTLE {
                return None;
            }
        }
        st.last.insert(key, Instant::now());
        dir
    };
    let spans = recent_spans(DUMP_SPANS);
    let seq = DUMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("{role}-{reason}-{seq}.jsonl"));
    let mut f = std::fs::File::create(&path).ok()?;
    for s in &spans {
        writeln!(f, "{}", s.to_json_line()).ok()?;
    }
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_words_round_trip_and_reject_torn() {
        let span = Span {
            trace_id: 7,
            span_id: 8,
            parent: 0,
            role: Role::Producer,
            op: Op::Shard,
            status: Status::Miss,
            t_start_us: 123,
            dur_us: 45,
            lease_id: 6,
            producer_id: 2,
        };
        let w = span.to_words();
        assert_eq!(Span::from_words(&w), Some(span));
        // Invalid role/op/status bytes and dirty upper tag bits are all
        // filtered (the torn-slot / hostile-frame defense).
        let mut bad = w;
        bad[3] = 0; // role 0
        assert_eq!(Span::from_words(&bad), None);
        bad[3] = 1 | (99 << 8); // op 99
        assert_eq!(Span::from_words(&bad), None);
        bad[3] = 1 | (1 << 8) | (9 << 16); // status 9
        assert_eq!(Span::from_words(&bad), None);
        bad[3] = w[3] | (1 << 40); // extra bits
        assert_eq!(Span::from_words(&bad), None);
    }

    #[test]
    fn json_line_has_fixed_key_order() {
        let span = Span {
            trace_id: 1,
            span_id: 2,
            parent: 0,
            role: Role::Consumer,
            op: Op::MultiGet,
            status: Status::Ok,
            t_start_us: 10,
            dur_us: 3,
            lease_id: 0,
            producer_id: 0,
        };
        let line = span.to_json_line();
        assert!(line.starts_with("{\"trace_id\":1,\"span_id\":2,\"parent\":0"), "{line}");
        assert!(line.contains("\"role\":\"consumer\""), "{line}");
        assert!(line.contains("\"op\":\"multi_get\""), "{line}");
        assert!(line.ends_with("\"status\":\"ok\"}"), "{line}");
    }

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let a = new_id();
        let b = new_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn guards_nest_restore_and_dump() {
        // One sequential test covers recording, nesting, adopt, ring
        // readback, and the dump path: the module's globals (rings,
        // ambient context) are exercised without cross-test races.
        assert_eq!(current(), (0, 0));
        let (root_trace, root_span, child_span);
        {
            let root = SpanGuard::root(Role::Consumer, Op::MultiGet);
            assert!(root.is_active());
            root_trace = root.trace_id();
            root_span = root.span_id();
            assert_eq!(current(), (root_trace, root_span));
            {
                let mut child = SpanGuard::child(Role::Consumer, Op::Wire);
                child.set_lease(77);
                child_span = child.span_id();
                assert_eq!(current(), (root_trace, child_span));
            }
            // Child restored the parent context.
            assert_eq!(current(), (root_trace, root_span));
        }
        assert_eq!(current(), (0, 0));

        // An adopted remote context parents a producer-side span.
        let shard_span;
        {
            let _adopted = adopt(root_trace, child_span);
            let mut g = SpanGuard::child(Role::Producer, Op::Shard);
            g.set_producer(3);
            shard_span = g.span_id();
            assert!(g.is_active());
        }
        assert_eq!(current(), (0, 0));

        let spans = recent_spans(DUMP_SPANS);
        let mine: Vec<&Span> =
            spans.iter().filter(|s| s.trace_id == root_trace).collect();
        assert_eq!(mine.len(), 3, "root + wire child + adopted shard");
        let root = mine.iter().find(|s| s.span_id == root_span).unwrap();
        assert_eq!(root.parent, 0);
        let wire = mine.iter().find(|s| s.span_id == child_span).unwrap();
        assert_eq!(wire.parent, root_span);
        assert_eq!(wire.lease_id, 77);
        let shard = mine.iter().find(|s| s.span_id == shard_span).unwrap();
        assert_eq!(shard.parent, child_span);
        assert_eq!(shard.producer_id, 3);
        assert_eq!(shard.role, Role::Producer);

        // A child without any ambient trace records nothing.
        let idle = SpanGuard::child(Role::Consumer, Op::Seal);
        assert!(!idle.is_active());
        assert_eq!(idle.trace_id(), 0);
        drop(idle);

        // Dump: JSONL to the configured dir, throttled per reason.
        let dir = std::env::temp_dir().join(format!("memtrade-trace-test-{root_trace:x}"));
        set_dump_dir(Some(&dir));
        let path = dump("consumer", "unit-test").expect("first dump must write");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(
            text.lines().any(|l| l.contains(&format!("\"trace_id\":{root_trace}"))),
            "dump must contain the recorded trace"
        );
        assert!(
            dump("consumer", "unit-test").is_none(),
            "same-reason dump inside the throttle window must be suppressed"
        );
        assert!(dump("consumer", "other-reason").is_some());
        set_dump_dir(None);
        assert!(dump("consumer", "unit-test-2").is_none(), "no dir = no dump");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ring_wraps_without_growing() {
        let ring = SpanRing::new();
        let span = Span {
            trace_id: 5,
            span_id: 6,
            parent: 0,
            role: Role::Broker,
            op: Op::Grant,
            status: Status::Ok,
            t_start_us: 1,
            dur_us: 1,
            lease_id: 0,
            producer_id: 0,
        };
        // Over-fill so the ring wraps; under Miri one lap past the end
        // proves the same thing at interpreter speed.
        let records = if cfg!(miri) { RING_SPANS + 32 } else { RING_SPANS * 3 };
        for _ in 0..records {
            ring.record(&span.to_words());
        }
        let mut out = Vec::new();
        ring.read_into(&mut out);
        assert_eq!(out.len(), RING_SPANS);
    }
}

//! The telemetry spine: one metrics plane shared by every layer of the
//! marketplace, from shard-lock hold times to broker placement feedback.
//!
//! Three live primitives — [`Counter`], [`Gauge`], and the lock-free
//! log-bucketed [`Histogram`] — plus a [`Registry`] of named instruments
//! and a serializable point-in-time [`MetricSet`]. Components that keep
//! plain stats structs (the KV store's `KvStats`, the secure client's
//! `SecureKvStats`, ...) join the same plane through [`Observe`]: they
//! render into a `MetricSet` under a prefix, and from there everything
//! shares one wire form (`StatsQuery`/`Stats` on the control plane), one
//! JSON form (the `BENCH_*.json` artifacts), and one text form
//! (`memtrade top`).
//!
//! Formatting helpers (`Table`, `gb`, ...) used to live here; they are
//! presentation, not telemetry, and moved to [`crate::util::fmt`].

pub mod hist;

pub use hist::{Histogram, HistogramSnapshot, HIST_BUCKETS};

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event counter (one relaxed atomic add per event).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Guarded decrement: saturates at zero instead of wrapping to
    /// 2^64 - 1. For the rare "un-count" corrections (e.g. a released
    /// slot is not a *lost* slot) where a racing path may not have
    /// recorded the increment being undone.
    pub fn dec_saturating(&self) {
        let _ =
            self.0.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Clone is a snapshot: the new counter starts at the observed value
/// (used by report structs that freeze stats at scenario end).
impl Clone for Counter {
    fn clone(&self) -> Self {
        Counter(AtomicU64::new(self.get()))
    }
}

/// Point-in-time signed level (bytes offered, slabs held, observed p99).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Clone for Gauge {
    fn clone(&self) -> Self {
        let g = Gauge::new();
        g.set(self.get());
        g
    }
}

/// One observed metric value in a [`MetricSet`].
#[derive(Clone, Debug, PartialEq)]
pub enum Metric {
    Counter(u64),
    Gauge(i64),
    Histogram(HistogramSnapshot),
}

/// A named, ordered snapshot of metrics: the unit that travels on the
/// wire (`StatsQuery` reply), renders to JSON (benches), and renders to
/// text (`memtrade top`). Names are dotted paths (`data.op_us`,
/// `producer.3.observed_p99_us`); `BTreeMap` keeps every rendering
/// deterministic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricSet {
    entries: BTreeMap<String, Metric>,
}

/// Join `prefix` and `name` with a dot (bare `name` when no prefix) —
/// the naming convention every [`Observe`] impl uses.
pub fn scoped(prefix: &str, name: &str) -> String {
    if prefix.is_empty() {
        name.to_string()
    } else {
        format!("{prefix}.{name}")
    }
}

impl MetricSet {
    pub fn new() -> Self {
        MetricSet::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn set(&mut self, name: impl Into<String>, value: Metric) {
        self.entries.insert(name.into(), value);
    }

    pub fn set_counter(&mut self, name: impl Into<String>, v: u64) {
        self.set(name, Metric::Counter(v));
    }

    pub fn set_gauge(&mut self, name: impl Into<String>, v: i64) {
        self.set(name, Metric::Gauge(v));
    }

    pub fn set_histogram(&mut self, name: impl Into<String>, s: HistogramSnapshot) {
        self.set(name, Metric::Histogram(s));
    }

    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.entries.get(name)
    }

    /// Counter value by name (also accepts a gauge, as its magnitude).
    pub fn counter(&self, name: &str) -> Option<u64> {
        match self.entries.get(name)? {
            Metric::Counter(v) => Some(*v),
            Metric::Gauge(v) => Some((*v).max(0) as u64),
            Metric::Histogram(_) => None,
        }
    }

    pub fn gauge(&self, name: &str) -> Option<i64> {
        match self.entries.get(name)? {
            Metric::Gauge(v) => Some(*v),
            Metric::Counter(v) => Some(*v as i64),
            Metric::Histogram(_) => None,
        }
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        match self.entries.get(name)? {
            Metric::Histogram(s) => Some(s),
            _ => None,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Metric)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// JSON object keyed by metric name (histograms nest their own
    /// object, see [`HistogramSnapshot::to_json`]).
    pub fn to_json(&self) -> String {
        let fields: Vec<String> = self
            .entries
            .iter()
            .map(|(name, m)| {
                let v = match m {
                    Metric::Counter(v) => v.to_string(),
                    Metric::Gauge(v) => v.to_string(),
                    Metric::Histogram(s) => s.to_json(),
                };
                format!("\"{name}\": {v}")
            })
            .collect();
        format!("{{{}}}", fields.join(", "))
    }

    /// Aligned text render, one metric per line.
    pub fn render(&self) -> String {
        let width = self.entries.keys().map(String::len).max().unwrap_or(0);
        let mut out = String::new();
        for (name, m) in &self.entries {
            let v = match m {
                Metric::Counter(v) => v.to_string(),
                Metric::Gauge(v) => v.to_string(),
                Metric::Histogram(s) => s.render(),
            };
            out.push_str(&format!("{name:<width$}  {v}\n"));
        }
        out
    }
}

/// Anything that can publish itself onto the metrics plane. Implemented
/// by the live [`Registry`] and by every legacy stats struct
/// (`KvStats`, `SecureKvStats`, `PoolStats`, `AgentStats`,
/// `BrokerStats`, `SiloStats`, `GuestStats`), so one `MetricSet` can
/// carry a whole process's telemetry.
pub trait Observe {
    /// Write this component's metrics into `out` under `prefix`
    /// (`""` = bare names).
    fn observe(&self, prefix: &str, out: &mut MetricSet);
}

/// A set of named live instruments. Lookup-or-create takes a short
/// mutex on a cold path; the returned `Arc` is then held by the hot
/// path, which touches only its own atomics.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::new()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::new()))
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::new()))
            .clone()
    }

    /// Snapshot every registered instrument into a [`MetricSet`].
    pub fn snapshot(&self) -> MetricSet {
        let mut out = MetricSet::new();
        self.observe("", &mut out);
        out
    }
}

impl Observe for Registry {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.set_counter(scoped(prefix, name), c.get());
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.set_gauge(scoped(prefix, name), g.get());
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.set_histogram(scoped(prefix, name), h.snapshot());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let snap = c.clone();
        c.inc();
        assert_eq!(snap.get(), 5);
        assert_eq!(c.get(), 6);

        let g = Gauge::new();
        g.set(-3);
        g.add(10);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn counter_decrement_saturates_at_zero() {
        // Regression shape for PoolStats::slots_lost: an un-count on a
        // counter that never counted must stay 0, not wrap to 2^64 - 1.
        let c = Counter::new();
        c.dec_saturating();
        assert_eq!(c.get(), 0);
        c.inc();
        c.dec_saturating();
        c.dec_saturating();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn registry_is_live_and_shared() {
        let r = Registry::new();
        let a = r.counter("ops");
        let b = r.counter("ops");
        a.inc();
        b.inc();
        assert_eq!(r.counter("ops").get(), 2);
        r.gauge("level").set(42);
        r.histogram("lat_us").record(100);
        let snap = r.snapshot();
        assert_eq!(snap.counter("ops"), Some(2));
        assert_eq!(snap.gauge("level"), Some(42));
        assert_eq!(snap.histogram("lat_us").unwrap().count(), 1);
    }

    #[test]
    fn registry_render_is_byte_stable_across_insertion_orders() {
        // Regression guard: `BENCH_*.json` and `StatsQuery` output must
        // not churn between runs. Both `Registry` and `MetricSet` sit on
        // BTreeMaps, so two registries built in opposite orders must
        // produce byte-identical JSON and text renders. If a future
        // refactor swaps in a hash map for speed, this test is the trip
        // wire.
        let names = ["data.op_us", "repl.lag", "ctrl.heartbeats", "byzantine.tampered"];
        let forward = Registry::new();
        let backward = Registry::new();
        for n in names {
            forward.counter(n).add(7);
        }
        for n in names.iter().rev() {
            backward.counter(n).add(7);
        }
        let (f, b) = (forward.snapshot(), backward.snapshot());
        assert_eq!(f.to_json(), b.to_json());
        assert_eq!(f.render(), b.render());
        let keys: Vec<&str> = f.iter().map(|(n, _)| n).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted, "snapshot iteration must be sorted");
    }

    #[test]
    fn metric_set_prefixing_render_and_json() {
        let r = Registry::new();
        r.counter("hits").add(7);
        let mut out = MetricSet::new();
        r.observe("store", &mut out);
        assert_eq!(out.counter("store.hits"), Some(7));
        let json = out.to_json();
        assert!(json.contains("\"store.hits\": 7"), "{json}");
        assert!(out.render().contains("store.hits"));
        // Deterministic ordering.
        let mut m = MetricSet::new();
        m.set_counter("b", 2);
        m.set_counter("a", 1);
        let names: Vec<&str> = m.iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a", "b"]);
    }
}

//! Lock-free log-bucketed latency histogram: the one latency instrument
//! every layer shares, from the producer store's per-op service time to
//! the broker's placement feedback and the `cargo bench` JSON artifacts.
//!
//! Design constraints (this sits on the hottest paths in the system):
//!
//! * `record(v)` is exactly **one** relaxed atomic add — no allocation,
//!   no locking, no floating point; `record_traced(v, trace)` adds at
//!   most one relaxed store (the bucket's **exemplar** trace id, so a
//!   tail bucket can name the trace that landed in it);
//! * fixed memory: 64 power-of-two buckets (bucket 0 holds zeros,
//!   bucket *i* holds `[2^(i-1), 2^i)`), so a histogram is 512 bytes of
//!   `AtomicU64` regardless of traffic (1 KiB with the exemplar slots);
//! * snapshots are plain `[u64; 64]` copies that support **deltas**
//!   (windowed rates: the producer agent heartbeats `snapshot - previous
//!   snapshot` so the broker sees the *last window's* p99, not the
//!   lifetime's), merging, p50/p90/p99/p999 with intra-bucket linear
//!   interpolation, and JSON + aligned-text rendering.
//!
//! Quantile error is bounded by the bucket width (< 2x, typically far
//! less with interpolation) — the right trade for a feedback signal and
//! trend tracking; exact-percentile needs are out of scope.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets (covers the full `u64` range).
pub const HIST_BUCKETS: usize = 64;

/// Bucket index of `v`: 0 for 0, else `floor(log2(v)) + 1`, clamped.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive-exclusive value range `[lo, hi)` of bucket `i`.
fn bucket_bounds(i: usize) -> (u64, u64) {
    match i {
        0 => (0, 1),
        _ => (1u64 << (i - 1), if i >= 63 { u64::MAX } else { 1u64 << i }),
    }
}

/// Shared, thread-safe histogram. Unit-agnostic: callers pick one unit
/// per instrument (microseconds on network paths, nanoseconds in the
/// benches) and name the metric accordingly (`op_us`, `seal_ns`, ...).
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; HIST_BUCKETS],
    /// Last trace id that landed in each bucket (0 = none) — the
    /// exemplar that lets `memtrade top` name a p99 offender. Written
    /// only by [`Histogram::record_traced`]; plain [`Histogram::record`]
    /// never touches it.
    exemplars: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Clone for Histogram {
    fn clone(&self) -> Self {
        let h = Histogram::new();
        for (dst, src) in h.counts.iter().zip(&self.counts) {
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        for (dst, src) in h.exemplars.iter().zip(&self.exemplars) {
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        h
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            exemplars: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one sample: a single relaxed atomic add.
    // lint: no-alloc
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// [`Histogram::record`] plus an exemplar: when `trace_id` is
    /// nonzero, pin it as the bucket's most recent trace — one extra
    /// relaxed store, still allocation- and lock-free. Last-writer-wins
    /// is deliberate: an exemplar is a *sample* of the bucket, and the
    /// freshest one is the most debuggable.
    // lint: no-alloc
    #[inline]
    pub fn record_traced(&self, v: u64, trace_id: u64) {
        let i = bucket_index(v);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        if trace_id != 0 {
            self.exemplars[i].store(trace_id, Ordering::Relaxed);
        }
    }

    /// Convenience for recording a `Duration` in microseconds.
    #[inline]
    pub fn record_elapsed_us(&self, since: std::time::Instant) {
        self.record(since.elapsed().as_micros() as u64);
    }

    /// Total samples recorded (sum over buckets).
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Fold another histogram's counts into this one. Exemplars: the
    /// other's fill buckets this one has no exemplar for.
    pub fn merge(&self, other: &Histogram) {
        for (dst, src) in self.counts.iter().zip(&other.counts) {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        for (dst, src) in self.exemplars.iter().zip(&other.exemplars) {
            let theirs = src.load(Ordering::Relaxed);
            if theirs != 0 && dst.load(Ordering::Relaxed) == 0 {
                dst.store(theirs, Ordering::Relaxed);
            }
        }
    }

    /// Consistent-enough copy of the bucket counts (individual loads are
    /// atomic; concurrent records may land between loads, which a delta
    /// of two snapshots absorbs as part of the next window).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|i| self.counts[i].load(Ordering::Relaxed)),
            exemplars: std::array::from_fn(|i| self.exemplars[i].load(Ordering::Relaxed)),
        }
    }
}

/// A point-in-time copy of a [`Histogram`]: plain counts supporting
/// deltas, merging, quantiles, and rendering.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub counts: [u64; HIST_BUCKETS],
    /// Per-bucket exemplar trace ids (0 = none), copied from the live
    /// histogram's pins at snapshot time.
    pub exemplars: [u64; HIST_BUCKETS],
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { counts: [0; HIST_BUCKETS], exemplars: [0; HIST_BUCKETS] }
    }
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// The window between `earlier` and `self`, bucket-wise. Saturating:
    /// a racing concurrent record can make one bucket's earlier load
    /// exceed the later one by an in-flight sample — that never
    /// underflows into a 2^64 phantom count.
    pub fn delta(&self, earlier: &HistogramSnapshot) -> HistogramSnapshot {
        HistogramSnapshot {
            counts: std::array::from_fn(|i| {
                self.counts[i].saturating_sub(earlier.counts[i])
            }),
            // The window keeps the *later* snapshot's exemplars: an
            // exemplar is last-writer-wins, so the freshest pin is the
            // right sample for the window that ends at `self`.
            exemplars: self.exemplars,
        }
    }

    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (dst, src) in self.counts.iter_mut().zip(&other.counts) {
            *dst += src;
        }
        for (dst, src) in self.exemplars.iter_mut().zip(&other.exemplars) {
            if *dst == 0 {
                *dst = *src;
            }
        }
    }

    /// Estimated q-quantile (q in [0, 1]), interpolating linearly inside
    /// the bucket holding the target rank. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - acc) as f64 / c as f64;
                return lo as f64 + frac * (hi - lo) as f64;
            }
            acc += c;
        }
        bucket_bounds(HIST_BUCKETS - 1).1 as f64
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Bucket-midpoint-weighted mean (same error bound as the buckets).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let (lo, hi) = bucket_bounds(i);
                c as f64 * (lo as f64 + hi as f64) / 2.0
            })
            .sum();
        sum / n as f64
    }

    /// Nonzero buckets as `(bucket_index, count)` pairs — the wire and
    /// JSON form (at most 64 entries, usually a handful).
    pub fn nonzero_buckets(&self) -> Vec<(u8, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i as u8, c))
            .collect()
    }

    /// Rebuild from `(bucket_index, count)` pairs (wire decode). Out-of-
    /// range indices are rejected by the caller (the codec bounds them);
    /// duplicate indices accumulate saturating, so a hostile frame
    /// repeating a bucket with huge counts cannot overflow (a debug
    /// panic / silent release wrap in a path hardened against exactly
    /// such frames).
    pub fn from_buckets(buckets: &[(u8, u64)]) -> HistogramSnapshot {
        Self::from_parts(buckets, &[])
    }

    /// Nonzero exemplar pins as `(bucket_index, trace_id)` pairs — the
    /// v6 wire form, alongside [`HistogramSnapshot::nonzero_buckets`].
    pub fn nonzero_exemplars(&self) -> Vec<(u8, u64)> {
        self.exemplars
            .iter()
            .enumerate()
            .filter(|(_, &t)| t > 0)
            .map(|(i, &t)| (i as u8, t))
            .collect()
    }

    /// Rebuild from bucket-count pairs plus exemplar pairs (v6 wire
    /// decode). Same hardening as [`HistogramSnapshot::from_buckets`];
    /// duplicate exemplar indices are last-writer-wins like the live
    /// instrument.
    pub fn from_parts(buckets: &[(u8, u64)], exemplars: &[(u8, u64)]) -> HistogramSnapshot {
        let mut s = HistogramSnapshot::default();
        for &(i, c) in buckets {
            if (i as usize) < HIST_BUCKETS {
                s.counts[i as usize] = s.counts[i as usize].saturating_add(c);
            }
        }
        for &(i, t) in exemplars {
            if (i as usize) < HIST_BUCKETS {
                s.exemplars[i as usize] = t;
            }
        }
        s
    }

    /// The exemplar trace id nearest the tail: the highest pinned bucket
    /// at or above the bucket holding the p99 rank. `None` when the
    /// histogram is empty or nothing at the tail was recorded traced —
    /// how `memtrade top` and the benches resolve "who was slow".
    pub fn p99_exemplar(&self) -> Option<u64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        let target = (0.99 * n as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        let mut p99_bucket = HIST_BUCKETS - 1;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                p99_bucket = i;
                break;
            }
        }
        (p99_bucket..HIST_BUCKETS)
            .rev()
            .find(|&i| self.exemplars[i] != 0)
            .map(|i| self.exemplars[i])
    }

    /// JSON object: count, quantiles, mean, and the nonzero buckets.
    pub fn to_json(&self) -> String {
        let buckets: Vec<String> = self
            .nonzero_buckets()
            .iter()
            .map(|(i, c)| format!("[{i},{c}]"))
            .collect();
        format!(
            "{{\"count\":{},\"mean\":{:.1},\"p50\":{:.1},\"p90\":{:.1},\"p99\":{:.1},\
             \"p999\":{:.1},\"buckets\":[{}]}}",
            self.count(),
            self.mean(),
            self.p50(),
            self.p90(),
            self.p99(),
            self.p999(),
            buckets.join(",")
        )
    }

    /// One-line text render for `memtrade top` and log output. When a
    /// tail exemplar is pinned, it is appended as `p99ex=<trace id>` so
    /// the worst offender is nameable straight from the top view.
    pub fn render(&self) -> String {
        let base = format!(
            "n={} mean={:.1} p50={:.1} p90={:.1} p99={:.1} p999={:.1}",
            self.count(),
            self.mean(),
            self.p50(),
            self.p90(),
            self.p99(),
            self.p999()
        );
        match self.p99_exemplar() {
            Some(t) => format!("{base} p99ex={t:#018x}"),
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn bucket_layout() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
        for v in [0u64, 1, 2, 3, 9, 1000, 1 << 40] {
            let (lo, hi) = bucket_bounds(bucket_index(v));
            assert!(lo <= v && (v < hi || hi == u64::MAX), "v={v} lo={lo} hi={hi}");
        }
    }

    #[test]
    fn record_count_quantiles() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 1000);
        // Bucketed quantiles are within a bucket width of the truth.
        let p50 = s.p50();
        assert!((250.0..=1024.0).contains(&p50), "p50={p50}");
        assert!(s.p99() >= s.p90() && s.p90() >= s.p50());
        assert!(s.quantile(1.0) >= 512.0);
        assert!(s.mean() > 0.0);
    }

    #[test]
    fn zero_and_empty() {
        let h = Histogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0.0);
        h.record(0);
        let s = h.snapshot();
        assert_eq!(s.count(), 1);
        assert_eq!(s.counts[0], 1);
        assert!(s.quantile(0.5) < 1.0);
    }

    #[test]
    fn delta_is_the_window() {
        let h = Histogram::new();
        for _ in 0..10 {
            h.record(100);
        }
        let s1 = h.snapshot();
        for _ in 0..5 {
            h.record(100_000);
        }
        let d = h.snapshot().delta(&s1);
        assert_eq!(d.count(), 5);
        // The window's p50 reflects only the new (slow) samples.
        assert!(d.p50() >= 65536.0, "window p50 = {}", d.p50());
        // Saturating: a delta the wrong way around never underflows.
        let backwards = s1.delta(&h.snapshot());
        assert!(backwards.counts.iter().all(|&c| c < 1 << 32));
    }

    #[test]
    fn merge_conserves() {
        let a = Histogram::new();
        let b = Histogram::new();
        for i in 0..100u64 {
            a.record(i);
            b.record(i * 7);
        }
        let n = a.count() + b.count();
        a.merge(&b);
        assert_eq!(a.count(), n);
        let mut sa = a.snapshot();
        sa.merge(&b.snapshot());
        assert_eq!(sa.count(), n + b.count());
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        // Reduced under Miri (the CI `miri` job runs this to check the
        // relaxed-atomic recording for UB); full-size natively.
        const THREADS: u64 = if cfg!(miri) { 4 } else { 8 };
        const PER_THREAD: u64 = if cfg!(miri) { 250 } else { 10_000 };
        let h = Arc::new(Histogram::new());
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..PER_THREAD {
                        h.record(t * 1000 + i % 997);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), THREADS * PER_THREAD);
    }

    #[test]
    fn wire_form_round_trips() {
        let h = Histogram::new();
        for v in [0u64, 1, 5, 5, 900, 900, 900, 1 << 33] {
            h.record(v);
        }
        let s = h.snapshot();
        let rebuilt = HistogramSnapshot::from_buckets(&s.nonzero_buckets());
        assert_eq!(rebuilt, s);
        let json = s.to_json();
        assert!(json.contains("\"count\":8"), "{json}");
        assert!(s.render().contains("n=8"));
    }

    #[test]
    fn exemplars_pin_resolve_and_round_trip() {
        let h = Histogram::new();
        for _ in 0..99 {
            h.record(10); // fast bulk, untraced
        }
        h.record_traced(90_000, 0xCAFE); // the tail sample, traced
        h.record_traced(9, 0); // trace id 0 must pin nothing
        let s = h.snapshot();
        assert_eq!(s.count(), 101);
        assert_eq!(s.p99_exemplar(), Some(0xCAFE), "tail bucket names its trace");
        assert!(s.render().contains("p99ex=0x000000000000cafe"), "{}", s.render());
        // Wire round trip carries exemplars; delta keeps the later pins.
        let rebuilt =
            HistogramSnapshot::from_parts(&s.nonzero_buckets(), &s.nonzero_exemplars());
        assert_eq!(rebuilt, s);
        let d = s.delta(&HistogramSnapshot::default());
        assert_eq!(d.p99_exemplar(), Some(0xCAFE));
        // An untraced histogram resolves no exemplar and renders none.
        let plain = Histogram::new();
        plain.record(7);
        assert_eq!(plain.snapshot().p99_exemplar(), None);
        assert!(!plain.snapshot().render().contains("p99ex"));
    }

    #[test]
    fn exemplar_merge_prefers_existing_pins() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record_traced(100, 5);
        b.record_traced(100, 6);
        b.record_traced(1 << 30, 7);
        a.merge(&b);
        let s = a.snapshot();
        assert_eq!(s.exemplars[bucket_index(100)], 5, "a's own pin survives");
        assert_eq!(s.exemplars[bucket_index(1 << 30)], 7, "b fills a's empty bucket");
    }
}

//! Chaos scenarios: the full marketplace (broker daemon + two producer
//! agents + lease-aware consumer pool, all over real TCP) run under a
//! seeded fault schedule, with the paper's resilience invariants
//! checked machine-readably.
//!
//! One scenario = one [`ChaosConfig`] (a seed plus a [`ChaosMix`] of
//! fault families). The runner:
//!
//!  1. derives per-plane [`FaultPlan`]s (and optionally a Byzantine
//!     producer) from the seed,
//!  2. boots the topology and provisions the pool,
//!  3. drives secure PUT/GET traffic while the faults run — optionally
//!     killing a producer mid-run, racing renewals against forged
//!     revocations, or killing the *primary broker* under a warm
//!     standby (`failover`) so takeover and client failover run under
//!     load,
//!  4. disarms every fault source and measures reconvergence back to
//!     target capacity,
//!  5. sweeps the working set twice to check the invariants.
//!
//! Invariants ([`ChaosOutcome::invariant_violations`]):
//!
//!  * **No panic** — the runner returning at all is the check; a panic
//!    anywhere in the stack fails the calling test/CLI.
//!  * **Zero integrity escapes** — every GET that *verifies* must
//!    return exactly the bytes that were PUT; tampering and corruption
//!    must surface as `BadHash`/`BadCiphertext` misses, never as wrong
//!    data ([`ChaosOutcome::integrity_escapes`]).
//!  * **No lost acknowledged writes on surviving producers** — after
//!    faults stop, a key that reads back once must keep reading back
//!    ([`ChaosOutcome::lost_acked_writes`]).
//!  * **Reconvergence** — the pool returns to its target capacity once
//!    faults stop ([`ChaosOutcome::reconverged`],
//!    [`ChaosOutcome::recovery_ms`]).
//!
//! Reproducibility: every fault decision comes from RNG streams that
//! are pure functions of the seed and a per-connection index (see
//! [`crate::net::faults`]), so a red run is replayed with
//! `memtrade chaos --seed <seed> --mix <mix>`. Thread/timing
//! interleavings still vary run to run — the *schedules* are what the
//! seed pins down.

use crate::consumer::client::SecureKv;
use crate::core::config::BrokerConfig;
use crate::core::SimTime;
use crate::market::{
    BrokerServer, BrokerServerConfig, PoolStats, ProducerAgent, ProducerAgentConfig,
    RemotePool, RemotePoolConfig,
};
use crate::net::control::{CtrlClient, CtrlRequest};
use crate::net::faults::{ByzantineSpec, FaultPlan, FaultSpec};
use crate::trace;
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// 1 MB slabs keep grants cheap and scenarios fast.
const SLAB: u64 = 1 << 20;
/// Slabs per producer agent.
const AGENT_SLABS: u64 = 16;
/// Slabs the pool holds at target (≤ one agent's capacity, so a
/// mid-run kill still leaves enough for full reconvergence).
const TARGET_SLABS: u32 = 12;

/// Which fault families a scenario runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosMix {
    /// Seeded faults on every accepted broker control connection.
    pub control_faults: bool,
    /// Seeded faults on every consumer-pool data connection.
    pub data_faults: bool,
    /// Both producers serve a seeded fraction of GET hits tampered
    /// (both, because placement may land every lease on one producer).
    pub byzantine: bool,
    /// Kill producer 1 (no deregister) halfway through the fault phase.
    pub kill_producer: bool,
    /// Race renewals against forged lease revocations on guessed ids.
    pub revoke_race: bool,
    /// Boot a warm standby broker and kill the primary halfway through
    /// the fault phase: the standby must take over and every client
    /// must fail over to it (mix name `failover`).
    pub kill_broker: bool,
}

impl ChaosMix {
    /// Nothing at all — the baseline the bench compares against.
    pub fn clean() -> Self {
        ChaosMix::default()
    }

    /// Every fault family at once: the bench's standard mix.
    pub fn standard() -> Self {
        ChaosMix {
            control_faults: true,
            data_faults: true,
            byzantine: true,
            kill_producer: true,
            revoke_race: true,
        }
    }

    /// Broker failover alone: kill the primary mid-run and demand the
    /// warm standby takes over with zero invariant violations.
    pub fn failover() -> Self {
        ChaosMix { kill_broker: true, ..Default::default() }
    }

    /// Parse a CLI mix name: `clean`, `standard`, or any `+`-joined
    /// combination of fault families (`control`, `data`, `byzantine`,
    /// `kill`, `race`, `failover` — e.g. `data+kill`). `None` for an
    /// unknown name. Round-trips with [`Self::label`], so a printed
    /// reproduction command always parses back to the mix that ran.
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "clean" => return Some(Self::clean()),
            "standard" => return Some(Self::standard()),
            _ => {}
        }
        let mut mix = ChaosMix::default();
        for part in name.split('+') {
            match part {
                "control" => mix.control_faults = true,
                "data" => mix.data_faults = true,
                "byzantine" => mix.byzantine = true,
                "kill" => mix.kill_producer = true,
                "race" => mix.revoke_race = true,
                "failover" => mix.kill_broker = true,
                _ => return None,
            }
        }
        Some(mix)
    }

    pub const NAMES: &'static [&'static str] =
        &["clean", "standard", "control", "data", "byzantine", "kill", "race", "failover"];

    /// Canonical printable name; [`Self::from_name`] parses it back.
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.control_faults {
            parts.push("control");
        }
        if self.data_faults {
            parts.push("data");
        }
        if self.byzantine {
            parts.push("byzantine");
        }
        if self.kill_producer {
            parts.push("kill");
        }
        if self.revoke_race {
            parts.push("race");
        }
        if self.kill_broker {
            parts.push("failover");
        }
        if parts.is_empty() {
            "clean".to_string()
        } else {
            parts.join("+")
        }
    }
}

/// One seeded chaos scenario.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    pub seed: u64,
    pub mix: ChaosMix,
    /// Working-set keys (always re-put with the same per-key value, so
    /// any verified GET has exactly one legal answer).
    pub keys: u32,
    pub value_bytes: usize,
    /// Data operations driven during the fault phase.
    pub fault_ops: u64,
    /// Flight-recorder dump directory for every role in the scenario
    /// (all roles share this process, so one dir collects them all).
    /// `None` leaves the process-global dump dir untouched.
    pub dump_dir: Option<PathBuf>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 1,
            mix: ChaosMix::standard(),
            keys: 150,
            value_bytes: 256,
            fault_ops: 400,
            dump_dir: None,
        }
    }
}

/// What one scenario observed; see the module doc for the invariants.
#[derive(Clone, Debug)]
pub struct ChaosOutcome {
    pub seed: u64,
    /// Printable schedule descriptor (mix + derived fault rates).
    pub schedule: String,
    /// Data ops driven during the fault phase, and their throughput.
    pub ops: u64,
    pub ops_per_sec: f64,
    pub hits: u64,
    pub misses: u64,
    /// Tampered/corrupted responses the envelope rejected (good).
    pub integrity_failures: u64,
    /// Verified GETs that returned wrong bytes (must be zero).
    pub integrity_escapes: u64,
    /// Responses the Byzantine producer actually served tampered.
    pub tampered: u64,
    /// Keys that read back after reconvergence and then vanished.
    pub lost_acked_writes: u64,
    /// Pool back at target capacity after faults stopped.
    pub reconverged: bool,
    /// Faults-stop → reconverged, in milliseconds (NaN if never).
    pub recovery_ms: f64,
    pub held_slabs_after: u32,
    /// Standby takeovers observed (`None` = scenario had no standby).
    /// A `failover` mix must see exactly one.
    pub broker_takeovers: Option<u64>,
    pub pool_stats: PoolStats,
    /// Flight-recorder dumps found in `dump_dir` after the run (empty
    /// when no dir was configured or no anomaly fired).
    pub dump_files: Vec<PathBuf>,
}

impl ChaosOutcome {
    /// Human-readable invariant violations; empty = scenario passed.
    pub fn invariant_violations(&self) -> Vec<String> {
        let mut v = Vec::new();
        if self.integrity_escapes > 0 {
            v.push(format!(
                "{} integrity escape(s): a verified GET returned wrong bytes",
                self.integrity_escapes
            ));
        }
        if self.lost_acked_writes > 0 {
            v.push(format!(
                "{} acknowledged write(s) lost on surviving producers after faults stopped",
                self.lost_acked_writes
            ));
        }
        if !self.reconverged {
            v.push(format!(
                "pool never reconverged to {TARGET_SLABS} slabs (held {})",
                self.held_slabs_after
            ));
        }
        if self.broker_takeovers == Some(0) {
            v.push("standby broker never took over after the primary was killed".to_string());
        }
        v
    }

    pub fn report(&self) -> String {
        format!(
            "seed={} [{}]\n  ops {} ({:.0} ops/s) | hits {} misses {} | integrity: \
             {} caught, {} escaped, {} tampered\n  lost acked writes {} | reconverged {} \
             in {:.0} ms (held {}/{TARGET_SLABS}, takeovers {:?}) | pool: grants {} lost {} \
             renewals {} io_errs {} dead_calls {} ctrl_errs {}",
            self.seed,
            self.schedule,
            self.ops,
            self.ops_per_sec,
            self.hits,
            self.misses,
            self.integrity_failures,
            self.integrity_escapes,
            self.tampered,
            self.lost_acked_writes,
            self.reconverged,
            self.recovery_ms,
            self.held_slabs_after,
            self.broker_takeovers,
            self.pool_stats.grants.get(),
            self.pool_stats.slots_lost.get(),
            self.pool_stats.renewals.get(),
            self.pool_stats.io_errors.get(),
            self.pool_stats.dead_calls.get(),
            self.pool_stats.control_errors.get(),
        )
    }
}

/// Derive one direction-pair of fault rates from the scenario RNG.
/// Rates are kept in ranges where the system should stay *degraded but
/// live*; the disarm phase then demands full recovery.
fn derive_spec(rng: &mut Rng) -> FaultSpec {
    FaultSpec {
        drop_p: rng.uniform(0.0, 0.04),
        delay_p: rng.uniform(0.0, 0.08),
        delay_max_ms: 1 + rng.below(12),
        disconnect_p: rng.uniform(0.0, 0.012),
        truncate_p: rng.uniform(0.0, 0.02),
        duplicate_p: rng.uniform(0.0, 0.03),
        bitflip_p: rng.uniform(0.0, 0.025),
    }
}

fn spec_label(s: &FaultSpec) -> String {
    format!(
        "drop={:.3} delay={:.3}/{}ms disc={:.4} trunc={:.3} dup={:.3} flip={:.3}",
        s.drop_p, s.delay_p, s.delay_max_ms, s.disconnect_p, s.truncate_p, s.duplicate_p,
        s.bitflip_p
    )
}

/// The one legal value for key `k` under `seed`: re-puts are always
/// byte-identical, so a verified GET has exactly one correct answer.
fn value_for(seed: u64, k: u32, len: usize) -> Vec<u8> {
    let mut rng = Rng::new(seed ^ 0x7A1E ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

fn key_for(k: u32) -> Vec<u8> {
    format!("ck{k}").into_bytes()
}

/// Spin until `cond` holds or `timeout` passes; true if it held.
fn wait_for(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

/// Run one scenario end to end. Panics only on harness failures (bind
/// errors, a broker that never comes up *without* faults installed);
/// system misbehavior lands in the outcome instead.
pub fn run_chaos(cfg: &ChaosConfig) -> ChaosOutcome {
    let mut rng = Rng::new(cfg.seed ^ 0xC4A0_5000);

    // Arm the flight recorder before any role boots, so the first
    // anomaly of the run already has somewhere to dump. Only set when
    // configured: the dir is process-global and clearing it here would
    // race a concurrently running scenario that did configure one.
    if let Some(dir) = &cfg.dump_dir {
        let _ = std::fs::create_dir_all(dir);
        trace::set_dump_dir(Some(dir.as_path()));
    }

    // --- Derive the schedule from the seed.
    let ctrl_plan = cfg
        .mix
        .control_faults
        .then(|| FaultPlan::new(cfg.seed ^ 0xC7, derive_spec(&mut rng), derive_spec(&mut rng)));
    let data_plan = cfg
        .mix
        .data_faults
        .then(|| FaultPlan::new(cfg.seed ^ 0xDA, derive_spec(&mut rng), derive_spec(&mut rng)));
    let byz = cfg
        .mix
        .byzantine
        .then(|| ByzantineSpec::new(cfg.seed ^ 0xB2, rng.uniform(0.15, 0.4)));
    let schedule = {
        let mut s = format!("mix={}", cfg.mix.label());
        if let Some(p) = &ctrl_plan {
            s += &format!(" ctrl[r: {} | w: {}]", spec_label(&p.read), spec_label(&p.write));
        }
        if let Some(p) = &data_plan {
            s += &format!(" data[r: {} | w: {}]", spec_label(&p.read), spec_label(&p.write));
        }
        if let Some(b) = &byz {
            s += &format!(" byz[p={:.2}]", b.tamper_p);
        }
        s
    };

    // --- Boot the topology. The broker binds clean; its *accepted*
    // control connections carry the fault schedule.
    let broker_cfg = BrokerConfig {
        slab_bytes: SLAB,
        min_lease: SimTime::from_millis(200),
        ..Default::default()
    };
    let broker = BrokerServer::start(
        "127.0.0.1:0",
        broker_cfg.clone(),
        BrokerServerConfig {
            tick: Duration::from_millis(20),
            producer_timeout: Duration::from_millis(600),
            forecast_min_samples: usize::MAX,
            faults: ctrl_plan.clone(),
            ..Default::default()
        },
    )
    .expect("broker bind");

    // Failover scenarios boot a warm standby replicating the primary's
    // lease-event log. It shares the control fault schedule — the
    // replication stream itself runs through the primary's faulty
    // accepted connections.
    let standby = cfg.mix.kill_broker.then(|| {
        BrokerServer::start(
            "127.0.0.1:0",
            broker_cfg.clone(),
            BrokerServerConfig {
                tick: Duration::from_millis(20),
                producer_timeout: Duration::from_millis(600),
                forecast_min_samples: usize::MAX,
                faults: ctrl_plan.clone(),
                standby_of: Some(broker.addr().to_string()),
                takeover_after: Duration::from_millis(400),
                ..Default::default()
            },
        )
        .expect("standby bind")
    });
    // Ordered failover list every client gets: primary first.
    let mut broker_list = vec![broker.addr().to_string()];
    if let Some(s) = &standby {
        broker_list.push(s.addr().to_string());
    }
    let mut primary = Some(broker);

    let start_agent = |id: u64, byzantine: Option<ByzantineSpec>| -> ProducerAgent {
        let agent_cfg = ProducerAgentConfig {
            producer: id,
            brokers: broker_list.clone(),
            data_addr: "127.0.0.1:0".to_string(),
            advertise: None,
            capacity_bytes: AGENT_SLABS * SLAB,
            harvest: false,
            heartbeat: Duration::from_millis(50),
            shards: 2,
            rate_bps: None,
            seed: cfg.seed ^ id,
            ctrl_call_timeout: Duration::from_millis(250),
            // Failover must finish inside the recovery budget: retry
            // promptly, cap low, keep the jitter.
            redial_backoff: Duration::from_millis(100),
            redial_backoff_cap: Duration::from_secs(1),
            ctrl_faults: None,
            data_faults: None,
            byzantine,
            // Chaos scenarios poke the system through faults, not stats
            // polls; skip the extra listener per agent.
            stats_addr: None,
            slo_p99_us: 0,
        };
        // Registration runs through the (possibly faulty) control
        // plane; retry fresh connections until one schedule lets the
        // handshake through.
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match ProducerAgent::start(agent_cfg.clone()) {
                Ok(a) => return a,
                Err(e) if Instant::now() < deadline => {
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => panic!("agent {id} never registered: {e} (schedule {schedule})"),
            }
        }
    };
    let mut agents = vec![start_agent(1, byz.clone()), start_agent(2, byz.clone())];

    let pool_cfg = RemotePoolConfig {
        consumer: 9,
        brokers: broker_list.clone(),
        target_slabs: TARGET_SLABS,
        min_slabs: 1,
        lease_ttl: Duration::from_millis(700),
        renew_margin: Duration::from_millis(300),
        maintain_every: Duration::from_millis(20),
        reconnect_backoff: Duration::from_millis(100),
        reconnect_backoff_cap: Duration::from_secs(1),
        data_call_timeout: Duration::from_millis(150),
        ctrl_call_timeout: Duration::from_millis(250),
        data_window: 2,
        ctrl_faults: None, // broker-side plan already faults this plane
        data_faults: data_plan.clone(),
    };
    let mut pool = {
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            match RemotePool::connect(pool_cfg.clone()) {
                Ok(p) => break p,
                Err(e) if Instant::now() < deadline => {
                    let _ = e;
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => panic!("pool never connected: {e} (schedule {schedule})"),
            }
        }
    };

    // Best-effort provisioning: under faults, partial capacity is fine
    // — full capacity is only demanded after the disarm.
    wait_for(Duration::from_secs(4), || {
        pool.maintain();
        pool.held_slabs() >= TARGET_SLABS.min(4)
    });

    // --- Optional renew-vs-revoke racer: forged producer-side
    // revocations on guessed lease ids (they are a small counter),
    // racing the pool's renewals and the broker's expiry sweeps.
    let race_stop = Arc::new(AtomicBool::new(false));
    let racer = cfg.mix.revoke_race.then(|| {
        let addr = broker_list[0].clone();
        let stop = race_stop.clone();
        std::thread::spawn(move || {
            let mut ctrl: Option<CtrlClient> = None;
            let mut lease_guess: u64 = 1;
            while !stop.load(Ordering::Relaxed) {
                if ctrl.is_none() {
                    ctrl = CtrlClient::connect_timeout(&addr, Duration::from_millis(500))
                        .ok()
                        .map(|mut c| {
                            let _ = c.set_call_timeout(Duration::from_millis(250));
                            c
                        });
                }
                if let Some(c) = ctrl.as_mut() {
                    let producer = 1 + (lease_guess % 2);
                    let req =
                        CtrlRequest::Revoke { producer, lease: lease_guess, trace: 0 };
                    if c.call(&req).is_err() {
                        ctrl = None;
                    }
                    lease_guess = 1 + (lease_guess % 48);
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        })
    });

    // --- Fault phase: secure traffic while the schedule runs.
    let mut secure = SecureKv::with_iv_seed(Some([0x5E; 16]), true, 1, cfg.seed ^ 0x5EC);
    let mut op_rng = Rng::new(cfg.seed ^ 0x0500);
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut escapes = 0u64;
    let mut killed = false;
    let phase_budget = Duration::from_secs(8);
    let t_phase = Instant::now();
    let mut ops_done = 0u64;
    for op in 0..cfg.fault_ops {
        if t_phase.elapsed() > phase_budget {
            break;
        }
        let halfway = op >= cfg.fault_ops / 2 || t_phase.elapsed() > phase_budget / 2;
        if cfg.mix.kill_producer && !killed && halfway {
            agents[0].kill();
            killed = true;
        }
        // Kill the primary broker under load: the warm standby must
        // promote itself and every client must fail over to it while
        // traffic keeps flowing.
        if cfg.mix.kill_broker && halfway {
            if let Some(p) = primary.take() {
                p.stop();
            }
        }
        // ~25% of iterations drive *batch* frames (multi-get or
        // multi-put), so transport faults land mid-batch — truncating
        // between ops, duplicating batch responses — and Byzantine
        // tampering is exercised per op inside batches; the rest stay
        // single-op.
        if op_rng.chance(0.15) {
            let m = 2 + op_rng.below(7) as usize;
            let ks: Vec<u32> =
                (0..m).map(|_| op_rng.below(cfg.keys as u64) as u32).collect();
            let keys: Vec<Vec<u8>> = ks.iter().map(|&k| key_for(k)).collect();
            let key_refs: Vec<&[u8]> = keys.iter().map(Vec::as_slice).collect();
            for (j, got) in secure.multi_get(&mut pool, &key_refs).into_iter().enumerate() {
                match got {
                    Some(v) => {
                        hits += 1;
                        if v != value_for(cfg.seed, ks[j], cfg.value_bytes) {
                            escapes += 1;
                        }
                    }
                    None => misses += 1,
                }
            }
            ops_done += m as u64;
        } else if op_rng.chance(0.1) {
            let m = 2 + op_rng.below(3) as usize;
            let ks: Vec<u32> =
                (0..m).map(|_| op_rng.below(cfg.keys as u64) as u32).collect();
            let keys: Vec<Vec<u8>> = ks.iter().map(|&k| key_for(k)).collect();
            let vals: Vec<Vec<u8>> =
                ks.iter().map(|&k| value_for(cfg.seed, k, cfg.value_bytes)).collect();
            let items: Vec<(&[u8], &[u8])> = keys
                .iter()
                .zip(&vals)
                .map(|(k, v)| (k.as_slice(), v.as_slice()))
                .collect();
            let _ = secure.multi_put(&mut pool, &items);
            ops_done += m as u64;
        } else {
            let k = op_rng.below(cfg.keys as u64) as u32;
            let key = key_for(k);
            if op_rng.chance(0.4) {
                let _ = secure.put(&mut pool, &key, &value_for(cfg.seed, k, cfg.value_bytes));
            } else {
                match secure.get(&mut pool, &key) {
                    Some(v) => {
                        hits += 1;
                        if v != value_for(cfg.seed, k, cfg.value_bytes) {
                            escapes += 1;
                        }
                    }
                    None => misses += 1,
                }
            }
            ops_done += 1;
        }
    }
    let ops_per_sec = ops_done as f64 / t_phase.elapsed().as_secs_f64().max(1e-9);
    if cfg.mix.kill_producer && !killed {
        agents[0].kill();
        killed = true;
    }
    // A failover scenario whose op loop ended early still kills the
    // primary: recovery below must run against the standby.
    if cfg.mix.kill_broker {
        if let Some(p) = primary.take() {
            p.stop();
        }
    }

    // --- Disarm everything; the marketplace must heal on its own.
    race_stop.store(true, Ordering::Relaxed);
    if let Some(h) = racer {
        let _ = h.join();
    }
    if let Some(p) = &ctrl_plan {
        p.disarm();
    }
    if let Some(p) = &data_plan {
        p.disarm();
    }
    if let Some(b) = &byz {
        b.disarm();
    }
    let t_recover = Instant::now();
    let mut reconverged = wait_for(Duration::from_secs(8), || {
        pool.maintain();
        pool.held_slabs() >= TARGET_SLABS
    });
    let mut recovery_ms = t_recover.elapsed().as_secs_f64() * 1e3;
    // Stabilize for one full lease TTL: slots the broker silently ended
    // during the faults get renewed-or-killed-and-refilled, so the
    // sweeps below only see capacity that is actually backed. This
    // fixed window is harness bookkeeping, not recovery — it is kept
    // out of recovery_ms so the metric stays comparable across PRs.
    let t_stable = Instant::now();
    while t_stable.elapsed() < Duration::from_millis(900) {
        pool.maintain();
        std::thread::sleep(Duration::from_millis(20));
    }
    if reconverged && pool.held_slabs() < TARGET_SLABS {
        // Capacity dipped during stabilization (a stale slot died on
        // renewal): charge only the extra re-provisioning time.
        let t_rewait = Instant::now();
        reconverged = wait_for(Duration::from_secs(4), || {
            pool.maintain();
            pool.held_slabs() >= TARGET_SLABS
        });
        recovery_ms += t_rewait.elapsed().as_secs_f64() * 1e3;
    }
    if !reconverged {
        recovery_ms = f64::NAN;
    }
    // Live producer stores sized to their lease targets, so re-puts
    // below land in real budget.
    wait_for(Duration::from_secs(3), || {
        agents.iter().skip(usize::from(killed)).all(|a| {
            a.store().map(|s| s.max_bytes() as u64).unwrap_or(0) == a.target_bytes()
        })
    });

    // --- Refill the working set (clean network now), then the two
    // invariant sweeps.
    for k in 0..cfg.keys {
        let key = key_for(k);
        if secure.get(&mut pool, &key).is_none() {
            let _ = secure.put(&mut pool, &key, &value_for(cfg.seed, k, cfg.value_bytes));
        }
    }
    let mut sweep1 = vec![false; cfg.keys as usize];
    for k in 0..cfg.keys {
        if let Some(v) = secure.get(&mut pool, &key_for(k)) {
            if v != value_for(cfg.seed, k, cfg.value_bytes) {
                escapes += 1;
            } else {
                sweep1[k as usize] = true;
            }
        }
    }
    let mut lost_acked_writes = 0u64;
    for k in 0..cfg.keys {
        let now = secure.get(&mut pool, &key_for(k));
        match now {
            Some(v) => {
                if v != value_for(cfg.seed, k, cfg.value_bytes) {
                    escapes += 1;
                }
            }
            None => {
                if sweep1[k as usize] {
                    lost_acked_writes += 1;
                }
            }
        }
    }

    let tampered: u64 = agents.iter().map(|a| a.byzantine_tampered()).sum();
    let broker_takeovers = standby
        .as_ref()
        .map(|s| s.metrics().counter("repl.takeovers").unwrap_or(0));
    // Collect whatever the flight recorder dumped during the run, so
    // the CLI (and CI, on a red run) can name the evidence files.
    let dump_files: Vec<PathBuf> = match &cfg.dump_dir {
        Some(dir) => {
            let mut v: Vec<PathBuf> = std::fs::read_dir(dir)
                .map(|rd| {
                    rd.filter_map(|e| e.ok())
                        .map(|e| e.path())
                        .filter(|p| p.extension().is_some_and(|x| x == "jsonl"))
                        .collect()
                })
                .unwrap_or_default();
            v.sort();
            v
        }
        None => Vec::new(),
    };
    let outcome = ChaosOutcome {
        seed: cfg.seed,
        schedule,
        ops: ops_done,
        ops_per_sec,
        hits,
        misses,
        integrity_failures: secure.stats.integrity_failures,
        integrity_escapes: escapes,
        tampered,
        lost_acked_writes,
        reconverged,
        recovery_ms,
        held_slabs_after: pool.held_slabs(),
        broker_takeovers,
        pool_stats: pool.stats.clone(),
        dump_files,
    };

    drop(pool);
    for a in agents.drain(..) {
        a.stop();
    }
    if let Some(p) = primary {
        p.stop();
    }
    if let Some(s) = standby {
        s.stop();
    }
    outcome
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_labels_round_trip_through_from_name() {
        // The printed reproduction command must parse back to the mix
        // that ran — for every combination, not just the single-family
        // names.
        let mixes = [
            ChaosMix::clean(),
            ChaosMix::standard(),
            ChaosMix { data_faults: true, kill_producer: true, ..Default::default() },
            ChaosMix { control_faults: true, revoke_race: true, ..Default::default() },
            ChaosMix { byzantine: true, ..Default::default() },
            ChaosMix::failover(),
            ChaosMix { data_faults: true, kill_broker: true, ..Default::default() },
        ];
        for m in mixes {
            assert_eq!(ChaosMix::from_name(&m.label()), Some(m), "{}", m.label());
        }
        assert_eq!(ChaosMix::from_name("bogus"), None);
        assert_eq!(ChaosMix::from_name("data+bogus"), None);
    }
}

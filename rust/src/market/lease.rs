//! Lease lifecycle state machine, shared by the broker daemon and the
//! discrete-event simulator.
//!
//! The table is clock-agnostic: every operation takes `now_us` (a
//! monotonic microsecond count — wall clock in the daemon, `SimTime` in
//! the simulator), so the state machine can be unit-tested on a mock
//! clock and reused verbatim by both drivers.
//!
//! States: `Active` → `Expired` (TTL ran out), `Revoked` (producer took
//! the memory back early, or died), or `Released` (consumer returned it)
//! — all terminal. Transitions are *lazy* as well as swept: `renew`/
//! `release`/`revoke` first lapse an overdue lease, so renew-after-expiry
//! and expiry-while-a-revocation-is-in-flight resolve deterministically
//! (the expiry wins). Every transition is queued once for the
//! accounting consumer ([`LeaseTable::take_ended`]) and tracked
//! per-producer for heartbeat acks.

use std::collections::HashMap;

/// Lifecycle state of one lease. All non-`Active` states are terminal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseState {
    Active,
    Expired,
    Revoked,
    Released,
}

impl LeaseState {
    pub fn is_terminal(self) -> bool {
        !matches!(self, LeaseState::Active)
    }
}

/// One brokered lease as tracked by the control plane.
#[derive(Clone, Debug)]
pub struct LeaseRecord {
    pub id: u64,
    pub consumer: u64,
    pub producer: u64,
    pub slabs: u32,
    pub slab_bytes: u64,
    /// Agreed price, nano-dollars per slab-hour.
    pub price_nd_per_slab_hour: i64,
    pub granted_us: u64,
    /// Lease duration; each successful renewal extends expiry by this.
    pub duration_us: u64,
    pub expiry_us: u64,
    pub state: LeaseState,
    /// Grant has been announced to the producer (heartbeat ack).
    announced: bool,
}

impl LeaseRecord {
    pub fn bytes(&self) -> u64 {
        self.slabs as u64 * self.slab_bytes
    }

    /// Remaining lifetime at `now_us` (0 once overdue).
    pub fn ttl_us(&self, now_us: u64) -> u64 {
        self.expiry_us.saturating_sub(now_us)
    }
}

/// Why a lease operation was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeaseError {
    Unknown(u64),
    /// The lease already reached the given terminal state.
    Ended(u64, LeaseState),
    /// An *active* lease with this id already exists.
    Duplicate(u64),
}

impl std::fmt::Display for LeaseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LeaseError::Unknown(id) => write!(f, "unknown lease {id}"),
            LeaseError::Ended(id, s) => write!(f, "lease {id} already ended ({s:?})"),
            LeaseError::Duplicate(id) => write!(f, "lease {id} already active"),
        }
    }
}

impl std::error::Error for LeaseError {}

/// A completed lifecycle transition, for registry/billing accounting.
#[derive(Clone, Debug)]
pub struct LeaseEnd {
    pub record: LeaseRecord,
    pub cause: LeaseState,
}

/// One entry of the broker's append-only replication log: every market
/// state change the primary makes, in the order it made them. A standby
/// replays the stream through [`LeaseTable::apply_event`] to own an
/// equivalent lease book at takeover. Lifetimes are remaining TTLs
/// (clock-agnostic, like the wire); producer membership changes ride
/// the same log so the standby also knows who is alive and where.
#[derive(Clone, Debug, PartialEq)]
pub enum LeaseEvent {
    Granted {
        lease: u64,
        consumer: u64,
        producer: u64,
        slabs: u32,
        slab_bytes: u64,
        price_nd_per_slab_hour: i64,
        ttl_us: u64,
    },
    Renewed { lease: u64, ttl_us: u64 },
    Released { lease: u64 },
    Revoked { lease: u64 },
    Expired { lease: u64 },
    ProducerUp { producer: u64, endpoint: String, capacity_gb: f32 },
    ProducerDown { producer: u64 },
}

/// The lease book: id → record, plus an accounting queue of ended
/// leases and per-producer announcement tracking.
#[derive(Default)]
pub struct LeaseTable {
    leases: HashMap<u64, LeaseRecord>,
    /// Transitions not yet drained by [`Self::take_ended`].
    ended: Vec<LeaseEnd>,
    /// Terminal lease ids not yet acked to their producer. Records stay
    /// in `leases` until acked so late renews get a precise refusal.
    end_unacked: Vec<u64>,
}

impl LeaseTable {
    /// Record a freshly granted lease. Lease ids come from the grantor
    /// (the [`crate::broker::Broker`]); a terminal record under the same
    /// id is superseded, an active one is a [`LeaseError::Duplicate`].
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &mut self,
        id: u64,
        consumer: u64,
        producer: u64,
        slabs: u32,
        slab_bytes: u64,
        price_nd_per_slab_hour: i64,
        now_us: u64,
        duration_us: u64,
    ) -> Result<(), LeaseError> {
        if let Some(existing) = self.leases.get(&id) {
            if existing.state == LeaseState::Active {
                return Err(LeaseError::Duplicate(id));
            }
            self.end_unacked.retain(|&e| e != id);
        }
        self.leases.insert(
            id,
            LeaseRecord {
                id,
                consumer,
                producer,
                slabs,
                slab_bytes,
                price_nd_per_slab_hour,
                granted_us: now_us,
                duration_us,
                // Saturating: a hostile/buggy u64::MAX TTL must not wrap
                // into an instant expiry (or panic the sweep in debug).
                expiry_us: now_us.saturating_add(duration_us),
                state: LeaseState::Active,
                announced: false,
            },
        );
        Ok(())
    }

    pub fn get(&self, id: u64) -> Option<&LeaseRecord> {
        self.leases.get(&id)
    }

    pub fn len(&self) -> usize {
        self.leases.len()
    }

    pub fn is_empty(&self) -> bool {
        self.leases.is_empty()
    }

    pub fn active(&self) -> impl Iterator<Item = &LeaseRecord> {
        self.leases.values().filter(|l| l.state == LeaseState::Active)
    }

    pub fn active_count(&self) -> usize {
        self.active().count()
    }

    /// Total bytes of this producer's active leases — the authoritative
    /// store size its agent must maintain.
    pub fn producer_target_bytes(&self, producer: u64) -> u64 {
        self.active().filter(|l| l.producer == producer).map(|l| l.bytes()).sum()
    }

    /// Lapse one overdue lease in place; returns its (possibly updated)
    /// state. Terminal transitions queue an accounting event.
    fn lapse(
        leases: &mut HashMap<u64, LeaseRecord>,
        ended: &mut Vec<LeaseEnd>,
        end_unacked: &mut Vec<u64>,
        id: u64,
        now_us: u64,
    ) -> Option<LeaseState> {
        let rec = leases.get_mut(&id)?;
        if rec.state == LeaseState::Active && now_us >= rec.expiry_us {
            rec.state = LeaseState::Expired;
            ended.push(LeaseEnd { record: rec.clone(), cause: LeaseState::Expired });
            end_unacked.push(id);
        }
        Some(rec.state)
    }

    /// Extend an active lease by its original duration. Renewing an
    /// overdue lease fails with `Ended(Expired)` — the expiry wins, and
    /// the consumer must request fresh capacity.
    pub fn renew(&mut self, id: u64, now_us: u64) -> Result<u64, LeaseError> {
        let state =
            Self::lapse(&mut self.leases, &mut self.ended, &mut self.end_unacked, id, now_us)
                .ok_or(LeaseError::Unknown(id))?;
        if state.is_terminal() {
            return Err(LeaseError::Ended(id, state));
        }
        let rec = self.leases.get_mut(&id).unwrap();
        rec.expiry_us = now_us.saturating_add(rec.duration_us);
        Ok(rec.expiry_us)
    }

    fn end_with(
        &mut self,
        id: u64,
        now_us: u64,
        cause: LeaseState,
    ) -> Result<LeaseRecord, LeaseError> {
        debug_assert!(cause.is_terminal());
        let state =
            Self::lapse(&mut self.leases, &mut self.ended, &mut self.end_unacked, id, now_us)
                .ok_or(LeaseError::Unknown(id))?;
        if state.is_terminal() {
            // Double-release, revoke-after-expiry, expiry-while-a-
            // revocation-was-in-flight: the earlier transition stands.
            return Err(LeaseError::Ended(id, state));
        }
        let rec = self.leases.get_mut(&id).unwrap();
        rec.state = cause;
        let snapshot = rec.clone();
        self.ended.push(LeaseEnd { record: snapshot.clone(), cause });
        self.end_unacked.push(id);
        Ok(snapshot)
    }

    /// Consumer returns the lease (graceful end).
    pub fn release(&mut self, id: u64, now_us: u64) -> Result<LeaseRecord, LeaseError> {
        self.end_with(id, now_us, LeaseState::Released)
    }

    /// Producer takes the memory back early (counts against reputation).
    pub fn revoke(&mut self, id: u64, now_us: u64) -> Result<LeaseRecord, LeaseError> {
        self.end_with(id, now_us, LeaseState::Revoked)
    }

    /// Revoke every active lease of a producer (it died or deregistered).
    /// The producer is gone, so no ack will ever come: all its records —
    /// including earlier expiries still awaiting ack — are gc'd now.
    pub fn revoke_all_for_producer(&mut self, producer: u64, now_us: u64) -> Vec<LeaseRecord> {
        let ids: Vec<u64> = self
            .active()
            .filter(|l| l.producer == producer)
            .map(|l| l.id)
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            if let Ok(rec) = self.revoke(id, now_us) {
                out.push(rec);
            }
        }
        self.end_unacked
            .retain(|id| self.leases.get(id).is_some_and(|r| r.producer != producer));
        self.leases.retain(|_, r| r.producer != producer || !r.state.is_terminal());
        out
    }

    /// Transition every overdue active lease to `Expired`; returns the
    /// newly expired records.
    pub fn sweep_expired(&mut self, now_us: u64) -> Vec<LeaseRecord> {
        let due: Vec<u64> = self
            .leases
            .values()
            .filter(|l| l.state == LeaseState::Active && now_us >= l.expiry_us)
            .map(|l| l.id)
            .collect();
        let mut out = Vec::with_capacity(due.len());
        for id in due {
            Self::lapse(&mut self.leases, &mut self.ended, &mut self.end_unacked, id, now_us);
            out.push(self.leases[&id].clone());
        }
        out
    }

    /// Drain the accounting queue: every terminal transition exactly once.
    pub fn take_ended(&mut self) -> Vec<LeaseEnd> {
        std::mem::take(&mut self.ended)
    }

    /// Active leases of `producer` not yet announced to it; marks them
    /// announced (piggybacked on its next heartbeat ack).
    pub fn take_unannounced(&mut self, producer: u64) -> Vec<LeaseRecord> {
        let mut out = Vec::new();
        for rec in self.leases.values_mut() {
            if rec.producer == producer && rec.state == LeaseState::Active && !rec.announced {
                rec.announced = true;
                out.push(rec.clone());
            }
        }
        out
    }

    /// Forget announcements to `producer`: its agent reconnected with a
    /// blank slate (a control-plane blip or restart), so the next
    /// heartbeat ack must re-carry every active lease. Pending ends stay
    /// queued and re-carry too.
    pub fn reset_announcements(&mut self, producer: u64) {
        for rec in self.leases.values_mut() {
            if rec.producer == producer && rec.state == LeaseState::Active {
                rec.announced = false;
            }
        }
    }

    /// Replay one replicated [`LeaseEvent`] at local time `now_us`.
    ///
    /// Every outcome the primary already decided is taken as
    /// authoritative, so refusals the table would hand a live caller
    /// are tolerated here: a duplicate grant, a renew/end on a lease
    /// this replica already lapsed, or an end for a lease it never saw
    /// (log gap) each leave the earlier local state standing. The
    /// takeover re-registration path repairs whatever a gap cost.
    /// Applying a log prefix and then its suffix is exactly applying
    /// the whole log — the invariant the failover proptest pins down.
    pub fn apply_event(&mut self, ev: &LeaseEvent, now_us: u64) {
        match ev {
            LeaseEvent::Granted {
                lease,
                consumer,
                producer,
                slabs,
                slab_bytes,
                price_nd_per_slab_hour,
                ttl_us,
            } => {
                let _ = self.insert(
                    *lease,
                    *consumer,
                    *producer,
                    *slabs,
                    *slab_bytes,
                    *price_nd_per_slab_hour,
                    now_us,
                    *ttl_us,
                );
            }
            LeaseEvent::Renewed { lease, .. } => {
                let _ = self.renew(*lease, now_us);
            }
            LeaseEvent::Released { lease } => {
                let _ = self.release(*lease, now_us);
            }
            LeaseEvent::Revoked { lease } => {
                let _ = self.revoke(*lease, now_us);
            }
            LeaseEvent::Expired { lease } => {
                let _ = self.end_with(*lease, now_us, LeaseState::Expired);
            }
            LeaseEvent::ProducerUp { .. } => {} // registry-level; no lease change
            LeaseEvent::ProducerDown { producer } => {
                self.revoke_all_for_producer(*producer, now_us);
            }
        }
    }

    /// Terminal lease ids of `producer` not yet acked to it; acking
    /// garbage-collects the records.
    pub fn take_ended_unacked(&mut self, producer: u64) -> Vec<u64> {
        let mut out = Vec::new();
        self.end_unacked.retain(|&id| match self.leases.get(&id) {
            Some(rec) if rec.producer == producer => {
                out.push(id);
                false
            }
            Some(_) => true,
            None => false,
        });
        for id in &out {
            self.leases.remove(id);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB64: u64 = 64 << 20;

    fn table_with(id: u64, now: u64, ttl: u64) -> LeaseTable {
        let mut t = LeaseTable::default();
        t.insert(id, 100, 1, 4, MB64, 42, now, ttl).unwrap();
        t
    }

    #[test]
    fn grant_renew_expire_on_mock_clock() {
        let mut t = table_with(1, 0, 1_000);
        assert_eq!(t.get(1).unwrap().expiry_us, 1_000);
        assert_eq!(t.get(1).unwrap().ttl_us(400), 600);
        // Renew at 900 pushes expiry to 900 + duration.
        assert_eq!(t.renew(1, 900).unwrap(), 1_900);
        // Sweep before expiry: nothing.
        assert!(t.sweep_expired(1_800).is_empty());
        // Sweep after: expired exactly once.
        let swept = t.sweep_expired(1_900);
        assert_eq!(swept.len(), 1);
        assert_eq!(swept[0].state, LeaseState::Expired);
        assert!(t.sweep_expired(2_000).is_empty());
        let ends = t.take_ended();
        assert_eq!(ends.len(), 1);
        assert_eq!(ends[0].cause, LeaseState::Expired);
        assert!(t.take_ended().is_empty());
    }

    #[test]
    fn renew_after_expiry_refused_even_without_sweep() {
        let mut t = table_with(1, 0, 1_000);
        // No sweep ran; the lazy lapse inside renew must still refuse.
        assert_eq!(t.renew(1, 1_000), Err(LeaseError::Ended(1, LeaseState::Expired)));
        // The lapse was recorded for accounting exactly once.
        assert_eq!(t.take_ended().len(), 1);
        assert_eq!(t.renew(1, 1_100), Err(LeaseError::Ended(1, LeaseState::Expired)));
        assert!(t.take_ended().is_empty());
    }

    #[test]
    fn revoke_and_double_release() {
        let mut t = table_with(1, 0, 10_000);
        t.insert(2, 100, 1, 2, MB64, 42, 0, 10_000).unwrap();
        assert_eq!(t.revoke(1, 100).unwrap().state, LeaseState::Revoked);
        assert_eq!(t.renew(1, 200), Err(LeaseError::Ended(1, LeaseState::Revoked)));
        assert_eq!(t.release(2, 100).unwrap().state, LeaseState::Released);
        // Double-release is a precise refusal, not a second transition.
        assert_eq!(t.release(2, 200), Err(LeaseError::Ended(2, LeaseState::Released)));
        let ends = t.take_ended();
        assert_eq!(ends.len(), 2);
    }

    #[test]
    fn expiry_beats_revocation_in_flight() {
        // A revoke that arrives after the expiry instant (e.g. queued on
        // the wire while the sweep ran) resolves as Expired, not Revoked.
        let mut t = table_with(1, 0, 1_000);
        assert_eq!(t.revoke(1, 1_000), Err(LeaseError::Ended(1, LeaseState::Expired)));
        assert_eq!(t.get(1).unwrap().state, LeaseState::Expired);
        let ends = t.take_ended();
        assert_eq!(ends.len(), 1);
        assert_eq!(ends[0].cause, LeaseState::Expired);
    }

    #[test]
    fn unknown_and_duplicate() {
        let mut t = table_with(1, 0, 1_000);
        assert_eq!(t.renew(9, 0), Err(LeaseError::Unknown(9)));
        assert_eq!(t.release(9, 0), Err(LeaseError::Unknown(9)));
        assert_eq!(
            t.insert(1, 100, 1, 4, MB64, 42, 0, 1_000),
            Err(LeaseError::Duplicate(1))
        );
        // A terminal record may be superseded (the sim re-leases ids).
        t.revoke(1, 10).unwrap();
        t.insert(1, 100, 1, 4, MB64, 42, 20, 1_000).unwrap();
        assert_eq!(t.get(1).unwrap().state, LeaseState::Active);
    }

    #[test]
    fn producer_announcement_and_ack_flow() {
        let mut t = table_with(1, 0, 1_000);
        t.insert(2, 100, 1, 2, MB64, 42, 0, 5_000).unwrap();
        t.insert(3, 100, 7, 8, MB64, 42, 0, 5_000).unwrap();
        assert_eq!(t.producer_target_bytes(1), 6 * MB64);
        // Announce producer 1's grants once.
        let g = t.take_unannounced(1);
        assert_eq!(g.len(), 2);
        assert!(t.take_unannounced(1).is_empty());
        assert_eq!(t.take_unannounced(7).len(), 1);
        // Lease 1 expires; the end is acked to producer 1 once, then gc'd.
        t.sweep_expired(1_000);
        assert_eq!(t.producer_target_bytes(1), 2 * MB64);
        assert_eq!(t.take_ended_unacked(1), vec![1]);
        assert!(t.take_ended_unacked(1).is_empty());
        assert!(t.get(1).is_none());
        // A renew arriving after gc gets Unknown — the slot is long dead.
        assert_eq!(t.renew(1, 1_100), Err(LeaseError::Unknown(1)));
    }

    #[test]
    fn dead_producer_revocation_is_immediate() {
        let mut t = table_with(1, 0, 100_000);
        t.insert(2, 101, 1, 2, MB64, 42, 0, 100_000).unwrap();
        t.insert(3, 100, 7, 8, MB64, 42, 0, 100_000).unwrap();
        let revoked = t.revoke_all_for_producer(1, 50);
        assert_eq!(revoked.len(), 2);
        assert_eq!(t.producer_target_bytes(1), 0);
        // Gone from the table (no ack will ever come), but accounted.
        assert!(t.get(1).is_none() && t.get(2).is_none());
        assert_eq!(t.take_ended().len(), 2);
        assert_eq!(t.get(3).unwrap().state, LeaseState::Active);
        assert!(t.take_ended_unacked(1).is_empty());
    }

    fn granted(lease: u64, producer: u64, slabs: u32, ttl: u64) -> LeaseEvent {
        LeaseEvent::Granted {
            lease,
            consumer: 100,
            producer,
            slabs,
            slab_bytes: MB64,
            price_nd_per_slab_hour: 42,
            ttl_us: ttl,
        }
    }

    #[test]
    fn replay_builds_equivalent_book_and_tolerates_gaps() {
        let mut t = LeaseTable::default();
        t.apply_event(&granted(1, 1, 4, 10_000), 0);
        t.apply_event(&granted(2, 1, 2, 10_000), 0);
        t.apply_event(&granted(3, 7, 8, 10_000), 0);
        assert_eq!(t.producer_target_bytes(1), 6 * MB64);
        // Primary-decided ends replay as the primary's cause.
        t.apply_event(&LeaseEvent::Renewed { lease: 1, ttl_us: 10_000 }, 5_000);
        t.apply_event(&LeaseEvent::Released { lease: 2 }, 6_000);
        t.apply_event(&LeaseEvent::Expired { lease: 3 }, 7_000);
        assert_eq!(t.get(1).unwrap().expiry_us, 15_000);
        assert_eq!(t.get(2).unwrap().state, LeaseState::Released);
        assert_eq!(t.get(3).unwrap().state, LeaseState::Expired);
        // Gap tolerance: events about leases this replica never saw, or
        // already-ended ones, leave local state standing — no panic.
        t.apply_event(&LeaseEvent::Revoked { lease: 99 }, 7_000);
        t.apply_event(&LeaseEvent::Released { lease: 2 }, 8_000);
        t.apply_event(&granted(1, 1, 4, 10_000), 8_000); // duplicate grant
        assert_eq!(t.get(1).unwrap().expiry_us, 15_000, "duplicate must not reset");
        // A dead producer revokes everything it still holds.
        t.apply_event(&LeaseEvent::ProducerDown { producer: 1 }, 9_000);
        assert_eq!(t.producer_target_bytes(1), 0);
    }

    #[test]
    fn dead_producer_gc_includes_expired_unacked_records() {
        // A lease expires, the producer dies before acking the end: the
        // death sweep must gc the expired record too, not leak it.
        let mut t = table_with(1, 0, 1_000);
        t.insert(2, 100, 1, 2, MB64, 42, 0, 100_000).unwrap();
        t.sweep_expired(1_000); // lease 1 expires, awaits producer ack
        assert_eq!(t.take_ended().len(), 1);
        let revoked = t.revoke_all_for_producer(1, 2_000);
        assert_eq!(revoked.len(), 1); // only the still-active lease 2
        assert!(t.is_empty(), "expired-unacked record leaked");
        assert!(t.take_ended_unacked(1).is_empty());
    }
}

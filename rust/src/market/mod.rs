//! The marketplace control plane (paper §3/§5/§6 as a *running system*):
//! the three roles of Memtrade as networked processes, plus the lease
//! lifecycle state machine they and the simulator share.
//!
//! * [`BrokerServer`] — the broker daemon: the in-process
//!   [`crate::broker::Broker`] (registry, placement, pricing,
//!   availability prediction) behind the control wire protocol
//!   ([`crate::net::control`]), with monotonic-clock lease expiry, dead-
//!   producer sweeps, persisted per-producer usage histories, and warm-
//!   standby failover: a primary streams its lease-event log to a
//!   standby that replays it and takes over when the primary goes
//!   silent.
//! * [`ProducerAgent`] — registers with the broker, decides offered
//!   capacity with the real harvester control loop, serves data-plane
//!   traffic via [`crate::net::tcp::ProducerStoreServer`], heartbeats,
//!   and shrinks its store when leases end or memory is reclaimed.
//! * [`RemotePool`] — the lease-aware consumer pool: requests slabs,
//!   routes keys deterministically to live leases, renews before
//!   expiry, and turns revocation and connection loss into cache
//!   misses, never errors.
//! * [`lease`] — the clock-agnostic lease state machine (grant → renew
//!   → expire / revoke / release), unit-tested on a mock clock and
//!   driven by both the daemon (wall clock) and [`crate::sim::cluster`]
//!   (simulated time).
//! * [`chaos`] — seeded chaos scenarios: the whole topology run under
//!   [`crate::net::faults`] fault schedules (plus Byzantine producers,
//!   mid-run kills, and renew-vs-revoke races), with the paper's
//!   resilience invariants checked machine-readably.
//! * [`stats_server`] — the read-only `StatsQuery` endpoint producer
//!   agents mount next to their data plane, so every marketplace role
//!   is observable over the wire (`memtrade top`).

pub mod broker_server;
pub mod chaos;
pub mod lease;
pub mod producer_agent;
pub mod remote_pool;
pub mod stats_server;

pub use broker_server::{BrokerServer, BrokerServerConfig};
pub use chaos::{run_chaos, ChaosConfig, ChaosMix, ChaosOutcome};
pub use lease::{LeaseEnd, LeaseError, LeaseEvent, LeaseRecord, LeaseState, LeaseTable};
pub use producer_agent::{AgentStats, ProducerAgent, ProducerAgentConfig};
pub use remote_pool::{PoolStats, RemotePool, RemotePoolConfig};
pub use stats_server::StatsServer;

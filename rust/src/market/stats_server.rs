//! A minimal control-plane stats endpoint: answers `StatsQuery` with a
//! caller-supplied [`MetricSet`] snapshot and refuses everything else.
//!
//! The broker daemon answers `StatsQuery` on its main control port; a
//! producer agent has no control listener of its own (it *dials* the
//! broker), so it mounts one of these next to its data plane. The
//! endpoint speaks the ordinary control handshake, which means
//! `memtrade top` and any `CtrlClient` can poll it — and a data-plane
//! client dialing it by mistake gets the standard "wrong plane" error.

use crate::metrics::MetricSet;
use crate::net::control::{
    server_handshake_patient, CtrlRequest, CtrlResponse, RefuseCode, CONTROL_MAGIC,
};
use crate::net::faults::FaultyStream;
use crate::net::wire::{read_frame_into_patient, write_frame};
use std::io::{self, BufReader, BufWriter};
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Builds the snapshot served to each `StatsQuery` (called per query,
/// so the numbers are always live).
pub type MetricsSource = Arc<dyn Fn() -> MetricSet + Send + Sync>;

/// A read-only stats listener (one thread per connection; stats polls
/// are low-rate).
pub struct StatsServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
}

impl StatsServer {
    pub fn start<A: ToSocketAddrs>(addr: A, source: MetricsSource) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let start = Instant::now();

        let stop2 = stop.clone();
        let accept_handle = std::thread::spawn(move || {
            let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        conn_handles.retain(|h| !h.is_finished());
                        stream.set_nodelay(true).ok();
                        let stop = stop2.clone();
                        let source = source.clone();
                        conn_handles.push(std::thread::spawn(move || {
                            let _ = serve_stats_conn(
                                FaultyStream::clean(stream),
                                source,
                                stop,
                                start,
                            );
                        }));
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            for h in conn_handles {
                let _ = h.join();
            }
        });

        Ok(StatsServer { local_addr, stop, accept_handle: Some(accept_handle) })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StatsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_stats_conn(
    stream: FaultyStream,
    source: MetricsSource,
    stop: Arc<AtomicBool>,
    start: Instant,
) -> io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let keep_going = || !stop.load(Ordering::Relaxed);
    if server_handshake_patient(&mut reader, &mut writer, CONTROL_MAGIC, keep_going)?
        .is_none()
    {
        return Ok(());
    }
    let mut frame: Vec<u8> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    loop {
        let keep_going = || !stop.load(Ordering::Relaxed);
        match read_frame_into_patient(&mut reader, &mut frame, keep_going) {
            Ok(true) => {}
            Ok(false) | Err(_) => return Ok(()),
        }
        let resp = match CtrlRequest::decode(&frame) {
            Ok(CtrlRequest::StatsQuery) => CtrlResponse::Stats {
                uptime_us: start.elapsed().as_micros() as u64,
                metrics: source(),
            },
            // The flight recorder is process-global, so this read-only
            // endpoint can serve the hosting role's recent spans too —
            // `memtrade trace` points here for producer-side rings.
            Ok(CtrlRequest::TraceQuery { max }) => CtrlResponse::Traces {
                spans: crate::trace::recent_spans((max as usize).min(4096)),
            },
            Ok(_) => CtrlResponse::Refused {
                code: RefuseCode::Malformed,
                detail: "read-only endpoint: only StatsQuery/TraceQuery are served here".into(),
            },
            Err(e) => CtrlResponse::Refused {
                code: RefuseCode::Malformed,
                detail: e.to_string(),
            },
        };
        out.clear();
        resp.encode_into(&mut out);
        write_frame(&mut writer, &out)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::control::CtrlClient;

    #[test]
    fn serves_live_snapshots_and_refuses_other_requests() {
        let hits = Arc::new(crate::metrics::Counter::new());
        let hits2 = hits.clone();
        let source: MetricsSource = Arc::new(move || {
            let mut m = MetricSet::new();
            m.set_counter("hits", hits2.get());
            m
        });
        let server = StatsServer::start("127.0.0.1:0", source).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        let CtrlResponse::Stats { metrics, .. } =
            ctrl.call(&CtrlRequest::StatsQuery).unwrap()
        else {
            panic!("not a stats reply")
        };
        assert_eq!(metrics.counter("hits"), Some(0));
        hits.add(3);
        // Live: the next poll sees the new value over the same conn.
        let CtrlResponse::Stats { metrics, uptime_us } =
            ctrl.call(&CtrlRequest::StatsQuery).unwrap()
        else {
            panic!("not a stats reply")
        };
        assert_eq!(metrics.counter("hits"), Some(3));
        assert!(uptime_us > 0);
        // Anything else is refused, not misinterpreted.
        let resp = ctrl.call(&CtrlRequest::Deregister { producer: 1 }).unwrap();
        assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");
        server.stop();
    }
}

//! The lease-aware consumer pool: requests slabs from the broker, holds
//! a slot table of (lease, producer endpoint, [`KvClient`]) entries, and
//! serves [`crate::consumer::SecureKv`] as its [`KvTransport`].
//!
//! Routing: new PUTs are routed *deterministically* — FNV-1a over the
//! consumer key, modulo the live slots — replacing `SecureKv`'s blind
//! round-robin (the pool overrides [`KvTransport::route_put`]); GETs and
//! DELETEs follow the slot index recorded in the key's metadata, so
//! reads always go where the write went.
//!
//! Loss model: a revoked, expired, or unreachable lease turns its slot
//! dead. Calls against a dead slot answer like a cache miss (`NotFound`
//! / `Rejected`) — never an error — which makes `SecureKv` drop the
//! key's metadata exactly as it does for a producer-side eviction. The
//! pool then re-requests capacity from the broker; reused slot indices
//! are safe because wire keys never repeat (see below).
//!
//! Wire keys are namespaced: `SecureKv`'s substitute keys are a counter
//! starting at zero *per consumer per process lifetime*, and a producer
//! agent serves all its leases from one flat store — so the pool
//! prefixes every outgoing key with its consumer id plus a per-session
//! nonce. Without the id, two consumers sharing a producer would
//! silently overwrite each other's values; without the nonce, a
//! restarted consumer whose old leases were still warm would collide
//! with its previous life's keys and misread them as corruption.
//!
//! Renewal runs opportunistically inside `call` (and via an explicit
//! [`RemotePool::maintain`]): each live lease is renewed once less than
//! `renew_margin` of its TTL remains.
//!
//! Batching: the pool overrides [`KvTransport::call_multi`], so a
//! `SecureKv` multi-op that grouped its keys by routed slot lands here
//! as one group per producer and travels as true batch frames on that
//! slot's connection — one round trip per producer instead of one per
//! key, with the same per-op miss degradation when a slot is dead or
//! dies mid-batch.
//!
//! Failover: `brokers` is an ordered endpoint list (primary first).
//! A dial failure, desynced stream, or `NotPrimary` refusal advances
//! the pool to the next endpoint under a jittered exponential backoff
//! ([`crate::util::Backoff`]); leases survive the hop because the
//! standby replays the primary's lease-event log and honors them after
//! takeover.

use crate::consumer::client::{KvTransport, DEAD_ROUTE};
use crate::metrics::{scoped, Counter, Histogram, MetricSet, Observe};
use crate::net::control::{CtrlClient, CtrlRequest, CtrlResponse, GrantInfo, RefuseCode};
use crate::net::faults::FaultPlan;
use crate::net::tcp::KvClient;
use crate::net::wire::{Request, Response};
use crate::trace::{self, Op as TraceOp, Role, SpanGuard};
use crate::util::hash::fnv1a_64;
use crate::util::Backoff;
use std::io;
use std::time::{Duration, Instant};

/// Bound on reconnect/redial attempts made from the data path: a
/// black-holed broker or producer costs this much once per backoff
/// window, not the OS's multi-minute SYN retry schedule per call.
const DIAL_TIMEOUT: Duration = Duration::from_secs(2);

#[derive(Clone, Debug)]
pub struct RemotePoolConfig {
    pub consumer: u64,
    /// Broker control endpoints, `host:port`, in failover order
    /// (primary first, then standbys). The pool talks to one at a time
    /// and advances to the next — wrapping — when the current one fails
    /// to dial, desyncs, or answers `NotPrimary`.
    pub brokers: Vec<String>,
    /// Slabs the pool tries to hold at all times.
    pub target_slabs: u32,
    /// Partial-allocation floor per request.
    pub min_slabs: u32,
    /// Lease duration requested from the broker.
    pub lease_ttl: Duration,
    /// Renew a lease once its remaining TTL drops below this.
    pub renew_margin: Duration,
    /// Opportunistic maintenance cadence inside `call`.
    pub maintain_every: Duration,
    /// After a failed broker reconnect or call, don't retry (and thus
    /// stall a data call again) until a backoff delay has passed. This
    /// is the *first* window of a capped exponential schedule with
    /// seeded jitter ([`Backoff`]): small enough that failover to a
    /// standby is prompt, doubling per consecutive failure toward
    /// `reconnect_backoff_cap` so a wedged broker can't keep the data
    /// path stalled back-to-back.
    pub reconnect_backoff: Duration,
    /// Ceiling of the reconnect backoff schedule.
    pub reconnect_backoff_cap: Duration,
    /// Longest a data-plane call may wait for its response: a producer
    /// that stops answering mid-stream surfaces as a dead slot (cache
    /// misses) instead of wedging the consumer forever.
    pub data_call_timeout: Duration,
    /// Longest a control call may wait for the broker's answer.
    pub ctrl_call_timeout: Duration,
    /// In-flight frame window configured on each slot's data client:
    /// batches larger than the negotiated per-frame cap pipeline their
    /// chunks up to this many frames deep (1 = strict one-shot).
    pub data_window: usize,
    /// Chaos plane: fault schedule for dialed broker connections.
    pub ctrl_faults: Option<FaultPlan>,
    /// Chaos plane: fault schedule for dialed producer connections.
    pub data_faults: Option<FaultPlan>,
}

impl Default for RemotePoolConfig {
    fn default() -> Self {
        RemotePoolConfig {
            consumer: 1,
            brokers: vec!["127.0.0.1:7070".to_string()],
            target_slabs: 8,
            min_slabs: 1,
            lease_ttl: Duration::from_secs(600),
            renew_margin: Duration::from_secs(120),
            maintain_every: Duration::from_millis(50),
            reconnect_backoff: Duration::from_millis(500),
            reconnect_backoff_cap: Duration::from_secs(10),
            data_call_timeout: Duration::from_secs(2),
            ctrl_call_timeout: crate::net::control::CONTROL_CALL_TIMEOUT,
            data_window: 1,
            ctrl_faults: None,
            data_faults: None,
        }
    }
}

/// Live pool counters ([`crate::metrics::Counter`]s, so the running
/// pool can be observed — and cloned as a snapshot — without pausing
/// the data path). Reads are `.get()`.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// Leases granted to this pool over its lifetime.
    pub grants: Counter,
    /// Slots lost to revocation, expiry, or connection failure.
    pub slots_lost: Counter,
    pub renewals: Counter,
    pub renewal_failures: Counter,
    /// RequestSlabs calls made to refill toward the target.
    pub rerequests: Counter,
    /// Data-plane I/O errors absorbed as misses.
    pub io_errors: Counter,
    /// Calls routed to a dead slot and answered as misses.
    pub dead_calls: Counter,
    /// Broker control-plane failures (reconnected on next maintain).
    pub control_errors: Counter,
    /// Times the pool advanced to the next broker endpoint in its
    /// failover list.
    pub broker_failovers: Counter,
}

impl Observe for PoolStats {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        out.set_counter(scoped(prefix, "grants"), self.grants.get());
        out.set_counter(scoped(prefix, "slots_lost"), self.slots_lost.get());
        out.set_counter(scoped(prefix, "renewals"), self.renewals.get());
        out.set_counter(scoped(prefix, "renewal_failures"), self.renewal_failures.get());
        out.set_counter(scoped(prefix, "rerequests"), self.rerequests.get());
        out.set_counter(scoped(prefix, "io_errors"), self.io_errors.get());
        out.set_counter(scoped(prefix, "dead_calls"), self.dead_calls.get());
        out.set_counter(scoped(prefix, "control_errors"), self.control_errors.get());
        out.set_counter(scoped(prefix, "broker_failovers"), self.broker_failovers.get());
    }
}

struct Slot {
    lease: u64,
    endpoint: String,
    slabs: u32,
    deadline: Instant,
    client: KvClient,
}

/// The consumer's window onto the marketplace: leased slabs mounted as
/// remote KV capacity behind the [`KvTransport`] trait.
///
/// # Example
///
/// One broker, one producer agent, one consumer pool — the full
/// marketplace control plane on loopback — then a secure PUT/GET
/// through a leased remote slab:
///
/// ```
/// use memtrade::consumer::client::SecureKv;
/// use memtrade::market::{
///     BrokerServer, ProducerAgent, ProducerAgentConfig, RemotePool, RemotePoolConfig,
/// };
/// use std::time::{Duration, Instant};
///
/// let broker =
///     BrokerServer::start("127.0.0.1:0", Default::default(), Default::default()).unwrap();
/// let agent = ProducerAgent::start(ProducerAgentConfig {
///     producer: 1,
///     brokers: vec![broker.addr().to_string()],
///     data_addr: "127.0.0.1:0".to_string(),
///     capacity_bytes: 64 << 20,
///     harvest: false,
///     heartbeat: Duration::from_millis(25),
///     seed: 1,
///     ..Default::default()
/// })
/// .unwrap();
/// let mut pool = RemotePool::connect(RemotePoolConfig {
///     consumer: 9,
///     brokers: vec![broker.addr().to_string()],
///     target_slabs: 4,
///     min_slabs: 1,
///     maintain_every: Duration::from_millis(10),
///     ..Default::default()
/// })
/// .unwrap();
///
/// // Grants are leased and mounted asynchronously: drive the pool
/// // until the first secure write lands on remote memory.
/// let mut kv = SecureKv::with_iv_seed(Some([5u8; 16]), true, 1, 7);
/// let deadline = Instant::now() + Duration::from_secs(10);
/// while !kv.put(&mut pool, b"key", b"value") {
///     pool.maintain();
///     std::thread::sleep(Duration::from_millis(5));
///     assert!(Instant::now() < deadline, "no remote capacity mounted");
/// }
/// assert_eq!(kv.get(&mut pool, b"key"), Some(b"value".to_vec()));
/// drop(pool);
/// agent.stop();
/// broker.stop();
/// ```
pub struct RemotePool {
    cfg: RemotePoolConfig,
    ctrl: Option<CtrlClient>,
    /// Slot index (== `SecureKv` producer index) → slot; `None` is dead.
    /// Indices are stable for the pool's lifetime; dead ones are reused.
    slots: Vec<Option<Slot>>,
    /// Cached indices of live slots, for O(1) deterministic routing.
    live: Vec<u32>,
    held_slabs: u32,
    next_maintain: Instant,
    /// Earliest time a broker reconnect may be attempted again.
    reconnect_after: Instant,
    /// Jittered exponential schedule feeding `reconnect_after`.
    backoff: Backoff,
    /// Index into `cfg.brokers` of the endpoint currently in use.
    broker_idx: usize,
    /// Session nonce mixed into the wire-key namespace (see module doc).
    session: u64,
    /// Connections dialed so far — the per-connection index of the
    /// fault plans' determinism contract (control and data share it).
    conn_seq: u64,
    /// Consecutive `NotPrimary` refusals across broker endpoints: a
    /// streak means the pool is orbiting standbys without finding a
    /// primary (anomaly → flight-recorder dump).
    notprimary_streak: u32,
    pub stats: PoolStats,
    /// Data-plane call latency (µs) as *this consumer* observes it —
    /// one sample per routed call or per-producer batch group.
    pub data_call_us: Histogram,
}

impl RemotePool {
    /// Connect to the broker and request the target capacity. Succeeds
    /// even when no capacity is grantable yet (the pool keeps retrying);
    /// check [`Self::held_slabs`] if initial capacity is required.
    pub fn connect(cfg: RemotePoolConfig) -> io::Result<Self> {
        let session = crate::util::clock::unix_micros();
        // Seed the reconnect jitter per consumer (and session): at a
        // broker failover the whole fleet notices together, and
        // identically-seeded schedules would retry in lockstep.
        let backoff = Backoff::new(
            cfg.reconnect_backoff,
            cfg.reconnect_backoff_cap,
            cfg.consumer ^ session,
        );
        let mut pool = RemotePool {
            cfg,
            ctrl: None,
            slots: Vec::new(),
            live: Vec::new(),
            held_slabs: 0,
            next_maintain: Instant::now(),
            reconnect_after: Instant::now(),
            backoff,
            broker_idx: 0,
            session,
            conn_seq: 0,
            notprimary_streak: 0,
            stats: PoolStats::default(),
            data_call_us: Histogram::new(),
        };
        if let Some(plan) = pool.cfg.ctrl_faults.as_ref() {
            plan.log_banner("consumer-pool ctrl");
        }
        if let Some(plan) = pool.cfg.data_faults.as_ref() {
            plan.log_banner("consumer-pool data");
        }
        // Bounded initial dial, trying each endpoint once: a black-holed
        // broker fails over (or fails fast) here instead of hanging the
        // constructor on the OS SYN schedule.
        let mut last_err = None;
        for _ in 0..pool.cfg.brokers.len().max(1) {
            match pool.dial_ctrl(crate::net::control::HANDSHAKE_TIMEOUT) {
                Ok(c) => {
                    pool.ctrl = Some(c);
                    break;
                }
                Err(e) => {
                    last_err = Some(e);
                    pool.advance_broker();
                }
            }
        }
        match pool.ctrl {
            Some(_) => {
                pool.refill();
                Ok(pool)
            }
            None => Err(last_err.unwrap_or_else(|| {
                io::Error::new(io::ErrorKind::InvalidInput, "no broker endpoints configured")
            })),
        }
    }

    /// Dial the current broker endpoint, install the chaos plan if one
    /// is configured, and bound per-call response waits.
    fn dial_ctrl(&mut self, timeout: Duration) -> io::Result<CtrlClient> {
        let addr = self.cfg.brokers.get(self.broker_idx).ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "no broker endpoints configured")
        })?;
        let conn = self.conn_seq;
        self.conn_seq += 1;
        let mut ctrl = match &self.cfg.ctrl_faults {
            Some(plan) => CtrlClient::connect_faulty(addr, timeout, plan, conn)?,
            None => CtrlClient::connect_timeout(addr, timeout)?,
        };
        ctrl.set_call_timeout(self.cfg.ctrl_call_timeout)?;
        Ok(ctrl)
    }

    /// Rotate to the next broker endpoint in the failover list.
    fn advance_broker(&mut self) {
        if self.cfg.brokers.len() > 1 {
            self.broker_idx = (self.broker_idx + 1) % self.cfg.brokers.len();
            self.stats.broker_failovers.inc();
        }
    }

    pub fn held_slabs(&self) -> u32 {
        self.held_slabs
    }

    /// Everything this pool observes, on the shared metrics plane.
    pub fn metrics(&self) -> MetricSet {
        let mut out = MetricSet::new();
        self.stats.observe("pool", &mut out);
        out.set_histogram("pool.data_call_us", self.data_call_us.snapshot());
        out.set_gauge("pool.held_slabs", self.held_slabs as i64);
        out.set_gauge("pool.live_slots", self.live.len() as i64);
        out
    }

    pub fn live_slots(&self) -> usize {
        self.live.len()
    }

    /// Total slot indices ever in use (live + dead).
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Endpoints currently served, one entry per live slot (a producer
    /// backing several leases appears several times).
    pub fn live_endpoints(&self) -> Vec<String> {
        self.live
            .iter()
            .filter_map(|&i| self.slots[i as usize].as_ref())
            .map(|s| s.endpoint.clone())
            .collect()
    }

    /// Distinct producer endpoints currently backing live slots.
    pub fn distinct_endpoints(&self) -> Vec<String> {
        let mut eps = self.live_endpoints();
        eps.sort();
        eps.dedup();
        eps
    }

    fn rebuild_live(&mut self) {
        self.live = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i as u32)
            .collect();
    }

    fn kill_slot(&mut self, index: usize) {
        if let Some(slot) = self.slots.get_mut(index).and_then(|s| s.take()) {
            self.held_slabs -= slot.slabs;
            self.stats.slots_lost.inc();
            self.rebuild_live();
        }
    }

    fn add_grant(&mut self, g: GrantInfo, now: Instant) {
        let conn = self.conn_seq;
        self.conn_seq += 1;
        let dialed = match &self.cfg.data_faults {
            Some(plan) => KvClient::connect_faulty(&g.endpoint, DIAL_TIMEOUT, plan, conn),
            None => KvClient::connect_timeout(&g.endpoint, DIAL_TIMEOUT),
        };
        let mut client = match dialed {
            Ok(c) => c,
            Err(_) => {
                // Producer vanished between grant and dial; the lease
                // will expire broker-side.
                self.stats.slots_lost.inc();
                return;
            }
        };
        // A slot that stops answering must become a dead slot (misses),
        // not a wedged consumer: bound every data call's response wait.
        if client.set_call_timeout(Some(self.cfg.data_call_timeout)).is_err() {
            self.stats.slots_lost.inc();
            return;
        }
        client.set_window(self.cfg.data_window);
        let slot = Slot {
            lease: g.lease,
            endpoint: g.endpoint,
            slabs: g.slabs,
            deadline: now + Duration::from_micros(g.ttl_us),
            client,
        };
        self.held_slabs += slot.slabs;
        self.stats.grants.inc();
        match self.slots.iter().position(Option::is_none) {
            Some(i) => self.slots[i] = Some(slot),
            None => self.slots.push(Some(slot)),
        }
        self.rebuild_live();
    }

    fn reconnect_ctrl(&mut self) -> bool {
        if self.ctrl.is_some() {
            return true;
        }
        let now = Instant::now();
        if now < self.reconnect_after {
            return false;
        }
        match self.dial_ctrl(DIAL_TIMEOUT) {
            Ok(c) => {
                self.ctrl = Some(c);
                self.backoff.reset();
                true
            }
            Err(_) => {
                self.stats.control_errors.inc();
                self.reconnect_after = now + self.backoff.next_delay();
                // Try the next endpoint on the following attempt: an
                // unreachable primary usually means its standby serves.
                self.advance_broker();
                false
            }
        }
    }

    /// A broker answered `NotPrimary`. One refusal is normal mid-
    /// failover; three in a row means the pool is orbiting standbys
    /// without ever finding a primary — dump the flight recorder so the
    /// orbit is diagnosable after the fact (reset on any grant/renew).
    fn note_notprimary(&mut self) {
        self.notprimary_streak += 1;
        if self.notprimary_streak == 3 {
            trace::dump("consumer", "notprimary-storm");
        }
    }

    /// A control call failed: the connection is desynced, the broker is
    /// wedged, or it answered `NotPrimary`. Drop it, advance to the
    /// next endpoint, and back off, so the data path — which runs
    /// maintenance inline — pays at most one stall per backoff window.
    fn ctrl_failed(&mut self) {
        self.stats.control_errors.inc();
        self.ctrl = None;
        self.reconnect_after = Instant::now() + self.backoff.next_delay();
        self.advance_broker();
    }

    /// Ask the broker for whatever is missing toward the target.
    fn refill(&mut self) {
        if self.held_slabs >= self.cfg.target_slabs || !self.reconnect_ctrl() {
            return;
        }
        let want = self.cfg.target_slabs - self.held_slabs;
        self.stats.rerequests.inc();
        // Control verbs carry a trace id too: the broker's grant span
        // joins this trace, tying placement decisions to the consumer
        // that asked.
        let span = SpanGuard::root(Role::Consumer, TraceOp::Grant);
        let req = CtrlRequest::RequestSlabs {
            consumer: self.cfg.consumer,
            slabs: want,
            min_slabs: self.cfg.min_slabs.min(want),
            ttl_us: self.cfg.lease_ttl.as_micros() as u64,
            trace: span.trace_id(),
        };
        match self.ctrl.as_mut().unwrap().call(&req) {
            Ok(CtrlResponse::Grants { leases }) => {
                self.notprimary_streak = 0;
                let now = Instant::now();
                for g in leases {
                    self.add_grant(g, now);
                }
            }
            // A standby answered: this endpoint holds the book but does
            // not grant. Advance to the next; waiting here (the
            // NoCapacity treatment) would starve the pool forever.
            Ok(CtrlResponse::Refused { code: RefuseCode::NotPrimary, .. }) => {
                self.note_notprimary();
                self.ctrl_failed();
            }
            Ok(CtrlResponse::Refused { .. }) => {} // NoCapacity: retry later
            Ok(_) => {
                // Response type doesn't match the request: the stream
                // is desynced (e.g. a duplicated frame shifted every
                // later response by one). Interpreting shifted
                // responses would corrupt lease state forever — drop
                // the connection and start clean. Chaos flushed this
                // out: `duplicate` faults left pools permanently
                // misreading renews as grants and vice versa.
                self.ctrl_failed();
            }
            Err(_) => self.ctrl_failed(),
        }
    }

    /// Lease upkeep: expire overdue slots locally, renew the ones coming
    /// due, re-request lost capacity. Runs opportunistically from
    /// `call`; long-idle consumers should call it on a timer.
    pub fn maintain(&mut self) {
        let now = Instant::now();
        // Local expiry first: a slot we failed to renew in time is dead
        // even if the broker is unreachable.
        let overdue: Vec<usize> = self
            .live
            .iter()
            .map(|&i| i as usize)
            .filter(|&i| {
                self.slots[i].as_ref().is_some_and(|s| now >= s.deadline)
            })
            .collect();
        for i in overdue {
            self.kill_slot(i);
        }
        // Renewals.
        if self.reconnect_ctrl() {
            let due: Vec<usize> = self
                .live
                .iter()
                .map(|&i| i as usize)
                .filter(|&i| {
                    self.slots[i]
                        .as_ref()
                        .is_some_and(|s| s.deadline.saturating_duration_since(now)
                            < self.cfg.renew_margin)
                })
                .collect();
            for i in due {
                let lease = self.slots[i].as_ref().unwrap().lease;
                let span = SpanGuard::root(Role::Consumer, TraceOp::Renew);
                let renew = CtrlRequest::Renew {
                    consumer: self.cfg.consumer,
                    lease,
                    trace: span.trace_id(),
                };
                match self.ctrl.as_mut().unwrap().call(&renew) {
                    // The ack must name the lease we renewed: a Renewed
                    // for a *different* lease is a shifted (desynced)
                    // stream that happens to be renew-shaped, and
                    // extending this slot on its TTL would keep traffic
                    // flowing to slabs the broker already reclaimed.
                    Ok(CtrlResponse::Renewed { lease: acked, ttl_us }) if acked == lease => {
                        self.notprimary_streak = 0;
                        self.stats.renewals.inc();
                        if let Some(slot) = self.slots[i].as_mut() {
                            slot.deadline = now + Duration::from_micros(ttl_us);
                        }
                    }
                    // `NotPrimary` says nothing about *this lease* —
                    // the standby simply doesn't serve renews. Killing
                    // the slot would shed healthy capacity at exactly
                    // the moment of failover; move brokers instead.
                    Ok(CtrlResponse::Refused { code: RefuseCode::NotPrimary, .. }) => {
                        self.note_notprimary();
                        self.ctrl_failed();
                        break;
                    }
                    Ok(CtrlResponse::Refused { .. }) => {
                        // Refused: expired, revoked, or forgotten — the
                        // remote memory is gone; downstream it's misses.
                        self.stats.renewal_failures.inc();
                        self.kill_slot(i);
                    }
                    Ok(_) => {
                        // Desynced stream (see refill): killing slots on
                        // shifted responses would shed healthy capacity.
                        self.ctrl_failed();
                        break;
                    }
                    Err(_) => {
                        self.ctrl_failed();
                        break;
                    }
                }
            }
        }
        self.refill();
    }

    /// Release every live lease (graceful teardown).
    pub fn release_all(&mut self) {
        let leases: Vec<u64> = self
            .live
            .iter()
            .filter_map(|&i| self.slots[i as usize].as_ref())
            .map(|s| s.lease)
            .collect();
        if !leases.is_empty() && self.reconnect_ctrl() {
            let consumer = self.cfg.consumer;
            let ctrl = self.ctrl.as_mut().unwrap();
            for lease in leases {
                let _ = ctrl.call(&CtrlRequest::Release { consumer, lease });
            }
        }
        for i in 0..self.slots.len() {
            if self.slots[i].is_some() {
                self.kill_slot(i);
                // A released slot is not "lost". Guarded decrement: if a
                // racing maintenance path (or a future kill_slot variant)
                // ever recovers a slot without recording the loss, the
                // un-count must saturate at zero — not wrap the gauge to
                // 2^64 - 1 and report a catastrophic loss rate forever.
                self.stats.slots_lost.dec_saturating();
            }
        }
    }

    /// The response a missing producer yields: exactly what the store
    /// answers for absent data, so `SecureKv` treats it as a miss.
    fn miss_response(req: &Request) -> Response {
        match req {
            Request::Get { .. } => Response::NotFound,
            Request::Put { .. } => Response::Rejected,
            Request::Delete { .. } => Response::Deleted(false),
            Request::Ping => Response::Pong,
        }
    }

    /// Prefix the request key with our consumer id and session nonce:
    /// producer stores are shared across this producer's leases *and
    /// consumers*, and `SecureKv` counters collide both across
    /// consumers and across one consumer's restarts.
    fn namespace_key(&self, req: &mut Request) {
        let (Request::Get { key } | Request::Put { key, .. } | Request::Delete { key }) = req
        else {
            return;
        };
        let mut namespaced = Vec::with_capacity(16 + key.len());
        namespaced.extend_from_slice(&self.cfg.consumer.to_le_bytes());
        namespaced.extend_from_slice(&self.session.to_le_bytes());
        namespaced.extend_from_slice(key);
        *key = namespaced;
    }
}

impl Drop for RemotePool {
    fn drop(&mut self) {
        self.release_all();
    }
}

impl KvTransport for RemotePool {
    fn call(&mut self, producer_index: u32, mut req: Request) -> Response {
        let now = Instant::now();
        if now >= self.next_maintain {
            self.maintain();
            self.next_maintain = now + self.cfg.maintain_every;
        }
        self.namespace_key(&mut req);
        if producer_index == DEAD_ROUTE {
            // `route_put` found zero live slots: a deterministic
            // recorded miss. Even if the maintain above just revived
            // capacity, this call was routed dead and stays dead —
            // resurrecting it onto an arbitrary slot index would hand
            // `SecureKv` metadata at an index the routing never chose.
            self.stats.dead_calls.inc();
            return Self::miss_response(&req);
        }
        let index = producer_index as usize;
        // Route span: which slot (lease + producer index) this op landed
        // on — the parent of the client's wire span. No-op untraced.
        let mut route = SpanGuard::child(Role::Consumer, TraceOp::Route);
        let t_call = Instant::now();
        let result = match self.slots.get_mut(index).and_then(|s| s.as_mut()) {
            Some(slot) => {
                route.set_lease(slot.lease);
                route.set_producer(producer_index as u64);
                slot.client.call(&req)
            }
            None => {
                self.stats.dead_calls.inc();
                return Self::miss_response(&req);
            }
        };
        self.data_call_us.record_traced(t_call.elapsed().as_micros() as u64, trace::current().0);
        match result {
            Ok(resp) => resp,
            Err(_) => {
                // Connection loss == the remote memory is gone: kill the
                // slot, answer as a miss, refill in the background.
                self.stats.io_errors.inc();
                self.kill_slot(index);
                self.maintain();
                Self::miss_response(&req)
            }
        }
    }

    /// Batched calls against one routed slot: the whole group travels
    /// as true batch frames on the slot's connection (chunked to the
    /// handshake-negotiated cap). `SecureKv`'s multi-ops group by
    /// routed slot before calling, so a consumer multi-get fans out as
    /// one batch per producer. Dead slots degrade to *per-op* misses —
    /// exactly the single-call loss model — and a connection failure
    /// mid-batch kills the slot and answers every op in the group as a
    /// miss (the acked-write guarantee lives with surviving producers,
    /// not the lost connection).
    fn call_multi(&mut self, producer_index: u32, mut reqs: Vec<Request>) -> Vec<Response> {
        if reqs.is_empty() {
            return Vec::new();
        }
        let now = Instant::now();
        if now >= self.next_maintain {
            self.maintain();
            self.next_maintain = now + self.cfg.maintain_every;
        }
        for req in &mut reqs {
            self.namespace_key(req);
        }
        if producer_index == DEAD_ROUTE {
            self.stats.dead_calls.add(reqs.len() as u64);
            return reqs.iter().map(Self::miss_response).collect();
        }
        let index = producer_index as usize;
        let mut route = SpanGuard::child(Role::Consumer, TraceOp::Route);
        let t_call = Instant::now();
        let result = match self.slots.get_mut(index).and_then(|s| s.as_mut()) {
            Some(slot) => {
                route.set_lease(slot.lease);
                route.set_producer(producer_index as u64);
                slot.client.call_batch(&reqs)
            }
            None => {
                self.stats.dead_calls.add(reqs.len() as u64);
                return reqs.iter().map(Self::miss_response).collect();
            }
        };
        self.data_call_us.record_traced(t_call.elapsed().as_micros() as u64, trace::current().0);
        match result {
            Ok(resps) if resps.len() == reqs.len() => resps,
            Ok(_) | Err(_) => {
                self.stats.io_errors.inc();
                self.kill_slot(index);
                self.maintain();
                reqs.iter().map(Self::miss_response).collect()
            }
        }
    }

    /// Deterministic key→slab routing over the live slots. With zero
    /// live slots the PUT is routed to [`DEAD_ROUTE`], the recorded-
    /// miss path — never to the caller's round-robin hint, which is a
    /// producer index in *`SecureKv`'s* table, not ours, and may be
    /// dead, reused, or out of range (chaos flushed this out as
    /// sporadic PUTs landing on a just-revived unrelated slot).
    fn route_put(&mut self, key: &[u8], _round_robin_hint: u32) -> u32 {
        if self.live.is_empty() {
            DEAD_ROUTE
        } else {
            self.live[(fnv1a_64(key) % self.live.len() as u64) as usize]
        }
    }
}

//! The broker daemon: the in-process [`Broker`] (registry, placement,
//! pricing, availability prediction) served over the control-plane wire
//! protocol, with lease expiry tracked on a monotonic clock, dead
//! producers swept on heartbeat timeout, and per-producer usage
//! histories persisted for the predictor across restarts.
//!
//! Threading: one accept loop, one thread per control connection (the
//! control plane is low-rate — heartbeats and lease operations, never
//! data), and one maintenance ticker (expiry sweep, death sweep,
//! forecast refresh, accounting). All share one `Mutex<State>`; the
//! data plane never touches it.
//!
//! Revocations and grants reach producers by piggybacking on heartbeat
//! *acks* (pull, not push): each ack carries the authoritative store
//! size plus the grants/ends since the last ack. An ack lost in flight
//! is repaired by the agent's reconnect: re-registration keeps the
//! producer's active leases and re-announces all of them on the next
//! ack (and `target_bytes` is authoritative in every ack regardless).
//! Consumers learn of lost leases when a renew is refused or the
//! data-plane connection drops — both of which the
//! [`crate::market::RemotePool`] turns into cache misses.
//!
//! ## Warm-standby failover
//!
//! A second daemon started with `standby_of` replicates the primary:
//! every market state change the primary makes is appended to a bounded
//! lease-event log ([`crate::market::lease::LeaseEvent`]), and the
//! standby pulls it with `ReplicaPoll` on its maintenance cadence,
//! replaying each event through its own [`LeaseTable`] and adopting
//! grants into its in-process [`Broker`] (so post-takeover lease ids
//! never collide). It also tails the shared usage-history dir so its
//! predictor knows what the primary knew. Until takeover it answers
//! every market verb with `NotPrimary` (only `StatsQuery` and
//! `ReplicaPoll` are served), so a client that dials it by mistake is
//! told to move on rather than silently served stale state. When
//! replication polls fail for `takeover_after`, the standby promotes
//! itself; producers and consumers fail over on their own (ordered
//! endpoint lists), and the keep-leases re-registration path repairs
//! whatever a replication gap lost.

use crate::broker::{AvailabilityPredictor, Broker, ConsumerRequest, PricingEngine, PricingStrategy};
use crate::core::config::BrokerConfig;
use crate::core::{ConsumerId, Lease, LeaseId, Money, ProducerId, SimTime, GIB};
use crate::market::lease::{LeaseError, LeaseEvent, LeaseState, LeaseTable};
use crate::metrics::{MetricSet, Observe, Registry as MetricsRegistry};
use crate::net::control::{
    CtrlClient, CtrlRequest, CtrlResponse, GrantInfo, HelloInfo, ProducerGrant, RefuseCode,
    CONTROL_MAGIC,
};
use crate::net::event_loop::{spawn_loops, EventLoops, Service};
use crate::net::faults::FaultPlan;
use crate::net::wire::CodecError;
use crate::trace::{self, Op as TraceOp, Role as TraceRole, SpanGuard};
use std::collections::{HashMap, VecDeque};
use std::io::{self, Write};
use std::net::{TcpListener, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Daemon-side tunables (the market economics live in [`BrokerConfig`]).
#[derive(Clone, Debug)]
pub struct BrokerServerConfig {
    /// Exogenous spot price used for pricing, $/GB·hour.
    pub spot_per_gb_hour: Money,
    /// Maintenance cadence: expiry sweep and death sweep.
    pub tick: Duration,
    /// Forecast/pricing cadence. The batched AR fit is the broker's one
    /// expensive computation and runs under the state lock — it gets
    /// its own, much slower clock (the paper refreshes every 5 min).
    pub forecast_every: Duration,
    /// A producer missing heartbeats for this long is declared dead and
    /// its leases revoked.
    pub producer_timeout: Duration,
    /// Persist per-producer usage histories here (one file per producer)
    /// and replay them on re-registration, so the predictor survives
    /// broker and producer restarts.
    pub history_dir: Option<PathBuf>,
    /// Run the real availability forecast only once every non-empty
    /// history has at least this many samples (the AR fit needs a
    /// window); younger producers are leased optimistically at their
    /// reported free slabs.
    pub forecast_min_samples: usize,
    /// Chaos plane: fault schedule installed on every accepted control
    /// connection (None in production — the accepted streams are then
    /// plain pass-throughs).
    pub faults: Option<FaultPlan>,
    /// Warm-standby mode: poll this primary's lease-event log, replay
    /// it, and refuse market verbs with `NotPrimary` until takeover.
    /// Point `history_dir` at the primary's so usage histories carry
    /// over too.
    pub standby_of: Option<String>,
    /// Standby only: promote to primary after this long without one
    /// successful replication poll.
    pub takeover_after: Duration,
}

impl Default for BrokerServerConfig {
    fn default() -> Self {
        BrokerServerConfig {
            spot_per_gb_hour: Money::from_dollars(0.0005),
            tick: Duration::from_millis(100),
            forecast_every: Duration::from_secs(60),
            producer_timeout: Duration::from_secs(3),
            history_dir: None,
            forecast_min_samples: 16,
            faults: None,
            standby_of: None,
            takeover_after: Duration::from_secs(2),
        }
    }
}

/// Replication log bound: events kept for standbys to poll. A standby
/// that falls further behind than this sees a sequence gap (tolerated —
/// re-registration at takeover repairs what it missed), which beats the
/// primary buffering without bound for a standby that may never return.
const REPL_LOG_CAP: usize = 65_536;

/// Most events one `ReplicaPoll` answer carries, whatever the poller
/// asked for: keeps a catch-up answer a bounded frame, not a 65k-event
/// wall. The standby simply polls again for the rest.
const REPL_POLL_MAX: u32 = 512;

/// Best-effort on-disk usage history: `<dir>/producer-<id>.history`,
/// one `"<us> <used_gb>"` line per heartbeat. Loads run rarely (agent
/// registration) and read only a bounded tail; appends run on a
/// dedicated writer thread so no disk I/O ever happens under the
/// broker's state lock.
#[derive(Clone)]
struct HistoryStore {
    dir: PathBuf,
}

/// One usage sample on its way to the history writer thread.
type HistorySample = (u64, u64, f32);

/// Replay cap: the registry's usage ring holds 288 samples
/// ([`Registry::register_producer`] uses `TimeSeries::new(288)`), so
/// replaying more would be parsed and immediately overwritten — all
/// while holding the broker's state lock.
const HISTORY_REPLAY_CAP: usize = 288;

impl HistoryStore {
    fn open(dir: PathBuf) -> io::Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(HistoryStore { dir })
    }

    fn path(&self, producer: u64) -> PathBuf {
        self.dir.join(format!("producer-{producer}.history"))
    }

    /// Bytes of file tail read on load: comfortably holds
    /// `HISTORY_REPLAY_CAP` lines, and bounds the work done (under the
    /// state lock) when an agent re-registers against a large file.
    const TAIL_BYTES: u64 = 64 * 1024;
    /// Compaction threshold: an append beyond this first rewrites the
    /// file down to the replay tail, so heartbeats can't grow it
    /// without bound.
    const COMPACT_BYTES: u64 = 1 << 22;

    /// Returns the parsed tail samples plus a count of lines skipped as
    /// unparsable — above all the torn final line a crash mid-append
    /// leaves behind. A history file is best-effort forecast input, so
    /// replay tolerates damage line by line; it never errors the whole
    /// load over one bad record.
    fn load(&self, producer: u64) -> (Vec<(u64, f32)>, usize) {
        use std::io::{Read, Seek, SeekFrom};
        let Ok(mut f) = std::fs::File::open(self.path(producer)) else {
            return (Vec::new(), 0);
        };
        let len = f.metadata().map(|m| m.len()).unwrap_or(0);
        let truncated = len > Self::TAIL_BYTES;
        if truncated && f.seek(SeekFrom::End(-(Self::TAIL_BYTES as i64))).is_err() {
            return (Vec::new(), 0);
        }
        let mut bytes = Vec::new();
        if f.read_to_end(&mut bytes).is_err() {
            return (Vec::new(), 0);
        }
        // Torn appends can leave non-UTF-8 garbage too; keep whatever
        // lines survive rather than refusing the file.
        let text = String::from_utf8_lossy(&bytes);
        let tail: &str = if truncated {
            // The seek likely landed mid-line; drop the partial one.
            text.split_once('\n').map(|(_, rest)| rest).unwrap_or("")
        } else {
            text.as_ref()
        };
        let mut skipped = 0usize;
        let mut samples: Vec<(u64, f32)> = Vec::new();
        for line in tail.lines() {
            let mut it = line.split_whitespace();
            let parsed = (|| {
                let us: u64 = it.next()?.parse().ok()?;
                let gb: f32 = it.next()?.parse().ok()?;
                Some((us, gb))
            })();
            match parsed {
                Some(s) => samples.push(s),
                None if line.trim().is_empty() => {}
                None => skipped += 1,
            }
        }
        if samples.len() > HISTORY_REPLAY_CAP {
            samples.drain(..samples.len() - HISTORY_REPLAY_CAP);
        }
        (samples, skipped)
    }

    fn append(&self, producer: u64, us: u64, used_gb: f32) {
        let path = self.path(producer);
        let oversized = std::fs::metadata(&path)
            .map(|m| m.len() > Self::COMPACT_BYTES)
            .unwrap_or(false);
        if oversized {
            let keep = self.load(producer).0;
            let mut text = String::with_capacity(keep.len() * 24);
            for (us, gb) in &keep {
                text.push_str(&format!("{us} {gb}\n"));
            }
            // Write-temp-then-rename: a crash mid-compaction leaves the
            // old file or the new one, never a half-written history.
            let tmp = path.with_extension("history.tmp");
            let r = std::fs::write(&tmp, text).and_then(|_| std::fs::rename(&tmp, &path));
            if let Err(e) = r {
                eprintln!("broker: history compaction failed for producer {producer}: {e}");
            }
        }
        let r = std::fs::OpenOptions::new()
            .create(true)
            .read(true)
            .append(true)
            .open(path)
            .and_then(|mut f| {
                // A crash mid-append can leave the file without its
                // trailing newline; gluing the next sample onto the torn
                // line would forge a parsable-but-bogus record. Check the
                // last byte and start a fresh line if needed.
                use std::io::{Read, Seek, SeekFrom};
                if f.metadata()?.len() > 0 {
                    f.seek(SeekFrom::End(-1))?;
                    let mut b = [0u8; 1];
                    f.read_exact(&mut b)?;
                    if b[0] != b'\n' {
                        writeln!(f)?;
                    }
                }
                writeln!(f, "{us} {used_gb}")
            });
        if let Err(e) = r {
            eprintln!("broker: history append failed for producer {producer}: {e}");
        }
    }
}

struct ProducerEntry {
    endpoint: String,
    last_heartbeat_us: u64,
}

/// Which side of the failover pair this daemon currently is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Role {
    Primary,
    Standby,
}

struct State {
    broker: Broker,
    leases: LeaseTable,
    producers: HashMap<u64, ProducerEntry>,
    history: Option<HistoryStore>,
    /// Samples queued for the history writer thread (never blocks).
    history_tx: Option<mpsc::Sender<HistorySample>>,
    cfg: BrokerServerConfig,
    /// Daemon-level live counters/gauges (control verbs, sweeps) —
    /// served to `StatsQuery` along with the market + per-producer view.
    telemetry: MetricsRegistry,
    /// `Standby` refuses market verbs and replays the primary's log
    /// until promoted.
    role: Role,
    /// Append-only lease-event log served to `ReplicaPoll`, bounded at
    /// [`REPL_LOG_CAP`] (older events are evicted; a lagging standby
    /// sees the gap). A standby keeps its own copy current too, so its
    /// log continues seamlessly after takeover.
    repl_log: VecDeque<LeaseEvent>,
    /// Sequence number of `repl_log.front()`.
    repl_base_seq: u64,
    /// Standby only: newest history-file timestamp replayed per
    /// producer, so periodic tailing never double-feeds the predictor.
    history_replayed_us: HashMap<u64, u64>,
}

impl State {
    fn core_lease(rec: &crate::market::lease::LeaseRecord) -> Lease {
        Lease {
            id: LeaseId(rec.id),
            consumer: ConsumerId(rec.consumer),
            producer: ProducerId(rec.producer),
            slabs: rec.slabs,
            slab_bytes: rec.slab_bytes,
            start: SimTime::from_micros(rec.granted_us),
            duration: SimTime::from_micros(rec.duration_us),
            price_per_slab_hour: Money(rec.price_nd_per_slab_hour),
        }
    }

    /// Append one event to the replication log (evicting the oldest
    /// past [`REPL_LOG_CAP`]). Every market state change flows through
    /// here or dies unreplicated.
    fn log_event(&mut self, ev: LeaseEvent) {
        if self.repl_log.len() >= REPL_LOG_CAP {
            self.repl_log.pop_front();
            self.repl_base_seq += 1;
        }
        self.repl_log.push_back(ev);
    }

    /// Apply queued lease terminations to the registry (reputation,
    /// free-slab return) and the replication log. Revocations count as
    /// broken leases (§5). This is the single choke point every
    /// terminal transition — sweep, release, revoke, death — drains
    /// through, so it is also where ends are replicated.
    fn apply_lease_ends(&mut self) {
        for end in self.leases.take_ended() {
            let lease = Self::core_lease(&end.record);
            let counter = match end.cause {
                LeaseState::Expired => "leases.expired",
                LeaseState::Revoked => "leases.revoked",
                LeaseState::Released => "leases.released",
                LeaseState::Active => "leases.ended_active",
            };
            self.telemetry.counter(counter).inc();
            let id = end.record.id;
            match end.cause {
                LeaseState::Expired => self.log_event(LeaseEvent::Expired { lease: id }),
                LeaseState::Revoked => self.log_event(LeaseEvent::Revoked { lease: id }),
                LeaseState::Released => self.log_event(LeaseEvent::Released { lease: id }),
                LeaseState::Active => {}
            }
            self.broker.lease_ended(&lease, end.cause == LeaseState::Revoked);
        }
    }

    /// Standby: replay one replicated event into the full market state
    /// — lease table, in-process broker (registry accounting + id
    /// counter), and producer membership — and mirror it into our own
    /// log so it continues seamlessly after takeover. End events are
    /// not mirrored directly: applying them queues the same terminal
    /// transition locally, and [`Self::apply_lease_ends`] logs it.
    fn apply_replicated(&mut self, ev: &LeaseEvent, now_us: u64) {
        match ev {
            LeaseEvent::Granted { lease, .. } => {
                // Fresh unless an *active* record already holds the id
                // (a re-polled overlap); terminal records are superseded
                // and their registry accounting was already unwound.
                let fresh =
                    self.leases.get(*lease).map_or(true, |r| r.state.is_terminal());
                self.leases.apply_event(ev, now_us);
                if fresh {
                    if let Some(rec) = self.leases.get(*lease) {
                        self.broker.adopt_lease(&Self::core_lease(rec));
                        self.log_event(ev.clone());
                    }
                }
            }
            LeaseEvent::Renewed { .. } => {
                self.leases.apply_event(ev, now_us);
                self.log_event(ev.clone());
            }
            LeaseEvent::Released { .. }
            | LeaseEvent::Revoked { .. }
            | LeaseEvent::Expired { .. } => {
                self.leases.apply_event(ev, now_us);
            }
            LeaseEvent::ProducerUp { producer, endpoint, capacity_gb } => {
                self.broker
                    .registry
                    .register_producer(ProducerId(*producer), *capacity_gb);
                self.producers.insert(
                    *producer,
                    ProducerEntry { endpoint: endpoint.clone(), last_heartbeat_us: now_us },
                );
                self.log_event(ev.clone());
            }
            LeaseEvent::ProducerDown { producer } => {
                self.log_event(ev.clone());
                self.leases.apply_event(ev, now_us);
                self.apply_lease_ends();
                self.broker.registry.deregister_producer(ProducerId(*producer));
                self.producers.remove(producer);
                self.history_replayed_us.remove(producer);
            }
        }
        self.apply_lease_ends();
    }

    /// Standby: replay usage-history samples appended since the last
    /// tail, for every producer learned from the log, so the predictor
    /// knows at takeover what the primary knew. Bounded work under the
    /// lock: one 64 KB tail per producer, on the slow tail cadence.
    fn tail_history(&mut self) {
        let Some(h) = self.history.clone() else { return };
        let ids: Vec<u64> = self.producers.keys().copied().collect();
        for id in ids {
            let last = self.history_replayed_us.get(&id).copied();
            let (samples, skipped) = h.load(id);
            if skipped > 0 {
                self.telemetry.counter("history.lines_skipped").add(skipped as u64);
            }
            for (us, gb) in samples {
                if last.map_or(true, |l| us > l) {
                    self.broker
                        .registry
                        .report_usage(ProducerId(id), SimTime::from_micros(us), gb);
                    self.history_replayed_us.insert(id, us);
                }
            }
        }
    }

    /// Takeover: the primary went silent past the deadline. Start
    /// granting, and stamp every known producer as just-heard-from —
    /// each gets a full heartbeat timeout to fail over and re-register
    /// (which re-announces its leases and repairs anything a
    /// replication gap lost) before the death sweep may claim it.
    fn promote(&mut self, now_us: u64) {
        self.role = Role::Primary;
        self.telemetry.counter("repl.takeovers").inc();
        // Takeover is an anomaly worth a flight-recorder dump: the spans
        // leading up to it show what the replication loop last saw.
        trace::dump("broker", "takeover");
        for e in self.producers.values_mut() {
            e.last_heartbeat_us = now_us;
        }
        self.apply_optimistic_safety();
    }

    /// Producers whose history is still too short for the AR fit are
    /// leased at face value: what they report free is presumed safe.
    fn apply_optimistic_safety(&mut self) {
        let min = self.cfg.forecast_min_samples;
        for p in self.broker.registry.producers_mut() {
            if p.usage.len() < min {
                p.predicted_safe_slabs = p.free_slabs + p.slabs_leased_now;
            }
        }
    }

    /// Forecast refresh, gated until every non-empty history can support
    /// the AR fit (a single short series would poison the whole batch).
    fn refresh_forecasts(&mut self, now_us: u64) {
        let min = self.cfg.forecast_min_samples;
        let lens: Vec<usize> = self
            .broker
            .registry
            .producers()
            .map(|p| p.usage.len())
            .filter(|&n| n > 0)
            .collect();
        if !lens.is_empty() && lens.iter().all(|&n| n >= min) {
            let now = SimTime::from_micros(now_us);
            self.broker.predictor.refresh(&mut self.broker.registry, now);
        }
        self.broker.pricing.adjust(
            &self.broker.registry,
            self.cfg.spot_per_gb_hour,
            self.broker.cfg.slab_bytes,
        );
        self.apply_optimistic_safety();
    }

    /// Declare producers dead after `producer_timeout` without a
    /// heartbeat: revoke their leases, forget their endpoints.
    fn sweep_dead_producers(&mut self, now_us: u64) {
        let timeout_us = self.cfg.producer_timeout.as_micros() as u64;
        let dead: Vec<u64> = self
            .producers
            .iter()
            .filter(|(_, e)| now_us.saturating_sub(e.last_heartbeat_us) > timeout_us)
            .map(|(&id, _)| id)
            .collect();
        for id in dead {
            self.telemetry.counter("sweep.producers_dead").inc();
            self.drop_producer(id, now_us);
        }
    }

    /// The broker's whole observable state in one [`MetricSet`]: daemon
    /// counters, market-level gauges, the in-process broker's stats,
    /// and — crucially for `memtrade top` — the per-producer *observed*
    /// data-plane telemetry that placement ranks by.
    fn metrics(&self, now_us: u64) -> MetricSet {
        let mut m = self.telemetry.snapshot();
        self.broker.stats.observe("broker", &mut m);
        m.set_gauge("market.uptime_us", now_us as i64);
        // 0 = primary, 1 = standby (`memtrade top` names the role).
        m.set_gauge("market.role", (self.role == Role::Standby) as i64);
        m.set_gauge(
            "market.repl_log_seq",
            (self.repl_base_seq + self.repl_log.len() as u64) as i64,
        );
        m.set_gauge("market.producers", self.producers.len() as i64);
        m.set_gauge("market.active_leases", self.leases.active_count() as i64);
        m.set_gauge("market.price_nd_per_slab_hour", self.broker.current_price().0);
        for p in self.broker.registry.producers() {
            let id = p.id.0;
            let pre = format!("producer.{id}");
            m.set_gauge(format!("{pre}.observed_p99_us"), p.observed_p99_us as i64);
            m.set_gauge(format!("{pre}.ops_per_sec"), p.observed_ops_per_sec as i64);
            m.set_gauge(format!("{pre}.free_slabs"), p.free_slabs as i64);
            m.set_gauge(format!("{pre}.leased_slabs"), p.slabs_leased_now as i64);
            m.set_gauge(format!("{pre}.safe_slabs"), p.predicted_safe_slabs as i64);
            m.set_gauge(format!("{pre}.reputation_pct"), (p.reputation() * 100.0) as i64);
        }
        m
    }

    fn drop_producer(&mut self, id: u64, now_us: u64) {
        self.log_event(LeaseEvent::ProducerDown { producer: id });
        self.leases.revoke_all_for_producer(id, now_us);
        self.apply_lease_ends();
        self.broker.registry.deregister_producer(ProducerId(id));
        self.producers.remove(&id);
        self.history_replayed_us.remove(&id);
    }

    fn refused(code: RefuseCode, detail: impl Into<String>) -> CtrlResponse {
        CtrlResponse::Refused { code, detail: detail.into() }
    }

    /// Lease ids are a guessable counter, so lifecycle operations must
    /// prove identity: `Renew`/`Release` only by the lease's consumer,
    /// `Revoke` only by its producer. Returns the refusal, if any.
    fn verify_holder(&self, lease: u64, claimed: u64, as_consumer: bool) -> Option<CtrlResponse> {
        let rec = self.leases.get(lease)?;
        let holder = if as_consumer { rec.consumer } else { rec.producer };
        if holder == claimed {
            None
        } else {
            Some(Self::refused(
                RefuseCode::Malformed,
                format!("lease {lease} is not held by participant {claimed}"),
            ))
        }
    }

    fn refuse_lease_error(e: LeaseError) -> CtrlResponse {
        let code = match e {
            LeaseError::Unknown(_) => RefuseCode::UnknownLease,
            LeaseError::Ended(_, LeaseState::Expired) => RefuseCode::LeaseExpired,
            LeaseError::Ended(_, LeaseState::Revoked) => RefuseCode::LeaseRevoked,
            LeaseError::Ended(_, LeaseState::Released) => RefuseCode::LeaseReleased,
            LeaseError::Ended(_, LeaseState::Active) | LeaseError::Duplicate(_) => {
                RefuseCode::Malformed
            }
        };
        Self::refused(code, e.to_string())
    }

    fn handle(&mut self, req: CtrlRequest, now_us: u64) -> CtrlResponse {
        let now = SimTime::from_micros(now_us);
        // Lifecycle verbs carry the caller's trace id (v6): adopt it so
        // the broker's span lands in the same causal chain the consumer
        // or producer started. A zero id means the caller wasn't tracing.
        let (verb_trace, verb_op) = match &req {
            CtrlRequest::RequestSlabs { trace, .. } => (*trace, Some(TraceOp::Grant)),
            CtrlRequest::Renew { trace, .. } => (*trace, Some(TraceOp::Renew)),
            CtrlRequest::Revoke { trace, .. } => (*trace, Some(TraceOp::Revoke)),
            _ => (0, None),
        };
        let _adopt = (verb_trace != 0).then(|| trace::adopt(verb_trace, 0));
        let _verb_span = verb_op.map(|op| SpanGuard::child(TraceRole::Broker, op));
        // A standby serves observers and replicas only; every market
        // verb is told to try the next endpoint. Granting from two
        // brokers at once is the one thing failover must never do.
        if self.role == Role::Standby
            && !matches!(
                req,
                CtrlRequest::StatsQuery
                    | CtrlRequest::ReplicaPoll { .. }
                    | CtrlRequest::TraceQuery { .. }
            )
        {
            return Self::refused(
                RefuseCode::NotPrimary,
                "standby broker: not serving market requests until takeover",
            );
        }
        match req {
            CtrlRequest::Register { producer, capacity_gb, endpoint, free_bytes } => {
                // A re-registration while still considered alive is
                // usually a control-plane blip (lost ack, reconnect),
                // not a death: keep its active leases — a truly
                // restarted store just serves misses, which is the
                // system's loss model anyway — and re-announce them so
                // the agent relearns its book from the next ack. Actual
                // death is the heartbeat-timeout sweep's job.
                self.telemetry.counter("ctrl.registrations").inc();
                let rejoining = self.producers.contains_key(&producer);
                if rejoining {
                    self.leases.reset_announcements(producer);
                }
                let free_slabs = (free_bytes / self.broker.cfg.slab_bytes) as u32;
                self.broker.registry.register_producer(ProducerId(producer), capacity_gb);
                if !rejoining {
                    // Replay persisted usage history (fresh broker-side
                    // record); a rejoining producer's history is live.
                    if let Some(h) = self.history.clone() {
                        let (samples, skipped) = h.load(producer);
                        if skipped > 0 {
                            self.telemetry
                                .counter("history.lines_skipped")
                                .add(skipped as u64);
                        }
                        for (us, gb) in samples {
                            self.broker.registry.report_usage(
                                ProducerId(producer),
                                SimTime::from_micros(us),
                                gb,
                            );
                        }
                    }
                }
                self.broker
                    .registry
                    .update_producer_resources(ProducerId(producer), free_slabs, 1.0, 1.0);
                self.apply_optimistic_safety();
                self.log_event(LeaseEvent::ProducerUp {
                    producer,
                    endpoint: endpoint.clone(),
                    capacity_gb,
                });
                self.producers
                    .insert(producer, ProducerEntry { endpoint, last_heartbeat_us: now_us });
                CtrlResponse::Registered { producer, slab_bytes: self.broker.cfg.slab_bytes }
            }
            CtrlRequest::Heartbeat {
                producer,
                free_slabs,
                used_gb,
                cpu_headroom,
                bandwidth_headroom,
                observed_p99_us,
                observed_ops_per_sec,
            } => {
                let Some(entry) = self.producers.get_mut(&producer) else {
                    return Self::refused(
                        RefuseCode::UnknownProducer,
                        format!("producer {producer} is not registered"),
                    );
                };
                self.telemetry.counter("ctrl.heartbeats").inc();
                entry.last_heartbeat_us = now_us;
                self.broker.registry.report_usage(ProducerId(producer), now, used_gb);
                if let Some(tx) = &self.history_tx {
                    let _ = tx.send((producer, now_us, used_gb));
                }
                self.broker.registry.update_producer_resources(
                    ProducerId(producer),
                    free_slabs,
                    cpu_headroom as f64,
                    bandwidth_headroom as f64,
                );
                // The feedback loop: measured data-plane behavior flows
                // into the registry, and placement ranks by it.
                self.broker.registry.report_observed_telemetry(
                    ProducerId(producer),
                    observed_p99_us as u64,
                    observed_ops_per_sec as u64,
                );
                self.apply_optimistic_safety();
                self.leases.sweep_expired(now_us);
                self.apply_lease_ends();
                let granted = self
                    .leases
                    .take_unannounced(producer)
                    .into_iter()
                    .map(|rec| ProducerGrant {
                        lease: rec.id,
                        consumer: rec.consumer,
                        slabs: rec.slabs,
                        slab_bytes: rec.slab_bytes,
                        ttl_us: rec.ttl_us(now_us),
                    })
                    .collect();
                let ended = self.leases.take_ended_unacked(producer);
                CtrlResponse::HeartbeatAck {
                    target_bytes: self.leases.producer_target_bytes(producer),
                    granted,
                    ended,
                }
            }
            CtrlRequest::RequestSlabs { consumer, slabs, min_slabs, ttl_us, trace: _ } => {
                self.telemetry.counter("ctrl.slab_requests").inc();
                if slabs == 0 {
                    return Self::refused(RefuseCode::Malformed, "zero slabs requested");
                }
                // Clamp hostile TTLs: 30 days is far beyond any sane
                // lease, and keeps expiry arithmetic comfortably finite.
                const MAX_TTL_US: u64 = 30 * 24 * 3600 * 1_000_000;
                let ttl_us = ttl_us.min(MAX_TTL_US);
                self.leases.sweep_expired(now_us);
                self.apply_lease_ends();
                self.broker.registry.register_consumer(ConsumerId(consumer));
                let request = ConsumerRequest {
                    consumer: ConsumerId(consumer),
                    slabs,
                    min_slabs: min_slabs.max(1),
                    lease: SimTime::from_micros(ttl_us),
                    max_price_per_slab_hour: None,
                    latency_us_to: Default::default(),
                    weights: None,
                };
                let leases = self.broker.request_memory(now, request);
                // No server-side queue: the pool retries on its own.
                self.broker.drain_pending();
                let mut grants = Vec::with_capacity(leases.len());
                for lease in &leases {
                    let endpoint = match self.producers.get(&lease.producer.0) {
                        Some(e) => e.endpoint.clone(),
                        None => {
                            // Ungrantable after all: return the slabs the
                            // registry already counted against the producer.
                            self.broker.lease_ended(lease, false);
                            continue;
                        }
                    };
                    let duration_us = lease.duration.as_micros();
                    if self
                        .leases
                        .insert(
                            lease.id.0,
                            consumer,
                            lease.producer.0,
                            lease.slabs,
                            lease.slab_bytes,
                            lease.price_per_slab_hour.0,
                            now_us,
                            duration_us,
                        )
                        .is_err()
                    {
                        self.broker.lease_ended(lease, false);
                        continue;
                    }
                    self.log_event(LeaseEvent::Granted {
                        lease: lease.id.0,
                        consumer,
                        producer: lease.producer.0,
                        slabs: lease.slabs,
                        slab_bytes: lease.slab_bytes,
                        price_nd_per_slab_hour: lease.price_per_slab_hour.0,
                        ttl_us: duration_us,
                    });
                    grants.push(GrantInfo {
                        lease: lease.id.0,
                        producer: lease.producer.0,
                        endpoint,
                        slabs: lease.slabs,
                        slab_bytes: lease.slab_bytes,
                        ttl_us: duration_us,
                        price_nd_per_slab_hour: lease.price_per_slab_hour.0,
                    });
                }
                if grants.is_empty() {
                    Self::refused(RefuseCode::NoCapacity, "no grantable capacity right now")
                } else {
                    CtrlResponse::Grants { leases: grants }
                }
            }
            CtrlRequest::Renew { consumer, lease, trace: _ } => {
                self.telemetry.counter("ctrl.renews").inc();
                if let Some(r) = self.verify_holder(lease, consumer, true) {
                    return r;
                }
                match self.leases.renew(lease, now_us) {
                    Ok(new_expiry) => {
                        let ttl_us = new_expiry - now_us;
                        self.log_event(LeaseEvent::Renewed { lease, ttl_us });
                        CtrlResponse::Renewed { lease, ttl_us }
                    }
                    Err(e) => {
                        self.apply_lease_ends();
                        Self::refuse_lease_error(e)
                    }
                }
            }
            CtrlRequest::Release { consumer, lease } => {
                self.telemetry.counter("ctrl.releases").inc();
                if let Some(r) = self.verify_holder(lease, consumer, true) {
                    return r;
                }
                match self.leases.release(lease, now_us) {
                    Ok(_) => {
                        self.apply_lease_ends();
                        CtrlResponse::Released { lease }
                    }
                    Err(e) => {
                        self.apply_lease_ends();
                        Self::refuse_lease_error(e)
                    }
                }
            }
            CtrlRequest::Revoke { producer, lease, trace: _ } => {
                self.telemetry.counter("ctrl.revokes").inc();
                if let Some(r) = self.verify_holder(lease, producer, false) {
                    return r;
                }
                match self.leases.revoke(lease, now_us) {
                    Ok(_) => {
                        self.apply_lease_ends();
                        CtrlResponse::Revoked { lease }
                    }
                    Err(e) => {
                        self.apply_lease_ends();
                        Self::refuse_lease_error(e)
                    }
                }
            }
            CtrlRequest::Deregister { producer } => {
                if self.producers.contains_key(&producer) {
                    self.drop_producer(producer, now_us);
                    CtrlResponse::Deregistered { producer }
                } else {
                    Self::refused(
                        RefuseCode::UnknownProducer,
                        format!("producer {producer} is not registered"),
                    )
                }
            }
            CtrlRequest::StatsQuery => {
                self.telemetry.counter("ctrl.stats_queries").inc();
                CtrlResponse::Stats { uptime_us: now_us, metrics: self.metrics(now_us) }
            }
            CtrlRequest::TraceQuery { max } => {
                self.telemetry.counter("ctrl.trace_queries").inc();
                CtrlResponse::Traces { spans: trace::recent_spans((max as usize).min(4096)) }
            }
            CtrlRequest::ReplicaPoll { from_seq, max } => {
                self.telemetry.counter("ctrl.replica_polls").inc();
                let next_seq = self.repl_base_seq + self.repl_log.len() as u64;
                // Standby lag as the primary sees it: how far behind the
                // poller's cursor is right now. Surfaces in `memtrade top`
                // so a wedged or slow standby is visible before takeover.
                self.telemetry
                    .gauge("repl.lag")
                    .set(next_seq.saturating_sub(from_seq) as i64);
                // Clamp into the retained window: polling below the
                // base is the gap case (first_seq > from_seq tells the
                // standby), polling past the end is just caught-up.
                let start = from_seq.clamp(self.repl_base_seq, next_seq);
                let idx = (start - self.repl_base_seq) as usize;
                let take = (max.min(REPL_POLL_MAX)) as usize;
                let events: Vec<LeaseEvent> =
                    self.repl_log.iter().skip(idx).take(take).cloned().collect();
                CtrlResponse::ReplicaEvents { first_seq: start, events }
            }
        }
    }
}

/// The networked broker daemon (`memtrade broker` in the CLI).
pub struct BrokerServer {
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    loops: Option<EventLoops>,
    maint_handle: Option<JoinHandle<()>>,
    history_handle: Option<JoinHandle<()>>,
    repl_handle: Option<JoinHandle<()>>,
    state: Arc<Mutex<State>>,
    start: Instant,
}

impl BrokerServer {
    /// Bind and serve. `broker_cfg` sets the market economics (slab
    /// size, min lease, placement weights); `cfg` the daemon behavior.
    pub fn start<A: ToSocketAddrs>(
        addr: A,
        broker_cfg: BrokerConfig,
        cfg: BrokerServerConfig,
    ) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        if let Some(plan) = cfg.faults.as_ref() {
            plan.log_banner("broker");
        }

        let slab_frac = broker_cfg.slab_bytes as f64 / GIB as f64;
        let initial_price = cfg
            .spot_per_gb_hour
            .scale(broker_cfg.initial_price_fraction * slab_frac);
        let broker = Broker::new(
            broker_cfg.clone(),
            AvailabilityPredictor::auto(),
            PricingEngine::new(
                PricingStrategy::FixedFraction,
                initial_price,
                broker_cfg.price_step_dollars,
            ),
        );
        let history = match cfg.history_dir.clone() {
            Some(dir) => Some(HistoryStore::open(dir)?),
            None => None,
        };
        let stop = Arc::new(AtomicBool::new(false));
        // Appends run on their own thread: heartbeat handling must never
        // touch the disk while holding the state lock.
        let (history_tx, history_handle) = match history.clone() {
            Some(store) => {
                let (tx, rx) = mpsc::channel::<HistorySample>();
                let stop = stop.clone();
                let handle = std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match rx.recv_timeout(Duration::from_millis(100)) {
                            Ok((producer, us, gb)) => store.append(producer, us, gb),
                            Err(mpsc::RecvTimeoutError::Timeout) => {}
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                });
                (Some(tx), Some(handle))
            }
            None => (None, None),
        };
        let role = if cfg.standby_of.is_some() { Role::Standby } else { Role::Primary };
        let state = Arc::new(Mutex::new(State {
            broker,
            leases: LeaseTable::default(),
            producers: HashMap::new(),
            history,
            history_tx,
            cfg: cfg.clone(),
            telemetry: MetricsRegistry::new(),
            role,
            repl_log: VecDeque::new(),
            repl_base_seq: 0,
            history_replayed_us: HashMap::new(),
        }));
        let start = Instant::now();

        // One epoll loop thread holds every control connection: agent
        // heartbeats are tiny request/response frames and all real work
        // happens under the state lock anyway, so a single loop carries
        // thousands of agents without a thread per peer.
        let loops = spawn_loops(
            listener,
            stop.clone(),
            cfg.faults.clone(),
            ControlPlane { state: state.clone(), start },
            1,
        )?;

        let maint_handle = {
            let stop = stop.clone();
            let state = state.clone();
            let tick = cfg.tick;
            let forecast_every = cfg.forecast_every;
            std::thread::spawn(move || {
                let mut last_forecast: Option<Instant> = None;
                while !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(tick);
                    let now_us = start.elapsed().as_micros() as u64;
                    let mut s = state.lock().unwrap();
                    s.leases.sweep_expired(now_us);
                    s.apply_lease_ends();
                    // A standby hears no heartbeats; sweeping producers
                    // for silence would kill them all. Its membership
                    // view is the replicated log until promotion.
                    if s.role == Role::Primary {
                        s.sweep_dead_producers(now_us);
                    }
                    // Forecast + pricing on their own (slow) cadence: the
                    // AR fit holds the lock and must not run per tick.
                    let due =
                        last_forecast.map_or(true, |t| t.elapsed() >= forecast_every);
                    if due {
                        s.refresh_forecasts(now_us);
                        last_forecast = Some(Instant::now());
                    }
                }
            })
        };

        let repl_handle = cfg.standby_of.clone().map(|primary| {
            let stop = stop.clone();
            let state = state.clone();
            let tick = cfg.tick;
            let takeover_after = cfg.takeover_after;
            std::thread::spawn(move || {
                replication_loop(&primary, state, stop, start, tick, takeover_after)
            })
        });

        Ok(BrokerServer {
            local_addr,
            stop,
            loops: Some(loops),
            maint_handle: Some(maint_handle),
            history_handle,
            repl_handle,
            state,
            start,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// Microseconds on the daemon's monotonic clock.
    pub fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    pub fn producer_count(&self) -> usize {
        self.state.lock().unwrap().producers.len()
    }

    /// Live metrics snapshot — exactly what a `StatsQuery` answers.
    pub fn metrics(&self) -> MetricSet {
        let now_us = self.start.elapsed().as_micros() as u64;
        self.state.lock().unwrap().metrics(now_us)
    }

    pub fn active_lease_count(&self) -> usize {
        self.state.lock().unwrap().leases.active_count()
    }

    /// Current market price per slab-hour.
    pub fn current_price(&self) -> Money {
        self.state.lock().unwrap().broker.current_price()
    }

    /// Is this daemon currently granting (primary), or a warm standby?
    /// Flips exactly once, at takeover.
    pub fn is_primary(&self) -> bool {
        self.state.lock().unwrap().role == Role::Primary
    }

    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(loops) = self.loops.take() {
            loops.stop_and_join();
        }
        if let Some(h) = self.maint_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.history_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.repl_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for BrokerServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The control plane as an event-loop [`Service`]: decode one control
/// frame, run the verb against the shared [`State`] under its lock,
/// encode the response. Connections carry no per-peer state — producer
/// identity travels in every frame — so `Conn = ()` and a reconnecting
/// agent resumes mid-conversation for free.
#[derive(Clone)]
struct ControlPlane {
    state: Arc<Mutex<State>>,
    /// The daemon's monotonic epoch; control verbs take `now_us` as a
    /// value (that is what keeps the lease table replayable).
    start: Instant,
}

impl Service for ControlPlane {
    type Conn = ();

    fn magic(&self) -> [u8; 4] {
        CONTROL_MAGIC
    }

    fn open_conn(&self, _conn: u64, _hello: HelloInfo) {}

    fn on_frame(&self, _conn: &mut (), frame: &[u8], out: &mut Vec<u8>) {
        let resp = match CtrlRequest::decode(frame) {
            Ok(req) => {
                let now_us = self.start.elapsed().as_micros() as u64;
                self.state.lock().unwrap().handle(req, now_us)
            }
            Err(e @ CodecError::UnknownTag(_)) => CtrlResponse::Refused {
                code: RefuseCode::Malformed,
                detail: format!("not a control frame: {e}"),
            },
            Err(e) => CtrlResponse::Refused {
                code: RefuseCode::Malformed,
                detail: e.to_string(),
            },
        };
        resp.encode_into(out);
    }
}

/// The standby's side of replication: poll the primary's lease-event
/// log on the maintenance tick, replay each batch under the state
/// lock, tail the shared usage-history dir on a slow cadence, and
/// promote after `takeover_after` without one successful poll.
fn replication_loop(
    primary: &str,
    state: Arc<Mutex<State>>,
    stop: Arc<AtomicBool>,
    start: Instant,
    tick: Duration,
    takeover_after: Duration,
) {
    use crate::net::control::CONTROL_CALL_TIMEOUT;
    // Every network wait is bounded well inside the takeover deadline,
    // or one wedged dial/call could eat the whole silence budget and
    // stall the promotion the deadline exists to guarantee.
    let call_timeout = (takeover_after / 2)
        .max(Duration::from_millis(100))
        .min(CONTROL_CALL_TIMEOUT);
    let mut ctrl: Option<CtrlClient> = None;
    let mut from_seq: u64 = 0;
    let mut last_ok = Instant::now();
    let mut last_tail = Instant::now();
    // A full batch means the primary has more queued: poll again
    // without sleeping, so catch-up runs at wire speed, not tick speed.
    let mut catching_up = false;
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        if !catching_up {
            std::thread::sleep(tick);
        }
        catching_up = false;
        if ctrl.is_none() {
            if let Ok(mut c) = CtrlClient::connect_timeout(primary, call_timeout) {
                let _ = c.set_call_timeout(call_timeout);
                ctrl = Some(c);
            }
        }
        if let Some(c) = ctrl.as_mut() {
            match c.call(&CtrlRequest::ReplicaPoll { from_seq, max: REPL_POLL_MAX }) {
                Ok(CtrlResponse::ReplicaEvents { first_seq, events }) => {
                    last_ok = Instant::now();
                    let now_us = start.elapsed().as_micros() as u64;
                    let n = events.len() as u64;
                    let mut s = state.lock().unwrap();
                    if first_seq > from_seq {
                        // Fell past the primary's bounded log; tolerated
                        // — producer re-registration after takeover
                        // re-announces whatever the gap lost.
                        s.telemetry.counter("repl.gaps").inc();
                    }
                    for ev in &events {
                        s.apply_replicated(ev, now_us);
                    }
                    s.telemetry.counter("repl.events_applied").add(n);
                    from_seq = first_seq + n;
                    catching_up = n == u64::from(REPL_POLL_MAX);
                }
                // A refusal, decode error, or timeout leaves the stream
                // possibly desynced: drop it and re-dial next round.
                Ok(_) | Err(_) => ctrl = None,
            }
        }
        if last_tail.elapsed() >= Duration::from_secs(1) {
            last_tail = Instant::now();
            state.lock().unwrap().tail_history();
        }
        if last_ok.elapsed() >= takeover_after {
            let now_us = start.elapsed().as_micros() as u64;
            let mut s = state.lock().unwrap();
            // Final history tail first: promote with everything the
            // primary persisted before it died.
            s.tail_history();
            s.promote(now_us);
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::control::CtrlClient;

    fn quick_cfg() -> (BrokerConfig, BrokerServerConfig) {
        let broker_cfg = BrokerConfig {
            min_lease: SimTime::from_millis(200),
            ..Default::default()
        };
        let cfg = BrokerServerConfig {
            tick: Duration::from_millis(20),
            producer_timeout: Duration::from_millis(400),
            forecast_min_samples: 1_000_000, // stay optimistic in tests
            ..Default::default()
        };
        (broker_cfg, cfg)
    }

    fn register(ctrl: &mut CtrlClient, producer: u64, free_slabs: u32) {
        let resp = ctrl
            .call(&CtrlRequest::Register {
                producer,
                capacity_gb: 8.0,
                endpoint: format!("127.0.0.1:{}", 9000 + producer),
                free_bytes: free_slabs as u64 * crate::core::DEFAULT_SLAB_BYTES,
            })
            .unwrap();
        assert!(matches!(resp, CtrlResponse::Registered { .. }), "{resp:?}");
    }

    #[test]
    fn register_request_renew_release_over_tcp() {
        let (b, c) = quick_cfg();
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 1, 32);
        assert_eq!(server.producer_count(), 1);

        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 4,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        assert_eq!(leases.iter().map(|g| g.slabs).sum::<u32>(), 4);
        assert_eq!(server.active_lease_count(), leases.len());
        let id = leases[0].lease;

        let resp = ctrl.call(&CtrlRequest::Renew { consumer: 9, lease: id, trace: 0 }).unwrap();
        assert!(matches!(resp, CtrlResponse::Renewed { lease, .. } if lease == id));
        // Identity is enforced: another participant cannot end the lease.
        let resp = ctrl.call(&CtrlRequest::Release { consumer: 8, lease: id }).unwrap();
        assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");
        let resp = ctrl.call(&CtrlRequest::Revoke { producer: 99, lease: id, trace: 0 }).unwrap();
        assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");
        let resp = ctrl.call(&CtrlRequest::Release { consumer: 9, lease: id }).unwrap();
        assert_eq!(resp, CtrlResponse::Released { lease: id });
        let resp = ctrl.call(&CtrlRequest::Release { consumer: 9, lease: id }).unwrap();
        assert!(
            matches!(resp, CtrlResponse::Refused { code: RefuseCode::LeaseReleased, .. }),
            "{resp:?}"
        );
        server.stop();
    }

    #[test]
    fn heartbeat_acks_carry_grants_and_ends() {
        let (b, c) = quick_cfg();
        let slab_bytes = b.slab_bytes;
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 5, 16);

        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 2,
                min_slabs: 1,
                ttl_us: 250_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        let id = leases[0].lease;

        let hb = CtrlRequest::Heartbeat {
            producer: 5,
            free_slabs: 14,
            used_gb: 2.0,
            cpu_headroom: 0.9,
            bandwidth_headroom: 0.9,
            observed_p99_us: 320,
            observed_ops_per_sec: 900,
        };
        let resp = ctrl.call(&hb).unwrap();
        let CtrlResponse::HeartbeatAck { target_bytes, granted, ended } = resp else {
            panic!("{resp:?}")
        };
        assert_eq!(target_bytes, 2 * slab_bytes);
        assert_eq!(granted.len(), leases.len());
        assert_eq!(granted[0].lease, id);
        assert!(ended.is_empty());

        // Let the (short) lease expire, then the next ack reports the end
        // and a zero target.
        std::thread::sleep(Duration::from_millis(400));
        let resp = ctrl.call(&hb).unwrap();
        let CtrlResponse::HeartbeatAck { target_bytes, granted, ended } = resp else {
            panic!("{resp:?}")
        };
        assert_eq!(target_bytes, 0);
        assert!(granted.is_empty());
        assert!(ended.contains(&id), "{ended:?}");
        // Renewing the expired (and gc'd) lease is cleanly refused.
        let resp = ctrl.call(&CtrlRequest::Renew { consumer: 9, lease: id, trace: 0 }).unwrap();
        assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");
        server.stop();
    }

    #[test]
    fn stats_query_reports_market_and_observed_telemetry() {
        let (b, c) = quick_cfg();
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 4, 16);
        let hb = |p99: u32, ops: u32| CtrlRequest::Heartbeat {
            producer: 4,
            free_slabs: 16,
            used_gb: 1.0,
            cpu_headroom: 0.9,
            bandwidth_headroom: 0.9,
            observed_p99_us: p99,
            observed_ops_per_sec: ops,
        };
        ctrl.call(&hb(4_200, 77)).unwrap();
        let resp = ctrl.call(&CtrlRequest::StatsQuery).unwrap();
        let CtrlResponse::Stats { uptime_us, metrics } = resp else { panic!("{resp:?}") };
        assert!(uptime_us > 0);
        assert_eq!(metrics.gauge("market.producers"), Some(1));
        assert_eq!(metrics.counter("ctrl.heartbeats"), Some(1));
        assert_eq!(metrics.counter("ctrl.registrations"), Some(1));
        assert_eq!(metrics.gauge("producer.4.observed_p99_us"), Some(4_200));
        assert_eq!(metrics.gauge("producer.4.ops_per_sec"), Some(77));
        // An idle heartbeat window (p99 = 0) keeps the latency evidence
        // but zeroes the throughput gauge.
        ctrl.call(&hb(0, 0)).unwrap();
        let CtrlResponse::Stats { metrics, .. } =
            ctrl.call(&CtrlRequest::StatsQuery).unwrap()
        else {
            panic!()
        };
        assert_eq!(metrics.gauge("producer.4.observed_p99_us"), Some(4_200));
        assert_eq!(metrics.gauge("producer.4.ops_per_sec"), Some(0));
        // The in-process accessor serves the same snapshot shape.
        assert_eq!(server.metrics().gauge("market.producers"), Some(1));
        server.stop();
    }

    #[test]
    fn reregistration_keeps_leases_and_reannounces() {
        let (b, c) = quick_cfg();
        let slab_bytes = b.slab_bytes;
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 3, 32);
        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 4,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        let hb = CtrlRequest::Heartbeat {
            producer: 3,
            free_slabs: 28,
            used_gb: 2.0,
            cpu_headroom: 0.9,
            bandwidth_headroom: 0.9,
            observed_p99_us: 0,
            observed_ops_per_sec: 0,
        };
        // First ack announces the grant...
        let CtrlResponse::HeartbeatAck { granted, .. } = ctrl.call(&hb).unwrap() else {
            panic!()
        };
        assert_eq!(granted.len(), leases.len());
        // ...the agent "loses" that ack and reconnects: re-registration
        // must keep the lease and re-announce it, not revoke it.
        register(&mut ctrl, 3, 32);
        assert_eq!(server.active_lease_count(), leases.len());
        let CtrlResponse::HeartbeatAck { target_bytes, granted, .. } =
            ctrl.call(&hb).unwrap()
        else {
            panic!()
        };
        assert_eq!(granted.len(), leases.len(), "grants not re-announced");
        assert_eq!(target_bytes, 4 * slab_bytes);
        server.stop();
    }

    #[test]
    fn dead_producer_swept_and_leases_revoked() {
        let (b, c) = quick_cfg();
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 1, 32);
        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 4,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        // No heartbeats: past the timeout the producer and its leases go.
        std::thread::sleep(Duration::from_millis(700));
        assert_eq!(server.producer_count(), 0);
        assert_eq!(server.active_lease_count(), 0);
        let resp = ctrl
            .call(&CtrlRequest::Renew { consumer: 9, lease: leases[0].lease, trace: 0 })
            .unwrap();
        assert!(matches!(resp, CtrlResponse::Refused { .. }), "{resp:?}");
        server.stop();
    }

    #[test]
    fn history_persists_across_restart() {
        let dir = std::env::temp_dir().join(format!(
            "memtrade-history-test-{}-{}",
            std::process::id(),
            crate::util::clock::unix_nanos()
        ));
        let (b, mut c) = quick_cfg();
        c.history_dir = Some(dir.clone());
        let store = HistoryStore::open(dir.clone()).unwrap();
        for t in 0..40u64 {
            store.append(77, t * 1_000, 2.5);
        }
        let server = BrokerServer::start("127.0.0.1:0", b, c).unwrap();
        let mut ctrl = CtrlClient::connect(server.addr()).unwrap();
        register(&mut ctrl, 77, 16);
        // The replayed history landed in the registry.
        {
            let s = server.state.lock().unwrap();
            let p = s.broker.registry.producer(ProducerId(77)).unwrap();
            assert_eq!(p.usage.len(), 40);
        }
        // A heartbeat appends a new sample to the same file.
        ctrl.call(&CtrlRequest::Heartbeat {
            producer: 77,
            free_slabs: 16,
            used_gb: 2.75,
            cpu_headroom: 1.0,
            bandwidth_headroom: 1.0,
            observed_p99_us: 0,
            observed_ops_per_sec: 0,
        })
        .unwrap();
        // Appends flow through the writer thread; wait for the flush.
        let deadline = Instant::now() + Duration::from_secs(2);
        while store.load(77).0.len() != 41 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(store.load(77).0.len(), 41);
        server.stop();
        std::fs::remove_dir_all(&dir).ok();
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "memtrade-{tag}-{}-{}",
            std::process::id(),
            crate::util::clock::unix_nanos()
        ))
    }

    #[test]
    fn history_replay_skips_torn_final_line() {
        let dir = temp_dir("history-torn");
        let store = HistoryStore::open(dir.clone()).unwrap();
        for t in 1..=10u64 {
            store.append(5, t * 1_000, 1.5);
        }
        // Simulate a crash mid-append: chop 5 bytes off the final
        // "10000 1.5\n", leaving "10000" — a line with no second token.
        // (Cutting fewer bytes would leave "10000 1." which *parses*;
        // torn floats are indistinguishable from valid ones.)
        let path = store.path(5);
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (samples, skipped) = store.load(5);
        assert_eq!(samples.len(), 9, "the 9 intact lines replay");
        assert_eq!(skipped, 1, "the torn line is counted, not fatal");
        assert_eq!(samples.last(), Some(&(9_000, 1.5)));
        // A subsequent append starts a fresh line — it must not glue
        // onto the torn one and forge a parsable-but-bogus sample.
        store.append(5, 11_000, 2.0);
        let (samples, skipped) = store.load(5);
        assert_eq!(skipped, 1);
        assert_eq!(samples.len(), 10);
        assert_eq!(samples.last(), Some(&(11_000, 2.0)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn history_compaction_is_atomic_and_bounded() {
        let dir = temp_dir("history-compact");
        let store = HistoryStore::open(dir.clone()).unwrap();
        let path = store.path(8);
        // A file past the compaction threshold (~4 MB of lines)...
        let big = "123456 2.5\n".repeat(420_000);
        assert!(big.len() as u64 > HistoryStore::COMPACT_BYTES);
        std::fs::write(&path, big).unwrap();
        // ...is rewritten down to the replay tail by one append.
        store.append(8, 999_999, 3.5);
        let len = std::fs::metadata(&path).unwrap().len();
        assert!(len < HistoryStore::TAIL_BYTES, "compacted to {len} bytes");
        assert!(
            !path.with_extension("history.tmp").exists(),
            "temp file renamed away, not left behind"
        );
        let (samples, skipped) = store.load(8);
        assert_eq!(skipped, 0);
        assert!(samples.len() <= HISTORY_REPLAY_CAP + 1);
        assert_eq!(samples.last(), Some(&(999_999, 3.5)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn standby_replicates_and_takes_over() {
        let (b, c) = quick_cfg();
        let primary = BrokerServer::start("127.0.0.1:0", b.clone(), c.clone()).unwrap();
        let standby_cfg = BrokerServerConfig {
            standby_of: Some(primary.addr().to_string()),
            takeover_after: Duration::from_millis(400),
            ..c
        };
        let standby = BrokerServer::start("127.0.0.1:0", b, standby_cfg).unwrap();
        assert!(primary.is_primary());
        assert!(!standby.is_primary());

        // Build market state on the primary: a producer and a grant.
        let mut ctrl = CtrlClient::connect(primary.addr()).unwrap();
        register(&mut ctrl, 1, 32);
        let resp = ctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 4,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases } = resp else { panic!("{resp:?}") };
        let id = leases[0].lease;

        // Meanwhile the standby refuses market verbs but answers stats.
        let mut sctrl = CtrlClient::connect(standby.addr()).unwrap();
        let resp = sctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 1,
                min_slabs: 1,
                ttl_us: 1_000_000,
                trace: 0,
            })
            .unwrap();
        assert!(
            matches!(resp, CtrlResponse::Refused { code: RefuseCode::NotPrimary, .. }),
            "{resp:?}"
        );
        let CtrlResponse::Stats { metrics, .. } =
            sctrl.call(&CtrlRequest::StatsQuery).unwrap()
        else {
            panic!()
        };
        assert_eq!(metrics.gauge("market.role"), Some(1));

        // The replicated book converges to the primary's.
        let deadline = Instant::now() + Duration::from_secs(3);
        while (standby.producer_count() != 1
            || standby.active_lease_count() != leases.len())
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(standby.producer_count(), 1);
        assert_eq!(standby.active_lease_count(), leases.len());

        // Kill the primary; the standby promotes within takeover_after
        // (plus poll slack) and starts serving the same book.
        primary.stop();
        let deadline = Instant::now() + Duration::from_secs(3);
        while !standby.is_primary() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(standby.is_primary(), "standby never promoted");
        // The consumer's lease survives failover: renew succeeds there.
        let resp = sctrl.call(&CtrlRequest::Renew { consumer: 9, lease: id, trace: 0 }).unwrap();
        assert!(
            matches!(resp, CtrlResponse::Renewed { lease, .. } if lease == id),
            "{resp:?}"
        );
        // Fresh grants never collide with adopted lease ids.
        let resp = sctrl
            .call(&CtrlRequest::RequestSlabs {
                consumer: 9,
                slabs: 2,
                min_slabs: 1,
                ttl_us: 60_000_000,
                trace: 0,
            })
            .unwrap();
        let CtrlResponse::Grants { leases: fresh } = resp else { panic!("{resp:?}") };
        for g in &fresh {
            assert!(g.lease > id, "fresh lease {} collides with adopted {id}", g.lease);
        }
        let CtrlResponse::Stats { metrics, .. } =
            sctrl.call(&CtrlRequest::StatsQuery).unwrap()
        else {
            panic!()
        };
        assert_eq!(metrics.gauge("market.role"), Some(0));
        assert_eq!(metrics.counter("repl.takeovers"), Some(1));
        standby.stop();
    }
}

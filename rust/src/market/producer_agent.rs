//! The producer agent: one process-side of the marketplace that owns a
//! data-plane [`ProducerStoreServer`], registers with the broker, and
//! heartbeats its harvester-decided availability.
//!
//! Per heartbeat the agent:
//!  1. decides offered capacity — either a fixed pool, or by stepping
//!     the real harvester control loop (Algorithm 1) against a modeled
//!     guest workload on the wall clock;
//!  2. if the guest took memory back below what is leased, *revokes* its
//!     newest leases at the broker and shrinks the store immediately
//!     (consumers see cache misses, never corruption);
//!  3. sends `Heartbeat` and applies the ack: the broker's
//!     `target_bytes` (total active leased bytes) is authoritative, so
//!     the store is grown/shrunk to exactly that — lease expiry and
//!     revocation therefore provably shrink the producer store.
//!
//! The store starts at zero budget: until the broker grants a lease on
//! this producer, every PUT is rejected.
//!
//! Failover: `brokers` is an ordered endpoint list (primary first).
//! When the current broker stops answering — or refuses with
//! `NotPrimary` — the agent advances to the next endpoint under a
//! jittered exponential backoff and re-registers there. Re-registration
//! re-announces the complete active book on the next heartbeat ack, so
//! a promoted standby relearns anything its replicated log missed.

use crate::core::config::HarvesterConfig;
use crate::core::{SimTime, GIB};
use crate::kv::ShardedKvStore;
use crate::market::stats_server::{MetricsSource, StatsServer};
use crate::mem::SwapDevice;
use crate::metrics::{scoped, Counter, Gauge, Histogram, MetricSet, Observe};
use crate::net::control::{CtrlClient, CtrlRequest, CtrlResponse, RefuseCode};
use crate::net::faults::{ByzantineSpec, FaultPlan};
use crate::net::tcp::ProducerStoreServer;
use crate::producer::Harvester;
use crate::trace::{self, Op as TraceOp, Role as TraceRole, SpanGuard};
use crate::util::Backoff;
use crate::workload::apps::{AppKind, AppModel, AppRunner};
use std::collections::HashMap;
use std::io;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct ProducerAgentConfig {
    pub producer: u64,
    /// Broker control endpoints, `host:port`, in failover order
    /// (primary first, then standbys). The agent registers with the
    /// first that accepts and advances — wrapping — when it fails.
    pub brokers: Vec<String>,
    /// Data-plane bind address (port 0 = ephemeral).
    pub data_addr: String,
    /// Endpoint advertised to the broker (consumers dial this). Needed
    /// when binding a wildcard address — `0.0.0.0:p` is not dialable
    /// from another host. None = the bound address.
    pub advertise: Option<String>,
    /// Guest VM size; with `harvest` off, the whole pool is offered.
    pub capacity_bytes: u64,
    /// Drive offered capacity with the real harvester control loop over
    /// a modeled guest app instead of offering `capacity_bytes` flat.
    pub harvest: bool,
    pub heartbeat: Duration,
    /// Store shards (0 = one per core).
    pub shards: usize,
    /// Data-plane rate limit, bytes/sec (None = unlimited).
    pub rate_bps: Option<u64>,
    pub seed: u64,
    /// Longest a control call may wait for the broker's answer before
    /// the agent treats the connection as lost and reconnects.
    pub ctrl_call_timeout: Duration,
    /// First redial delay after a failed broker dial or registration;
    /// doubles per consecutive failure up to `redial_backoff_cap` with
    /// seeded jitter ([`Backoff`]), so a fleet of agents doesn't hammer
    /// a just-promoted standby in lockstep.
    pub redial_backoff: Duration,
    /// Ceiling of the redial backoff schedule.
    pub redial_backoff_cap: Duration,
    /// Chaos plane: fault schedule for this agent's broker connections.
    pub ctrl_faults: Option<FaultPlan>,
    /// Chaos plane: fault schedule installed on accepted data-plane
    /// connections.
    pub data_faults: Option<FaultPlan>,
    /// Chaos plane: serve a seeded fraction of GET hits tampered
    /// (corrupted / stale / truncated) — the Byzantine producer the
    /// §6.1 envelope is tested against.
    pub byzantine: Option<ByzantineSpec>,
    /// Where to mount the read-only `StatsQuery` endpoint (port 0 =
    /// ephemeral; `None` = no stats endpoint). `memtrade top` and tests
    /// poll it for this agent's live data-plane telemetry.
    pub stats_addr: Option<String>,
    /// Data-plane p99 SLO, µs (0 = no SLO). A heartbeat window whose
    /// observed p99 exceeds this triggers a flight-recorder dump, so
    /// the spans behind the breach are on disk before the ring wraps.
    pub slo_p99_us: u64,
}

impl Default for ProducerAgentConfig {
    fn default() -> Self {
        ProducerAgentConfig {
            producer: 1,
            brokers: vec!["127.0.0.1:7070".to_string()],
            data_addr: "127.0.0.1:0".to_string(),
            advertise: None,
            capacity_bytes: GIB,
            harvest: false,
            heartbeat: Duration::from_millis(500),
            shards: 0,
            rate_bps: None,
            seed: 1,
            ctrl_call_timeout: crate::net::control::CONTROL_CALL_TIMEOUT,
            redial_backoff: Duration::from_millis(500),
            redial_backoff_cap: Duration::from_secs(10),
            ctrl_faults: None,
            data_faults: None,
            byzantine: None,
            stats_addr: Some("127.0.0.1:0".to_string()),
            slo_p99_us: 0,
        }
    }
}

/// Counters shared with the agent loop (all monotonic except the
/// gauges), on the shared metrics plane.
#[derive(Default)]
pub struct AgentStats {
    /// Gauge: bytes the broker says must be leased out right now.
    pub target_bytes: Gauge,
    /// Gauge: bytes the harvester currently offers to the market.
    pub offered_bytes: Gauge,
    /// Gauge: observed data-plane p99 (µs) over the last heartbeat
    /// window — exactly what the heartbeat reported to the broker.
    pub data_p99_us: Gauge,
    /// Gauge: data-plane ops/sec over the last heartbeat window.
    pub data_ops_per_sec: Gauge,
    pub heartbeats: Counter,
    pub leases_started: Counter,
    pub leases_ended: Counter,
    pub revokes_sent: Counter,
    pub control_errors: Counter,
    /// Times the agent advanced to the next broker endpoint in its
    /// failover list.
    pub broker_failovers: Counter,
}

impl Observe for AgentStats {
    fn observe(&self, prefix: &str, out: &mut MetricSet) {
        out.set_gauge(scoped(prefix, "target_bytes"), self.target_bytes.get());
        out.set_gauge(scoped(prefix, "offered_bytes"), self.offered_bytes.get());
        out.set_gauge(scoped(prefix, "data_p99_us"), self.data_p99_us.get());
        out.set_gauge(scoped(prefix, "data_ops_per_sec"), self.data_ops_per_sec.get());
        out.set_counter(scoped(prefix, "heartbeats"), self.heartbeats.get());
        out.set_counter(scoped(prefix, "leases_started"), self.leases_started.get());
        out.set_counter(scoped(prefix, "leases_ended"), self.leases_ended.get());
        out.set_counter(scoped(prefix, "revokes_sent"), self.revokes_sent.get());
        out.set_counter(scoped(prefix, "control_errors"), self.control_errors.get());
        out.set_counter(scoped(prefix, "broker_failovers"), self.broker_failovers.get());
    }
}

/// Harvester control loop driven by the wall clock: the same
/// [`Harvester`] state machine the simulator runs, stepped against a
/// modeled guest app each heartbeat.
struct HarvestLoop {
    app: AppRunner,
    harvester: Harvester,
    last_us: u64,
}

impl HarvestLoop {
    fn new(capacity_bytes: u64, heartbeat: Duration, seed: u64) -> Self {
        // Redis-shaped guest scaled to the configured VM size.
        let mut model = AppModel::preset(AppKind::Redis);
        model.vm_bytes = capacity_bytes;
        model.footprint_bytes = (capacity_bytes as f64 * 0.55) as u64;
        let page_bytes = (capacity_bytes / 256).clamp(1 << 20, 64 << 20);
        let cfg = HarvesterConfig {
            // Real time runs much faster than the paper's 5-minute
            // cadence; scale the gates to the heartbeat so the loop
            // makes progress in seconds, not hours.
            cooling_period: SimTime::from_micros(2 * heartbeat.as_micros() as u64),
            epoch: SimTime::from_micros(heartbeat.as_micros() as u64),
            recovery_period: SimTime::from_micros(10 * heartbeat.as_micros() as u64),
            ..Default::default()
        };
        let mut app = AppRunner::new(
            model,
            page_bytes,
            SwapDevice::Ssd,
            Some(cfg.cooling_period),
            seed,
        );
        app.ops_cap_per_epoch = 200;
        let harvester = Harvester::new(cfg, capacity_bytes);
        HarvestLoop { app, harvester, last_us: 0 }
    }

    /// One wall-clock epoch; returns harvestable (offerable) bytes.
    fn step(&mut self, now_us: u64) -> u64 {
        let now = SimTime::from_micros(now_us);
        let epoch = SimTime::from_micros(now_us.saturating_sub(self.last_us).max(1));
        self.last_us = now_us;
        let rec = self.app.run_epoch(now, epoch);
        let promotions = self.app.memory.promotions();
        self.harvester.record_sample(now, rec.mean(), promotions);
        self.harvester.step_epoch(now, &mut self.app.memory);
        self.app.memory.shape().harvestable
    }
}

/// A running producer agent: data-plane server + broker control loop.
pub struct ProducerAgent {
    cfg: ProducerAgentConfig,
    stop: Arc<AtomicBool>,
    loop_handle: Option<JoinHandle<()>>,
    server: Option<ProducerStoreServer>,
    stats_server: Option<StatsServer>,
    data_addr: std::net::SocketAddr,
    stats: Arc<AgentStats>,
}

impl ProducerAgent {
    /// Boot the data plane, register with the broker (synchronously, so
    /// a dead broker fails fast), and start heartbeating.
    pub fn start(cfg: ProducerAgentConfig) -> io::Result<Self> {
        let shards = if cfg.shards == 0 {
            crate::net::tcp::default_shards()
        } else {
            cfg.shards
        };
        let server = ProducerStoreServer::start_chaotic(
            &cfg.data_addr,
            cfg.capacity_bytes as usize,
            cfg.rate_bps,
            cfg.seed,
            shards,
            cfg.data_faults.clone(),
            cfg.byzantine.clone(),
        )?;
        // Stamp the data plane with our market identity so its shard
        // spans name this producer in cross-role traces.
        server.set_producer_id(cfg.producer);
        if let Some(plan) = cfg.ctrl_faults.as_ref() {
            plan.log_banner("producer-agent ctrl");
        }
        // Nothing is leased yet: zero budget until the broker says so.
        server.shrink_to(0);
        let data_addr = server.addr();
        let endpoint = cfg.advertise.clone().unwrap_or_else(|| data_addr.to_string());
        if cfg.advertise.is_none() && data_addr.ip().is_unspecified() {
            eprintln!(
                "producer agent: bound {data_addr} but advertising a wildcard address — \
                 remote consumers cannot dial it; pass an advertise endpoint"
            );
        }
        let store = server.store().clone();

        let mut harvest = cfg
            .harvest
            .then(|| HarvestLoop::new(cfg.capacity_bytes, cfg.heartbeat, cfg.seed));
        let start = Instant::now();
        let offered0 = match &mut harvest {
            Some(h) => h.step(1),
            None => cfg.capacity_bytes,
        };

        // Register with the first broker that accepts, in failover
        // order: a standby listed first answers `NotPrimary` and we
        // simply move on to the one actually granting.
        let mut registered: Option<(CtrlClient, u64, usize)> = None;
        let mut conn_seq = 0u64;
        let mut last_err =
            io::Error::new(io::ErrorKind::InvalidInput, "no broker endpoints configured");
        for idx in 0..cfg.brokers.len() {
            let conn_idx = conn_seq;
            conn_seq += 1;
            let mut c = match dial_broker(&cfg, &cfg.brokers[idx], conn_idx) {
                Ok(c) => c,
                Err(e) => {
                    last_err = e;
                    continue;
                }
            };
            match c.call(&CtrlRequest::Register {
                producer: cfg.producer,
                capacity_gb: cfg.capacity_bytes as f32 / GIB as f32,
                endpoint: endpoint.clone(),
                free_bytes: offered0,
            }) {
                Ok(CtrlResponse::Registered { slab_bytes, .. }) => {
                    registered = Some((c, slab_bytes, idx));
                    break;
                }
                Ok(other) => {
                    last_err = io::Error::new(
                        io::ErrorKind::ConnectionRefused,
                        format!("broker {} refused registration: {other:?}", cfg.brokers[idx]),
                    );
                }
                Err(e) => last_err = e,
            }
        }
        let Some((ctrl, slab_bytes, broker_idx)) = registered else {
            return Err(last_err);
        };

        let stats = Arc::new(AgentStats::default());
        stats.offered_bytes.set(offered0 as i64);
        let stop = Arc::new(AtomicBool::new(false));

        // Mount the read-only stats endpoint: the agent's own stats,
        // the data plane's live registry (op latency, ops, shard-lock
        // holds), and the store's counters, all in one MetricSet.
        let stats_server = match &cfg.stats_addr {
            Some(addr) => {
                let stats = stats.clone();
                let telemetry = server.telemetry().clone();
                let store = store.clone();
                let producer = cfg.producer;
                let source: MetricsSource = Arc::new(move || {
                    let mut m = MetricSet::new();
                    m.set_gauge("agent.producer", producer as i64);
                    stats.observe("agent", &mut m);
                    telemetry.observe("data", &mut m);
                    store.stats().observe("store", &mut m);
                    m.set_gauge("store.used_bytes", store.used_bytes() as i64);
                    m.set_gauge("store.max_bytes", store.max_bytes() as i64);
                    m.set_gauge("store.keys", store.len() as i64);
                    m
                });
                Some(StatsServer::start(addr, source)?)
            }
            None => None,
        };

        let data_op_us = server.telemetry().histogram("op_us");
        let loop_handle = {
            let cfg = cfg.clone();
            let stop = stop.clone();
            let stats = stats.clone();
            // Jitter seeded per producer: a fleet failing over together
            // must not redial the standby in lockstep.
            let backoff = Backoff::new(
                cfg.redial_backoff,
                cfg.redial_backoff_cap,
                cfg.seed ^ cfg.producer,
            );
            std::thread::spawn(move || {
                agent_loop(AgentLoop {
                    cfg,
                    endpoint,
                    conn: Some(ctrl),
                    conn_seq,
                    broker_idx,
                    backoff,
                    redial_after: Instant::now(),
                    store,
                    harvest,
                    slab_bytes,
                    start,
                    stop,
                    stats,
                    data_op_us,
                })
            })
        };

        Ok(ProducerAgent {
            cfg,
            stop,
            loop_handle: Some(loop_handle),
            server: Some(server),
            stats_server,
            data_addr,
            stats,
        })
    }

    /// Data-plane endpoint consumers dial.
    pub fn data_addr(&self) -> std::net::SocketAddr {
        self.data_addr
    }

    /// The read-only `StatsQuery` endpoint, if one was configured.
    pub fn stats_addr(&self) -> Option<std::net::SocketAddr> {
        self.stats_server.as_ref().map(|s| s.addr())
    }

    /// The served store (for stats and budget assertions).
    pub fn store(&self) -> Option<&Arc<ShardedKvStore>> {
        self.server.as_ref().map(|s| s.store())
    }

    pub fn stats(&self) -> &AgentStats {
        &self.stats
    }

    /// Byzantine-mode responses this agent's store served tampered
    /// (0 unless configured with a [`ByzantineSpec`], or after `kill`).
    pub fn byzantine_tampered(&self) -> u64 {
        self.server.as_ref().map(|s| s.byzantine_tampered()).unwrap_or(0)
    }

    pub fn target_bytes(&self) -> u64 {
        self.stats.target_bytes.get().max(0) as u64
    }

    pub fn offered_bytes(&self) -> u64 {
        self.stats.offered_bytes.get().max(0) as u64
    }

    /// Simulated crash: kill the control loop and the data plane without
    /// telling the broker. It finds out via missed heartbeats; consumers
    /// via connection loss.
    pub fn kill(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
        if let Some(server) = self.server.take() {
            server.stop();
        }
        if let Some(s) = self.stats_server.take() {
            s.stop();
        }
    }

    /// Graceful exit: deregister (the broker revokes our leases at
    /// once), then shut everything down.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.loop_handle.take() {
            let _ = h.join();
        }
        // Deregister over a clean connection: teardown must not race a
        // chaos plan that could eat the goodbye. Whichever broker is
        // primary right now takes it; the rest refuse or are dead.
        for addr in &self.cfg.brokers {
            let Ok(mut ctrl) = CtrlClient::connect(addr) else { continue };
            let bye = CtrlRequest::Deregister { producer: self.cfg.producer };
            if matches!(ctrl.call(&bye), Ok(CtrlResponse::Deregistered { .. })) {
                break;
            }
        }
        if let Some(server) = self.server.take() {
            server.stop();
        }
        if let Some(s) = self.stats_server.take() {
            s.stop();
        }
    }
}

impl Drop for ProducerAgent {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Dial one broker endpoint with the agent's chaos plan (if any)
/// installed and per-call response waits bounded. `conn` indexes this
/// agent's control connections for the fault plan's determinism
/// contract.
fn dial_broker(cfg: &ProducerAgentConfig, addr: &str, conn: u64) -> io::Result<CtrlClient> {
    let mut ctrl = match &cfg.ctrl_faults {
        Some(plan) => CtrlClient::connect_faulty(
            addr,
            crate::net::control::HANDSHAKE_TIMEOUT,
            plan,
            conn,
        )?,
        None => CtrlClient::connect(addr)?,
    };
    ctrl.set_call_timeout(cfg.ctrl_call_timeout)?;
    Ok(ctrl)
}

struct AgentLoop {
    cfg: ProducerAgentConfig,
    /// The *bound* data-plane endpoint (not the 0-port bind address).
    endpoint: String,
    conn: Option<CtrlClient>,
    /// Control connections dialed so far (the chaos plan's index).
    conn_seq: u64,
    /// Index into `cfg.brokers` of the endpoint currently in use.
    broker_idx: usize,
    /// Jittered exponential redial schedule feeding `redial_after`.
    backoff: Backoff,
    /// Earliest time another dial attempt may be made.
    redial_after: Instant,
    store: Arc<ShardedKvStore>,
    harvest: Option<HarvestLoop>,
    slab_bytes: u64,
    start: Instant,
    stop: Arc<AtomicBool>,
    stats: Arc<AgentStats>,
    /// The data plane's per-op service-latency histogram; heartbeats
    /// report the p99 + ops/sec of the delta since the last beat.
    data_op_us: Arc<Histogram>,
}

fn agent_loop(mut a: AgentLoop) {
    // lease id -> bytes, learned from heartbeat acks; insertion order
    // doubles as grant order so reclaim revokes the newest first.
    let mut active: HashMap<u64, u64> = HashMap::new();
    let mut grant_order: Vec<u64> = Vec::new();
    // After a re-registration the broker re-announces our *complete*
    // active book on the next ack; rebuild from it wholesale so entries
    // that ended while we were disconnected don't linger.
    let mut rebuild_book = false;
    // Telemetry window: heartbeats report the p99/ops-per-sec of the
    // data plane *since the last beat* (a delta of the live histogram),
    // so the broker sees current behavior, not lifetime averages.
    let mut window_start = Instant::now();
    let mut window_snap = a.data_op_us.snapshot();

    while !a.stop.load(Ordering::Relaxed) {
        std::thread::sleep(a.cfg.heartbeat);
        if a.stop.load(Ordering::Relaxed) {
            break;
        }
        let now_us = a.start.elapsed().as_micros() as u64;
        let offered = match &mut a.harvest {
            Some(h) => h.step(now_us),
            None => a.cfg.capacity_bytes,
        };
        a.stats.offered_bytes.set(offered as i64);

        // Re-establish the control connection if it dropped (broker
        // restart, failover, or transient failure): reconnect and
        // re-register, gated by the jittered backoff so a wedged or
        // just-promoted broker isn't hammered every heartbeat. The
        // broker keeps our active leases across a re-registration, so
        // availability must still be reported net of them — a full-
        // capacity report here would invite over-granting.
        if a.conn.is_none() {
            if Instant::now() < a.redial_after {
                continue;
            }
            let conn_idx = a.conn_seq;
            a.conn_seq += 1;
            let addr = a.cfg.brokers[a.broker_idx % a.cfg.brokers.len().max(1)].clone();
            let dial_failed = |a: &mut AgentLoop| {
                a.stats.control_errors.inc();
                a.redial_after = Instant::now() + a.backoff.next_delay();
                if a.cfg.brokers.len() > 1 {
                    a.broker_idx = (a.broker_idx + 1) % a.cfg.brokers.len();
                    a.stats.broker_failovers.inc();
                }
            };
            let Ok(mut c) = dial_broker(&a.cfg, &addr, conn_idx) else {
                dial_failed(&mut a);
                continue;
            };
            let leased_now: u64 = active.values().sum();
            let reg = CtrlRequest::Register {
                producer: a.cfg.producer,
                capacity_gb: a.cfg.capacity_bytes as f32 / GIB as f32,
                endpoint: a.endpoint.clone(),
                free_bytes: offered.saturating_sub(leased_now),
            };
            if !matches!(c.call(&reg), Ok(CtrlResponse::Registered { .. })) {
                // A standby's `NotPrimary` lands here too: same cure —
                // back off and try the next endpoint.
                dial_failed(&mut a);
                continue;
            }
            a.backoff.reset();
            rebuild_book = true;
            a.conn = Some(c);
        }

        // Harvester reclaim: the guest needs memory back. Give up the
        // newest leases until we fit, shrinking the store right away —
        // downstream this is cache misses, never errors (§4.2).
        let mut leased: u64 = active.values().sum();
        let mut lost_conn = false;
        while leased > offered {
            let Some(&victim) = grant_order.last() else { break };
            let bytes = active.remove(&victim).unwrap_or(0);
            grant_order.pop();
            leased -= bytes;
            a.stats.revokes_sent.inc();
            // Revocation starts a fresh trace here (the producer is the
            // causal origin); the broker adopts it via the verb's id.
            let mut span = SpanGuard::root(TraceRole::Producer, TraceOp::Revoke);
            span.set_lease(victim);
            let revoke = CtrlRequest::Revoke {
                producer: a.cfg.producer,
                lease: victim,
                trace: span.trace_id(),
            };
            if a.conn.as_mut().unwrap().call(&revoke).is_err() {
                a.stats.control_errors.inc();
                lost_conn = true;
                break;
            }
        }
        if (a.store.max_bytes() as u64) > leased {
            a.store.shrink_to(leased as usize);
        }
        if lost_conn {
            a.conn = None;
            continue;
        }

        // Observed data-plane telemetry for this window.
        let snap = a.data_op_us.snapshot();
        let window = snap.delta(&window_snap);
        let dt = window_start.elapsed().as_secs_f64().max(1e-6);
        window_snap = snap;
        window_start = Instant::now();
        let observed_ops_per_sec = (window.count() as f64 / dt).round() as u32;
        let observed_p99_us = if window.count() > 0 {
            window.p99().round().min(u32::MAX as f64) as u32
        } else {
            0 // no traffic observed: nothing to report this window
        };
        a.stats.data_ops_per_sec.set(observed_ops_per_sec as i64);
        if observed_p99_us > 0 {
            a.stats.data_p99_us.set(observed_p99_us as i64);
        }
        // SLO breach: capture the window's spans before the ring wraps.
        // The dump's own throttle keeps a sustained breach from spamming.
        if a.cfg.slo_p99_us > 0 && observed_p99_us as u64 > a.cfg.slo_p99_us {
            trace::dump("producer", "p99-breach");
        }

        let hb = CtrlRequest::Heartbeat {
            producer: a.cfg.producer,
            free_slabs: (offered.saturating_sub(leased) / a.slab_bytes) as u32,
            used_gb: a.cfg.capacity_bytes.saturating_sub(offered) as f32 / GIB as f32,
            cpu_headroom: 0.9,
            bandwidth_headroom: 0.9,
            observed_p99_us,
            observed_ops_per_sec,
        };
        match a.conn.as_mut().unwrap().call(&hb) {
            Ok(CtrlResponse::HeartbeatAck { target_bytes, granted, ended }) => {
                a.stats.heartbeats.inc();
                if rebuild_book {
                    // This ack re-announces every active lease.
                    active.clear();
                    grant_order.clear();
                    rebuild_book = false;
                }
                for g in granted {
                    if active.insert(g.lease, g.slabs as u64 * g.slab_bytes).is_none() {
                        grant_order.push(g.lease);
                        a.stats.leases_started.inc();
                    }
                }
                for id in ended {
                    if active.remove(&id).is_some() {
                        grant_order.retain(|&l| l != id);
                        a.stats.leases_ended.inc();
                    }
                }
                // The broker's view is authoritative for the budget.
                let cur = a.store.max_bytes() as u64;
                if target_bytes < cur {
                    a.store.shrink_to(target_bytes as usize);
                } else if target_bytes > cur {
                    a.store.grow_to(target_bytes as usize);
                }
                a.stats.target_bytes.set(target_bytes as i64);
            }
            Ok(CtrlResponse::Refused { code: RefuseCode::UnknownProducer, .. }) => {
                // Broker restarted and forgot us: re-register next tick
                // at the *same* endpoint — it is primary, just amnesiac.
                a.stats.control_errors.inc();
                a.conn = None;
            }
            Ok(CtrlResponse::Refused { code: RefuseCode::NotPrimary, .. }) => {
                // The broker we talk to demoted or was always a standby:
                // advance to the next endpoint right away.
                a.stats.control_errors.inc();
                a.conn = None;
                if a.cfg.brokers.len() > 1 {
                    a.broker_idx = (a.broker_idx + 1) % a.cfg.brokers.len();
                    a.stats.broker_failovers.inc();
                }
            }
            Ok(_) => {
                // Any other answer to a heartbeat means the response
                // stream is desynced (e.g. a duplicated frame shifted
                // every later response) — keeping the connection would
                // misread acks forever. Reconnect and re-register; the
                // broker re-announces our whole book on the next ack.
                a.stats.control_errors.inc();
                a.conn = None;
            }
            Err(_) => {
                a.stats.control_errors.inc();
                a.conn = None;
            }
        }
    }
}

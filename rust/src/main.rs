//! Memtrade CLI — the launcher for every role and experiment.
//!
//! ```text
//! memtrade figure <id> [--quick]        regenerate a paper table/figure
//! memtrade figure all [--quick]         regenerate everything
//! memtrade broker [--port P] [...]      run the marketplace broker daemon
//! memtrade agent --broker <a> [...]     run a producer agent (data + control)
//! memtrade producer --port <p> [...]    run a bare TCP producer store
//! memtrade consumer --addr <a> [...]    run a YCSB consumer against one store
//! memtrade consumer --broker <a> [...]  ... against broker-leased slabs
//! memtrade sim [--minutes N]            run the cluster simulation
//! memtrade replay [--steps N]           run the Google-style replay
//! memtrade chaos [--seed S] [--mix M]   run seeded fault-injection scenarios
//! memtrade top --broker <a>             live marketplace telemetry (StatsQuery)
//! memtrade trace --broker <a>           fetch live span rings (TraceQuery)
//! memtrade lint [--root DIR]            check the repo's own invariants
//! memtrade list                         list experiment ids
//! ```
//!
//! Argument parsing is hand-rolled (offline build: no clap).

use memtrade::consumer::client::{KvTransport, SecureKv};
use memtrade::core::config::BrokerConfig;
use memtrade::core::{Money, SimTime};
use memtrade::figures;
use memtrade::market::chaos::{run_chaos, ChaosConfig, ChaosMix};
use memtrade::market::{
    BrokerServer, BrokerServerConfig, ProducerAgent, ProducerAgentConfig, RemotePool,
    RemotePoolConfig,
};
use memtrade::metrics::{Metric, MetricSet};
use memtrade::net::control::{CtrlClient, CtrlRequest, CtrlResponse};
use memtrade::net::tcp::{KvClient, ProducerStoreServer};
use memtrade::sim::cluster::{ClusterSim, ClusterSimConfig, ConsumerMode};
use memtrade::sim::replay::{run as replay_run, ReplayConfig};
use memtrade::trace::Span;
use memtrade::util::rng::Rng;
use memtrade::workload::ycsb::{Op, YcsbWorkload};
use std::process::ExitCode;
use std::time::Duration;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }
    fn flag_u64(&self, name: &str, default: u64) -> u64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

const USAGE: &str = "\
memtrade — a disaggregated-memory marketplace (paper reproduction)

USAGE:
  memtrade figure <id>|all [--quick]
  memtrade broker [--port P] [--history-dir DIR] [--spot-gb-hour $]
                  [--producer-timeout-ms N] [--min-lease-secs N]
                  [--standby-of HOST:PORT] [--takeover-ms N]
  memtrade agent --broker HOST:PORT[,HOST:PORT...] [--id N] [--mb N]
                 [--heartbeat-ms N] [--advertise HOST:PORT] [--harvest]
                 [--shards N] [--rate-mbps R] [--stats-port P]
  memtrade producer [--port P] [--mb N] [--rate-mbps R] [--shards N]
  memtrade consumer --addr HOST:PORT | --broker HOST:PORT[,HOST:PORT...]
                    [--slabs N] [--ops N] [--value-bytes B] [--no-encrypt]
                    [--batch N] [--window W]
  memtrade sim [--minutes N] [--producers N] [--consumers N] [--remote PCT]
  memtrade replay [--steps N] [--producers N] [--consumers N]
  memtrade chaos [--seed S | --seeds N] [--mix MIX] [--ops N] [--keys N]
                 [--dump-dir DIR]
                 (MIX: clean|standard, or +-joined fault families:
                  control|data|byzantine|kill|race|failover, e.g. data+kill)
  memtrade top --broker HOST:PORT | --addr HOST:PORT [--interval-ms N] [--once]
  memtrade trace --broker HOST:PORT | --addr HOST:PORT [--max N] [--trace ID]
  memtrade lint [--root DIR]
  memtrade list
";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return ExitCode::FAILURE;
    }
    let cmd = argv[0].clone();
    let args = parse_args(&argv[1..]);
    match cmd.as_str() {
        "figure" => cmd_figure(&args),
        "broker" => cmd_broker(&args),
        "agent" => cmd_agent(&args),
        "producer" => cmd_producer(&args),
        "consumer" => cmd_consumer(&args),
        "sim" => cmd_sim(&args),
        "replay" => cmd_replay(&args),
        "chaos" => cmd_chaos(&args),
        "top" => cmd_top(&args),
        "trace" => cmd_trace(&args),
        "lint" => cmd_lint(&args),
        "list" => {
            for id in figures::ALL {
                println!("{id}");
            }
            ExitCode::SUCCESS
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            ExitCode::SUCCESS
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_figure(args: &Args) -> ExitCode {
    let Some(id) = args.positional.first() else {
        eprintln!("figure: missing id (try `memtrade list`)");
        return ExitCode::FAILURE;
    };
    let quick = args.has("quick");
    let ids: Vec<&str> = if id == "all" {
        figures::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        println!("=== {id} ===");
        if let Err(e) = figures::run(id, quick) {
            eprintln!("{e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_broker(args: &Args) -> ExitCode {
    let port = args.flag_u64("port", 7070);
    let broker_cfg = BrokerConfig {
        min_lease: SimTime::from_secs(args.flag_u64("min-lease-secs", 600)),
        ..Default::default()
    };
    let standby_of = args.flag("standby-of").map(str::to_string);
    let cfg = BrokerServerConfig {
        spot_per_gb_hour: Money::from_dollars(
            args.flag("spot-gb-hour").and_then(|v| v.parse().ok()).unwrap_or(0.0005),
        ),
        producer_timeout: Duration::from_millis(args.flag_u64("producer-timeout-ms", 3000)),
        history_dir: args.flag("history-dir").map(std::path::PathBuf::from),
        standby_of: standby_of.clone(),
        takeover_after: Duration::from_millis(args.flag_u64("takeover-ms", 2000)),
        ..Default::default()
    };
    let server = match BrokerServer::start(format!("0.0.0.0:{port}"), broker_cfg, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("broker bind failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    match &standby_of {
        Some(primary) => println!(
            "broker daemon listening on {} (warm standby of {primary})",
            server.addr()
        ),
        None => println!("broker daemon listening on {} (control plane, primary)", server.addr()),
    }
    println!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(Duration::from_secs(10));
        let role = if server.is_primary() { "primary" } else { "standby" };
        println!(
            "{role} | producers {} | active leases {} | price {}/slab·h",
            server.producer_count(),
            server.active_lease_count(),
            server.current_price(),
        );
    }
}

fn cmd_agent(args: &Args) -> ExitCode {
    let Some(broker) = args.flag("broker") else {
        eprintln!("agent: --broker HOST:PORT[,HOST:PORT...] required");
        return ExitCode::FAILURE;
    };
    let cfg = ProducerAgentConfig {
        producer: args.flag_u64("id", 1),
        // Comma-separated list: first endpoint is tried first, the rest
        // are failover targets (warm standbys).
        brokers: broker.split(',').map(str::to_string).collect(),
        data_addr: format!("0.0.0.0:{}", args.flag_u64("port", 0)),
        // A wildcard bind is not dialable from other hosts; multi-host
        // deployments must say what consumers should dial.
        advertise: args.flag("advertise").map(str::to_string),
        capacity_bytes: args.flag_u64("mb", 1024) << 20,
        harvest: args.has("harvest"),
        heartbeat: Duration::from_millis(args.flag_u64("heartbeat-ms", 500)),
        shards: args.flag_u64("shards", 0) as usize,
        rate_bps: args
            .flag("rate-mbps")
            .and_then(|v| v.parse::<u64>().ok())
            .map(|m| m * 1_000_000 / 8),
        seed: args.flag_u64("id", 1),
        stats_addr: Some(format!("0.0.0.0:{}", args.flag_u64("stats-port", 0))),
        ..Default::default()
    };
    let agent = match ProducerAgent::start(cfg) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("agent start failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "producer agent up: data plane {}, registered with broker {broker}",
        agent.data_addr()
    );
    if let Some(addr) = agent.stats_addr() {
        println!("stats endpoint on {addr} (poll with `memtrade top --addr {addr}`)");
    }
    println!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(Duration::from_secs(10));
        println!(
            "offered {} MB | leased {} MB | store {} entries",
            agent.offered_bytes() >> 20,
            agent.target_bytes() >> 20,
            agent.store().map(|s| s.len()).unwrap_or(0),
        );
    }
}

fn cmd_producer(args: &Args) -> ExitCode {
    let port = args.flag_u64("port", 7077);
    let mb = args.flag_u64("mb", 256);
    let rate = args.flag("rate-mbps").and_then(|v| v.parse::<u64>().ok());
    let shards = args.flag_u64("shards", 0) as usize; // 0 = auto (per core)
    let shards = if shards == 0 { memtrade::net::tcp::default_shards() } else { shards };
    let server = match ProducerStoreServer::start_sharded(
        format!("0.0.0.0:{port}"),
        (mb as usize) << 20,
        rate.map(|m| m * 1_000_000 / 8),
        1,
        shards,
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    let n_shards = server.store().num_shards();
    println!(
        "producer store listening on {} ({} MB, {} shards -> max ~{} MB/object{})",
        server.addr(),
        mb,
        n_shards,
        (mb as usize / n_shards).max(1),
        rate.map(|r| format!(", {r} Mb/s limit")).unwrap_or_default()
    );
    println!("press Ctrl-C to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive a YCSB read/update mix through the secure KV over any
/// transport, printing throughput/latency/hit-ratio at the end.
/// `batch > 1` groups ops into `SecureKv` multi-ops (true batch frames
/// on wire transports), amortizing the per-request round trip; latency
/// is then recorded per batch, divided across its ops.
fn drive_ycsb<T: KvTransport>(
    secure: &mut SecureKv,
    transport: &mut T,
    ops: u64,
    value_bytes: usize,
    batch: usize,
) {
    let workload = YcsbWorkload::paper_default((ops / 4).max(100), value_bytes);
    let mut rng = Rng::new(5);
    let mut rec = memtrade::util::stats::LatencyRecorder::new();
    let started = std::time::Instant::now();
    let batch = batch.max(1);
    let mut done = 0u64;
    while done < ops {
        let n = batch.min((ops - done) as usize);
        if n == 1 {
            let op = workload.next_op(&mut rng);
            let key = YcsbWorkload::key_bytes(op.key());
            let t0 = std::time::Instant::now();
            match op {
                Op::Read { .. } => {
                    if secure.get(transport, &key).is_none() {
                        let value = vec![0xAB; value_bytes];
                        let _ = secure.put(transport, &key, &value);
                    }
                }
                Op::Update { .. } => {
                    let value = vec![0xCD; value_bytes];
                    let _ = secure.put(transport, &key, &value);
                }
            }
            rec.record(t0.elapsed().as_micros() as f64);
            done += 1;
            continue;
        }
        // Collect one batch of ops, split reads from updates.
        let mut read_keys: Vec<Vec<u8>> = Vec::new();
        let mut update_keys: Vec<Vec<u8>> = Vec::new();
        for _ in 0..n {
            let op = workload.next_op(&mut rng);
            let key = YcsbWorkload::key_bytes(op.key());
            match op {
                Op::Read { .. } => read_keys.push(key),
                Op::Update { .. } => update_keys.push(key),
            }
        }
        let t0 = std::time::Instant::now();
        // Batched reads; misses refill the cache as batched writes.
        let read_refs: Vec<&[u8]> = read_keys.iter().map(Vec::as_slice).collect();
        let got = secure.multi_get(transport, &read_refs);
        let refill_value = vec![0xAB; value_bytes];
        let refills: Vec<(&[u8], &[u8])> = read_refs
            .iter()
            .zip(&got)
            .filter(|(_, g)| g.is_none())
            .map(|(k, _)| (*k, refill_value.as_slice()))
            .collect();
        if !refills.is_empty() {
            let _ = secure.multi_put(transport, &refills);
        }
        let update_value = vec![0xCD; value_bytes];
        let updates: Vec<(&[u8], &[u8])> = update_keys
            .iter()
            .map(|k| (k.as_slice(), update_value.as_slice()))
            .collect();
        if !updates.is_empty() {
            let _ = secure.multi_put(transport, &updates);
        }
        rec.record(t0.elapsed().as_micros() as f64 / n as f64);
        done += n as u64;
    }
    let dt = started.elapsed().as_secs_f64();
    println!(
        "{} ops in {:.2}s ({:.0} ops/s) | avg {:.1}µs p50 {:.1}µs p99 {:.1}µs | hit ratio {:.3}",
        ops,
        dt,
        ops as f64 / dt,
        rec.mean(),
        rec.p50(),
        rec.p99(),
        secure.hit_ratio(),
    );
}

fn cmd_consumer(args: &Args) -> ExitCode {
    let ops = args.flag_u64("ops", 10_000);
    let value_bytes = args.flag_u64("value-bytes", 1024) as usize;
    let encrypt = !args.has("no-encrypt");
    // --batch N: group N ops per SecureKv multi-op (one batch frame per
    // routed producer). --window W: in-flight frame window on the data
    // connections (chunked batches pipeline W frames deep).
    let batch = args.flag_u64("batch", 1) as usize;
    let window = args.flag_u64("window", 1) as usize;
    let mut secure = SecureKv::new(encrypt.then_some([3u8; 16]), true, 1);

    if let Some(broker) = args.flag("broker") {
        // Marketplace mode: lease slabs via the broker and route through
        // the lease-aware pool.
        let cfg = RemotePoolConfig {
            consumer: args.flag_u64("id", 1000),
            brokers: broker.split(',').map(str::to_string).collect(),
            target_slabs: args.flag_u64("slabs", 4) as u32,
            data_window: window,
            ..Default::default()
        };
        let mut pool = match RemotePool::connect(cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("broker connect failed: {e}");
                return ExitCode::FAILURE;
            }
        };
        println!(
            "leased {} slabs across {} producers (batch {batch}, window {window})",
            pool.held_slabs(),
            pool.live_slots()
        );
        drive_ycsb(&mut secure, &mut pool, ops, value_bytes, batch);
        let s = &pool.stats;
        println!(
            "pool: grants {} | renewals {} | slots lost {} | re-requests {} | io errors {}",
            s.grants.get(),
            s.renewals.get(),
            s.slots_lost.get(),
            s.rerequests.get(),
            s.io_errors.get()
        );
        println!("pool data-call latency: {}", pool.data_call_us.snapshot().render());
        return ExitCode::SUCCESS;
    }

    let Some(addr) = args.flag("addr") else {
        eprintln!("consumer: --addr or --broker HOST:PORT required");
        return ExitCode::FAILURE;
    };
    let mut client = match KvClient::connect(addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("connect failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    client.set_window(window);
    println!(
        "connected to {addr} (batch {batch}, window {window}, negotiated max batch {})",
        client.negotiated_max_batch()
    );
    // A KvClient is itself a KvTransport: multi-ops become real batch
    // frames on this connection.
    drive_ycsb(&mut secure, &mut client, ops, value_bytes, batch);
    ExitCode::SUCCESS
}

fn cmd_sim(args: &Args) -> ExitCode {
    let minutes = args.flag_u64("minutes", 10);
    let cfg = ClusterSimConfig {
        n_producers: args.flag_u64("producers", 8) as usize,
        n_consumers: args.flag_u64("consumers", 6) as usize,
        remote_fraction: args.flag_u64("remote", 30) as f64 / 100.0,
        mode: ConsumerMode::Secure,
        use_pjrt: !args.has("no-pjrt"),
        ..Default::default()
    };
    println!(
        "cluster sim: {} producers, {} consumers, {}% remote, {} min",
        cfg.n_producers,
        cfg.n_consumers,
        (cfg.remote_fraction * 100.0) as u32,
        minutes
    );
    let mut sim = ClusterSim::new(cfg);
    sim.bootstrap();
    sim.run(SimTime::from_mins(minutes));
    println!(
        "consumer avg {:.2} ms, p99 {:.2} ms | leased {:.1} GB | price {}",
        sim.consumer_mean_latency() / 1000.0,
        sim.consumer_p99_latency() / 1000.0,
        sim.leased_bytes() as f64 / (1u64 << 30) as f64,
        Money::from_dollars(sim.broker.current_price().as_dollars()),
    );
    ExitCode::SUCCESS
}

/// Run seeded chaos scenarios (broker + 2 agents + pool under fault
/// injection) and report the resilience invariants per seed. Exits
/// non-zero if any invariant is violated — the printed seed + mix
/// reproduce the failure exactly (`memtrade chaos --seed S --mix M`).
fn cmd_chaos(args: &Args) -> ExitCode {
    let mix_name = args.flag("mix").unwrap_or("standard");
    let Some(mix) = ChaosMix::from_name(mix_name) else {
        eprintln!("chaos: unknown mix {mix_name:?} (one of: {})", ChaosMix::NAMES.join("|"));
        return ExitCode::FAILURE;
    };
    let seeds: Vec<u64> = match args.flag("seed") {
        Some(s) => match s.parse() {
            Ok(v) => vec![v],
            Err(_) => {
                eprintln!("chaos: --seed must be an integer, got {s:?}");
                return ExitCode::FAILURE;
            }
        },
        None => (1..=args.flag_u64("seeds", 5)).collect(),
    };
    let mut failures = 0u32;
    for &seed in &seeds {
        let cfg = ChaosConfig {
            seed,
            mix,
            keys: args.flag_u64("keys", 150) as u32,
            fault_ops: args.flag_u64("ops", 400),
            dump_dir: args.flag("dump-dir").map(std::path::PathBuf::from),
            ..Default::default()
        };
        println!("=== chaos seed {seed} mix {} ===", mix.label());
        let outcome = run_chaos(&cfg);
        println!("{}", outcome.report());
        let violations = outcome.invariant_violations();
        if violations.is_empty() {
            println!("PASS");
        } else {
            failures += 1;
            println!("FAIL (reproduce: memtrade chaos --seed {seed} --mix {})", mix.label());
            for v in &violations {
                println!("  violation: {v}");
            }
        }
        if !outcome.dump_files.is_empty() {
            println!("  flight-recorder dumps:");
            for f in &outcome.dump_files {
                println!("    {}", f.display());
            }
        }
    }
    if failures == 0 {
        println!("\nall {} scenario(s) passed", seeds.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("\n{failures}/{} scenario(s) violated invariants", seeds.len());
        ExitCode::FAILURE
    }
}

fn cmd_replay(args: &Args) -> ExitCode {
    let cfg = ReplayConfig {
        steps: args.flag_u64("steps", 288) as usize,
        n_producers: args.flag_u64("producers", 100) as usize,
        n_consumers: args.flag_u64("consumers", 200) as usize,
        use_pjrt: !args.has("no-pjrt"),
        ..Default::default()
    };
    let r = replay_run(cfg);
    println!(
        "requests {} | slabs granted {}/{} ({:.1}%)",
        r.requests,
        r.slabs_granted,
        r.slabs_requested,
        100.0 * r.slabs_granted as f64 / r.slabs_requested.max(1) as f64
    );
    println!(
        "utilization {:.1}% -> {:.1}% | overprediction {:.2}% | revoked {:.2}%",
        100.0 * r.base_utilization,
        100.0 * r.memtrade_utilization,
        100.0 * r.overprediction_fraction,
        100.0 * r.revoked_fraction,
    );
    ExitCode::SUCCESS
}

/// Poll one `StatsQuery` from a broker or agent stats endpoint.
fn poll_stats(addr: &str) -> std::io::Result<(u64, MetricSet)> {
    let mut ctrl = CtrlClient::connect_timeout(addr, Duration::from_secs(2))?;
    match ctrl.call(&CtrlRequest::StatsQuery)? {
        CtrlResponse::Stats { uptime_us, metrics } => Ok((uptime_us, metrics)),
        other => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unexpected stats reply: {other:?}"),
        )),
    }
}

/// Render one stats snapshot: a per-producer table (built from the
/// broker's `producer.<id>.<field>` gauges) above the raw metric list.
fn render_top(uptime_us: u64, m: &MetricSet) -> String {
    use memtrade::util::fmt::Table;
    let mut producers: std::collections::BTreeMap<u64, std::collections::BTreeMap<String, i64>> =
        Default::default();
    for (name, metric) in m.iter() {
        if let Some((id, field)) = name
            .strip_prefix("producer.")
            .and_then(|t| t.split_once('.'))
            .and_then(|(id, f)| id.parse::<u64>().ok().map(|id| (id, f)))
        {
            let v = match metric {
                Metric::Counter(v) => *v as i64,
                Metric::Gauge(v) => *v,
                Metric::Histogram(_) => continue,
            };
            producers.entry(id).or_default().insert(field.to_string(), v);
        }
    }
    // Brokers publish their failover role (0 = primary, 1 = standby);
    // agent stats endpoints have no such gauge.
    let role = match m.gauge("market.role") {
        Some(0) => " | role primary",
        Some(_) => " | role standby",
        None => "",
    };
    let mut out = format!(
        "memtrade top — uptime {:.1}s{role} | producers {} | active leases {} | \
         price {} nd/slab·h\n\n",
        uptime_us as f64 / 1e6,
        m.gauge("market.producers").unwrap_or(0),
        m.gauge("market.active_leases").unwrap_or(0),
        m.gauge("market.price_nd_per_slab_hour").unwrap_or(0),
    );
    if !producers.is_empty() {
        let mut t = Table::new(vec![
            "producer", "p99 µs", "ops/s", "free", "leased", "safe", "rep %",
        ]);
        for (id, f) in &producers {
            let g = |k: &str| f.get(k).copied().unwrap_or(0).to_string();
            t.row(vec![
                id.to_string(),
                g("observed_p99_us"),
                g("ops_per_sec"),
                g("free_slabs"),
                g("leased_slabs"),
                g("safe_slabs"),
                g("reputation_pct"),
            ]);
        }
        out.push_str(&t.markdown());
        out.push('\n');
    }
    let mut rest = MetricSet::new();
    for (name, metric) in m.iter() {
        if !name.starts_with("producer.") {
            rest.set(name, metric.clone());
        }
    }
    out.push_str(&rest.render());
    out
}

/// Fetch a live span ring over the control plane (`TraceQuery`).
fn fetch_traces(addr: &str, max: u32) -> std::io::Result<Vec<Span>> {
    let mut ctrl = CtrlClient::connect_timeout(addr, Duration::from_secs(2))?;
    match ctrl.call(&CtrlRequest::TraceQuery { max })? {
        CtrlResponse::Traces { spans } => Ok(spans),
        other => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("unexpected trace reply: {other:?}"),
        )),
    }
}

/// Print one span and its children, indented by causal depth. A span
/// whose parent never made the ring (wrapped, or recorded by a peer
/// this endpoint can't see) is printed by the caller at top level.
fn print_span_tree(s: &Span, all: &[&Span], depth: usize) {
    let mut line = format!(
        "{:indent$}{} [{}] {}µs {}",
        "",
        s.op.as_str(),
        s.role.as_str(),
        s.dur_us,
        s.status.as_str(),
        indent = 2 + depth * 2
    );
    if s.lease_id != 0 {
        line += &format!(" lease={}", s.lease_id);
    }
    if s.producer_id != 0 {
        line += &format!(" producer={}", s.producer_id);
    }
    println!("{line}");
    for c in all {
        if c.parent == s.span_id && c.span_id != s.span_id {
            print_span_tree(c, all, depth + 1);
        }
    }
}

/// Fetch recent spans from a live ring (`memtrade trace`): group them
/// into per-trace causal trees and print each, oldest trace first.
/// `--trace ID` (decimal or 0x-hex — exactly what `memtrade top`
/// prints as `p99ex=`) narrows the output to one causal chain.
fn cmd_trace(args: &Args) -> ExitCode {
    let Some(addr) = args.flag("broker").or_else(|| args.flag("addr")) else {
        eprintln!("trace: --broker HOST:PORT (or --addr for an agent stats endpoint) required");
        return ExitCode::FAILURE;
    };
    let max = args.flag_u64("max", 512).min(4096) as u32;
    let filter = match args.flag("trace") {
        Some(s) => {
            let parsed = match s.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => s.parse().ok(),
            };
            let Some(id) = parsed else {
                eprintln!("trace: --trace must be a decimal or 0x-hex id, got {s:?}");
                return ExitCode::FAILURE;
            };
            Some(id)
        }
        None => None,
    };
    let spans = match fetch_traces(addr, max) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("trace: query failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    let mut traces: std::collections::BTreeMap<u64, Vec<&Span>> = Default::default();
    for s in &spans {
        if filter.is_none() || filter == Some(s.trace_id) {
            traces.entry(s.trace_id).or_default().push(s);
        }
    }
    if traces.is_empty() {
        match filter {
            Some(id) => println!("no spans for trace {id:#018x} in the last {max} recorded"),
            None => println!("no spans recorded at {addr}"),
        }
        return ExitCode::SUCCESS;
    }
    for (trace_id, mut list) in traces {
        list.sort_by_key(|s| (s.t_start_us, s.span_id));
        println!("trace {trace_id:#018x} ({} span(s))", list.len());
        let ids: std::collections::HashSet<u64> = list.iter().map(|s| s.span_id).collect();
        for s in &list {
            if s.parent == 0 || !ids.contains(&s.parent) {
                print_span_tree(s, &list, 0);
            }
        }
    }
    ExitCode::SUCCESS
}

/// Live marketplace telemetry: poll `StatsQuery` on a broker (or an
/// agent stats endpoint via --addr) and render it, `top`-style.
fn cmd_top(args: &Args) -> ExitCode {
    let Some(addr) = args.flag("broker").or_else(|| args.flag("addr")) else {
        eprintln!("top: --broker HOST:PORT (or --addr for an agent stats endpoint) required");
        return ExitCode::FAILURE;
    };
    let interval = Duration::from_millis(args.flag_u64("interval-ms", 1000));
    let once = args.has("once");
    loop {
        match poll_stats(addr) {
            Ok((uptime_us, metrics)) => {
                if !once {
                    // ANSI clear + home, like top(1).
                    print!("\x1b[2J\x1b[H");
                }
                print!("{}", render_top(uptime_us, &metrics));
            }
            Err(e) => {
                if once {
                    eprintln!("top: stats poll failed: {e}");
                    return ExitCode::FAILURE;
                }
                eprintln!("top: stats poll failed: {e} (retrying)");
            }
        }
        if once {
            return ExitCode::SUCCESS;
        }
        std::thread::sleep(interval);
    }
}

fn cmd_lint(args: &Args) -> ExitCode {
    // Default root: the crate directory when run from inside it (CI's
    // working-directory is `rust/`), else the `rust/` subdir when run
    // from the repo root.
    let root = args
        .flag("root")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            if std::path::Path::new("src/lib.rs").exists() {
                std::path::PathBuf::from(".")
            } else {
                std::path::PathBuf::from("rust")
            }
        });
    match memtrade::analysis::lint_tree(&root) {
        Err(e) => {
            eprintln!("lint: {e}");
            ExitCode::FAILURE
        }
        Ok(report) if report.is_clean() => {
            println!("memtrade lint: clean ({} files checked)", report.files);
            ExitCode::SUCCESS
        }
        Ok(report) => {
            for d in &report.diagnostics {
                println!("{d}");
            }
            eprintln!(
                "memtrade lint: {} violation(s) across {} files checked",
                report.diagnostics.len(),
                report.files
            );
            ExitCode::FAILURE
        }
    }
}

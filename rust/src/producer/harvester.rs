//! The harvester control loop — paper §4.1, Algorithm 1.
//!
//! Performance convention: the metric is *latency-like* (lower is
//! better); apps without a latency metric report the promotion rate
//! (swap-ins per epoch), which is also lower-better, as the paper does.
//!
//! Per epoch the harvester:
//!  1. records a performance sample (into the *baseline* distribution too
//!     when the epoch saw no page-ins — the paper's trick for estimating
//!     un-harvested performance while harvesting);
//!  2. declares a *drop* when recent p99 exceeds baseline p99 by
//!     `P99Threshold` and enters recovery (cgroup limit disabled);
//!  3. declares a *severe* drop when the recent performance is worse than
//!     every recorded baseline point for `severe_epochs` consecutive
//!     epochs, and asks Silo to prefetch `ChunkSize` back from disk;
//!  4. otherwise, if out of recovery and past the Silo CoolingPeriod
//!     since the last reclaim-triggering step, lowers the cgroup limit by
//!     `ChunkSize`.

use crate::core::config::HarvesterConfig;
use crate::core::SimTime;
use crate::mem::GuestMemory;
use crate::util::avl::WindowedDist;

/// Current mode of the control loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HarvesterMode {
    Harvesting,
    /// In recovery until the stored time.
    Recovery { until: SimTime },
}

/// What the control loop did this epoch (for logging/experiments).
#[derive(Clone, Copy, Debug, Default)]
pub struct HarvestReport {
    pub lowered_limit_by: u64,
    pub entered_recovery: bool,
    pub severe: bool,
    pub prefetched_bytes: u64,
    /// Bytes the *manager* must urgently return (guest burst while leased
    /// memory exceeds what is now harvestable).
    pub reclaim_needed_bytes: u64,
}

pub struct Harvester {
    cfg: HarvesterConfig,
    /// Performance when un-harvested (samples from no-page-in epochs).
    baseline: WindowedDist,
    /// All recent performance samples.
    recent: WindowedDist,
    mode: HarvesterMode,
    /// Current cgroup limit we have imposed (bytes); starts unlimited.
    limit_bytes: u64,
    vm_bytes: u64,
    /// Promotion counter at the previous sample (page-in detection).
    last_promotions: u64,
    /// Whether the last sample interval saw page-ins.
    saw_page_in: bool,
    /// Time of the last limit decrease that actually displaced pages.
    last_reclaiming_step: Option<SimTime>,
    severe_streak: u32,
    /// Latest performance sample (the "current performance" of §4.1's
    /// burst handling).
    last_perf: Option<f64>,
    pub mode_changes: u64,
}

impl Harvester {
    pub fn new(cfg: HarvesterConfig, vm_bytes: u64) -> Self {
        let window = cfg.window_size;
        Harvester {
            cfg,
            baseline: WindowedDist::new(window),
            recent: WindowedDist::new(window),
            mode: HarvesterMode::Harvesting,
            limit_bytes: vm_bytes,
            vm_bytes,
            last_promotions: 0,
            saw_page_in: false,
            last_reclaiming_step: None,
            severe_streak: 0,
            last_perf: None,
            mode_changes: 0,
        }
    }

    pub fn mode(&self) -> HarvesterMode {
        self.mode
    }
    pub fn limit_bytes(&self) -> u64 {
        self.limit_bytes
    }
    pub fn config(&self) -> &HarvesterConfig {
        &self.cfg
    }

    /// Bytes currently harvested from the guest (VM total minus what the
    /// app+Silo still hold).
    pub fn harvested_bytes(&self, mem: &GuestMemory) -> u64 {
        mem.shape().harvestable
    }

    /// Record one performance sample (lower = better). `promotions` is
    /// the guest's cumulative swap-in counter, used to detect page-ins
    /// (RunHarvester lines 8-10 of Algorithm 1).
    pub fn record_sample(&mut self, now: SimTime, perf: f64, promotions: u64) {
        let page_ins = promotions.saturating_sub(self.last_promotions);
        self.last_promotions = promotions;
        self.saw_page_in = page_ins > 0;
        if !self.saw_page_in {
            self.baseline.insert(now, perf);
        } else {
            self.baseline.expire(now);
        }
        self.recent.insert(now, perf);
        self.last_perf = Some(perf);
    }

    fn drop_detected(&self) -> bool {
        match (self.baseline.quantile(0.99), self.recent.quantile(0.99)) {
            (Some(base), Some(recent)) => recent > base * (1.0 + self.cfg.p99_threshold),
            _ => false,
        }
    }

    fn severe_drop(&self) -> bool {
        // Current performance worse than *all* recorded baseline points
        // (§4.1 "Handling Workload Bursts").
        match (self.baseline.max(), self.last_perf) {
            (Some(base_max), Some(current)) => current > base_max,
            _ => false,
        }
    }

    /// One epoch of Algorithm 1 against the guest memory.
    pub fn step_epoch(&mut self, now: SimTime, mem: &mut GuestMemory) -> HarvestReport {
        let mut report = HarvestReport::default();

        // Severe-drop burst mitigation (§4.1 "Handling Workload Bursts").
        if self.severe_drop() {
            self.severe_streak += 1;
        } else {
            self.severe_streak = 0;
        }
        if self.severe_streak >= self.cfg.severe_epochs {
            report.severe = true;
            let fetched = mem.prefetch(self.cfg.chunk_bytes, now);
            report.prefetched_bytes = fetched as u64 * mem.page_bytes();
            self.severe_streak = 0;
        }

        match self.mode {
            HarvesterMode::Recovery { until } => {
                if now >= until && !self.drop_detected() {
                    self.mode = HarvesterMode::Harvesting;
                    self.mode_changes += 1;
                } else {
                    // DoRecovery: keep the limit disabled.
                    mem.disable_cgroup_limit();
                    self.limit_bytes = self.vm_bytes;
                }
            }
            HarvesterMode::Harvesting => {
                if self.drop_detected() {
                    // Enter recovery: disable the cgroup limit entirely.
                    report.entered_recovery = true;
                    mem.disable_cgroup_limit();
                    self.limit_bytes = self.vm_bytes;
                    self.mode = HarvesterMode::Recovery { until: now + self.cfg.recovery_period };
                    self.mode_changes += 1;
                    // A recovery invalidates leased headroom: the manager
                    // must return everything beyond what remains safe.
                    report.reclaim_needed_bytes = 0; // refined by caller via shapes
                } else {
                    // Respect the Silo cooling gate after a reclaiming step.
                    let gated = self
                        .last_reclaiming_step
                        .is_some_and(|t| now.saturating_sub(t) < self.cfg.cooling_period);
                    if !gated {
                        // DoHarvest: lower the limit by one chunk below the
                        // smaller of (current limit, current RSS).
                        let rss = mem.rss_pages() as u64 * mem.page_bytes();
                        let base = self.limit_bytes.min(rss.max(mem.page_bytes()));
                        let new_limit = base.saturating_sub(self.cfg.chunk_bytes);
                        let displaces = new_limit < rss;
                        mem.set_cgroup_limit(new_limit, now);
                        report.lowered_limit_by = self.limit_bytes.saturating_sub(new_limit);
                        self.limit_bytes = new_limit;
                        if displaces {
                            self.last_reclaiming_step = Some(now);
                        }
                    }
                }
            }
        }
        report
    }

    /// Baseline p99 estimate (for diagnostics / experiments).
    pub fn baseline_p99(&self) -> Option<f64> {
        self.baseline.quantile(0.99)
    }
    pub fn recent_p99(&self) -> Option<f64> {
        self.recent.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::SwapDevice;

    fn mem() -> GuestMemory {
        GuestMemory::new(
            1 << 30, // 1 GB VM
            512 << 20,
            1 << 20,
            SwapDevice::Ssd,
            Some(SimTime::from_secs(60)),
            3,
        )
    }

    fn cfg() -> HarvesterConfig {
        let mut c = HarvesterConfig::default();
        c.cooling_period = SimTime::from_secs(60);
        c.recovery_period = SimTime::from_secs(30);
        c
    }

    #[test]
    fn harvests_when_performance_stable() {
        let mut h = Harvester::new(cfg(), 1 << 30);
        let mut m = mem();
        let mut now;
        for i in 0..100 {
            now = SimTime::from_secs(i * 70); // past cooling each step
            h.record_sample(now, 100.0, 0);
            h.step_epoch(now, &mut m);
            m.tick(now); // cool Silo pages to disk
        }
        assert_eq!(h.mode(), HarvesterMode::Harvesting);
        assert!(h.limit_bytes() < 512 << 20, "limit {} never dropped", h.limit_bytes());
        assert!(m.shape().harvestable > 512 << 20);
    }

    #[test]
    fn cooling_gates_consecutive_reclaims() {
        let mut h = Harvester::new(cfg(), 1 << 30);
        let mut m = mem();
        // First step displaces pages (limit < RSS).
        h.record_sample(SimTime::from_secs(1), 100.0, 0);
        h.step_epoch(SimTime::from_secs(1), &mut m);
        let limit_after_first = h.limit_bytes();
        // Second step within the cooling period must not lower further.
        h.record_sample(SimTime::from_secs(5), 100.0, 0);
        h.step_epoch(SimTime::from_secs(5), &mut m);
        assert_eq!(h.limit_bytes(), limit_after_first);
        // After cooling, it resumes.
        h.record_sample(SimTime::from_secs(62), 100.0, 0);
        h.step_epoch(SimTime::from_secs(62), &mut m);
        assert!(h.limit_bytes() < limit_after_first);
    }

    #[test]
    fn p99_drop_triggers_recovery_and_disables_limit() {
        let mut h = Harvester::new(cfg(), 1 << 30);
        let mut m = mem();
        let mut now = SimTime::ZERO;
        // Build a baseline at 100µs.
        for i in 0..50 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 100.0, 0);
        }
        h.step_epoch(now, &mut m);
        // Sustained degradation with page-ins.
        for i in 51..80 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 150.0, i); // promotions increasing
        }
        let before_limit = m.cgroup_limit_bytes();
        let _ = before_limit;
        let r = h.step_epoch(now, &mut m);
        assert!(r.entered_recovery);
        assert!(matches!(h.mode(), HarvesterMode::Recovery { .. }));
        assert_eq!(m.cgroup_limit_bytes(), 1 << 30); // disabled = VM size
    }

    #[test]
    fn recovery_ends_after_period_when_perf_restored() {
        let mut h = Harvester::new(cfg(), 1 << 30);
        let mut m = mem();
        let mut now = SimTime::ZERO;
        for i in 0..50 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 100.0, 0);
        }
        for i in 50..60 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 200.0, i);
        }
        h.step_epoch(now, &mut m);
        assert!(matches!(h.mode(), HarvesterMode::Recovery { .. }));
        // Perf recovers; after the recovery period the p99 window still
        // contains bad samples, so keep feeding good ones until the drop
        // clears (samples expire after WindowSize; here good samples
        // outnumber them quickly at p99? No — p99 needs the bad tail to
        // expire or dilute: feed 6000 good samples).
        for i in 60..7000 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 100.0, 60); // constant promotions = no page-in
        }
        h.step_epoch(now, &mut m);
        assert_eq!(h.mode(), HarvesterMode::Harvesting);
    }

    #[test]
    fn severe_drop_prefetches() {
        let mut c = cfg();
        c.severe_epochs = 2;
        let mut h = Harvester::new(c, 1 << 30);
        let mut m = mem();
        let mut now = SimTime::ZERO;
        for i in 0..20 {
            now = SimTime::from_secs(i);
            h.record_sample(now, 100.0, 0);
        }
        // Harvest a chunk so something is on disk after cooling.
        h.step_epoch(now, &mut m);
        m.tick(SimTime::from_secs(200));
        assert!(m.disk_pages() > 0);
        // Catastrophic latency, worse than every baseline point.
        let mut report = HarvestReport::default();
        for i in 0..4 {
            now = SimTime::from_secs(300 + i);
            h.record_sample(now, 10_000.0, 100 + i);
            report = h.step_epoch(now, &mut m);
        }
        assert!(report.severe, "severe drop not flagged");
        assert!(report.prefetched_bytes > 0 || m.disk_pages() == 0);
    }
}

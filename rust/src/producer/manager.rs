//! The producer manager (paper §4.2): partitions harvested memory into
//! slabs, runs one producer store per consumer lease, enforces per-
//! consumer token-bucket bandwidth limits, reclaims memory proportionally
//! across stores when the harvester needs it back, and reports resource
//! availability to the broker.

use crate::core::{ConsumerId, Lease, LeaseId, ProducerId, SimTime};
use crate::kv::KvStore;
use crate::net::wire::{Request, Response};
use crate::util::token_bucket::TokenBucket;
use std::collections::HashMap;

/// Periodic availability report sent to the broker (§3).
#[derive(Clone, Copy, Debug)]
pub struct ProducerReport {
    pub producer: ProducerId,
    pub free_slabs: u32,
    pub harvestable_bytes: u64,
    pub leased_bytes: u64,
    /// 0..1 headroom metrics used by placement.
    pub cpu_headroom: f64,
    pub bandwidth_headroom: f64,
}

struct StoreEntry {
    store: KvStore,
    bucket: TokenBucket,
    lease: Lease,
}

/// Per-producer manager.
pub struct Manager {
    id: ProducerId,
    slab_bytes: u64,
    /// Harvested pool currently safe to lease (set each epoch).
    harvestable_bytes: u64,
    stores: HashMap<ConsumerId, StoreEntry>,
    seed: u64,
    /// Slabs evicted before lease expiry (reputation input, §5).
    pub broken_lease_slabs: u64,
    /// Total slabs ever leased (reputation denominator).
    pub leased_slab_total: u64,
}

impl Manager {
    pub fn new(id: ProducerId, slab_bytes: u64, seed: u64) -> Self {
        Manager {
            id,
            slab_bytes,
            harvestable_bytes: 0,
            stores: HashMap::new(),
            seed,
            broken_lease_slabs: 0,
            leased_slab_total: 0,
        }
    }

    pub fn slab_bytes(&self) -> u64 {
        self.slab_bytes
    }

    pub fn leased_bytes(&self) -> u64 {
        self.stores.values().map(|e| e.store.max_bytes() as u64).sum()
    }

    pub fn free_slabs(&self) -> u32 {
        (self.harvestable_bytes.saturating_sub(self.leased_bytes()) / self.slab_bytes) as u32
    }

    /// Refresh the leaseable pool from the guest's current shape.
    pub fn set_harvestable(&mut self, bytes: u64, now: SimTime) {
        self.harvestable_bytes = bytes;
        // If the pool shrank below what is leased, reclaim the difference.
        let leased = self.leased_bytes();
        if leased > bytes {
            self.reclaim(leased - bytes, now);
        }
    }

    pub fn harvestable_bytes(&self) -> u64 {
        self.harvestable_bytes
    }

    /// Broker assignment: create a producer store for this lease
    /// (paper: an empty Redis server per consumer, ~3 MB — modeled free).
    /// Returns false if the slabs no longer fit.
    pub fn grant_lease(&mut self, lease: Lease, bandwidth_bps: u64) -> bool {
        let bytes = lease.bytes();
        if bytes + self.leased_bytes() > self.harvestable_bytes {
            return false;
        }
        self.leased_slab_total += lease.slabs as u64;
        let store = KvStore::new(bytes as usize, self.seed ^ lease.id.0);
        let bucket = TokenBucket::new(bandwidth_bps, bandwidth_bps / 4);
        self.stores.insert(lease.consumer, StoreEntry { store, bucket, lease });
        true
    }

    /// Lease expiry (not renewed): terminate the store, return slabs.
    pub fn end_lease(&mut self, consumer: ConsumerId) -> Option<LeaseId> {
        self.stores.remove(&consumer).map(|e| e.lease.id)
    }

    pub fn lease_of(&self, consumer: ConsumerId) -> Option<&Lease> {
        self.stores.get(&consumer).map(|e| &e.lease)
    }

    pub fn active_leases(&self) -> impl Iterator<Item = &Lease> {
        self.stores.values().map(|e| &e.lease)
    }

    /// Serve one consumer request against its producer store, enforcing
    /// the rate limiter (paper §4.2: refuse when tokens are short).
    pub fn handle(&mut self, consumer: ConsumerId, req: &Request, now: SimTime) -> Response {
        let Some(entry) = self.stores.get_mut(&consumer) else {
            return Response::Error("no lease for consumer".into());
        };
        let io_bytes = req.wire_bytes() as u64;
        if !entry.bucket.try_consume(now, io_bytes) {
            let retry = entry
                .bucket
                .time_until(now, io_bytes)
                .unwrap_or(SimTime::from_secs(1));
            return Response::Throttled { retry_after_us: retry.as_micros() };
        }
        match req {
            Request::Get { key } => match entry.store.get(key) {
                Some(v) => Response::Value(v.to_vec()),
                None => Response::NotFound,
            },
            Request::Put { key, value } => {
                if entry.store.put(key, value) {
                    Response::Stored
                } else {
                    Response::Rejected
                }
            }
            Request::Delete { key } => Response::Deleted(entry.store.delete(key)),
            Request::Ping => Response::Pong,
        }
    }

    /// Harvester burst path (§4.2 "Eviction"): reclaim `bytes` across
    /// stores proportionally to their sizes, via their LRU eviction.
    pub fn reclaim(&mut self, bytes: u64, _now: SimTime) -> u64 {
        let leased = self.leased_bytes();
        if leased == 0 {
            return 0;
        }
        let mut freed = 0u64;
        let entries: Vec<ConsumerId> = self.stores.keys().copied().collect();
        for cid in entries {
            let entry = self.stores.get_mut(&cid).unwrap();
            let share = entry.store.max_bytes() as f64 / leased as f64;
            let target = (bytes as f64 * share).ceil() as u64;
            let new_max = (entry.store.max_bytes() as u64).saturating_sub(target);
            // Slabs taken back before expiry count against reputation.
            let slabs_lost = (entry.store.max_bytes() as u64 - new_max) / self.slab_bytes;
            self.broken_lease_slabs += slabs_lost;
            entry.store.shrink_to(new_max as usize);
            freed += target;
        }
        freed.min(bytes)
    }

    /// Fraction of leased slabs never prematurely evicted (reputation, §5).
    pub fn reputation(&self) -> f64 {
        if self.leased_slab_total == 0 {
            1.0
        } else {
            1.0 - (self.broken_lease_slabs as f64 / self.leased_slab_total as f64).min(1.0)
        }
    }

    /// Availability report for the broker.
    pub fn report(&self, cpu_headroom: f64, bandwidth_headroom: f64) -> ProducerReport {
        ProducerReport {
            producer: self.id,
            free_slabs: self.free_slabs(),
            harvestable_bytes: self.harvestable_bytes,
            leased_bytes: self.leased_bytes(),
            cpu_headroom,
            bandwidth_headroom,
        }
    }

    /// Run defragmentation on all stores (paper §4.2 "Defragmentation").
    pub fn defragment_all(&mut self) -> u64 {
        self.stores.values_mut().map(|e| e.store.defragment() as u64).sum()
    }

    /// Store statistics for one consumer (tests/metrics).
    pub fn store_stats(&self, consumer: ConsumerId) -> Option<crate::kv::KvStats> {
        self.stores.get(&consumer).map(|e| e.store.stats.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Money, DEFAULT_SLAB_BYTES};

    fn lease(id: u64, consumer: u64, slabs: u32) -> Lease {
        Lease {
            id: LeaseId(id),
            consumer: ConsumerId(consumer),
            producer: ProducerId(1),
            slabs,
            slab_bytes: DEFAULT_SLAB_BYTES,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(1),
            price_per_slab_hour: Money::from_dollars(0.0001),
        }
    }

    fn manager_with_pool(gb: u64) -> Manager {
        let mut m = Manager::new(ProducerId(1), DEFAULT_SLAB_BYTES, 5);
        m.set_harvestable(gb << 30, SimTime::ZERO);
        m
    }

    #[test]
    fn grant_serve_expire() {
        let mut m = manager_with_pool(2);
        assert!(m.grant_lease(lease(1, 10, 16), 1_000_000_000));
        let c = ConsumerId(10);
        let now = SimTime::from_secs(1);
        assert_eq!(
            m.handle(c, &Request::Put { key: b"k".to_vec(), value: b"v".to_vec() }, now),
            Response::Stored
        );
        assert_eq!(
            m.handle(c, &Request::Get { key: b"k".to_vec() }, now),
            Response::Value(b"v".to_vec())
        );
        assert_eq!(m.end_lease(c), Some(LeaseId(1)));
        assert!(matches!(
            m.handle(c, &Request::Ping, now),
            Response::Error(_)
        ));
    }

    #[test]
    fn cannot_overlease() {
        let mut m = manager_with_pool(1); // 16 slabs
        assert!(m.grant_lease(lease(1, 10, 10), 1_000_000));
        assert!(!m.grant_lease(lease(2, 11, 10), 1_000_000));
        assert!(m.grant_lease(lease(3, 12, 6), 1_000_000));
        assert_eq!(m.free_slabs(), 0);
    }

    #[test]
    fn rate_limits_per_consumer() {
        let mut m = manager_with_pool(2);
        assert!(m.grant_lease(lease(1, 10, 16), 1000)); // 1 KB/s
        let c = ConsumerId(10);
        let now = SimTime::ZERO;
        let big = Request::Put { key: b"k".to_vec(), value: vec![0u8; 8192] };
        assert!(matches!(
            m.handle(c, &big, now),
            Response::Throttled { .. }
        ));
    }

    #[test]
    fn reclaim_shrinks_proportionally_and_dings_reputation() {
        let mut m = manager_with_pool(4);
        assert!(m.grant_lease(lease(1, 10, 32), 1_000_000_000)); // 2 GB
        assert!(m.grant_lease(lease(2, 11, 16), 1_000_000_000)); // 1 GB
        assert_eq!(m.reputation(), 1.0);
        // Pool shrinks to 1.5 GB: reclaim 1.5 GB.
        m.set_harvestable(3 << 29, SimTime::from_secs(10));
        assert!(m.leased_bytes() <= 3 << 29);
        assert!(m.reputation() < 1.0);
        assert!(m.broken_lease_slabs >= 24);
    }

    #[test]
    fn report_consistent() {
        let mut m = manager_with_pool(2);
        assert!(m.grant_lease(lease(1, 10, 16), 1_000_000_000));
        let r = m.report(0.8, 0.6);
        assert_eq!(r.leased_bytes, 1 << 30);
        assert_eq!(r.free_slabs, 16);
        assert_eq!(r.harvestable_bytes, 2 << 30);
    }
}

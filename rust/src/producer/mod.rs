//! The producer side of Memtrade (paper §4): the **harvester**, an
//! application-aware control loop that decides when to harvest and when
//! to return memory (Algorithm 1), and the **manager**, which exposes
//! harvested memory to consumers as per-consumer producer stores with
//! slab accounting, LRU eviction on reclaim, and token-bucket rate
//! limiting (§4.2). [`Producer`] assembles both around an [`AppRunner`]
//! guest workload.

pub mod harvester;
pub mod manager;

pub use harvester::{Harvester, HarvesterMode, HarvestReport};
pub use manager::{Manager, ProducerReport};

use crate::core::config::HarvesterConfig;
use crate::core::{ProducerId, SimTime};
use crate::workload::apps::AppRunner;

/// A complete producer VM: guest app + harvester + manager.
pub struct Producer {
    pub id: ProducerId,
    pub app: AppRunner,
    pub harvester: Harvester,
    pub manager: Manager,
}

impl Producer {
    pub fn new(id: ProducerId, app: AppRunner, cfg: HarvesterConfig, slab_bytes: u64) -> Self {
        let vm_bytes = app.model.vm_bytes;
        let harvester = Harvester::new(cfg, vm_bytes);
        let manager = Manager::new(id, slab_bytes, id.0.wrapping_mul(0x9E3779B97F4A7C15));
        Producer { id, app, harvester, manager }
    }

    /// One monitoring epoch: run the app, feed the harvester, apply its
    /// action to the guest memory, refresh the manager's leaseable pool.
    /// Returns the epoch's mean application latency (µs).
    pub fn tick(&mut self, now: SimTime, epoch: SimTime) -> f64 {
        let rec = self.app.run_epoch(now, epoch);
        let perf = rec.mean();
        let promotions = self.app.memory.promotions();
        self.harvester.record_sample(now, perf, promotions);
        let report = self.harvester.step_epoch(now, &mut self.app.memory);

        // The manager may lease whatever the guest's shape says is
        // harvestable, still honoring outstanding leases.
        let shape = self.app.memory.shape();
        self.manager.set_harvestable(shape.harvestable, now);
        if report.reclaim_needed_bytes > 0 {
            self.manager.reclaim(report.reclaim_needed_bytes, now);
        }
        perf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::HarvesterConfig;
    use crate::core::SimTime;
    use crate::mem::SwapDevice;
    use crate::workload::apps::{AppKind, AppModel, AppRunner};

    #[test]
    fn producer_harvests_over_time_without_hurting_app() {
        let model = AppModel::preset(AppKind::Redis);
        let app = AppRunner::new(
            model,
            1 << 20, // 1 MB pages for test speed
            SwapDevice::Ssd,
            Some(SimTime::from_secs(30)),
            7,
        );
        let mut cfg = HarvesterConfig::default();
        cfg.cooling_period = SimTime::from_secs(30);
        cfg.epoch = SimTime::from_secs(5);
        let mut p = Producer::new(ProducerId(1), app, cfg, 64 << 20);

        let baseline = p.app.baseline_latency_us();
        let mut now = SimTime::ZERO;
        let mut last_perf = baseline;
        for _ in 0..600 {
            now += SimTime::from_secs(5);
            last_perf = p.tick(now, SimTime::from_secs(5));
        }
        let harvested = p.harvester.harvested_bytes(&p.app.memory);
        assert!(
            harvested > 1 << 30,
            "harvested only {} MB after 50 min",
            harvested >> 20
        );
        // Long-run perf within a few percent of baseline.
        assert!(
            last_perf < baseline * 1.10,
            "perf degraded: {last_perf} vs baseline {baseline}"
        );
    }
}

//! Workload and trace generators.
//!
//! The paper's evaluation drives Memtrade with (a) YCSB over Redis for
//! consumers, (b) six producer applications (Redis, memcached, MySQL,
//! XGBoost, Storm, CloudSuite), (c) Google/Alibaba/Snowflake cluster
//! traces, (d) the MemCachier commercial trace, and (e) AWS spot price
//! history. None of those proprietary inputs are available here, so each
//! has a from-scratch synthetic generator statistically shaped to the
//! published aggregate behaviour (see DESIGN.md §Substitutions).

pub mod apps;
pub mod cluster_trace;
pub mod memcachier;
pub mod spot;
pub mod ycsb;

pub use apps::{AppKind, AppModel, AppRunner};
pub use cluster_trace::{ClusterTrace, MachineClass};
pub use memcachier::MrcLibrary;
pub use spot::SpotPriceSeries;
pub use ycsb::{KeyDistribution, Op, YcsbWorkload};

//! Synthetic MemCachier application population (Fig 12/15, §7.4).
//!
//! The paper samples 36 applications from the MemCachier commercial trace
//! and uses their miss-ratio curves (MRCs) to drive consumer purchasing.
//! We generate an MRC library whose curve *family* matches Fig 15: a mix
//! of (a) smooth concave curves (Zipf-like reuse), (b) cliff curves that
//! drop sharply once the working set fits, and (c) flat/streaming curves
//! that barely benefit from cache.

use crate::util::rng::Rng;

/// One application's miss-ratio curve, sampled at `granularity_bytes`
/// increments of cache size.
#[derive(Clone, Debug)]
pub struct Mrc {
    pub app_id: u32,
    /// miss_ratio[s] = expected miss ratio with s*granularity bytes of cache.
    pub miss_ratio: Vec<f64>,
    pub granularity_bytes: u64,
    /// Request rate (ops/sec) for hit-value computation.
    pub req_rate: f64,
}

impl Mrc {
    /// Miss ratio at an arbitrary cache size (linear interpolation).
    pub fn at_bytes(&self, bytes: u64) -> f64 {
        let pos = bytes as f64 / self.granularity_bytes as f64;
        let lo = pos.floor() as usize;
        if lo + 1 >= self.miss_ratio.len() {
            return *self.miss_ratio.last().unwrap();
        }
        let frac = pos - lo as f64;
        self.miss_ratio[lo] * (1.0 - frac) + self.miss_ratio[lo + 1] * frac
    }

    pub fn hit_ratio_at(&self, bytes: u64) -> f64 {
        1.0 - self.at_bytes(bytes)
    }

    /// Smallest cache size achieving `target` fraction of the optimal
    /// (full-cache) hit ratio — the paper's §7.4 consumer sizing rule
    /// ("local memory serves at least 80% of its optimal hit ratio").
    pub fn size_for_relative_hit_ratio(&self, target: f64) -> u64 {
        let optimal = 1.0 - *self.miss_ratio.last().unwrap();
        if optimal <= 0.0 {
            return 0;
        }
        for (s, &mr) in self.miss_ratio.iter().enumerate() {
            if (1.0 - mr) >= target * optimal {
                return s as u64 * self.granularity_bytes;
            }
        }
        (self.miss_ratio.len() as u64 - 1) * self.granularity_bytes
    }

    /// Extra hits/sec gained by adding `extra` bytes on top of `local`.
    pub fn gain(&self, local: u64, extra: u64) -> f64 {
        self.req_rate * (self.hit_ratio_at(local + extra) - self.hit_ratio_at(local)).max(0.0)
    }

    /// The extra-hit curve the demand kernel consumes: gain at
    /// 0..n_sizes slabs of `slab_bytes` on top of `local`.
    pub fn gain_curve(&self, local: u64, slab_bytes: u64, n_sizes: usize) -> Vec<f32> {
        (0..n_sizes)
            .map(|s| self.gain(local, s as u64 * slab_bytes) as f32)
            .collect()
    }
}

/// Library of synthetic MemCachier-like MRCs.
pub struct MrcLibrary {
    pub mrcs: Vec<Mrc>,
}

impl MrcLibrary {
    /// The paper's 36-app population.
    pub fn paper_population(seed: u64) -> Self {
        Self::generate(36, seed)
    }

    pub fn generate(n_apps: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let granularity = 64 << 20; // one slab
        let points = 129; // 0..8 GB in 64 MB steps
        let mut mrcs = Vec::with_capacity(n_apps);
        for app_id in 0..n_apps {
            let shape = rng.below(10);
            let footprint_slabs = rng.uniform(8.0, 120.0);
            let req_rate = rng.uniform(50.0, 8_000.0);
            let floor = rng.uniform(0.0, 0.15); // compulsory misses
            let miss_ratio: Vec<f64> = (0..points)
                .map(|s| {
                    let x = s as f64 / footprint_slabs;
                    let mr = match shape {
                        // Smooth concave (Zipf-like): most MemCachier apps.
                        0..=5 => {
                            let alpha = rng.uniform(0.35, 0.8);
                            (1.0 - x.min(1.0).powf(alpha)).max(0.0)
                        }
                        // Cliff at the working set.
                        6 | 7 => {
                            if x >= 1.0 {
                                0.0
                            } else {
                                1.0 - 0.3 * x
                            }
                        }
                        // Two-knee curve.
                        8 => {
                            if x < 0.3 {
                                1.0 - 1.5 * x
                            } else if x < 1.0 {
                                0.55 - 0.55 * (x - 0.3) / 0.7
                            } else {
                                0.0
                            }
                        }
                        // Streaming / scan-heavy: cache barely helps.
                        _ => 1.0 - 0.15 * x.min(1.0),
                    };
                    (mr * (1.0 - floor) + floor).clamp(0.0, 1.0)
                })
                .collect();
            // Enforce monotone non-increasing (MRC property).
            let mut mono = miss_ratio.clone();
            for i in 1..mono.len() {
                if mono[i] > mono[i - 1] {
                    mono[i] = mono[i - 1];
                }
            }
            mrcs.push(Mrc {
                app_id: app_id as u32,
                miss_ratio: mono,
                granularity_bytes: granularity,
                req_rate,
            });
        }
        MrcLibrary { mrcs }
    }

    pub fn sample<'a>(&'a self, rng: &mut Rng) -> &'a Mrc {
        rng.choose(&self.mrcs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mrcs_monotone_nonincreasing() {
        let lib = MrcLibrary::paper_population(1);
        assert_eq!(lib.mrcs.len(), 36);
        for mrc in &lib.mrcs {
            for w in mrc.miss_ratio.windows(2) {
                assert!(w[1] <= w[0] + 1e-12, "app {} not monotone", mrc.app_id);
            }
            assert!(mrc.miss_ratio[0] > 0.5, "zero-size cache should miss a lot");
        }
    }

    #[test]
    fn interpolation() {
        let mrc = Mrc {
            app_id: 0,
            miss_ratio: vec![1.0, 0.5, 0.25],
            granularity_bytes: 100,
            req_rate: 1000.0,
        };
        assert!((mrc.at_bytes(0) - 1.0).abs() < 1e-12);
        assert!((mrc.at_bytes(50) - 0.75).abs() < 1e-12);
        assert!((mrc.at_bytes(100) - 0.5).abs() < 1e-12);
        assert!((mrc.at_bytes(10_000) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn sizing_rule() {
        let mrc = Mrc {
            app_id: 0,
            miss_ratio: vec![1.0, 0.6, 0.3, 0.1, 0.1],
            granularity_bytes: 100,
            req_rate: 1.0,
        };
        // optimal hit = 0.9; 80% of optimal = 0.72 -> needs mr <= 0.28 -> s=3.
        assert_eq!(mrc.size_for_relative_hit_ratio(0.8), 300);
        assert_eq!(mrc.size_for_relative_hit_ratio(0.0), 0);
    }

    #[test]
    fn gain_curve_concave_increasing() {
        let lib = MrcLibrary::paper_population(3);
        for mrc in &lib.mrcs {
            let local = mrc.size_for_relative_hit_ratio(0.8);
            let curve = mrc.gain_curve(local, 64 << 20, 64);
            assert_eq!(curve[0], 0.0);
            for w in curve.windows(2) {
                assert!(w[1] >= w[0] - 1e-6, "gain must be non-decreasing");
            }
        }
    }
}

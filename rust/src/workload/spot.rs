//! Synthetic AWS-spot-style price series (Fig 13 uses the historical
//! r3.large price in us-east-2b; we generate a mean-reverting series with
//! occasional demand spikes around that instance's typical price band).

use crate::core::Money;
use crate::util::rng::Rng;

/// Mean-reverting (Ornstein-Uhlenbeck-style) price series with jumps.
#[derive(Clone, Debug)]
pub struct SpotPriceSeries {
    /// $/hour for the whole instance at each step.
    pub prices: Vec<f64>,
    /// Instance memory, GB (r3.large = 15.25 GB).
    pub instance_gb: f64,
}

impl SpotPriceSeries {
    /// r3.large-like series: on-demand ~$0.166/h, spot hovering ~$0.04/h.
    pub fn r3_large(n_steps: usize, seed: u64) -> Self {
        Self::generate(n_steps, 0.040, 0.015, 0.166, 15.25, seed)
    }

    /// `mean`: long-run spot price; `vol`: step volatility scale;
    /// `cap`: on-demand ceiling; `instance_gb`: instance memory.
    pub fn generate(
        n_steps: usize,
        mean: f64,
        vol: f64,
        cap: f64,
        instance_gb: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let mut p = mean;
        let mut prices = Vec::with_capacity(n_steps);
        let mut spike_left = 0usize;
        let mut spike_mult = 1.0;
        for _ in 0..n_steps {
            // OU pull toward the mean + noise.
            p += 0.1 * (mean - p) + rng.normal(0.0, vol * 0.1);
            // Occasional demand spikes (interrupted capacity).
            if spike_left == 0 && rng.chance(0.01) {
                spike_left = rng.range(2, 12) as usize;
                spike_mult = rng.uniform(1.5, 3.5);
            }
            let effective = if spike_left > 0 {
                spike_left -= 1;
                p * spike_mult
            } else {
                p
            };
            prices.push(effective.clamp(mean * 0.25, cap));
        }
        SpotPriceSeries { prices, instance_gb }
    }

    /// Spot price normalized per GB·hour at step `t`.
    pub fn per_gb_hour(&self, t: usize) -> Money {
        let p = self.prices[t.min(self.prices.len() - 1)];
        Money::from_dollars(p / self.instance_gb)
    }

    pub fn len(&self) -> usize {
        self.prices.len()
    }
    pub fn is_empty(&self) -> bool {
        self.prices.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_in_band() {
        let s = SpotPriceSeries::r3_large(2000, 5);
        for &p in &s.prices {
            assert!(p >= 0.01 && p <= 0.166, "price {p} out of band");
        }
        let mean: f64 = s.prices.iter().sum::<f64>() / s.prices.len() as f64;
        assert!((0.02..0.09).contains(&mean), "mean {mean}");
    }

    #[test]
    fn has_spikes() {
        let s = SpotPriceSeries::r3_large(5000, 6);
        let mean: f64 = s.prices.iter().sum::<f64>() / s.prices.len() as f64;
        let peak = s.prices.iter().cloned().fold(0.0f64, f64::max);
        assert!(peak > mean * 1.8, "no spikes: peak {peak} mean {mean}");
    }

    #[test]
    fn per_gb_normalization() {
        let s = SpotPriceSeries { prices: vec![0.1525], instance_gb: 15.25 };
        assert!((s.per_gb_hour(0).as_dollars() - 0.01).abs() < 1e-9);
    }

    #[test]
    fn deterministic() {
        let a = SpotPriceSeries::r3_large(100, 9);
        let b = SpotPriceSeries::r3_large(100, 9);
        assert_eq!(a.prices, b.prices);
    }
}

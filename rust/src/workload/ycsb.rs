//! YCSB-style workload generator (paper §7: YCSB on Redis, Zipfian
//! constant 0.7, 95% reads / 5% updates; burst experiments shift the
//! distribution to uniform mid-run).

use crate::util::rng::{Rng, ScrambledZipfian};

/// Key-popularity distribution.
#[derive(Clone, Debug)]
pub enum KeyDistribution {
    Zipfian(f64),
    Uniform,
    /// Hotspot: `hot_fraction` of ops target `hot_set_fraction` of keys.
    Hotspot { hot_set_fraction: f64, hot_op_fraction: f64 },
}

/// One generated operation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    Read { key: u64 },
    Update { key: u64, value_size: usize },
}

impl Op {
    pub fn key(&self) -> u64 {
        match self {
            Op::Read { key } | Op::Update { key, .. } => *key,
        }
    }
    pub fn is_read(&self) -> bool {
        matches!(self, Op::Read { .. })
    }
}

/// YCSB-like generator.
pub struct YcsbWorkload {
    n_keys: u64,
    read_fraction: f64,
    value_size: usize,
    dist: KeyDistribution,
    zipf: Option<ScrambledZipfian>,
}

impl YcsbWorkload {
    /// The paper's consumer workload: Zipf 0.7, 95% reads.
    pub fn paper_default(n_keys: u64, value_size: usize) -> Self {
        Self::new(n_keys, value_size, 0.95, KeyDistribution::Zipfian(0.7))
    }

    pub fn new(
        n_keys: u64,
        value_size: usize,
        read_fraction: f64,
        dist: KeyDistribution,
    ) -> Self {
        let zipf = match &dist {
            KeyDistribution::Zipfian(theta) => Some(ScrambledZipfian::new(n_keys, *theta)),
            _ => None,
        };
        YcsbWorkload { n_keys, read_fraction, value_size, dist, zipf }
    }

    pub fn n_keys(&self) -> u64 {
        self.n_keys
    }
    pub fn value_size(&self) -> usize {
        self.value_size
    }

    /// Switch distribution mid-run (the paper's burst experiment flips
    /// Zipf -> uniform after one hour).
    pub fn set_distribution(&mut self, dist: KeyDistribution) {
        self.zipf = match &dist {
            KeyDistribution::Zipfian(theta) => {
                Some(ScrambledZipfian::new(self.n_keys, *theta))
            }
            _ => None,
        };
        self.dist = dist;
    }

    pub fn next_key(&self, rng: &mut Rng) -> u64 {
        match &self.dist {
            KeyDistribution::Zipfian(_) => self.zipf.as_ref().unwrap().sample(rng),
            KeyDistribution::Uniform => rng.below(self.n_keys),
            KeyDistribution::Hotspot { hot_set_fraction, hot_op_fraction } => {
                let hot_keys = ((self.n_keys as f64) * hot_set_fraction).max(1.0) as u64;
                if rng.chance(*hot_op_fraction) {
                    rng.below(hot_keys)
                } else {
                    hot_keys + rng.below((self.n_keys - hot_keys).max(1))
                }
            }
        }
    }

    pub fn next_op(&self, rng: &mut Rng) -> Op {
        let key = self.next_key(rng);
        if rng.chance(self.read_fraction) {
            Op::Read { key }
        } else {
            Op::Update { key, value_size: self.value_size }
        }
    }

    /// Encode a key the way YCSB does ("user" + number).
    pub fn key_bytes(key: u64) -> Vec<u8> {
        format!("user{key}").into_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_mix() {
        let w = YcsbWorkload::paper_default(10_000, 1024);
        let mut rng = Rng::new(1);
        let n = 100_000;
        let reads = (0..n).filter(|_| w.next_op(&mut rng).is_read()).count();
        let frac = reads as f64 / n as f64;
        assert!((frac - 0.95).abs() < 0.01, "read fraction {frac}");
    }

    #[test]
    fn zipfian_keys_skewed() {
        let w = YcsbWorkload::paper_default(1000, 100);
        let mut rng = Rng::new(2);
        let mut counts = vec![0u64; 1000];
        for _ in 0..100_000 {
            counts[w.next_key(&mut rng) as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile: u64 = counts[..100].iter().sum();
        assert!(top_decile > 40_000, "zipf top decile {top_decile}");
    }

    #[test]
    fn uniform_keys_flat() {
        let w = YcsbWorkload::new(1000, 100, 1.0, KeyDistribution::Uniform);
        let mut rng = Rng::new(3);
        let mut counts = vec![0u64; 1000];
        for _ in 0..100_000 {
            counts[w.next_key(&mut rng) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 2.5, "uniform spread {max}/{min}");
    }

    #[test]
    fn distribution_shift() {
        let mut w = YcsbWorkload::paper_default(1000, 100);
        let mut rng = Rng::new(4);
        w.set_distribution(KeyDistribution::Uniform);
        let mut counts = vec![0u64; 1000];
        for _ in 0..50_000 {
            counts[w.next_key(&mut rng) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max < 150, "after shift still skewed: {max}");
    }

    #[test]
    fn hotspot() {
        let w = YcsbWorkload::new(
            1000,
            100,
            1.0,
            KeyDistribution::Hotspot { hot_set_fraction: 0.1, hot_op_fraction: 0.9 },
        );
        let mut rng = Rng::new(5);
        let hot = (0..100_000).filter(|_| w.next_key(&mut rng) < 100).count();
        assert!((hot as f64 / 100_000.0 - 0.9).abs() < 0.02);
    }

    #[test]
    fn key_encoding() {
        assert_eq!(YcsbWorkload::key_bytes(42), b"user42".to_vec());
    }
}

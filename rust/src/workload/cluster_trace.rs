//! Synthetic cluster-trace generator shaped to the published aggregate
//! statistics of the Google (2011/2019), Alibaba (2018) and Snowflake
//! traces the paper analyzes (Fig 1, Fig 2, §7.2 replay, Fig 13 supply).
//!
//! Per-machine memory usage = base level + diurnal sinusoid + AR(1) noise
//! + occasional bursts, with per-cluster parameters chosen so the
//! aggregate utilization curves match the paper's reported levels:
//! Google memory usage never exceeding ~50%, Alibaba keeping >=30% unused,
//! Snowflake averaging ~80% unused, CPU 50-85% idle, network 50-75% idle.

use crate::util::rng::Rng;

/// Which published trace's aggregate shape to synthesize.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MachineClass {
    Google,
    Alibaba,
    Snowflake,
}

impl MachineClass {
    /// (mean memory util, diurnal amplitude, noise std, burst prob/step)
    fn params(self) -> (f64, f64, f64, f64) {
        match self {
            MachineClass::Google => (0.40, 0.06, 0.03, 0.002),
            MachineClass::Alibaba => (0.55, 0.10, 0.04, 0.004),
            MachineClass::Snowflake => (0.19, 0.05, 0.04, 0.003),
        }
    }

    fn cpu_mean(self) -> f64 {
        match self {
            MachineClass::Google => 0.30,
            MachineClass::Alibaba => 0.38,
            MachineClass::Snowflake => 0.25,
        }
    }
}

/// One machine's usage series (fractions of capacity, one sample/step).
#[derive(Clone, Debug)]
pub struct MachineTrace {
    pub mem: Vec<f64>,
    pub cpu: Vec<f64>,
    pub net: Vec<f64>,
}

/// A generated cluster trace.
pub struct ClusterTrace {
    pub class: MachineClass,
    pub machines: Vec<MachineTrace>,
    /// Steps per simulated day (diurnal period).
    pub steps_per_day: usize,
}

impl ClusterTrace {
    /// Generate `n_machines` × `n_steps` samples (`steps_per_day` sets the
    /// diurnal period; 288 = 5-minute samples).
    pub fn generate(
        class: MachineClass,
        n_machines: usize,
        n_steps: usize,
        steps_per_day: usize,
        seed: u64,
    ) -> Self {
        let (mean, diurnal, noise_std, burst_prob) = class.params();
        let mut master = Rng::new(seed);
        let mut machines = Vec::with_capacity(n_machines);
        for m in 0..n_machines {
            let mut rng = master.fork(m as u64);
            // Heterogeneous machines: each gets its own base level/phase.
            let base = (mean + rng.normal(0.0, 0.08)).clamp(0.05, 0.9);
            let phase = rng.f64() * std::f64::consts::TAU;
            let amp = diurnal * rng.uniform(0.5, 1.5);
            let cpu_base = (class.cpu_mean() + rng.normal(0.0, 0.08)).clamp(0.03, 0.9);

            let mut mem = Vec::with_capacity(n_steps);
            let mut cpu = Vec::with_capacity(n_steps);
            let mut net = Vec::with_capacity(n_steps);
            let mut ar = 0.0f64;
            let mut burst_left = 0usize;
            let mut burst_height = 0.0;
            for t in 0..n_steps {
                let day_pos = (t % steps_per_day) as f64 / steps_per_day as f64;
                let season = amp * (std::f64::consts::TAU * day_pos + phase).sin();
                ar = 0.9 * ar + rng.normal(0.0, noise_std);
                if burst_left == 0 && rng.chance(burst_prob) {
                    burst_left = rng.range(3, 24) as usize;
                    burst_height = rng.uniform(0.05, 0.25);
                }
                let burst = if burst_left > 0 {
                    burst_left -= 1;
                    burst_height
                } else {
                    0.0
                };
                let u = (base + season + ar + burst).clamp(0.01, 0.99);
                mem.push(u);
                // CPU/net loosely correlated with memory activity.
                let c = (cpu_base + 0.5 * season + 0.6 * ar + burst).clamp(0.01, 0.99);
                cpu.push(c);
                net.push((0.35 * c + 0.5 * burst + rng.normal(0.1, 0.05)).clamp(0.0, 0.99));
            }
            machines.push(MachineTrace { mem, cpu, net });
        }
        ClusterTrace { class, machines, steps_per_day }
    }

    pub fn n_steps(&self) -> usize {
        self.machines.first().map_or(0, |m| m.mem.len())
    }

    /// Cluster-wide memory utilization at step `t` (fraction).
    pub fn cluster_mem_util(&self, t: usize) -> f64 {
        let s: f64 = self.machines.iter().map(|m| m.mem[t]).sum();
        s / self.machines.len() as f64
    }

    pub fn cluster_cpu_util(&self, t: usize) -> f64 {
        let s: f64 = self.machines.iter().map(|m| m.cpu[t]).sum();
        s / self.machines.len() as f64
    }

    pub fn cluster_net_util(&self, t: usize) -> f64 {
        let s: f64 = self.machines.iter().map(|m| m.net[t]).sum();
        s / self.machines.len() as f64
    }

    /// CDF points of a utilization series (for Fig 1): returns the series
    /// sorted ascending.
    pub fn utilization_cdf(series: impl Iterator<Item = f64>) -> Vec<f64> {
        let mut v: Vec<f64> = series.collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Fig 2a: durations (in steps) for which each machine's *unallocated*
    /// memory stays >= `frac` of capacity, collected over all machines.
    pub fn availability_durations(&self, frac: f64) -> Vec<usize> {
        let mut out = Vec::new();
        for m in &self.machines {
            let mut run = 0usize;
            for &u in &m.mem {
                if 1.0 - u >= frac {
                    run += 1;
                } else if run > 0 {
                    out.push(run);
                    run = 0;
                }
            }
            if run > 0 {
                out.push(run);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(class: MachineClass) -> ClusterTrace {
        ClusterTrace::generate(class, 200, 288 * 2, 288, 7)
    }

    #[test]
    fn google_memory_stays_under_55pct() {
        let t = trace(MachineClass::Google);
        // Paper: Google cluster memory usage never exceeds ~50% of capacity
        // (hour averages). Allow small slack for synthetic noise.
        let max_util = (0..t.n_steps())
            .map(|s| t.cluster_mem_util(s))
            .fold(0.0f64, f64::max);
        assert!(max_util < 0.55, "google util peaked at {max_util}");
    }

    #[test]
    fn alibaba_keeps_30pct_unused() {
        let t = trace(MachineClass::Alibaba);
        let max_util = (0..t.n_steps())
            .map(|s| t.cluster_mem_util(s))
            .fold(0.0f64, f64::max);
        assert!(max_util <= 0.70 + 0.03, "alibaba util peaked at {max_util}");
    }

    #[test]
    fn snowflake_80pct_unutilized_on_average() {
        let t = trace(MachineClass::Snowflake);
        let mean: f64 = (0..t.n_steps()).map(|s| t.cluster_mem_util(s)).sum::<f64>()
            / t.n_steps() as f64;
        assert!((mean - 0.20).abs() < 0.06, "snowflake mean util {mean}");
    }

    #[test]
    fn cpu_half_or_more_idle() {
        for class in [MachineClass::Google, MachineClass::Alibaba, MachineClass::Snowflake] {
            let t = trace(class);
            let mean: f64 = (0..t.n_steps()).map(|s| t.cluster_cpu_util(s)).sum::<f64>()
                / t.n_steps() as f64;
            assert!(mean < 0.5, "{class:?} cpu util {mean}");
        }
    }

    #[test]
    fn availability_durations_long() {
        let t = trace(MachineClass::Google);
        // Most unallocated capacity (>=10% of machine) persists >= 1h
        // (12 steps at 5-min samples) — paper Fig 2a: 99% available >= 1h.
        let durs = t.availability_durations(0.10);
        assert!(!durs.is_empty());
        let long = durs.iter().filter(|&&d| d >= 12).count();
        let frac_long: f64 = durs
            .iter()
            .filter(|&&d| d >= 12)
            .map(|&d| d as f64)
            .sum::<f64>()
            / durs.iter().map(|&d| d as f64).sum::<f64>();
        assert!(frac_long > 0.9, "long-availability mass {frac_long} ({long} runs)");
    }

    #[test]
    fn deterministic() {
        let a = ClusterTrace::generate(MachineClass::Google, 5, 100, 288, 3);
        let b = ClusterTrace::generate(MachineClass::Google, 5, 100, 288, 3);
        assert_eq!(a.machines[2].mem, b.machines[2].mem);
    }
}

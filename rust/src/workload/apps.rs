//! Producer application models — the six workloads of Table 1 (§7),
//! each modeled as a page-access process over a [`GuestMemory`] with a
//! per-op base service time. An app has a *hot* region (Zipfian accesses),
//! a *warm* region (uniform, infrequent), and an *idle* region (allocated
//! but touched with tiny probability) — matching the paper's observation
//! that a large fraction of allocated memory is idle and harvestable.
//!
//! The model produces the paper's qualitative shapes: harvesting
//! unallocated + idle memory is nearly free; harvesting into the warm
//! region costs a little; harvesting hot pages hits a performance cliff
//! (Fig 3), which Silo flattens (Fig 6).

use crate::core::{SimTime, GIB, MIB};
use crate::mem::{AccessOutcome, GuestMemory, SwapDevice};
use crate::util::rng::{Rng, Zipfian};
use crate::util::stats::LatencyRecorder;

/// The six producer applications from the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AppKind {
    Redis,
    Memcached,
    Mysql,
    Xgboost,
    Storm,
    CloudSuite,
}

impl AppKind {
    pub const ALL: [AppKind; 6] = [
        AppKind::Redis,
        AppKind::Memcached,
        AppKind::Mysql,
        AppKind::Xgboost,
        AppKind::Storm,
        AppKind::CloudSuite,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AppKind::Redis => "Redis",
            AppKind::Memcached => "memcached",
            AppKind::Mysql => "MySQL",
            AppKind::Xgboost => "XGBoost",
            AppKind::Storm => "Storm",
            AppKind::CloudSuite => "CloudSuite",
        }
    }
}

/// Statistical description of one producer application.
#[derive(Clone, Debug)]
pub struct AppModel {
    pub kind: AppKind,
    /// Rightsized VM DRAM (paper §7 "VM Rightsizing").
    pub vm_bytes: u64,
    /// Application allocated footprint.
    pub footprint_bytes: u64,
    /// Fraction of the footprint that is hot (Zipf-accessed).
    pub hot_fraction: f64,
    /// Fraction of the footprint that is warm (uniform, occasional).
    pub warm_fraction: f64,
    /// Probability an access lands in the warm region.
    pub warm_access_prob: f64,
    /// Probability an access lands in the idle region.
    pub idle_access_prob: f64,
    /// Zipf skew within the hot region.
    pub zipf_theta: f64,
    /// Operations per second.
    pub ops_per_sec: f64,
    /// Pages touched per operation.
    pub pages_per_op: u32,
    /// Base (fault-free) mean op latency, µs.
    pub base_latency_us: f64,
}

impl AppModel {
    /// Presets matched to the paper's rightsized VMs (§7 "VM Rightsizing")
    /// and Table 1 harvest/idle profiles.
    pub fn preset(kind: AppKind) -> AppModel {
        match kind {
            // M5n.Large 8 GB; Zipf 0.7 over a ~4.5 GB dataset; Table 1:
            // 3.8 GB harvested, 17.4% of app memory, 0.0% loss.
            AppKind::Redis => AppModel {
                kind,
                vm_bytes: 8 * GIB,
                footprint_bytes: 4 * GIB + 512 * MIB,
                hot_fraction: 0.35,
                warm_fraction: 0.35,
                warm_access_prob: 0.05,
                idle_access_prob: 0.0005,
                zipf_theta: 0.7,
                ops_per_sec: 20_000.0,
                pages_per_op: 1,
                base_latency_us: 80.0,
            },
            // M5n.2xLarge 32 GB; MemCachier-like skew: huge idle tail
            // (Table 1: 51.4% of harvest was idle memory).
            AppKind::Memcached => AppModel {
                kind,
                vm_bytes: 32 * GIB,
                footprint_bytes: 26 * GIB,
                hot_fraction: 0.12,
                warm_fraction: 0.25,
                warm_access_prob: 0.04,
                idle_access_prob: 0.0002,
                zipf_theta: 0.85,
                ops_per_sec: 30_000.0,
                pages_per_op: 1,
                base_latency_us: 820.0,
            },
            // C6g.2xLarge 16 GB; buffer-pool locality.
            AppKind::Mysql => AppModel {
                kind,
                vm_bytes: 16 * GIB,
                footprint_bytes: 12 * GIB,
                hot_fraction: 0.25,
                warm_fraction: 0.30,
                warm_access_prob: 0.08,
                idle_access_prob: 0.001,
                zipf_theta: 0.75,
                ops_per_sec: 5_000.0,
                pages_per_op: 4,
                base_latency_us: 1570.0,
            },
            // M5n.2xLarge 32 GB; training sweeps a working set but leaves
            // loaded data idle between epochs (18.3 GB harvested!).
            AppKind::Xgboost => AppModel {
                kind,
                vm_bytes: 32 * GIB,
                footprint_bytes: 24 * GIB,
                hot_fraction: 0.15,
                warm_fraction: 0.15,
                warm_access_prob: 0.10,
                idle_access_prob: 0.0001,
                zipf_theta: 0.55,
                ops_per_sec: 50.0,
                pages_per_op: 256,
                base_latency_us: 20_000.0,
            },
            // C6g.xLarge 8 GB; streaming: small working set, everything hot.
            AppKind::Storm => AppModel {
                kind,
                vm_bytes: 8 * GIB,
                footprint_bytes: 4 * GIB,
                hot_fraction: 0.70,
                warm_fraction: 0.25,
                warm_access_prob: 0.25,
                idle_access_prob: 0.01,
                zipf_theta: 0.60,
                ops_per_sec: 10_000.0,
                pages_per_op: 2,
                base_latency_us: 5330.0,
            },
            // C6g.Large 4 GB; web serving with memcached+MySQL behind it.
            AppKind::CloudSuite => AppModel {
                kind,
                vm_bytes: 4 * GIB,
                footprint_bytes: 3 * GIB,
                hot_fraction: 0.30,
                warm_fraction: 0.40,
                warm_access_prob: 0.12,
                idle_access_prob: 0.002,
                zipf_theta: 0.70,
                ops_per_sec: 8_000.0,
                pages_per_op: 2,
                base_latency_us: 900.0,
            },
        }
    }

    pub fn idle_fraction(&self) -> f64 {
        1.0 - self.hot_fraction - self.warm_fraction
    }
}

/// Couples an [`AppModel`] to a [`GuestMemory`] and generates timed page
/// accesses, producing per-epoch latency summaries — the producer-side
/// "application" whose performance the harvester monitors.
pub struct AppRunner {
    pub model: AppModel,
    pub memory: GuestMemory,
    zipf: Zipfian,
    rng: Rng,
    hot_pages: u32,
    warm_pages: u32,
    /// Max ops simulated per epoch; real op count is scaled statistically.
    pub ops_cap_per_epoch: u32,
    /// Burst mode: accesses become uniform over the whole footprint
    /// (the paper's Zipf -> uniform workload shift, Fig 8).
    uniform_burst: bool,
}

impl AppRunner {
    pub fn new(
        model: AppModel,
        page_bytes: u64,
        device: SwapDevice,
        silo_cooling: Option<SimTime>,
        seed: u64,
    ) -> Self {
        let memory = GuestMemory::new(
            model.vm_bytes,
            model.footprint_bytes,
            page_bytes,
            device,
            silo_cooling,
            seed,
        );
        let total_pages = memory.app_pages();
        let hot_pages = ((total_pages as f64) * model.hot_fraction).max(1.0) as u32;
        let warm_pages = ((total_pages as f64) * model.warm_fraction) as u32;
        let zipf = Zipfian::new(hot_pages as u64, model.zipf_theta.min(0.99));
        AppRunner {
            model,
            memory,
            zipf,
            rng: Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15),
            hot_pages,
            warm_pages,
            ops_cap_per_epoch: 2_000,
            uniform_burst: false,
        }
    }

    /// Shift the access pattern to uniform over the entire footprint
    /// (Fig 8's burst protocol). Call `end_burst` to revert.
    pub fn set_distribution_uniform(&mut self) {
        self.uniform_burst = true;
    }
    pub fn end_burst(&mut self) {
        self.uniform_burst = false;
    }

    fn next_page(&mut self) -> u32 {
        let total = self.memory.app_pages();
        if self.uniform_burst {
            return self.rng.below(total as u64) as u32;
        }
        let r = self.rng.f64();
        if r < self.model.idle_access_prob {
            // Idle region.
            let idle_start = self.hot_pages + self.warm_pages;
            if idle_start < total {
                return idle_start + self.rng.below((total - idle_start) as u64) as u32;
            }
        } else if r < self.model.idle_access_prob + self.model.warm_access_prob
            && self.warm_pages > 0
        {
            return self.hot_pages + self.rng.below(self.warm_pages as u64) as u32;
        }
        self.zipf.sample(&mut self.rng) as u32
    }

    /// Simulate one monitoring epoch of `duration` ending at `now`.
    /// Returns (mean latency µs, ops simulated, recorder).
    pub fn run_epoch(&mut self, now: SimTime, duration: SimTime) -> LatencyRecorder {
        let ops_real = (self.model.ops_per_sec * duration.as_secs_f64()).max(1.0);
        let ops_sim = (ops_real as u32).min(self.ops_cap_per_epoch).max(1);
        let mut rec = LatencyRecorder::new();
        for _ in 0..ops_sim {
            let mut latency = self.model.base_latency_us;
            for _ in 0..self.model.pages_per_op {
                let page = self.next_page();
                let outcome = self.memory.access(page, now);
                latency += match outcome {
                    AccessOutcome::Hit => 0.0,
                    AccessOutcome::SiloHit => 5.0,
                    AccessOutcome::DiskFault => {
                        self.memory.device().read_latency().as_micros() as f64
                    }
                };
            }
            rec.record(latency);
        }
        // Advance Silo cooling.
        self.memory.tick(now);
        rec
    }

    /// Fault-free reference latency for this model.
    pub fn baseline_latency_us(&self) -> f64 {
        self.model.base_latency_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: u64 = 256 * 1024; // coarse pages for fast tests

    fn runner(kind: AppKind) -> AppRunner {
        AppRunner::new(
            AppModel::preset(kind),
            PAGE,
            SwapDevice::Ssd,
            Some(SimTime::from_secs(60)),
            42,
        )
    }

    #[test]
    fn presets_sane() {
        for kind in AppKind::ALL {
            let m = AppModel::preset(kind);
            assert!(m.footprint_bytes <= m.vm_bytes, "{kind:?}");
            assert!(m.hot_fraction + m.warm_fraction < 1.0, "{kind:?}");
            assert!(m.idle_fraction() > 0.0, "{kind:?}");
            assert!(m.ops_per_sec > 0.0 && m.base_latency_us > 0.0);
        }
    }

    #[test]
    fn unharvested_run_has_baseline_latency() {
        let mut r = runner(AppKind::Redis);
        let rec = r.run_epoch(SimTime::from_secs(1), SimTime::from_secs(1));
        assert!(rec.count() > 0);
        // Fully resident: no faults, mean == base latency.
        assert!((rec.mean() - r.baseline_latency_us()).abs() < 1e-9);
    }

    #[test]
    fn harvesting_idle_memory_is_cheap_hot_memory_is_not() {
        // Harvest to just above the hot+warm set: minimal impact.
        let mut gentle = runner(AppKind::Redis);
        let keep = (gentle.model.footprint_bytes as f64 * 0.8) as u64;
        gentle.memory.set_cgroup_limit(keep, SimTime::ZERO);
        let mut gentle_lat = 0.0;
        for ep in 1..=20 {
            let rec = gentle.run_epoch(SimTime::from_secs(ep * 120), SimTime::from_secs(5));
            gentle_lat = rec.mean();
        }

        // Harvest deep into the hot set: latency blows up.
        let mut harsh = runner(AppKind::Redis);
        let keep = (harsh.model.footprint_bytes as f64 * 0.10) as u64;
        harsh.memory.set_cgroup_limit(keep, SimTime::ZERO);
        let mut harsh_lat = 0.0;
        for ep in 1..=20 {
            let rec = harsh.run_epoch(SimTime::from_secs(ep * 120), SimTime::from_secs(5));
            harsh_lat = rec.mean();
        }
        let base = AppModel::preset(AppKind::Redis).base_latency_us;
        assert!(
            gentle_lat < base * 1.25,
            "gentle harvest too costly: {gentle_lat:.1}µs vs base {base:.1}µs"
        );
        assert!(
            harsh_lat > gentle_lat * 1.2,
            "cliff missing: gentle {gentle_lat:.1}µs harsh {harsh_lat:.1}µs"
        );
    }

    #[test]
    fn access_pattern_regions() {
        let mut r = runner(AppKind::Memcached);
        let hot = r.hot_pages;
        let warm = r.warm_pages;
        let mut hot_n = 0u64;
        let mut idle_n = 0u64;
        let n = 100_000;
        for _ in 0..n {
            let p = r.next_page();
            if p < hot {
                hot_n += 1;
            } else if p >= hot + warm {
                idle_n += 1;
            }
        }
        assert!(hot_n as f64 / n as f64 > 0.9);
        assert!((idle_n as f64 / n as f64) < 0.001);
    }
}

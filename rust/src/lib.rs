//! # Memtrade — a disaggregated-memory marketplace for public clouds
//!
//! Production-quality reproduction of *Memtrade* (Maruf et al., 2021) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the complete Memtrade system: producers
//!   ([`producer`]: harvester + Silo + manager), the market [`broker`]
//!   (registry, placement, pricing, availability prediction), secure
//!   [`consumer`] clients, the networked [`market`] control plane that
//!   deploys all three as broker daemon / producer agent / lease-aware
//!   consumer pool, and every substrate they need, built from scratch:
//!   a Redis-like KV store ([`kv`]), a guest-VM memory model with
//!   cgroup/PFRA/swap semantics ([`mem`]), AES-128-CBC + SHA-256
//!   ([`crypto`]), data- and control-plane wire protocols with simulated
//!   and TCP transports ([`net`]), end-to-end request tracing with a
//!   crash-dump flight recorder ([`trace`]), workload/trace generators
//!   ([`workload`]), and a discrete-event cluster simulator ([`sim`]).
//! * **Layer 2/1 (build-time python)** — the broker's numeric hot paths
//!   (batched ARIMA-family availability forecasting; MRC-driven market
//!   demand evaluation) authored in JAX + Pallas, AOT-lowered to HLO text
//!   and executed from [`runtime`] via the PJRT CPU client. Python never
//!   runs on the request path.
//!
//! See `DESIGN.md` (repo root) for the paper → module inventory, the
//! deliberate substitutions, and the experiment index.

pub mod analysis;
pub mod broker;
pub mod consumer;
pub mod core;
pub mod crypto;
pub mod figures;
pub mod kv;
pub mod market;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod producer;
pub mod runtime;
pub mod sim;
pub mod trace;
pub mod util;
pub mod workload;

pub use crate::core::{ConsumerId, Lease, LeaseId, MachineId, ProducerId, SlabId};

//! Core domain types shared across the whole system: identifiers, memory
//! slabs, leases, money, simulated time, and the global configuration.

pub mod config;

pub use config::MemtradeConfig;

use std::fmt;

/// Bytes in one mebibyte / gibibyte.
pub const MIB: u64 = 1 << 20;
pub const GIB: u64 = 1 << 30;

/// Default slab size: the granularity at which producer memory is leased
/// (paper §4.2; 64 MB default).
pub const DEFAULT_SLAB_BYTES: u64 = 64 * MIB;

/// Default harvesting chunk (paper §4: ChunkSize = 64 MB).
pub const DEFAULT_CHUNK_BYTES: u64 = 64 * MIB;

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default)]
        pub struct $name(pub u64);

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, concat!(stringify!($name), "#{}"), self.0)
            }
        }

        impl From<u64> for $name {
            fn from(v: u64) -> Self {
                Self(v)
            }
        }
    };
}

id_type!(/** A producer VM participating in the market. */ ProducerId);
id_type!(/** A consumer VM participating in the market. */ ConsumerId);
id_type!(/** A physical machine in the simulated cluster. */ MachineId);
id_type!(/** One leased 64 MB memory slab. */ SlabId);
id_type!(/** A brokered lease (consumer <-> one or more producers). */ LeaseId);

/// Simulated time in microseconds since simulation start.
///
/// All latency/throughput models and the harvester/broker control loops run
/// on this clock inside the discrete-event simulator; the real (tokio)
/// deployment path uses wall-clock time converted into the same unit.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_micros(us: u64) -> Self {
        SimTime(us)
    }
    pub fn from_millis(ms: u64) -> Self {
        SimTime(ms * 1_000)
    }
    pub fn from_secs(s: u64) -> Self {
        SimTime(s * 1_000_000)
    }
    pub fn from_secs_f64(s: f64) -> Self {
        SimTime((s * 1e6) as u64)
    }
    pub fn from_mins(m: u64) -> Self {
        Self::from_secs(m * 60)
    }
    pub fn from_hours(h: u64) -> Self {
        Self::from_secs(h * 3600)
    }

    pub fn as_micros(self) -> u64 {
        self.0
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn as_hours_f64(self) -> f64 {
        self.as_secs_f64() / 3600.0
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

/// Money in nano-dollars: slab-hour prices are fractions of a cent, and
/// the paper's price step is 0.002 ¢/GB·h ≈ 1.25 µ$/slab·h, so nano-dollar
/// integer arithmetic keeps the market exact.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Default)]
pub struct Money(pub i64);

impl Money {
    pub const ZERO: Money = Money(0);

    pub fn from_dollars(d: f64) -> Self {
        Money((d * 1e9).round() as i64)
    }
    pub fn from_cents(c: f64) -> Self {
        Self::from_dollars(c / 100.0)
    }
    pub fn as_dollars(self) -> f64 {
        self.0 as f64 / 1e9
    }
    pub fn as_cents(self) -> f64 {
        self.as_dollars() * 100.0
    }

    pub fn scale(self, f: f64) -> Money {
        Money((self.0 as f64 * f).round() as i64)
    }
}

impl std::ops::Add for Money {
    type Output = Money;
    fn add(self, rhs: Money) -> Money {
        Money(self.0 + rhs.0)
    }
}
impl std::ops::AddAssign for Money {
    fn add_assign(&mut self, rhs: Money) {
        self.0 += rhs.0;
    }
}
impl std::ops::Sub for Money {
    type Output = Money;
    fn sub(self, rhs: Money) -> Money {
        Money(self.0 - rhs.0)
    }
}

impl fmt::Display for Money {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "${:.6}", self.as_dollars())
    }
}

/// One leasable slab of producer memory.
#[derive(Clone, Debug)]
pub struct Slab {
    pub id: SlabId,
    pub producer: ProducerId,
    pub bytes: u64,
}

/// A lease matching one consumer to slabs on one producer (a consumer
/// request may be satisfied by several leases on different producers).
#[derive(Clone, Debug)]
pub struct Lease {
    pub id: LeaseId,
    pub consumer: ConsumerId,
    pub producer: ProducerId,
    pub slabs: u32,
    pub slab_bytes: u64,
    pub start: SimTime,
    pub duration: SimTime,
    /// Price agreed at lease time, per slab-hour.
    pub price_per_slab_hour: Money,
}

impl Lease {
    pub fn bytes(&self) -> u64 {
        self.slabs as u64 * self.slab_bytes
    }
    pub fn end(&self) -> SimTime {
        self.start + self.duration
    }
    pub fn total_cost(&self) -> Money {
        let hours = self.duration.as_hours_f64();
        self.price_per_slab_hour.scale(self.slabs as f64 * hours)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simtime_units() {
        assert_eq!(SimTime::from_secs(1).as_micros(), 1_000_000);
        assert_eq!(SimTime::from_mins(2), SimTime::from_secs(120));
        assert_eq!(SimTime::from_hours(1).as_secs_f64(), 3600.0);
        assert!((SimTime::from_millis(1500).as_secs_f64() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn simtime_arith() {
        let a = SimTime::from_secs(5);
        let b = SimTime::from_secs(3);
        assert_eq!((a + b).as_micros(), 8_000_000);
        assert_eq!((a - b).as_micros(), 2_000_000);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
    }

    #[test]
    fn money_round_trips() {
        let m = Money::from_dollars(1.25);
        assert!((m.as_dollars() - 1.25).abs() < 1e-9);
        assert_eq!(Money::from_cents(25.0), Money::from_dollars(0.25));
        assert_eq!((m + m - m), m);
        assert_eq!(m.scale(2.0), Money::from_dollars(2.5));
    }

    #[test]
    fn lease_cost() {
        let l = Lease {
            id: LeaseId(1),
            consumer: ConsumerId(1),
            producer: ProducerId(1),
            slabs: 16, // 1 GB of 64 MB slabs
            slab_bytes: DEFAULT_SLAB_BYTES,
            start: SimTime::ZERO,
            duration: SimTime::from_hours(2),
            price_per_slab_hour: Money::from_dollars(0.001),
        };
        assert_eq!(l.bytes(), GIB);
        assert_eq!(l.end(), SimTime::from_hours(2));
        assert!((l.total_cost().as_dollars() - 0.032).abs() < 1e-9);
    }

    #[test]
    fn ids_display() {
        assert_eq!(ProducerId(7).to_string(), "ProducerId#7");
        assert_eq!(SlabId::from(3u64), SlabId(3));
    }
}

//! Global configuration, mirroring every tunable the paper names.
//!
//! Defaults follow the paper's experimental setup (§7): 64 MB ChunkSize,
//! 5-minute CoolingPeriod, 1% P99Threshold, 6-hour WindowSize, 64 MB slabs,
//! 3-epoch severe-drop prefetch trigger, quarter-of-spot initial price and
//! 0.002 cent/GB·h price step.

use crate::core::{SimTime, DEFAULT_CHUNK_BYTES, DEFAULT_SLAB_BYTES};

/// Harvester tunables (paper §4.1, Algorithm 1).
#[derive(Clone, Debug)]
pub struct HarvesterConfig {
    /// Increment by which the cgroup limit is lowered per harvest step.
    pub chunk_bytes: u64,
    /// Silo residency before a cold page is evicted to disk; also the
    /// minimum wait between harvest steps once pages land in Silo.
    pub cooling_period: SimTime,
    /// Relative p99 degradation (recent vs baseline) treated as a drop.
    pub p99_threshold: f64,
    /// Expiry horizon for baseline/recent performance samples.
    pub window_size: SimTime,
    /// Performance-monitoring epoch length.
    pub epoch: SimTime,
    /// Consecutive severe epochs before Silo prefetches from disk.
    pub severe_epochs: u32,
    /// How long recovery mode lasts before harvesting may resume.
    pub recovery_period: SimTime,
    /// One performance sample is recorded each interval.
    pub sample_interval: SimTime,
}

impl Default for HarvesterConfig {
    fn default() -> Self {
        HarvesterConfig {
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            cooling_period: SimTime::from_mins(5),
            p99_threshold: 0.01,
            window_size: SimTime::from_hours(6),
            epoch: SimTime::from_secs(5),
            severe_epochs: 3,
            recovery_period: SimTime::from_mins(2),
            sample_interval: SimTime::from_secs(1),
        }
    }
}

/// Broker tunables (paper §5).
#[derive(Clone, Debug)]
pub struct BrokerConfig {
    pub slab_bytes: u64,
    /// Minimum lease duration accepted (paper §7.2 uses 10 minutes).
    pub min_lease: SimTime,
    /// Pending-request queue timeout.
    pub pending_timeout: SimTime,
    /// Placement-cost weights (paper §5.2); consumer requests may override.
    pub weights: PlacementWeights,
    /// Initial price = spot price fraction (paper §5.3: one quarter).
    pub initial_price_fraction: f64,
    /// Local-search price step, $/GB·hour (paper: 0.002 cents/GB·h).
    pub price_step_dollars: f64,
    /// Broker commission fraction of each transaction.
    pub commission: f64,
    /// Market/pricing epoch.
    pub market_epoch: SimTime,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        BrokerConfig {
            slab_bytes: DEFAULT_SLAB_BYTES,
            min_lease: SimTime::from_mins(10),
            pending_timeout: SimTime::from_mins(30),
            weights: PlacementWeights::default(),
            initial_price_fraction: 0.25,
            price_step_dollars: 0.00002, // 0.002 cents
            commission: 0.05,
            market_epoch: SimTime::from_mins(5),
        }
    }
}

/// Weighted placement-cost metrics (paper §5.2). Lower cost wins; each
/// component is normalized to [0, 1] before weighting.
#[derive(Clone, Copy, Debug)]
pub struct PlacementWeights {
    pub free_slabs: f64,
    pub predicted_availability: f64,
    pub bandwidth: f64,
    pub cpu: f64,
    pub latency: f64,
    pub reputation: f64,
}

impl Default for PlacementWeights {
    fn default() -> Self {
        PlacementWeights {
            free_slabs: 1.0,
            predicted_availability: 2.0,
            bandwidth: 0.5,
            cpu: 0.5,
            latency: 1.0,
            reputation: 1.5,
        }
    }
}

/// Consumer-side tunables (paper §6).
#[derive(Clone, Debug)]
pub struct ConsumerConfig {
    /// Encrypt values (AES-128-CBC) and substitute keys.
    pub encrypt: bool,
    /// Verify SHA-256 (truncated to 128-bit) integrity hashes.
    pub integrity: bool,
    /// Requested network bandwidth per lease, bytes/sec.
    pub bandwidth_bps: u64,
}

impl Default for ConsumerConfig {
    fn default() -> Self {
        ConsumerConfig {
            encrypt: true,
            integrity: true,
            bandwidth_bps: 125_000_000, // 1 Gb/s share of a 10 Gb NIC
        }
    }
}

/// Top-level configuration bundle.
#[derive(Clone, Debug, Default)]
pub struct MemtradeConfig {
    pub harvester: HarvesterConfig,
    pub broker: BrokerConfig,
    pub consumer: ConsumerConfig,
}

impl MemtradeConfig {
    /// Parse simple `key=value` overrides (e.g. from the CLI):
    /// `harvester.chunk_mb=128 broker.commission=0.1`.
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), String> {
        let parse_f64 =
            |v: &str| v.parse::<f64>().map_err(|e| format!("bad float {v:?}: {e}"));
        let parse_u64 =
            |v: &str| v.parse::<u64>().map_err(|e| format!("bad int {v:?}: {e}"));
        match key {
            "harvester.chunk_mb" => self.harvester.chunk_bytes = parse_u64(value)? << 20,
            "harvester.cooling_secs" => {
                self.harvester.cooling_period = SimTime::from_secs(parse_u64(value)?)
            }
            "harvester.p99_threshold" => self.harvester.p99_threshold = parse_f64(value)?,
            "harvester.window_hours" => {
                self.harvester.window_size = SimTime::from_hours(parse_u64(value)?)
            }
            "broker.slab_mb" => self.broker.slab_bytes = parse_u64(value)? << 20,
            "broker.commission" => self.broker.commission = parse_f64(value)?,
            "broker.price_step" => self.broker.price_step_dollars = parse_f64(value)?,
            "broker.initial_price_fraction" => {
                self.broker.initial_price_fraction = parse_f64(value)?
            }
            "consumer.encrypt" => self.consumer.encrypt = value == "true",
            "consumer.integrity" => self.consumer.integrity = value == "true",
            _ => return Err(format!("unknown config key {key:?}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = MemtradeConfig::default();
        assert_eq!(c.harvester.chunk_bytes, 64 << 20);
        assert_eq!(c.harvester.cooling_period, SimTime::from_mins(5));
        assert!((c.harvester.p99_threshold - 0.01).abs() < 1e-12);
        assert_eq!(c.harvester.window_size, SimTime::from_hours(6));
        assert_eq!(c.broker.slab_bytes, 64 << 20);
        assert!((c.broker.initial_price_fraction - 0.25).abs() < 1e-12);
        assert!((c.broker.price_step_dollars - 0.00002).abs() < 1e-12);
        assert_eq!(c.harvester.severe_epochs, 3);
    }

    #[test]
    fn overrides() {
        let mut c = MemtradeConfig::default();
        c.apply_override("harvester.chunk_mb", "128").unwrap();
        assert_eq!(c.harvester.chunk_bytes, 128 << 20);
        c.apply_override("broker.commission", "0.1").unwrap();
        assert!((c.broker.commission - 0.1).abs() < 1e-12);
        c.apply_override("consumer.encrypt", "false").unwrap();
        assert!(!c.consumer.encrypt);
        assert!(c.apply_override("nope", "1").is_err());
        assert!(c.apply_override("broker.commission", "x").is_err());
    }
}

//! Placement (paper §5.2): cost-ranked greedy assignment of consumer
//! slab requests onto producers, under uncertainty about availability.
//!
//! The placement cost of a producer is the weighted sum of normalized
//! metrics: free slabs, predicted availability, bandwidth and CPU
//! headroom, consumer-producer latency, and reputation. Consumers may
//! override the weights per request.

use crate::core::config::PlacementWeights;
use crate::core::{ConsumerId, Money, ProducerId, SimTime};
use std::collections::HashMap;

/// A consumer's allocation request (§5.2 constraints: online arrival,
/// partial allocation above `min_slabs` allowed).
#[derive(Clone, Debug)]
pub struct ConsumerRequest {
    pub consumer: ConsumerId,
    /// Desired slabs.
    pub slabs: u32,
    /// Minimum acceptable allocation (partial-allocation floor).
    pub min_slabs: u32,
    pub lease: SimTime,
    /// Budget cap; None = accept the market price.
    pub max_price_per_slab_hour: Option<Money>,
    /// Measured latency to each producer (µs); missing = default 200.
    pub latency_us_to: HashMap<ProducerId, u64>,
    /// Optional per-request weight override (§5.2).
    pub weights: Option<PlacementWeights>,
}

/// Placement-relevant snapshot of one producer.
#[derive(Clone, Debug)]
pub struct ProducerState {
    pub producer: ProducerId,
    pub free_slabs: u32,
    pub predicted_safe_slabs: u32,
    pub cpu_headroom: f64,
    pub bandwidth_headroom: f64,
    pub latency_us: u64,
    pub reputation: f64,
}

impl ProducerState {
    /// Slabs the broker will actually grant here: advertised free,
    /// but never beyond what the forecast says is safe.
    pub fn grantable_slabs(&self) -> u32 {
        self.free_slabs.min(self.predicted_safe_slabs)
    }
}

/// Outcome summary used by experiment harnesses.
#[derive(Clone, Debug, Default)]
pub struct PlacementOutcome {
    pub granted: u32,
    pub producers_used: u32,
}

/// Normalization cap for the latency cost component (µs).
const LATENCY_NORM_US: f64 = 5_000.0;

/// Placement cost: lower is better (§5.2).
pub fn cost(state: &ProducerState, weights: &PlacementWeights, max_free: u32) -> f64 {
    let free_term = if max_free == 0 {
        1.0
    } else {
        1.0 - state.free_slabs as f64 / max_free as f64
    };
    let avail_term = if state.free_slabs == 0 {
        1.0
    } else {
        1.0 - (state.predicted_safe_slabs.min(state.free_slabs) as f64
            / state.free_slabs as f64)
    };
    let bw_term = 1.0 - state.bandwidth_headroom.clamp(0.0, 1.0);
    let cpu_term = 1.0 - state.cpu_headroom.clamp(0.0, 1.0);
    let lat_term = (state.latency_us as f64 / LATENCY_NORM_US).min(1.0);
    let rep_term = 1.0 - state.reputation.clamp(0.0, 1.0);

    weights.free_slabs * free_term
        + weights.predicted_availability * avail_term
        + weights.bandwidth * bw_term
        + weights.cpu * cpu_term
        + weights.latency * lat_term
        + weights.reputation * rep_term
}

/// Rank producers by ascending cost for this request; producers with
/// nothing grantable are dropped.
pub fn rank(
    states: &[ProducerState],
    request: &ConsumerRequest,
    default_weights: &PlacementWeights,
) -> Vec<ProducerState> {
    let weights = request.weights.as_ref().unwrap_or(default_weights);
    let max_free = states.iter().map(|s| s.free_slabs).max().unwrap_or(0);
    let mut scored: Vec<(f64, &ProducerState)> = states
        .iter()
        .filter(|s| s.grantable_slabs() > 0)
        .map(|s| (cost(s, weights, max_free), s))
        .collect();
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    scored.into_iter().map(|(_, s)| s.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(id: u64, free: u32, safe: u32) -> ProducerState {
        ProducerState {
            producer: ProducerId(id),
            free_slabs: free,
            predicted_safe_slabs: safe,
            cpu_headroom: 0.8,
            bandwidth_headroom: 0.8,
            latency_us: 200,
            reputation: 1.0,
        }
    }

    fn request() -> ConsumerRequest {
        ConsumerRequest {
            consumer: ConsumerId(1),
            slabs: 16,
            min_slabs: 1,
            lease: SimTime::from_hours(1),
            max_price_per_slab_hour: None,
            latency_us_to: HashMap::new(),
            weights: None,
        }
    }

    #[test]
    fn grantable_capped_by_forecast() {
        assert_eq!(state(1, 100, 40).grantable_slabs(), 40);
        assert_eq!(state(1, 10, 40).grantable_slabs(), 10);
        assert_eq!(state(1, 0, 40).grantable_slabs(), 0);
    }

    #[test]
    fn rank_prefers_more_free_and_better_reputation() {
        let w = PlacementWeights::default();
        let mut bad_rep = state(2, 64, 64);
        bad_rep.reputation = 0.5;
        let ranked = rank(&[bad_rep, state(1, 64, 64)], &request(), &w);
        assert_eq!(ranked[0].producer, ProducerId(1));

        let ranked = rank(&[state(1, 8, 8), state(2, 64, 64)], &request(), &w);
        assert_eq!(ranked[0].producer, ProducerId(2));
    }

    #[test]
    fn rank_prefers_predicted_availability() {
        let w = PlacementWeights::default();
        // Producer 1 advertises 64 free but forecast only trusts 8.
        let ranked = rank(&[state(1, 64, 8), state(2, 64, 64)], &request(), &w);
        assert_eq!(ranked[0].producer, ProducerId(2));
    }

    #[test]
    fn rank_penalizes_latency() {
        let w = PlacementWeights::default();
        let mut far = state(2, 64, 64);
        far.latency_us = 4_000;
        let ranked = rank(&[far, state(1, 64, 64)], &request(), &w);
        assert_eq!(ranked[0].producer, ProducerId(1));
    }

    #[test]
    fn zero_grantable_dropped() {
        let w = PlacementWeights::default();
        let ranked = rank(&[state(1, 0, 64), state(2, 64, 0)], &request(), &w);
        assert!(ranked.is_empty());
    }

    #[test]
    fn weight_override_respected() {
        let mut req = request();
        // Only latency matters to this consumer.
        req.weights = Some(PlacementWeights {
            free_slabs: 0.0,
            predicted_availability: 0.0,
            bandwidth: 0.0,
            cpu: 0.0,
            latency: 1.0,
            reputation: 0.0,
        });
        let mut near_but_small = state(1, 2, 2);
        near_but_small.latency_us = 10;
        let mut far_but_big = state(2, 64, 64);
        far_but_big.latency_us = 3_000;
        let ranked = rank(&[far_but_big, near_but_small], &req, &PlacementWeights::default());
        assert_eq!(ranked[0].producer, ProducerId(1));
    }
}

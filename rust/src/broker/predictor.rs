//! Availability predictor (paper §5.1): per market epoch, batch every
//! producer's usage history through the AOT forecast artifact (ARIMA-
//! family (d,p) selection + safety margin, compiled from JAX/Pallas) and
//! cache the resulting safe-slab counts in the registry. Falls back to
//! the pure-Rust mirror when artifacts are unavailable.

use crate::broker::registry::Registry;
use crate::core::{SimTime, GIB};
use crate::runtime::arima_fallback;
use crate::runtime::engine::{
    Engine, ForecastEngine, ForecastResult, FORECAST_HORIZON, FORECAST_WINDOW,
};

enum Backend {
    Pjrt(ForecastEngine),
    Fallback,
}

/// Batched availability predictor.
pub struct AvailabilityPredictor {
    backend: Backend,
    window: usize,
    horizon: usize,
    /// Slab size for GB -> slab conversion (bound at refresh()).
    pub slab_bytes: u64,
    /// Number of refreshes run (diagnostics).
    pub refreshes: u64,
}

impl AvailabilityPredictor {
    /// Use the compiled PJRT artifact.
    pub fn from_engine(engine: ForecastEngine) -> Self {
        AvailabilityPredictor {
            backend: Backend::Pjrt(engine),
            window: FORECAST_WINDOW,
            horizon: FORECAST_HORIZON,
            slab_bytes: crate::core::DEFAULT_SLAB_BYTES,
            refreshes: 0,
        }
    }

    /// Load from the default artifacts dir, falling back when absent.
    pub fn auto() -> Self {
        let dir = Engine::default_dir();
        if Engine::artifacts_present(&dir) {
            if let Ok(engine) = Engine::load(&dir) {
                return Self::from_engine(engine.forecast);
            }
        }
        Self::fallback(FORECAST_WINDOW, FORECAST_HORIZON)
    }

    /// Pure-Rust mirror (tests, artifact-less runs).
    pub fn fallback(window: usize, horizon: usize) -> Self {
        AvailabilityPredictor {
            backend: Backend::Fallback,
            window,
            horizon,
            slab_bytes: crate::core::DEFAULT_SLAB_BYTES,
            refreshes: 0,
        }
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self.backend, Backend::Pjrt(_))
    }

    fn predict(&self, series: &[Vec<f32>], caps: &[f32]) -> Vec<ForecastResult> {
        match &self.backend {
            Backend::Pjrt(engine) => engine
                .predict(series, caps)
                .expect("PJRT forecast execution failed"),
            Backend::Fallback => {
                arima_fallback::forecast_batch(series, caps, 4, self.horizon, self.window)
            }
        }
    }

    /// Refresh every producer's `predicted_safe_slabs` and
    /// `predicted_next_usage` (§7.2 accuracy scoring input).
    pub fn refresh(&mut self, registry: &mut Registry, _now: SimTime) {
        let mut ids = Vec::new();
        let mut series = Vec::new();
        let mut caps = Vec::new();
        for p in registry.producers() {
            if p.usage.is_empty() {
                continue;
            }
            ids.push(p.id);
            series.push(p.usage.to_vec());
            caps.push(p.capacity_gb);
        }
        if ids.is_empty() {
            return;
        }
        let results = self.predict(&series, &caps);
        let slab_gb = self.slab_bytes as f32 / GIB as f32;
        let by_id: std::collections::HashMap<_, _> = ids.iter().zip(results).collect();
        for p in registry.producers_mut() {
            if let Some(r) = by_id.get(&p.id) {
                // Safe slabs = the *minimum* safe GB across the horizon —
                // memory must stay available for the whole lease.
                let min_safe = r.safe.iter().cloned().fold(f32::INFINITY, f32::min);
                p.predicted_safe_slabs = (min_safe.max(0.0) / slab_gb) as u32;
                p.predicted_next_usage = Some(r.pred[0]);
            }
        }
        self.refreshes += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ProducerId;

    #[test]
    fn refresh_populates_safe_slabs() {
        let mut reg = Registry::default();
        reg.register_producer(ProducerId(1), 32.0);
        // Steady 8 GB usage -> ~24 GB safe -> ~384 slabs of 64 MB.
        for t in 0..288 {
            reg.report_usage(ProducerId(1), SimTime::from_secs(t * 300), 8.0);
        }
        let mut pred = AvailabilityPredictor::fallback(288, 12);
        pred.refresh(&mut reg, SimTime::from_hours(24));
        let p = reg.producer(ProducerId(1)).unwrap();
        let safe = p.predicted_safe_slabs;
        assert!((350..=400).contains(&safe), "safe slabs {safe}");
        assert!(p.predicted_next_usage.unwrap() > 7.0);
        assert_eq!(pred.refreshes, 1);
    }

    #[test]
    fn rising_usage_shrinks_safe() {
        let mut reg = Registry::default();
        reg.register_producer(ProducerId(1), 32.0);
        reg.register_producer(ProducerId(2), 32.0);
        for t in 0..288 {
            reg.report_usage(ProducerId(1), SimTime::from_secs(t * 300), 8.0);
            // Producer 2 ramping up hard.
            reg.report_usage(
                ProducerId(2),
                SimTime::from_secs(t * 300),
                8.0 + 0.08 * t as f32,
            );
        }
        let mut pred = AvailabilityPredictor::fallback(288, 12);
        pred.refresh(&mut reg, SimTime::from_hours(24));
        let steady = reg.producer(ProducerId(1)).unwrap().predicted_safe_slabs;
        let rising = reg.producer(ProducerId(2)).unwrap().predicted_safe_slabs;
        assert!(rising < steady, "rising {rising} !< steady {steady}");
    }

    #[test]
    fn empty_history_skipped() {
        let mut reg = Registry::default();
        reg.register_producer(ProducerId(1), 32.0);
        let mut pred = AvailabilityPredictor::fallback(288, 12);
        pred.refresh(&mut reg, SimTime::ZERO);
        assert_eq!(reg.producer(ProducerId(1)).unwrap().predicted_safe_slabs, 0);
    }
}
